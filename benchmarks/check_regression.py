"""Bench regression gate: fail CI when serving benchmarks get worse.

The bench-smoke job used to only *upload* the fresh ``results/*.csv`` —
a PR could silently tank goodput or p99 and still go green.  This script
turns the tables into a gate:

1. **Baseline drift.**  The freshly produced ``results/table_paged.csv``
   and ``results/table_chunked.csv`` are compared against the *committed*
   copies (read via ``git show HEAD:<path>``, or ``--baseline-dir``):
   goodput must not drop and p99 must not rise beyond ``--tol-pct``.  The
   serving clock is the deterministic analytic roofline, so a genuine
   improvement should be committed as an updated CSV, not waved through.
   ``results/table_paged_attn.csv`` gates the decode hot path the same
   way: per-(impl, context, lanes) attention/step microseconds must not
   rise beyond tolerance.
2. **Structural orderings.**  Invariants the tables exist to prove are
   re-checked from the fresh CSVs, so the job fails even if a benchmark's
   own asserts are edited away: paged beats wave (p99 down, goodput up);
   chunked prefill beats stall-prefill on trading p99 with no less total
   goodput, at equal token counts; the fused paged-attention path strictly
   dominates gather+SDPA on modeled attention time, step time, and HBM
   bytes at every measured (context, lanes) point.

Usage:  python benchmarks/check_regression.py [--results DIR]
            [--baseline-dir DIR] [--tol-pct 5]
Exit status 0 = pass, 1 = regression (messages on stderr).
"""
from __future__ import annotations

import argparse
import csv
import io
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
TABLES = ("table_paged.csv", "table_chunked.csv")
#: the decode hot-path microbench: gated on time/bytes, not goodput/p99
ATTN_TABLE = "table_paged_attn.csv"


def read_rows(text: str):
    rows = list(csv.DictReader(io.StringIO(text)))
    if not rows:
        raise SystemExit("empty CSV")
    return rows


def load_fresh(results_dir: str, name: str):
    path = os.path.join(results_dir, name)
    with open(path) as f:
        return read_rows(f.read())


def load_baseline(name: str, baseline_dir: str | None):
    if baseline_dir is not None:
        with open(os.path.join(baseline_dir, name)) as f:
            return read_rows(f.read())
    out = subprocess.run(
        ["git", "show", f"HEAD:results/{name}"], cwd=REPO,
        capture_output=True, text=True)
    if out.returncode != 0:
        raise SystemExit(f"cannot read committed baseline for {name}: "
                         f"{out.stderr.strip()}")
    return read_rows(out.stdout)


def key_of(row):
    # table_paged rows key on "path"; table_chunked on ("path", "class")
    return (row["path"], row.get("class", ""))


def check_drift(name: str, fresh, base, tol_pct: float, errors):
    """Goodput must not drop, p99 must not rise, beyond tol_pct percent."""
    fresh_by, base_by = ({key_of(r): r for r in rows}
                         for rows in (fresh, base))
    if set(fresh_by) != set(base_by):
        errors.append(f"{name}: row set changed "
                      f"{sorted(base_by)} -> {sorted(fresh_by)}; "
                      "commit the regenerated CSV if intentional")
        return
    tol = tol_pct / 100.0
    for k, b in base_by.items():
        f = fresh_by[k]
        b_good, f_good = float(b["goodput"]), float(f["goodput"])
        if f_good < b_good * (1 - tol):
            errors.append(f"{name} {k}: goodput dropped "
                          f"{b_good} -> {f_good} (tol {tol_pct}%)")
        b_p99, f_p99 = float(b["p99_ms"]), float(f["p99_ms"])
        if f_p99 > b_p99 * (1 + tol):
            errors.append(f"{name} {k}: p99 rose "
                          f"{b_p99}ms -> {f_p99}ms (tol {tol_pct}%)")


def check_attn_drift(fresh, base, tol_pct: float, errors):
    """Fused/gather modeled attention and step time must not rise."""
    key = lambda r: (r["impl"], r["context"], r["lanes"])
    fresh_by, base_by = ({key(r): r for r in rows}
                         for rows in (fresh, base))
    if set(fresh_by) != set(base_by):
        errors.append(f"{ATTN_TABLE}: row set changed; commit the "
                      "regenerated CSV if intentional")
        return
    tol = tol_pct / 100.0
    for k, b in base_by.items():
        f = fresh_by[k]
        for col in ("attn_us", "step_us"):
            if float(f[col]) > float(b[col]) * (1 + tol):
                errors.append(f"{ATTN_TABLE} {k}: {col} rose "
                              f"{b[col]} -> {f[col]} (tol {tol_pct}%)")


def check_attn_orderings(rows, errors):
    """The fused kernel must strictly dominate gather+SDPA everywhere."""
    by = {(r["impl"], r["context"], r["lanes"]): r for r in rows}
    points = {(c, l) for i, c, l in by if i == "fused"}
    for c, l in sorted(points):
        f, g = by.get(("fused", c, l)), by.get(("gather", c, l))
        if f is None or g is None:
            errors.append(f"{ATTN_TABLE}: missing impl row at "
                          f"ctx={c} lanes={l}")
            continue
        for col in ("attn_us", "step_us", "hbm_kb"):
            if float(f[col]) >= float(g[col]):
                errors.append(f"{ATTN_TABLE} ctx={c} lanes={l}: fused "
                              f"{col} {f[col]} not below gather {g[col]}")


def check_orderings(paged, chunked, errors):
    """The structural claims the tables prove, re-checked from fresh data."""
    p = {r["path"]: r for r in paged}
    if float(p["paged"]["p99_ms"]) >= float(p["wave"]["p99_ms"]):
        errors.append("table_paged: paged p99 not below wave p99")
    if float(p["paged"]["goodput"]) < float(p["wave"]["goodput"]):
        errors.append("table_paged: paged goodput below wave goodput")
    if p["paged"]["tokens"] != p["wave"]["tokens"]:
        errors.append("table_paged: token counts diverged between paths")

    c = {(r["path"], r["class"]): r for r in chunked}
    if float(c[("chunked", "trading")]["p99_ms"]) \
            >= float(c[("stall", "trading")]["p99_ms"]):
        errors.append("table_chunked: chunked trading p99 not below stall's")
    if float(c[("chunked", "all")]["goodput"]) \
            < float(c[("stall", "all")]["goodput"]):
        errors.append("table_chunked: chunked goodput below stall goodput")
    if c[("chunked", "all")]["tokens"] != c[("stall", "all")]["tokens"]:
        errors.append("table_chunked: token counts diverged between paths")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=os.path.join(REPO, "results"),
                    help="directory holding the freshly produced CSVs")
    ap.add_argument("--baseline-dir", default=None,
                    help="read baselines from this directory instead of "
                         "git show HEAD:results/")
    ap.add_argument("--tol-pct", type=float, default=5.0,
                    help="allowed relative worsening before failing (%%)")
    args = ap.parse_args()

    errors: list[str] = []
    fresh = {}
    for name in TABLES:
        fresh[name] = load_fresh(args.results, name)
        base = load_baseline(name, args.baseline_dir)
        check_drift(name, fresh[name], base, args.tol_pct, errors)
    check_orderings(fresh["table_paged.csv"], fresh["table_chunked.csv"],
                    errors)
    attn_fresh = load_fresh(args.results, ATTN_TABLE)
    check_attn_drift(attn_fresh, load_baseline(ATTN_TABLE,
                                               args.baseline_dir),
                     args.tol_pct, errors)
    check_attn_orderings(attn_fresh, errors)

    if errors:
        for e in errors:
            print(f"REGRESSION: {e}", file=sys.stderr)
        return 1
    print(f"regression gate: {len(TABLES) + 1} tables OK "
          f"(tolerance {args.tol_pct}%)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
