"""Bench regression gate: fail CI when serving benchmarks get worse.

The bench-smoke job used to only *upload* the fresh ``results/*.csv`` —
a PR could silently tank goodput or p99 and still go green.  This script
turns the tables into a gate:

1. **Baseline drift.**  The freshly produced ``results/table_paged.csv``
   and ``results/table_chunked.csv`` are compared against the *committed*
   copies (read via ``git show HEAD:<path>``, or ``--baseline-dir``):
   goodput must not drop and p99 must not rise beyond ``--tol-pct``.  The
   serving clock is the deterministic analytic roofline, so a genuine
   improvement should be committed as an updated CSV, not waved through.
   ``results/table_paged_attn.csv`` gates the decode hot path the same
   way: per-(impl, context, lanes) attention/step microseconds must not
   rise beyond tolerance.  ``results/table_hybrid.csv`` gates the
   sliding-window paged path: per-context windowed step/KV costs and the
   hybrid-pool fleet goodput.  ``results/table_spec.csv`` gates the
   speculative-decoding fleet the same way, per (mix, arm).
   ``results/table_sessions.csv`` gates session serving per path:
   TTFT percentiles, hit rates, and goodput.
   ``results/table_faults.csv`` gates the fault-injected fleet per
   path (ceiling / naive / recovering) on goodput and p99.
   ``results/table_sharded.csv`` gates the sharded fleet per arm
   (sharded / fallback / net-aware / net-blind) the same way.
2. **Structural orderings.**  Invariants the tables exist to prove are
   re-checked from the fresh CSVs, so the job fails even if a benchmark's
   own asserts are edited away: paged beats wave (p99 down, goodput up);
   chunked prefill beats stall-prefill on trading p99 with no less total
   goodput, at equal token counts; the fused paged-attention path strictly
   dominates gather+SDPA on modeled attention time, step time, and HBM
   bytes at every measured (context, lanes) point; the windowed
   gemma3-class stack strictly undercuts its dense-uniform equivalent on
   step time and KV bytes beyond the window, and a fleet pool holding a
   windowed gemma3-class engine earns at least the goodput of the same
   pool priced dense; the learned-draft-depth fleet keeps its goodput at
   or above always-dense on the slack-rich class and above dense and
   every fixed-k deployment on the mixed workload, while its p99 on the
   deadline-tight class never exceeds dense (speculative rounds collapse
   to dense steps under deadline pressure); prefix sharing's session TTFT
   p50 sits strictly below the no-sharing path's with no less goodput at
   equal capacity; under the identical seeded fault schedule the
   token-exact-recovery fleet's goodput is strictly above the stranding
   (naive) fleet's, neither out-earns the fault-free ceiling, and
   recovery drops no more requests than stranding; at equal chip
   capacity one tensor-parallel engine out-earns eight single-chip
   replicas on deadline-tight decisions, and DCN/ICI-aware routing
   strictly out-earns the link-blind twin that took the DCN bait.

Malformed tables (empty, or missing the gated columns) fail the gate
with a named error rather than a traceback — a refactor that drops a
column must not slip through as a crash-then-green rerun.

3. **Trace invariants** (``--trace FILE``, repeatable).  Each exported
   Chrome trace (``table_paged.py --trace`` / the examples' ``--trace``)
   is replayed through :mod:`repro.obs.check_trace`; any violated serving
   invariant — page conservation, reservation non-negativity, clock
   monotonicity, exactly-once retirement — is a gate failure.  Because
   the analytic clock is deterministic and tracing must not move it, the
   CSVs regenerated *during a traced run* still have to match the
   committed baselines byte-for-byte — that comparison doubles as the
   zero-overhead check on the disabled-path contract.

Usage:  python benchmarks/check_regression.py [--results DIR]
            [--baseline-dir DIR] [--tol-pct 5] [--trace FILE ...]
Exit status 0 = pass, 1 = regression (messages on stderr).

Unit-tested in tests/test_check_regression.py: ``main(argv)`` takes its
argv explicitly and all filesystem access goes through --results /
--baseline-dir, so the tests drive the real entry point on synthetic
tables.
"""
from __future__ import annotations

import argparse
import csv
import io
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
TABLES = ("table_paged.csv", "table_chunked.csv")
#: the decode hot-path microbench: gated on time/bytes, not goodput/p99
ATTN_TABLE = "table_paged_attn.csv"
#: the sliding-window paged path: windowed-vs-dense costs + fleet goodput
HYBRID_TABLE = "table_hybrid.csv"
#: speculative decoding: learned per-class draft depth vs dense/fixed-k
SPEC_TABLE = "table_spec.csv"
#: session serving: prefix reuse + TTFT SLOs vs cold starts, per path
SESSIONS_TABLE = "table_sessions.csv"
#: fault recovery: token-exact recovery vs stranding under one schedule
FAULTS_TABLE = "table_faults.csv"
#: sharded fleet: tensor parallelism vs replication, link-aware routing
SHARDED_TABLE = "table_sharded.csv"


def read_rows(text: str):
    rows = list(csv.DictReader(io.StringIO(text)))
    if not rows:
        raise SystemExit("empty CSV")
    return rows


def col(row, name: str, table: str, errors) -> float | None:
    """A gated numeric cell.  A missing or non-numeric column is its own
    named regression (the historical behavior was a KeyError traceback,
    which CI surfaced as a crash instead of a finding)."""
    val = row.get(name)
    if val is None or val == "":
        errors.append(f"{table}: missing column {name!r}")
        return None
    try:
        return float(val)
    except ValueError:
        errors.append(f"{table}: non-numeric {name}={val!r}")
        return None


def load_fresh(results_dir: str, name: str):
    path = os.path.join(results_dir, name)
    with open(path) as f:
        return read_rows(f.read())


def load_baseline(name: str, baseline_dir: str | None):
    if baseline_dir is not None:
        with open(os.path.join(baseline_dir, name)) as f:
            return read_rows(f.read())
    out = subprocess.run(
        ["git", "show", f"HEAD:results/{name}"], cwd=REPO,
        capture_output=True, text=True)
    if out.returncode != 0:
        raise SystemExit(f"cannot read committed baseline for {name}: "
                         f"{out.stderr.strip()}")
    return read_rows(out.stdout)


def key_of(row):
    # table_paged rows key on "path"; table_chunked on ("path", "class").
    # .get, not [...]: a table missing its key column must surface as a
    # row-set-changed / missing-row finding, never a KeyError traceback.
    return (row.get("path"), row.get("class", ""))


def check_drift(name: str, fresh, base, tol_pct: float, errors):
    """Goodput must not drop, p99 must not rise, beyond tol_pct percent."""
    fresh_by, base_by = ({key_of(r): r for r in rows}
                         for rows in (fresh, base))
    if set(fresh_by) != set(base_by):
        errors.append(f"{name}: row set changed "
                      f"{sorted(base_by)} -> {sorted(fresh_by)}; "
                      "commit the regenerated CSV if intentional")
        return
    tol = tol_pct / 100.0
    for k, b in base_by.items():
        f = fresh_by[k]
        b_good, f_good = (col(r, "goodput", name, errors) for r in (b, f))
        if None not in (b_good, f_good) and f_good < b_good * (1 - tol):
            errors.append(f"{name} {k}: goodput dropped "
                          f"{b_good} -> {f_good} (tol {tol_pct}%)")
        b_p99, f_p99 = (col(r, "p99_ms", name, errors) for r in (b, f))
        if None not in (b_p99, f_p99) and f_p99 > b_p99 * (1 + tol):
            errors.append(f"{name} {k}: p99 rose "
                          f"{b_p99}ms -> {f_p99}ms (tol {tol_pct}%)")


def check_attn_drift(fresh, base, tol_pct: float, errors):
    """Fused/gather modeled attention and step time must not rise."""
    key = lambda r: (r.get("impl"), r.get("context"), r.get("lanes"))
    fresh_by, base_by = ({key(r): r for r in rows}
                         for rows in (fresh, base))
    if set(fresh_by) != set(base_by):
        errors.append(f"{ATTN_TABLE}: row set changed; commit the "
                      "regenerated CSV if intentional")
        return
    tol = tol_pct / 100.0
    for k, b in base_by.items():
        f = fresh_by[k]
        for c in ("attn_us", "step_us"):
            bv, fv = (col(r, c, ATTN_TABLE, errors) for r in (b, f))
            if None not in (bv, fv) and fv > bv * (1 + tol):
                errors.append(f"{ATTN_TABLE} {k}: {c} rose "
                              f"{bv} -> {fv} (tol {tol_pct}%)")


def check_attn_orderings(rows, errors):
    """The fused kernel must strictly dominate gather+SDPA everywhere."""
    by = {(r.get("impl"), r.get("context"), r.get("lanes")): r
          for r in rows}
    points = {(c, l) for i, c, l in by if i == "fused"}
    for c, l in sorted(points):
        f, g = by.get(("fused", c, l)), by.get(("gather", c, l))
        if f is None or g is None:
            errors.append(f"{ATTN_TABLE}: missing impl row at "
                          f"ctx={c} lanes={l}")
            continue
        for cname in ("attn_us", "step_us", "hbm_kb"):
            fv, gv = (col(r, cname, ATTN_TABLE, errors) for r in (f, g))
            if None not in (fv, gv) and fv >= gv:
                errors.append(f"{ATTN_TABLE} ctx={c} lanes={l}: fused "
                              f"{cname} {fv} not below gather {gv}")


def check_orderings(paged, chunked, errors):
    """The structural claims the tables prove, re-checked from fresh data."""
    p = {r.get("path"): r for r in paged}
    def num(tbl, row, name):
        return col(row, name, tbl, errors)
    pw, pp = p.get("wave"), p.get("paged")
    if pw is None or pp is None:
        errors.append("table_paged: missing wave/paged row")
    else:
        a, b = num("table_paged", pp, "p99_ms"), num("table_paged", pw,
                                                     "p99_ms")
        if None not in (a, b) and a >= b:
            errors.append("table_paged: paged p99 not below wave p99")
        a, b = num("table_paged", pp, "goodput"), num("table_paged", pw,
                                                      "goodput")
        if None not in (a, b) and a < b:
            errors.append("table_paged: paged goodput below wave goodput")
        if pp.get("tokens") != pw.get("tokens"):
            errors.append("table_paged: token counts diverged between paths")

    c = {(r.get("path"), r.get("class")): r for r in chunked}
    need = [("chunked", "trading"), ("stall", "trading"),
            ("chunked", "all"), ("stall", "all")]
    if any(k not in c for k in need):
        errors.append("table_chunked: missing path/class rows")
        return
    a = num("table_chunked", c[("chunked", "trading")], "p99_ms")
    b = num("table_chunked", c[("stall", "trading")], "p99_ms")
    if None not in (a, b) and a >= b:
        errors.append("table_chunked: chunked trading p99 not below stall's")
    a = num("table_chunked", c[("chunked", "all")], "goodput")
    b = num("table_chunked", c[("stall", "all")], "goodput")
    if None not in (a, b) and a < b:
        errors.append("table_chunked: chunked goodput below stall goodput")
    if c[("chunked", "all")].get("tokens") != c[("stall", "all")].get("tokens"):
        errors.append("table_chunked: token counts diverged between paths")


def check_hybrid_drift(fresh, base, tol_pct: float, errors):
    """The hybrid paged table: windowed/dense step+KV costs must not
    rise, fleet goodput must not drop, p99 must not rise."""
    key = lambda r: (r.get("kind"), r.get("name"), r.get("context"))
    fresh_by, base_by = ({key(r): r for r in rows}
                         for rows in (fresh, base))
    if set(fresh_by) != set(base_by):
        errors.append(f"{HYBRID_TABLE}: row set changed; commit the "
                      "regenerated CSV if intentional")
        return
    tol = tol_pct / 100.0
    for k, b in base_by.items():
        f = fresh_by[k]
        cols = (("attn_us", +1), ("step_us", +1), ("kv_kib", +1)) \
            if k[0] == "attn" else (("goodput", -1), ("p99_ms", +1))
        for cname, sign in cols:
            bv, fv = (col(r, cname, HYBRID_TABLE, errors) for r in (b, f))
            if None in (bv, fv):
                continue
            if sign > 0 and fv > bv * (1 + tol):
                errors.append(f"{HYBRID_TABLE} {k}: {cname} rose "
                              f"{bv} -> {fv} (tol {tol_pct}%)")
            if sign < 0 and fv < bv * (1 - tol):
                errors.append(f"{HYBRID_TABLE} {k}: {cname} dropped "
                              f"{bv} -> {fv} (tol {tol_pct}%)")


def check_hybrid_orderings(rows, errors):
    """Windowed pricing must undercut the dense equivalent beyond the
    window, and the hybrid-engine fleet pool must earn >= the dense-priced
    pool's goodput.  The window the strictness boundary uses rides in the
    table's own ``window`` column."""
    attn = {(r.get("name"), r.get("context")): r
            for r in rows if r.get("kind") == "attn"}
    windows = [col(r, "window", HYBRID_TABLE, errors)
               for (n, _), r in attn.items() if n == "windowed"]
    if not windows or None in windows:
        errors.append(f"{HYBRID_TABLE}: no windowed rows with a window")
        return
    window = int(windows[0])
    ctxs = sorted({int(c) for _, c in attn if c}, key=int)
    for ctx in ctxs:
        w = attn.get(("windowed", str(ctx)))
        d = attn.get(("dense", str(ctx)))
        if w is None or d is None:
            errors.append(f"{HYBRID_TABLE}: missing windowed/dense row at "
                          f"ctx={ctx}")
            continue
        for cname in ("attn_us", "step_us", "kv_kib"):
            wv, dv = (col(r, cname, HYBRID_TABLE, errors) for r in (w, d))
            if None in (wv, dv):
                continue
            if wv > dv:
                errors.append(f"{HYBRID_TABLE} ctx={ctx}: windowed "
                              f"{cname} {wv} above dense {dv}")
            if ctx > window and wv >= dv:
                errors.append(f"{HYBRID_TABLE} ctx={ctx}: windowed "
                              f"{cname} {wv} not strictly below dense "
                              f"{dv} beyond the window")
    fleet = {r.get("name"): r for r in rows if r.get("kind") == "fleet"}
    h, d = fleet.get("hybrid-pool"), fleet.get("dense-pool")
    if h is None or d is None:
        errors.append(f"{HYBRID_TABLE}: missing fleet pool rows")
        return
    hv, dv = (col(r, "goodput", HYBRID_TABLE, errors) for r in (h, d))
    if None not in (hv, dv) and hv < dv:
        errors.append(f"{HYBRID_TABLE}: hybrid-pool goodput {hv} below "
                      f"dense-pool {dv}")


def check_spec_drift(fresh, base, tol_pct: float, errors):
    """The speculation table: per-(mix, arm) goodput must not drop and
    p99 must not rise beyond tolerance."""
    key = lambda r: (r.get("mix"), r.get("arm"))
    fresh_by, base_by = ({key(r): r for r in rows}
                         for rows in (fresh, base))
    if set(fresh_by) != set(base_by):
        errors.append(f"{SPEC_TABLE}: row set changed; commit the "
                      "regenerated CSV if intentional")
        return
    tol = tol_pct / 100.0
    for k, b in base_by.items():
        f = fresh_by[k]
        bv, fv = (col(r, "goodput", SPEC_TABLE, errors) for r in (b, f))
        if None not in (bv, fv) and fv < bv * (1 - tol):
            errors.append(f"{SPEC_TABLE} {k}: goodput dropped "
                          f"{bv} -> {fv} (tol {tol_pct}%)")
        bv, fv = (col(r, "p99_ms", SPEC_TABLE, errors) for r in (b, f))
        if None not in (bv, fv) and fv > bv * (1 + tol):
            errors.append(f"{SPEC_TABLE} {k}: p99 rose "
                          f"{bv}ms -> {fv}ms (tol {tol_pct}%)")


def check_spec_orderings(rows, errors):
    """The claims the speculation table exists to prove: on the
    slack-rich class the learned arm converts draft depth into goodput
    (>= always-dense); on the deadline-tight class its p99 is never
    worse than dense (rounds collapse under pressure); and on the mixed
    workload learned per-class depth beats always-dense AND every
    fleet-wide fixed-k deployment at equal capacity."""
    by = {(r.get("mix"), r.get("arm")): r for r in rows}

    def need(mix, arm):
        row = by.get((mix, arm))
        if row is None:
            errors.append(f"{SPEC_TABLE}: missing row ({mix}, {arm})")
        return row

    chat_l, chat_d = need("chat", "spec-learned"), need("chat", "dense")
    if chat_l and chat_d:
        lv, dv = (col(r, "goodput", SPEC_TABLE, errors)
                  for r in (chat_l, chat_d))
        if None not in (lv, dv) and lv < dv:
            errors.append(f"{SPEC_TABLE} chat: spec-learned goodput {lv} "
                          f"below dense {dv}")
    trd_l, trd_d = need("trading", "spec-learned"), need("trading", "dense")
    if trd_l and trd_d:
        lv, dv = (col(r, "p99_ms", SPEC_TABLE, errors)
                  for r in (trd_l, trd_d))
        if None not in (lv, dv) and lv > dv:
            errors.append(f"{SPEC_TABLE} trading: spec-learned p99 {lv}ms "
                          f"above dense {dv}ms")
    mix_l = need("mixed", "spec-learned")
    if mix_l:
        lv = col(mix_l, "goodput", SPEC_TABLE, errors)
        rivals = [a for m, a in by
                  if m == "mixed" and (a == "dense" or a.startswith("fixed-"))]
        if not rivals:
            errors.append(f"{SPEC_TABLE}: no dense/fixed-k rows in mixed")
        for arm in sorted(rivals):
            rv = col(by[("mixed", arm)], "goodput", SPEC_TABLE, errors)
            if None not in (lv, rv) and lv < rv:
                errors.append(f"{SPEC_TABLE} mixed: spec-learned goodput "
                              f"{lv} below {arm} {rv}")


def check_sessions_drift(fresh, base, tol_pct: float, errors):
    """The sessions table: per-path TTFT p50 and p99 must not rise,
    goodput and hit rates must not drop, beyond tolerance."""
    fresh_by, base_by = ({r.get("path"): r for r in rows}
                         for rows in (fresh, base))
    if set(fresh_by) != set(base_by):
        errors.append(f"{SESSIONS_TABLE}: row set changed; commit the "
                      "regenerated CSV if intentional")
        return
    tol = tol_pct / 100.0
    for k, b in base_by.items():
        f = fresh_by[k]
        for cname, sign in (("ttft_p50_ms", +1), ("ttft_p99_ms", +1),
                            ("p99_ms", +1), ("goodput", -1),
                            ("hit_rate", -1), ("ttft_hit_rate", -1)):
            bv, fv = (col(r, cname, SESSIONS_TABLE, errors) for r in (b, f))
            if None in (bv, fv):
                continue
            if sign > 0 and fv > bv * (1 + tol):
                errors.append(f"{SESSIONS_TABLE} {k}: {cname} rose "
                              f"{bv} -> {fv} (tol {tol_pct}%)")
            if sign < 0 and fv < bv * (1 - tol):
                errors.append(f"{SESSIONS_TABLE} {k}: {cname} dropped "
                              f"{bv} -> {fv} (tol {tol_pct}%)")


def check_sessions_orderings(rows, errors):
    """The claims the sessions table exists to prove: at equal capacity,
    prefix sharing's TTFT p50 is *strictly* below no-sharing's, and its
    goodput is at least no-sharing's — a warm prefix can only remove
    prefill work."""
    by = {r.get("path"): r for r in rows}
    sh, ns = by.get("sharing"), by.get("no-sharing")
    if sh is None or ns is None:
        errors.append(f"{SESSIONS_TABLE}: missing sharing/no-sharing row")
        return
    sv, nv = (col(r, "ttft_p50_ms", SESSIONS_TABLE, errors)
              for r in (sh, ns))
    if None not in (sv, nv) and sv >= nv:
        errors.append(f"{SESSIONS_TABLE}: sharing ttft_p50 {sv}ms not "
                      f"strictly below no-sharing {nv}ms")
    sv, nv = (col(r, "goodput", SESSIONS_TABLE, errors) for r in (sh, ns))
    if None not in (sv, nv) and sv < nv:
        errors.append(f"{SESSIONS_TABLE}: sharing goodput {sv} below "
                      f"no-sharing {nv}")


def check_faults_orderings(rows, errors):
    """The claims the fault table exists to prove: under the identical
    seeded fault schedule, token-exact recovery earns *strictly* more
    goodput than stranding, drops no more requests, and no faulted row
    out-earns the fault-free ceiling."""
    by = {r.get("path"): r for r in rows}
    need = ("ceiling", "naive", "recovering")
    missing = [p for p in need if by.get(p) is None]
    if missing:
        errors.append(f"{FAULTS_TABLE}: missing rows {missing}")
        return
    g = {p: col(by[p], "goodput", FAULTS_TABLE, errors) for p in need}
    if None not in g.values():
        if g["recovering"] <= g["naive"]:
            errors.append(f"{FAULTS_TABLE}: recovering goodput "
                          f"{g['recovering']} not strictly above naive "
                          f"{g['naive']}")
        for p in ("naive", "recovering"):
            if g[p] > g["ceiling"]:
                errors.append(f"{FAULTS_TABLE}: {p} goodput {g[p]} above "
                              f"the fault-free ceiling {g['ceiling']}")
    dn, dr = (col(by[p], "dropped", FAULTS_TABLE, errors)
              for p in ("naive", "recovering"))
    if None not in (dn, dr) and dr > dn:
        errors.append(f"{FAULTS_TABLE}: recovering dropped {dr} requests, "
                      f"more than naive's {dn}")
    rt = col(by["recovering"], "retried", FAULTS_TABLE, errors)
    if rt is not None and rt <= 0:
        errors.append(f"{FAULTS_TABLE}: recovering row retried nothing — "
                      "the schedule exercises no recovery")


def check_sharded_drift(fresh, base, tol_pct: float, errors):
    """The sharded table: per-arm goodput must not drop and p99 must not
    rise beyond tolerance.  Rows key on ``arm``."""
    fresh_by, base_by = ({r.get("arm"): r for r in rows}
                         for rows in (fresh, base))
    if set(fresh_by) != set(base_by):
        errors.append(f"{SHARDED_TABLE}: row set changed; commit the "
                      "regenerated CSV if intentional")
        return
    tol = tol_pct / 100.0
    for k, b in base_by.items():
        f = fresh_by[k]
        bv, fv = (col(r, "goodput", SHARDED_TABLE, errors) for r in (b, f))
        if None not in (bv, fv) and fv < bv * (1 - tol):
            errors.append(f"{SHARDED_TABLE} {k}: goodput dropped "
                          f"{bv} -> {fv} (tol {tol_pct}%)")
        bv, fv = (col(r, "p99_ms", SHARDED_TABLE, errors) for r in (b, f))
        if None not in (bv, fv) and fv > bv * (1 + tol):
            errors.append(f"{SHARDED_TABLE} {k}: p99 rose "
                          f"{bv}ms -> {fv}ms (tol {tol_pct}%)")


def check_sharded_orderings(rows, errors):
    """The claims the sharded table exists to prove: at equal chip
    capacity one tensor-parallel engine out-earns eight single-chip
    replicas on deadline-tight decisions, and pricing the DCN/ICI
    collective tax into routing beats the link-blind twin — with the
    blind router having actually taken the bait (used the DCN-spanning
    engine), so the comparison is not vacuous."""
    by = {r.get("arm"): r for r in rows}
    need = ("sharded-tp8", "fallback-tp1", "net-aware", "net-blind")
    missing = [a for a in need if by.get(a) is None]
    if missing:
        errors.append(f"{SHARDED_TABLE}: missing rows {missing}")
        return
    g = {a: col(by[a], "goodput", SHARDED_TABLE, errors) for a in need}
    if None not in g.values():
        if g["sharded-tp8"] <= g["fallback-tp1"]:
            errors.append(f"{SHARDED_TABLE}: sharded-tp8 goodput "
                          f"{g['sharded-tp8']} not strictly above "
                          f"fallback-tp1 {g['fallback-tp1']} at equal "
                          "capacity")
        if g["net-aware"] <= g["net-blind"]:
            errors.append(f"{SHARDED_TABLE}: net-aware goodput "
                          f"{g['net-aware']} not strictly above "
                          f"net-blind {g['net-blind']}")
    shares = (by["net-blind"].get("engine_shares") or "").split("/")
    try:
        blind_dcn = int(shares[1])
    except (IndexError, ValueError):
        errors.append(f"{SHARDED_TABLE}: net-blind engine_shares "
                      f"{by['net-blind'].get('engine_shares')!r} malformed")
        return
    if blind_dcn <= 0:
        errors.append(f"{SHARDED_TABLE}: blind router never chose the "
                      "DCN-spanning engine — the aware/blind comparison "
                      "is vacuous")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=os.path.join(REPO, "results"),
                    help="directory holding the freshly produced CSVs")
    ap.add_argument("--baseline-dir", default=None,
                    help="read baselines from this directory instead of "
                         "git show HEAD:results/")
    ap.add_argument("--tol-pct", type=float, default=5.0,
                    help="allowed relative worsening before failing (%%)")
    ap.add_argument("--trace", action="append", default=[], metavar="FILE",
                    help="exported Chrome trace(s) to audit with "
                         "repro.obs.check_trace (repeatable)")
    args = ap.parse_args(argv)

    errors: list[str] = []
    fresh = {}
    for name in TABLES:
        fresh[name] = load_fresh(args.results, name)
        base = load_baseline(name, args.baseline_dir)
        check_drift(name, fresh[name], base, args.tol_pct, errors)
    check_orderings(fresh["table_paged.csv"], fresh["table_chunked.csv"],
                    errors)
    attn_fresh = load_fresh(args.results, ATTN_TABLE)
    check_attn_drift(attn_fresh, load_baseline(ATTN_TABLE,
                                               args.baseline_dir),
                     args.tol_pct, errors)
    check_attn_orderings(attn_fresh, errors)
    hybrid_fresh = load_fresh(args.results, HYBRID_TABLE)
    check_hybrid_drift(hybrid_fresh, load_baseline(HYBRID_TABLE,
                                                   args.baseline_dir),
                       args.tol_pct, errors)
    check_hybrid_orderings(hybrid_fresh, errors)
    spec_fresh = load_fresh(args.results, SPEC_TABLE)
    check_spec_drift(spec_fresh, load_baseline(SPEC_TABLE,
                                               args.baseline_dir),
                     args.tol_pct, errors)
    check_spec_orderings(spec_fresh, errors)
    sess_fresh = load_fresh(args.results, SESSIONS_TABLE)
    check_sessions_drift(sess_fresh, load_baseline(SESSIONS_TABLE,
                                                   args.baseline_dir),
                         args.tol_pct, errors)
    check_sessions_orderings(sess_fresh, errors)
    # the fault table keys on "path" like the serving tables, so the
    # generic goodput/p99 drift check applies as-is
    faults_fresh = load_fresh(args.results, FAULTS_TABLE)
    check_drift(FAULTS_TABLE, faults_fresh,
                load_baseline(FAULTS_TABLE, args.baseline_dir),
                args.tol_pct, errors)
    check_faults_orderings(faults_fresh, errors)
    sharded_fresh = load_fresh(args.results, SHARDED_TABLE)
    check_sharded_drift(sharded_fresh,
                        load_baseline(SHARDED_TABLE, args.baseline_dir),
                        args.tol_pct, errors)
    check_sharded_orderings(sharded_fresh, errors)

    for trace_path in args.trace:
        sys.path.insert(0, os.path.join(REPO, "src"))
        from repro.obs.check_trace import check_file
        for finding in check_file(trace_path):
            errors.append(f"{os.path.basename(trace_path)}: {finding}")

    if errors:
        for e in errors:
            print(f"REGRESSION: {e}", file=sys.stderr)
        return 1
    traced = f" + {len(args.trace)} trace(s)" if args.trace else ""
    print(f"regression gate: {len(TABLES) + 6} tables OK{traced} "
          f"(tolerance {args.tol_pct}%)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
