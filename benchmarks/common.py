"""Shared benchmark infrastructure: the trained sim-model ladder + FPX grid.

``build_ladder(task)`` trains the qwen-sim family on the task's Teacher
(decision supervision), runs Algorithm-1 calibration, and caches params +
eps to ``results/agents/`` so later tables reuse them.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.bench import agents as ag
from repro.bench.env import Teacher
from repro.bench.hft import HFTBench, HFTConfig
from repro.bench.streetfighter import SFConfig, N_ACTIONS
from repro.checkpoint import ckpt
from repro.configs import QWEN_SIM, QWEN_FULL, SIM_TO_FULL, get_config
from repro.core import assign as assign_mod
from repro.core import calibrate as calib_mod
from repro.data import pipeline as dp
from repro.models import transformer
from repro.models.modules import ExecContext

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
AGENT_DIR = os.path.join(RESULTS, "agents")

LADDER = ["qwen-sim-1.5b", "qwen-sim-3b", "qwen-sim-7b", "qwen-sim-14b"]
#: training budget per model: proportional to the real model's pretraining
#: compute (that is *why* bigger checkpoints decide better — emulating the
#: quality ladder this way is honest to the asset we cannot reproduce;
#: DESIGN.md §7.  Equal-budget capacity separation does NOT emerge at sim
#: scale — measured across MLP/chain/memorization teachers before settling
#: on this).
TRAIN_STEPS = {"qwen-sim-1.5b": 100, "qwen-sim-3b": 250,
               "qwen-sim-7b": 600, "qwen-sim-14b": 2600}
TRAIN_BATCH = 32
PROMPT_LEN = {"hft": 32, "sf": 24}
N_ACT = {"hft": 3, "sf": N_ACTIONS}


def task_teacher(task: str) -> Teacher:
    if task == "hft":
        c = HFTConfig()
        return Teacher(c.n_features, c.n_values, 3, seed=c.teacher_seed,
                       hidden=c.teacher_hidden, temperature=c.teacher_temp)
    c = SFConfig()
    return Teacher(c.n_features, c.n_values, N_ACTIONS, seed=c.teacher_seed,
                   hidden=c.teacher_hidden, temperature=c.teacher_temp)


def _paths(task: str, name: str):
    os.makedirs(AGENT_DIR, exist_ok=True)
    return (os.path.join(AGENT_DIR, f"{task}_{name}.ckpt"),
            os.path.join(AGENT_DIR, f"{task}_{name}_eps.json"))


def build_ladder(task: str, *, force: bool = False, verbose: bool = True
                 ) -> Dict[str, Tuple]:
    """Returns {sim_name: (params, eps, train_acc)}."""
    teacher = task_teacher(task)
    out = {}
    for name in LADDER:
        cfg = get_config(name)
        p_path, e_path = _paths(task, name)
        if not force and os.path.exists(p_path) and os.path.exists(e_path):
            like = jax.eval_shape(
                lambda k: transformer.init_params(k, cfg), jax.random.PRNGKey(0))
            params = ckpt.restore(p_path, like)
            meta = json.load(open(e_path))
            out[name] = (params, meta["eps"], meta.get("train_acc"))
            if verbose:
                print(f"# loaded {task}/{name} (train acc {meta.get('train_acc')})")
            continue
        if verbose:
            print(f"# training {task}/{name} ...")
        params, acc = ag.train_decision_model(
            cfg, teacher, steps=TRAIN_STEPS[name], batch=TRAIN_BATCH,
            prompt_len=PROMPT_LEN[task], seed=hash(name) % 2**31,
            log_every=200 if verbose else 0)
        # Algorithm-1 calibration on the task's observation stream
        rng = np.random.default_rng(5)
        batches = [ag.decision_batch(teacher, rng, batch=4,
                                     prompt_len=PROMPT_LEN[task])
                   for _ in range(2)]
        eps = calib_mod.calibrate(params, cfg, batches)
        ckpt.save(p_path, params)
        json.dump({"eps": eps, "train_acc": acc}, open(e_path, "w"))
        out[name] = (params, eps, acc)
    return out


def make_spec(task: str, sim_name: str, ladder, *, gamma: Optional[float],
              bits: Optional[int] = None) -> ag.AgentSpec:
    """gamma=None & bits in {16, 8, 4}: uniform precision.
    gamma=x: FPX assignment at compression ratio x (rest FP8)."""
    params, eps, _ = ladder[sim_name]
    full = get_config(SIM_TO_FULL[sim_name])
    sim = get_config(sim_name)
    if gamma is None:
        b = bits or 16
        policy = None if b >= 16 else {k: b for k in eps}
        return ag.AgentSpec(
            name=f"{sim_name.replace('qwen-sim-','')}-fp{b}",
            sim_cfg=sim, params=params, full_cfg=full, policy=policy,
            default_bits=b, avg_bits=float(b), gamma=0.0)
    assignment = assign_mod.assign_precision(eps, gamma)
    return ag.AgentSpec(
        name=f"{sim_name.replace('qwen-sim-','')}-fpx{gamma:g}",
        sim_cfg=sim, params=params, full_cfg=full, policy=assignment,
        default_bits=8, avg_bits=assign_mod.avg_bits(assignment), gamma=gamma)


def lm_ppl(spec: ag.AgentSpec, task: str) -> float:
    """Perplexity proxy (paper Table 2's PPL column): NLL of the correct
    action token under the quantized model, exponentiated."""
    teacher = task_teacher(task)
    ctx = ExecContext(policy=spec.policy, default_bits=spec.default_bits)
    acc = ag.eval_decision_accuracy(spec.params, spec.sim_cfg, teacher,
                                    ctx=ctx, prompt_len=PROMPT_LEN[task],
                                    n_actions=N_ACT[task])
    return acc  # returned as accuracy; tables label the column accordingly


def write_table(path: str, header: List[str], rows: List[List]) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    print(f"# wrote {path}")
