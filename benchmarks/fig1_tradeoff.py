"""Paper Figure 1: the latency-quality trade-off curves.

(a) FPX enables a smooth latency/accuracy frontier per model;
(b) SF win rate vs latency has an interior Pareto optimum;
(c) HFT daily yield vs latency has an interior optimum.

Emits CSV curves (results/fig1_*.csv) — plotting left to the reader.
"""
from __future__ import annotations

import sys

import numpy as np

from common import (LADDER, N_ACT, PROMPT_LEN, build_ladder, make_spec,
                    task_teacher, write_table)

sys.path.insert(0, "src")
from repro.bench import agents as ag
from repro.bench.hft import HFTBench, run_session
from repro.bench.streetfighter import play_match
from repro.models.modules import ExecContext

GAMMAS = tuple(round(0.1 * i, 1) for i in range(11))


def frontier(task: str, ladder) -> list:
    teacher = task_teacher(task)
    rows = []
    for sim in LADDER:
        for g in GAMMAS:
            spec = make_spec(task, sim, ladder, gamma=g)
            agent = ag.LLMAgent(spec, n_actions=N_ACT[task])
            acc = ag.eval_decision_accuracy(
                spec.params, spec.sim_cfg, teacher,
                ctx=ExecContext(policy=spec.policy,
                                default_bits=spec.default_bits),
                prompt_len=PROMPT_LEN[task], n_actions=N_ACT[task])
            rows.append([sim, f"{g:.1f}", f"{spec.avg_bits:.1f}",
                         f"{agent.latency_s*1e3:.1f}", f"{acc:.4f}"])
    return rows


def reward_curve(task: str, ladder, sim: str) -> list:
    rows = []
    for g in GAMMAS:
        spec = make_spec(task, sim, ladder, gamma=g)
        n_act = N_ACT[task]
        agent = ag.LLMAgent(spec, n_actions=n_act)
        if task == "hft":
            env = HFTBench()
            r = float(np.mean([run_session(env, agent, seed=s)["daily_yield"]
                               for s in range(3)]))
        else:
            ref = ag.LLMAgent(make_spec(task, "qwen-sim-3b", ladder,
                                        gamma=None, bits=16), n_actions=n_act)
            r = 100.0 * np.mean([play_match(agent, ref, rounds=1, seed=s) == 0
                                 for s in range(10)])
        rows.append([sim, f"{g:.1f}", f"{agent.latency_s*1e3:.1f}", f"{r:.2f}"])
        print(f"fig1 {task} {sim} gamma={g:.1f}: lat={agent.latency_s*1e3:.0f}ms "
              f"reward={r:+.2f}")
    return rows


def main():
    hft_ladder = build_ladder("hft")
    sf_ladder = build_ladder("sf")
    write_table("results/fig1a_frontier_hft.csv",
                ["model", "gamma", "avg_bits", "latency_ms", "decision_acc"],
                frontier("hft", hft_ladder))
    write_table("results/fig1b_sf_reward.csv",
                ["model", "gamma", "latency_ms", "winrate_pct"],
                reward_curve("sf", sf_ladder, "qwen-sim-3b"))
    write_table("results/fig1c_hft_reward.csv",
                ["model", "gamma", "latency_ms", "daily_yield_pct"],
                reward_curve("hft", hft_ladder, "qwen-sim-14b"))


if __name__ == "__main__":
    main()
