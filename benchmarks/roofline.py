"""§Roofline: three-term roofline per (arch x input-shape) from the dry-run.

  compute term    = step_FLOPs / (chips x 197 TF/s bf16)
  memory term     = step_HBM_bytes / (chips x 819 GB/s)
  collective term = per-chip collective bytes / 50 GB/s ICI link bw

Collective bytes come from the compiled SPMD HLO (loop-aware parse in
launch/dryrun.py; shapes there are already per-chip).  FLOPs/bytes use the
analytic workload model below: XLA's cost_analysis() counts scan bodies
ONCE (verified empirically — 2-layer and 4-layer models report identical
flops), so raw cost_analysis is recorded as a cross-check only.

Emits results/roofline.md + results/roofline.csv, consumed by EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, Optional, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ASSIGNED, INPUT_SHAPES, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.core import latency as lat

CHIPS = 256
PEAK = lat.PEAK_BF16
HBM = lat.HBM_BW
ICI = lat.ICI_BW

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


# ---------------------------------------------------------------------------
# Analytic workload model (global FLOPs / HBM bytes per step)
# ---------------------------------------------------------------------------

def _linear_flops_bytes(cfg: ModelConfig, n_tokens: int, w_bytes: float = 2.0
                        ) -> Tuple[float, float]:
    """All linears across layers: (flops, weight bytes)."""
    fl = wb = 0.0
    for d_in, d_out, mult in lat._per_layer_linears(cfg):
        fl += cfg.n_layers * 2.0 * n_tokens * mult * d_in * d_out
        wb += cfg.n_layers * d_in * d_out * w_bytes
    # embedding + lm head
    fl += 2.0 * n_tokens * cfg.d_model * cfg.vocab
    wb += cfg.d_model * cfg.vocab * w_bytes * (1 if cfg.tie_embeddings else 2)
    if cfg.encdec:
        enc_tokens = n_tokens  # encoder processes audio frames ~ seq tokens
        for d_in, d_out, mult in lat._per_layer_linears(cfg):
            fl += cfg.n_enc_layers * 2.0 * enc_tokens * mult * d_in * d_out
            wb += cfg.n_enc_layers * d_in * d_out * w_bytes
    return fl, wb


def _attn_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Score+combine FLOPs (4 * tokens * context * q_width), window-aware."""
    if cfg.arch_type == "ssm":
        # mLSTM chunkwise: ~attention within chunks of 64
        B, S = shape.global_batch, (1 if shape.kind == "decode" else shape.seq_len)
        di = int(cfg.d_model * cfg.mlstm_proj_factor)
        chunk = 64 if shape.kind != "decode" else 1
        return 4.0 * B * S * chunk * di
    B = shape.global_batch
    qw = cfg.n_heads * cfg.head_dim
    if shape.kind == "decode":
        ctx_full, new = shape.seq_len, 1
    else:
        ctx_full, new = shape.seq_len / 2.0, shape.seq_len   # causal avg
    W = cfg.sliding_window
    L = cfg.n_layers
    if W and cfg.local_global_ratio:
        sb = cfg.local_global_ratio + 1
        n_glob = L // sb
        n_loc = L - n_glob
    elif W:
        n_loc, n_glob = L, 0
    else:
        n_loc, n_glob = 0, L
    fl = 0.0
    if n_glob:
        fl += n_glob * 4.0 * B * new * ctx_full * qw
    if n_loc:
        fl += n_loc * 4.0 * B * new * min(ctx_full, W) * qw
    if cfg.arch_type == "hybrid":
        # mamba scan flops: ~6 * tokens * d_inner * state
        fl += L * 6.0 * B * new * cfg.d_inner * cfg.ssm_state
    if cfg.cross_attn_every:
        n_cross = L // cfg.cross_attn_every
        fl += n_cross * 4.0 * B * new * cfg.vision_tokens * qw
    if cfg.encdec:
        fl += L * 4.0 * B * new * cfg.audio_frames * qw
    return fl


def _kv_bytes(cfg: ModelConfig, shape: InputShape, dtype_bytes: int = 2) -> float:
    """Decode-step KV cache read traffic (bytes)."""
    if shape.kind != "decode" or cfg.arch_type == "ssm":
        if cfg.arch_type == "ssm" and shape.kind == "decode":
            pass
        if cfg.arch_type != "ssm":
            return 0.0
        # xlstm decode: matrix state read/write
        di = int(cfg.d_model * cfg.mlstm_proj_factor)
        hd = di // cfg.n_heads
        per = cfg.n_heads * hd * hd * 4.0 * 2        # C read+write, fp32
        return shape.global_batch * cfg.n_layers * per
    B, S = shape.global_batch, shape.seq_len
    kvw = cfg.n_kv_heads * cfg.head_dim
    W = cfg.sliding_window
    L = cfg.n_layers
    if W and cfg.local_global_ratio:
        sb = cfg.local_global_ratio + 1
        n_glob = L // sb
        n_loc = L - n_glob
    elif W:
        n_loc, n_glob = L, 0
    else:
        n_loc, n_glob = 0, L
    total = n_glob * 2.0 * B * S * kvw * dtype_bytes
    total += n_loc * 2.0 * B * min(S, W or S) * kvw * dtype_bytes
    if cfg.arch_type == "hybrid":
        total += L * B * cfg.d_inner * cfg.ssm_state * 4.0 * 2
    return total


def _attn_score_bytes(cfg: ModelConfig, shape: InputShape) -> float:
    """HBM traffic of materialized (B,H,Sq,Skv) attention scores — the naive
    (non-flash) attention baseline writes+reads them in fp32.  A fused
    (flash/chunked) attention keeps them in VMEM: pass flash=True to
    ``analytic`` to model that optimization (§Perf iteration)."""
    if cfg.arch_type == "ssm" or shape.kind == "decode":
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    L = cfg.n_layers
    W = cfg.sliding_window
    if W and cfg.local_global_ratio:
        sb = cfg.local_global_ratio + 1
        n_glob = L // sb
        n_loc = L - n_glob
    elif W:
        n_loc, n_glob = L, 0
    else:
        n_loc, n_glob = 0, L
    per = 0.0
    if n_glob:
        per += n_glob * B * cfg.n_heads * S * S * 0.5     # causal half
    if n_loc:
        per += n_loc * B * cfg.n_heads * S * min(S, W)
    return per * 4.0 * 2.0      # fp32, write+read


def analytic(cfg: ModelConfig, shape: InputShape, *,
             flash: bool = False, w_bits: float = 16.0) -> Dict[str, float]:
    """Global FLOPs / HBM bytes per step.

    flash:  fused attention (no S^2 score materialization) — §Perf variant.
    w_bits: weight storage width (16 baseline; 8/4/mixed for the FPX
            quantized-serving §Perf variant)."""
    n_tokens = shape.global_batch * (1 if shape.kind == "decode"
                                     else shape.seq_len)
    lin_fl, w_bytes = _linear_flops_bytes(cfg, n_tokens,
                                          w_bytes=w_bits / 8.0)
    attn_fl = _attn_flops(cfg, shape)
    fwd = lin_fl + attn_fl
    score_b = 0.0 if flash else _attn_score_bytes(cfg, shape)
    if shape.kind == "train":
        flops = 3.0 * fwd                       # fwd + bwd(2x)
        hbm = 3.0 * w_bytes + 3.0 * w_bytes * 2  # grads + fp32 adam moments
        hbm += 14.0 * n_tokens * cfg.d_model * cfg.n_layers  # act traffic
        hbm += 3.0 * score_b
    elif shape.kind == "prefill":
        flops = fwd
        hbm = w_bytes + 12.0 * n_tokens * cfg.d_model * cfg.n_layers + score_b
    else:
        flops = fwd
        hbm = w_bytes + _kv_bytes(cfg, shape) + \
            8.0 * n_tokens * cfg.d_model * cfg.n_layers

    n_active = cfg.n_active_params
    model_flops = (6.0 if shape.kind == "train" else 2.0) * n_active * n_tokens
    return {"flops": flops, "hbm_bytes": hbm, "model_flops": model_flops}


BOTTLENECK_FIX = {
    "compute": "more chips / lower-precision matmuls (int8 MXU) / sparser attn",
    "memory": "quantized weights+KV (FPX: 2-4x fewer HBM bytes), fused attention",
    "collective": "resharding: avoid per-layer activation all-reduce (2D sharding), overlap collectives with compute",
}


def roofline_row(cfg: ModelConfig, shape: InputShape,
                 dr: Optional[dict]) -> Dict[str, object]:
    a = analytic(cfg, shape)
    t_c = a["flops"] / (CHIPS * PEAK)
    t_m = a["hbm_bytes"] / (CHIPS * HBM)
    coll = dr.get("collective_bytes", {}) if dr else {}
    coll_bytes = float(sum(coll.values()))     # per-chip (SPMD shapes)
    t_x = coll_bytes / ICI
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    return {
        "arch": cfg.name, "shape": shape.name,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops": a["model_flops"],
        "useful_ratio": a["model_flops"] / max(a["flops"], 1.0),
        "fix": BOTTLENECK_FIX[dom],
        "raw_cost_flops": (dr or {}).get("cost", {}).get("flops"),
        "collective_bytes": coll_bytes,
    }


def load_dryrun(path: str) -> Dict[Tuple[str, str], dict]:
    out = {}
    if not os.path.exists(path):
        return out
    for line in open(path):
        r = json.loads(line)
        out[(r["arch"], r["shape"])] = r
    return out


def main(jsonl: str = None):
    jsonl = jsonl or os.path.join(RESULTS, "dryrun_single.jsonl")
    dr = load_dryrun(jsonl)
    rows = []
    for arch in ASSIGNED:
        for sname, shape in INPUT_SHAPES.items():
            rec = dr.get((arch, sname))
            if rec and "skipped" in rec:
                continue
            cfg = get_config(arch)
            rows.append(roofline_row(cfg, shape, rec))

    os.makedirs(RESULTS, exist_ok=True)
    md = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
          "dominant | useful FLOP ratio | what moves it down |",
          "|---|---|---|---|---|---|---|---|"]
    csv = ["arch,shape,compute_s,memory_s,collective_s,dominant,"
           "model_flops,useful_ratio,collective_bytes_per_chip"]
    for r in rows:
        md.append(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
                  f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
                  f"**{r['dominant']}** | {r['useful_ratio']:.2f} | {r['fix']} |")
        csv.append(f"{r['arch']},{r['shape']},{r['compute_s']:.6e},"
                   f"{r['memory_s']:.6e},{r['collective_s']:.6e},"
                   f"{r['dominant']},{r['model_flops']:.3e},"
                   f"{r['useful_ratio']:.3f},{r['collective_bytes']:.3e}")
        print(f"{r['arch']:24s} {r['shape']:12s} c={r['compute_s']:.2e} "
              f"m={r['memory_s']:.2e} x={r['collective_s']:.2e} -> {r['dominant']}")
    open(os.path.join(RESULTS, "roofline.md"), "w").write("\n".join(md) + "\n")
    open(os.path.join(RESULTS, "roofline.csv"), "w").write("\n".join(csv) + "\n")
    print(f"# wrote results/roofline.md ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    main()
