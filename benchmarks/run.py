"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV rows per the harness contract:
``us_per_call`` is the modeled TPU action latency (microseconds) of the
headline configuration; ``derived`` is the table's headline metric.
Full tables land in results/*.csv.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the reward simulations (tables 1/2/fig1)")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="export the paged serving run as a Chrome trace "
                         "(forwarded to table_paged)")
    args, _ = ap.parse_known_args()

    rows = []

    # --- Table 4: quantization latency ladder (analytic, fast) -----------
    import table4_latency
    t4 = table4_latency.main()
    fp4_14b = next(r for r in t4 if r[0] == "qwen2.5-14b" and r[1] == "FP4")
    rows.append(("table4_latency", float(fp4_14b[2]) * 1e3,
                 f"fp4_14b_rel={fp4_14b[3]}"))

    # --- Serving fleet: FPX routing vs static engines under traffic ------
    import table_serving
    ts = table_serving.main(verbose=False)
    mixed = [r for r in ts if r[0] == "mixed"]
    fleet = next(r for r in mixed if r[1] == "fleet-fpx")
    best_static = max((r for r in mixed if r[1].startswith("static")),
                      key=lambda r: float(r[8]))
    rows.append(("table_serving", float(fleet[7]) * 1e3,
                 f"goodput={fleet[8]}_vs_static{best_static[8]}"
                 f":hit={fleet[5]}"))

    # --- Paged KV-cache vs wave serving on real compute -------------------
    import table_paged
    tp = table_paged.main(verbose=False, trace_path=args.trace)
    tp_wave = next(r for r in tp if r[0] == "wave")
    tp_paged = next(r for r in tp if r[0] == "paged")
    rows.append(("table_paged", float(tp_paged[6]) * 1e3,
                 f"p99={tp_paged[6]}ms_vs_wave{tp_wave[6]}ms"
                 f":goodput={tp_paged[7]}_vs_{tp_wave[7]}"))

    # --- Chunked prefill vs stall-prefill paged serving -------------------
    import table_chunked
    tch = table_chunked.main(verbose=False)
    tc_stall = next(r for r in tch
                    if r[0] == "stall" and r[1] == "trading")
    tc_chunk = next(r for r in tch
                    if r[0] == "chunked" and r[1] == "trading")
    tc_all_s = next(r for r in tch if r[0] == "stall" and r[1] == "all")
    tc_all_c = next(r for r in tch if r[0] == "chunked" and r[1] == "all")
    rows.append(("table_chunked", float(tc_chunk[7]) * 1e3,
                 f"trading_p99={tc_chunk[7]}ms_vs_stall{tc_stall[7]}ms"
                 f":goodput={tc_all_c[8]}_vs_{tc_all_s[8]}"))

    # --- Fused paged flash-attention vs gather+SDPA (decode hot path) -----
    import table_paged_attn
    tpa_rows, tpa_flow = table_paged_attn.main(verbose=False)
    tpa_by = {(r[0], int(r[1]), int(r[2])): r for r in tpa_rows}
    f_row = tpa_by[("fused", 4096, 4)]
    g_row = tpa_by[("gather", 4096, 4)]
    rows.append(("table_paged_attn", float(f_row[4]),
                 f"step={f_row[4]}us_vs_gather{g_row[4]}us"
                 f":goodput={tpa_flow['fused'][0]:.0f}"
                 f"_vs_{tpa_flow['gather'][0]:.0f}"))

    # --- Hybrid sliding-window paged serving ------------------------------
    import table_hybrid
    th_rows, th_good = table_hybrid.main(verbose=False)
    th_by = {(r[0], r[1], int(r[2])): r for r in th_rows if r[0] == "attn"}
    w16k = th_by[("attn", "windowed", 16384)]
    d16k = th_by[("attn", "dense", 16384)]
    rows.append(("table_hybrid", float(w16k[5]),
                 f"step={w16k[5]}us_vs_dense{d16k[5]}us"
                 f":goodput={th_good['hybrid-pool']:.1f}"
                 f"_vs_{th_good['dense-pool']:.1f}"))

    # --- Session serving: prefix reuse + TTFT SLOs vs cold starts ---------
    import table_sessions
    tse = table_sessions.main(verbose=False)
    tse_by = {r[0]: r for r in tse}
    sh, ns = tse_by["sharing"], tse_by["no-sharing"]
    rows.append(("table_sessions", float(sh[7]) * 1e3,
                 f"ttft_p50={sh[7]}ms_vs_cold{ns[7]}ms"
                 f":goodput={sh[10]}_vs_{ns[10]}"))

    # --- Fault injection: token-exact recovery vs stranding ---------------
    import table_faults
    tf = table_faults.main(verbose=False)
    tf_by = {r[0]: r for r in tf}
    rec, nv = tf_by["recovering"], tf_by["naive"]
    rows.append(("table_faults", float(rec[7]),
                 f"goodput={rec[8]}_vs_naive{nv[8]}"
                 f":retried={rec[4]}:ceiling={tf_by['ceiling'][8]}"))

    # --- Sharded fleet: tensor parallelism + link-aware routing -----------
    import table_sharded
    tsh = table_sharded.main(verbose=False)
    tsh_by = {r[0]: r for r in tsh}
    shd, rep = tsh_by["sharded-tp8"], tsh_by["fallback-tp1"]
    aware, blind = tsh_by["net-aware"], tsh_by["net-blind"]
    rows.append(("table_sharded", float(shd[9]) * 1e3,
                 f"goodput={shd[10]}_vs_tp1{rep[10]}"
                 f":aware={aware[10]}_vs_blind{blind[10]}"))

    # --- Speculative decoding: learned draft depth vs dense/fixed-k -------
    import table_spec
    tsp = table_spec.main(verbose=False)
    tsp_by = {(r[0], r[1]): r for r in tsp}
    sp_l = tsp_by[("mixed", "spec-learned")]
    sp_d = tsp_by[("mixed", "dense")]
    sp_best_fixed = max((r for (m, a), r in tsp_by.items()
                         if m == "mixed" and a.startswith("fixed-")),
                        key=lambda r: float(r[8]))
    rows.append(("table_spec", float(sp_l[7]) * 1e3,
                 f"goodput={sp_l[8]}_vs_dense{sp_d[8]}"
                 f"_vs_{sp_best_fixed[1]}{sp_best_fixed[8]}"
                 f":itl={sp_l[9]}ms"))

    # --- Roofline table (from dry-run artifacts) --------------------------
    import roofline
    rl = roofline.main()
    if rl:
        dom = max(rl, key=lambda r: max(r["compute_s"], r["memory_s"],
                                        r["collective_s"]))
        worst_term = max(dom["compute_s"], dom["memory_s"], dom["collective_s"])
        rows.append(("roofline", worst_term * 1e6,
                     f"worst={dom['arch']}/{dom['shape']}:{dom['dominant']}"))

    if not args.fast:
        # --- Table 1: HFT daily yield + SF ELO ----------------------------
        import table1_hft
        t1h = table1_hft.main()
        best = t1h[0]
        rows.append(("table1_hft", float(best[2]) * 1e3,
                     f"best={best[0]}:yield={best[4]}%"))

        import table1_sf
        ratings = table1_sf.main()
        top = max(ratings, key=ratings.get)
        rows.append(("table1_sf", 0.0, f"best={top}:elo={ratings[top]:.1f}"))

        # --- Table 2: gamma sweeps ----------------------------------------
        import table2_gamma
        hft_rows, sf_rows = table2_gamma.main()
        best_g = max(hft_rows, key=lambda r: float(r[3]))
        rows.append(("table2_hft_gamma", float(best_g[1]) * 1e3,
                     f"gamma*={best_g[0]}:yield={best_g[3]}%"))
        best_g = max(sf_rows, key=lambda r: float(r[3]))
        rows.append(("table2_sf_gamma", float(best_g[1]) * 1e3,
                     f"gamma*={best_g[0]}:winrate={best_g[3]}%"))

        # --- Figure 1 curves ----------------------------------------------
        import fig1_tradeoff
        fig1_tradeoff.main()
        rows.append(("fig1_tradeoff", 0.0, "curves=results/fig1*.csv"))

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
