"""Paper Table 1 (top): HFTBench daily yield across model sizes x precision.

Candidates mirror the paper's reported set: {14B, 7B} x {FP16, FP8, FPX-best}
plus the smaller models.  FPX gamma per model is chosen by the Table-2 sweep
(best daily yield) — the paper reports "the best-performing setting".
"""
from __future__ import annotations

import sys

import numpy as np

from common import (LADDER, N_ACT, build_ladder, make_spec, task_teacher,
                    write_table, PROMPT_LEN)

sys.path.insert(0, "src")
from repro.bench import agents as ag
from repro.bench.env import Teacher
from repro.bench.hft import HFTBench, run_session
from repro.models.modules import ExecContext

SESSIONS = 6          # trading days averaged


def agent_yield(spec: ag.AgentSpec, *, sessions: int = SESSIONS) -> float:
    env = HFTBench()
    agent = ag.LLMAgent(spec, n_actions=3)
    ys = [run_session(env, agent, seed=s)["daily_yield"]
          for s in range(sessions)]
    return float(np.mean(ys))


def main(gammas=(0.1, 0.2, 0.3)) -> list:
    ladder = build_ladder("hft")
    teacher = task_teacher("hft")
    rows = []
    for sim in LADDER:
        cands = [make_spec("hft", sim, ladder, gamma=None, bits=16),
                 make_spec("hft", sim, ladder, gamma=None, bits=8)]
        # FPX: best gamma per model (paper protocol)
        fpx = [make_spec("hft", sim, ladder, gamma=g) for g in gammas]
        best, best_y = None, -1e9
        for s in fpx:
            y = agent_yield(s, sessions=3)
            if y > best_y:
                best, best_y = s, y
        cands.append(best)
        for spec in cands:
            agent = ag.LLMAgent(spec, n_actions=3)
            y = agent_yield(spec)
            acc = ag.eval_decision_accuracy(
                spec.params, spec.sim_cfg, teacher,
                ctx=ExecContext(policy=spec.policy,
                                default_bits=spec.default_bits),
                prompt_len=PROMPT_LEN["hft"], n_actions=N_ACT["hft"])
            rows.append([spec.name, f"{spec.avg_bits:.1f}",
                         f"{agent.latency_s*1e3:.0f}",
                         f"{acc:.3f}", f"{y:.2f}"])
            print(f"{spec.name:18s} bits={spec.avg_bits:4.1f} "
                  f"acc={acc:.3f} yield={y:+.2f}%")
    rows.sort(key=lambda r: -float(r[-1]))
    write_table("results/table1_hft.csv",
                ["model", "bitwidth_avg", "latency_ms", "decision_acc",
                 "daily_yield_pct"], rows)
    return rows


if __name__ == "__main__":
    main()
