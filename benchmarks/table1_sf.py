"""Paper Table 1 (bottom) / Table 3: StreetFighter ELO tournament.

Round-robin over (model size x precision) agents, paper protocol: matches
per pairing, ELO updated per round.
"""
from __future__ import annotations

import sys

import numpy as np

from common import LADDER, build_ladder, make_spec, write_table

sys.path.insert(0, "src")
from repro.bench import agents as ag, elo
from repro.bench.streetfighter import SFGame, play_match

ROUNDS_PER_PAIR = 8      # paper: 40 matches per pairing; 8 keeps CPU tractable
                         # (each "round" here is a best-of-3 match)


def main(gammas=(0.2, 0.3)) -> dict:
    ladder = build_ladder("sf")
    specs = []
    for sim in LADDER:
        specs.append(make_spec("sf", sim, ladder, gamma=None, bits=16))
        specs.append(make_spec("sf", sim, ladder, gamma=None, bits=8))
        for g in gammas:
            specs.append(make_spec("sf", sim, ladder, gamma=g))
    agents = [ag.LLMAgent(s, n_actions=5) for s in specs]
    names = [s.name for s in specs]

    def play(i: int, j: int, seed: int) -> float:
        w = play_match(agents[i], agents[j], rounds=1, seed=seed)
        return 1.0 if w == 0 else 0.0

    ratings = elo.tournament(names, play, rounds_per_pair=ROUNDS_PER_PAIR)
    rows = sorted(
        ([n, f"{s.avg_bits:.1f}", f"{agents[k].latency_s*1e3:.0f}",
          f"{ratings[n]:.2f}"]
         for k, (n, s) in enumerate(zip(names, specs))),
        key=lambda r: -float(r[-1]))
    for r in rows:
        print(f"{r[0]:18s} bits={r[1]:>4} lat={r[2]:>5}ms ELO={r[3]:>8}")
    write_table("results/table1_sf.csv",
                ["model", "bitwidth_avg", "latency_ms", "elo"], rows)
    return ratings


if __name__ == "__main__":
    main()
