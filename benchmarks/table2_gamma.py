"""Paper Table 2: performance under compression levels (gamma sweep).

HFTBench with the 14B-class model and StreetFighter (vs the FP16 3B) with
the 3B-class model, sweeping gamma over {0, 0.2, 0.3, 0.4, 0.6, 0.8, 1.0}.
Reports modeled latency, decision quality, and task reward — the paper's
interior-optimum claim (gamma* > 0, task-dependent) is the check.
"""
from __future__ import annotations

import sys

import numpy as np

from common import (N_ACT, PROMPT_LEN, build_ladder, make_spec, task_teacher,
                    write_table)

sys.path.insert(0, "src")
from repro.bench import agents as ag
from repro.bench.hft import HFTBench, run_session
from repro.bench.streetfighter import play_match
from repro.core import latency as lat_mod
from repro.models.modules import ExecContext

GAMMAS = (0.0, 0.2, 0.3, 0.4, 0.6, 0.8, 1.0)


def hft_sweep(ladder) -> list:
    teacher = task_teacher("hft")
    rows = []
    for g in GAMMAS:
        spec = make_spec("hft", "qwen-sim-14b", ladder, gamma=g)
        agent = ag.LLMAgent(spec, n_actions=3)
        env = HFTBench()
        y = float(np.mean([run_session(env, agent, seed=s)["daily_yield"]
                           for s in range(4)]))
        acc = ag.eval_decision_accuracy(
            spec.params, spec.sim_cfg, teacher,
            ctx=ExecContext(policy=spec.policy, default_bits=spec.default_bits),
            prompt_len=PROMPT_LEN["hft"], n_actions=3)
        rows.append([f"{g:.1f}", f"{agent.latency_s*1e3:.0f}",
                     f"{acc:.3f}", f"{y:.2f}"])
        print(f"HFT 14B gamma={g:.1f}: lat={agent.latency_s*1e3:.0f}ms "
              f"acc={acc:.3f} yield={y:+.2f}%")
    return rows


def sf_sweep(ladder) -> list:
    teacher = task_teacher("sf")
    ref = ag.LLMAgent(make_spec("sf", "qwen-sim-3b", ladder, gamma=None,
                                bits=16), n_actions=5)
    rows = []
    for g in GAMMAS:
        spec = make_spec("sf", "qwen-sim-3b", ladder, gamma=g)
        agent = ag.LLMAgent(spec, n_actions=5)
        wins = sum(play_match(agent, ref, rounds=1, seed=s) == 0
                   for s in range(16))
        acc = ag.eval_decision_accuracy(
            spec.params, spec.sim_cfg, teacher,
            ctx=ExecContext(policy=spec.policy, default_bits=spec.default_bits),
            prompt_len=PROMPT_LEN["sf"], n_actions=5)
        rows.append([f"{g:.1f}", f"{agent.latency_s*1e3:.0f}",
                     f"{acc:.3f}", f"{100*wins/16:.1f}"])
        print(f"SF 3B gamma={g:.1f}: lat={agent.latency_s*1e3:.0f}ms "
              f"acc={acc:.3f} winrate={100*wins/16:.1f}%")
    return rows


def main():
    hft_rows = hft_sweep(build_ladder("hft"))
    sf_rows = sf_sweep(build_ladder("sf"))
    write_table("results/table2_hft_gamma.csv",
                ["gamma", "latency_ms", "decision_acc", "daily_yield_pct"],
                hft_rows)
    write_table("results/table2_sf_gamma.csv",
                ["gamma", "latency_ms", "decision_acc", "winrate_pct"],
                sf_rows)
    return hft_rows, sf_rows


if __name__ == "__main__":
    main()
