"""Paper Table 4: per-action latency across quantization schemes.

Derived from the analytic TPU-v5e roofline latency model (core.latency):
FP16 / FP8 / W4A16(int) / FP4 for each Qwen2.5 size.  The validation target
is the paper's *ordering and ratios* (FP8 ~ 0.55x FP16, FP4 ~ 0.3x, W4A16
worse than FP8 and relatively worst for small models), not RTX-5090
milliseconds.
"""
from __future__ import annotations

import sys

from common import write_table

sys.path.insert(0, "src")
from repro.configs import QWEN_FULL
from repro.core import latency as lat_mod

#: paper Table 4 (RTX 5090, ms) for ratio comparison
PAPER = {
    "qwen2.5-1.5b": {"FP16": 203, "FP8": 142, "W4A16(int)": 254, "FP4": 83},
    "qwen2.5-3b": {"FP16": 349, "FP8": 222, "W4A16(int)": 323, "FP4": 147},
    "qwen2.5-7b": {"FP16": 619, "FP8": 394, "W4A16(int)": 537, "FP4": 248},
    "qwen2.5-14b": {"FP16": 1302, "FP8": 801, "W4A16(int)": 792, "FP4": 492},
}


def main():
    rows = []
    for name, cfg in QWEN_FULL.items():
        ours = lat_mod.quant_ladder(cfg)
        for scheme, t in ours.items():
            ours_rel = t / ours["FP16"]
            paper_rel = PAPER[name][scheme] / PAPER[name]["FP16"]
            rows.append([name, scheme, f"{t*1e3:.0f}", f"{ours_rel:.2f}",
                         f"{paper_rel:.2f}"])
            print(f"{name:14s} {scheme:12s} {t*1e3:7.0f} ms   "
                  f"rel={ours_rel:.2f} (paper rel={paper_rel:.2f})")
    write_table("results/table4_latency.csv",
                ["model", "scheme", "latency_ms_tpu", "rel_fp16_ours",
                 "rel_fp16_paper"], rows)
    return rows


if __name__ == "__main__":
    main()
