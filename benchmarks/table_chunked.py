"""Stall-prefill vs chunked-prefill paged serving: the head-of-line table.

Replays one seeded bursty trading+chat mix through the *same*
:class:`~repro.serving.paged_engine.ContinuousEngine` twice:

* ``stall``   — monolithic prefill (``prefill_chunk=None``): every chat
  prompt admission stalls all decode lanes for the full prompt, exactly
  the head-of-line blocking PR 2's ROADMAP flagged.
* ``chunked`` — ``prefill_chunk=CHUNK``: prompts are absorbed page-aligned
  chunks at a time through ``transformer.prefill_chunk``, one real decode
  step for the active lanes landing between chunks.

The mix is the paper's latency-sensitive regime: *trading* requests (short
prompts, tens-of-ms deadlines, bursty arrivals) share the engine with
*chat* requests (long, compute-bound prompts, loose deadlines) whose
prefills are the stall.  Both paths serve every request to its full budget
(``policy="serve"``), so they emit the *same greedy tokens*; the table
isolates what monolithic prefill costs: higher trading p99 and lower
goodput at equal work.  Chunking re-pays the weight read per chunk — total
prefill cost is ~20% higher — and still wins, which is the point: the tail
is made of stalls, not of work.

Run:  PYTHONPATH=src python benchmarks/table_chunked.py
Writes results/table_chunked.csv.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving.continuous import LatencyProfile
from repro.serving.paged_engine import ContinuousEngine
from repro.serving.scheduler import Request

from common import write_table, RESULTS

SIM_MODEL = "qwen-sim-1.5b"       # real compute at sim scale
LAT_MODEL = "qwen2.5-1.5b"        # the clock: full-scale roofline latency
AVG_BITS = 8.0
SLOTS = 4
PAGE = 16
CHUNK = 256                       # compute-bound chunk: overhead stays ~20%
MAX_CTX = 4224

TRADE_PROMPT = 32                 # single bucket per class bounds compiles
TRADE_NEW = 4
CHAT_PROMPT = 4096                # compute-bound: a ~32ms monolithic stall
CHAT_NEW = 8
N_TRADE = 32
SEED = 11


def make_requests(profile: LatencyProfile):
    """Seeded bursty mix: steady short-deadline trading arrivals with long
    chat prompts landing on top — the barrier's worst case, because every
    chat admission stalls a monolithic engine for a prefill longer than a
    trading request's whole deadline slack."""
    rng = np.random.default_rng(SEED)
    cfg = get_config(SIM_MODEL)
    svc_t = profile.service_s(TRADE_PROMPT, TRADE_NEW)
    reqs, t = [], 0.0
    rate_hz = 0.30 * SLOTS / svc_t           # ~30% of continuous capacity...
    for _ in range(N_TRADE):
        t += rng.exponential(1.0 / rate_hz)
        reqs.append(Request(
            rid=-1, cls_name="trading",
            prompt=rng.integers(0, cfg.vocab, TRADE_PROMPT).astype(np.int32),
            max_new=TRADE_NEW,
            deadline_s=float(rng.uniform(2.8, 4.2)) * svc_t,
            t_arrive=t))
    horizon = t
    svc_c = profile.service_s(CHAT_PROMPT, CHAT_NEW)
    for burst_at in (0.2, 0.45, 0.7):        # ...plus chat arrivals on top
        reqs.append(Request(
            rid=-1, cls_name="chat",
            prompt=rng.integers(0, cfg.vocab, CHAT_PROMPT).astype(np.int32),
            max_new=CHAT_NEW,
            deadline_s=float(rng.uniform(3.0, 5.0)) * svc_c,
            t_arrive=burst_at * horizon))
    reqs.sort(key=lambda r: r.t_arrive)
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


def run_engine(params, cfg, profile, reqs, prefill_chunk):
    pe = ContinuousEngine(params, cfg, slots=SLOTS, page_size=PAGE,
                          max_ctx=MAX_CTX, policy="serve", profile=profile,
                          prefill_chunk=prefill_chunk)
    for r in reqs:
        pe.submit(r)
    pe.run()
    return reqs


def summarize(path, reqs, cls=None):
    sel = [r for r in reqs if cls is None or r.cls_name == cls]
    done = [r for r in sel if r.t_finish is not None and not r.dropped]
    lats = np.asarray([r.latency_s for r in done])
    hit = sum(bool(r.met_deadline) for r in sel) / len(sel)
    goodput = sum(r.reward_weight for r in done if r.met_deadline)
    return [path, cls or "all", len(sel), len(done),
            int(sum(r.tokens_done for r in done)), f"{hit:.3f}",
            f"{np.percentile(lats, 50) * 1e3:.2f}",
            f"{np.percentile(lats, 99) * 1e3:.2f}", f"{goodput:.1f}"]


def main(verbose: bool = True):
    cfg = get_config(SIM_MODEL)
    profile = LatencyProfile(get_config(LAT_MODEL), AVG_BITS)
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    stall = run_engine(params, cfg, profile, make_requests(profile), None)
    chunked = run_engine(params, cfg, profile, make_requests(profile), CHUNK)
    # identical greedy work: the comparison is purely about time
    stall_toks = {r.rid: r.result_tokens for r in stall}
    for r in chunked:
        assert np.array_equal(stall_toks[r.rid], r.result_tokens), \
            f"request {r.rid}: stall and chunked tokens diverged"

    rows = []
    for cls in ("all", "trading", "chat"):
        sel = None if cls == "all" else cls
        rows.append(summarize("stall", stall, sel))
        rows.append(summarize("chunked", chunked, sel))
    if verbose:
        for row in rows:
            print(f"{row[0]:8s} {row[1]:8s} n={row[2]:3d} served={row[3]:3d} "
                  f"tokens={row[4]:4d} hit={row[5]} p50={row[6]}ms "
                  f"p99={row[7]}ms goodput={row[8]}")
    # acceptance: same tokens (asserted above), better tail for the
    # latency-sensitive class, no less goodput overall.  (Chat's own p99 is
    # *higher* chunked — its prefill spreads out and re-pays weight reads —
    # which is the trade: chat budgets are seconds, trading budgets are the
    # tail being protected.)
    s_tr = next(r for r in rows if r[0] == "stall" and r[1] == "trading")
    c_tr = next(r for r in rows if r[0] == "chunked" and r[1] == "trading")
    s_all = next(r for r in rows if r[0] == "stall" and r[1] == "all")
    c_all = next(r for r in rows if r[0] == "chunked" and r[1] == "all")
    assert float(c_tr[7]) < float(s_tr[7]), \
        f"chunked trading p99 {c_tr[7]}ms not below stall's {s_tr[7]}ms"
    assert float(c_all[8]) >= float(s_all[8]), \
        f"chunked goodput {c_all[8]} below stall goodput {s_all[8]}"
    write_table(os.path.join(RESULTS, "table_chunked.csv"),
                ["path", "class", "offered", "served", "tokens", "hit_rate",
                 "p50_ms", "p99_ms", "goodput"], rows)
    return rows


if __name__ == "__main__":
    main()
