"""Fault-recovery table: token-exact recovery vs stranding under one schedule.

The same seeded traffic — a slack-rich "agent" class (long decodes,
8-15s deadlines: the work a crash can strand but a redo can still save)
plus a deadline-tight "interactive" class — is replayed through the same
four-engine demo fleet under the same seeded fault schedule (crashes,
stalls, slowdowns), three ways:

* ``ceiling``      — no faults: what the schedule costs everyone;
* ``naive``        — faults with ``recover=False``: crashes are detected
                     (the breaker still opens, routing steers around the
                     outage) but reclaimed in-flight work is stranded —
                     dropped, never retried;
* ``recovering``   — full recovery: reclaimed work re-dispatches across
                     the healthy fleet as fresh attempts, token-identical
                     to the attempt that died, judged against the
                     *original* deadline;
* ``recovering+hedge`` — recovery plus hedged dispatch (duplicate a
                     request stuck in queue; first finisher wins).

The claims the regression gate re-checks from this CSV: **recovering
goodput is strictly above naive** under the identical schedule (what
token-exact recovery is worth), both fault rows sit at or below the
ceiling (injected faults cannot help), and recovering drops no more
requests than naive.

The clock is the deterministic analytic roofline and the fault schedule
is seeded, so the CSV is byte-reproducible and committed as a baseline.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serving import metrics, traffic
from repro.serving.faults import FaultInjector, generate_plan
from repro.serving.fleet import FleetRouter, demo_pool, demo_quality

from common import write_table, RESULTS

HORIZON_S = 20.0
PLAN_SEED = 3            # crashes land on *busy* engines (work to strand)
TRAFFIC_SEED = 7
HEDGE_DELAY_S = 1.0

CLASSES = [
    # long decodes with real slack: strandable, but a redo still meets
    # the deadline — the reward recovery exists to save
    traffic.TrafficClass("agent", rate_hz=3.0, deadline_range_s=(8.0, 15.0),
                         prompt_range=(128, 256), max_new_range=(48, 96),
                         reward_weight=2.0),
    # tight SLOs: a redo rarely helps, but stalls/slowdowns bite hard
    traffic.TrafficClass("interactive", rate_hz=10.0,
                         deadline_range_s=(0.5, 2.0),
                         prompt_range=(64, 128), max_new_range=(8, 16)),
]


def fault_plan():
    return generate_plan(4, HORIZON_S, seed=PLAN_SEED, crash_rate=0.15,
                         stall_rate=0.08, slowdown_rate=0.08)


def run_path(plan, *, recover: bool = True, hedge_delay_s=None):
    inj = FaultInjector(plan) if plan is not None else None
    router = FleetRouter(demo_pool(), quality=demo_quality, seed=1,
                         injector=inj, recover=recover,
                         hedge_delay_s=hedge_delay_s)
    arrivals = traffic.generate(CLASSES, HORIZON_S, seed=TRAFFIC_SEED)
    done = router.run([r.fresh() for r in arrivals])
    rep = metrics.summarize(done, HORIZON_S)
    fired = len(inj.fired) if inj is not None else 0
    return rep, done, fired


def main(verbose: bool = True):
    plan = fault_plan()
    paths = [
        ("ceiling", dict(plan=None)),
        ("naive", dict(plan=plan, recover=False)),
        ("recovering", dict(plan=plan)),
        ("recovering+hedge", dict(plan=plan, hedge_delay_s=HEDGE_DELAY_S)),
    ]
    rows = []
    for name, kw in paths:
        plan_arg = kw.pop("plan")
        rep, done, fired = run_path(plan_arg, **kw)
        tokens = sum(r.tokens_done for r in done
                     if not getattr(r, "hedge_loser", False))
        rows.append([name, rep.n, rep.served, rep.dropped, rep.retried,
                     rep.hedged, f"{rep.hit_rate:.3f}",
                     f"{rep.p99_s * 1e3:.1f}", f"{rep.goodput:.1f}",
                     tokens, fired])
        if verbose:
            print(f"{name:17s} n={rep.n:4d} served={rep.served:4d} "
                  f"dropped={rep.dropped:3d} retried={rep.retried:3d} "
                  f"hedged={rep.hedged:3d} hit={rep.hit_rate:.3f} "
                  f"p99={rep.p99_s*1e3:7.1f}ms goodput={rep.goodput:7.1f} "
                  f"faults={fired}")
    write_table(os.path.join(RESULTS, "table_faults.csv"),
                ["path", "offered", "served", "dropped", "retried",
                 "hedged", "hit_rate", "p99_ms", "goodput", "tokens",
                 "faults_fired"], rows)
    by = {r[0]: r for r in rows}
    g = lambda name: float(by[name][8])
    assert g("recovering") > g("naive"), \
        "token-exact recovery did not beat stranding"
    assert g("ceiling") >= g("recovering") and g("ceiling") >= g("naive"), \
        "a faulted fleet out-earned the fault-free ceiling"
    assert int(by["recovering"][3]) <= int(by["naive"][3]), \
        "recovery dropped more requests than stranding"
    assert int(by["recovering"][4]) > 0, "no retries: schedule too gentle"
    return rows


if __name__ == "__main__":
    main()
