"""Sliding-window paged serving: windowed vs dense KV traffic/step time,
and fleet goodput with gemma3-class engines in the pool.

Two claims, one table (``results/table_hybrid.csv``):

1. **Micro (kind=attn).**  At the gemma3-4b deployment point (5:1
   local:global, 1024-token window), the paged path's modeled per-step
   attention time, full decode-step time (``LatencyProfile.step_s`` — what
   admission projections and the router consume), and per-step KV HBM
   bytes, against the *dense-uniform equivalent* of the same stack (the
   window stripped — how the clock priced every stack before the paged
   path learned windows).  Below the window the two agree (the mask is
   inert); beyond it the windowed stack is strictly cheaper, because the
   sliding-window groups' out-of-window pages were freed mid-flight and
   the fused kernel reads only ``min(context, window)`` per local layer.

2. **Fleet (kind=fleet).**  The win flows through admission into goodput:
   the same seeded decode-heavy long-context stream through two FPX fleet
   pools (a slow high-quality qwen2.5-14b anchor plus a gemma3-class fast
   point) differing only in whether the gemma3-class engine gets
   window-aware paging — windowed (the hybrid paged path as shipped) vs
   its dense equivalent (every local layer paying full-context KV
   traffic, the only way to serve the stack before per-layer-group
   windows).  The windowed engine's cheaper steps admit more work within
   deadline, so the hybrid pool must earn at least the dense pool's
   goodput at identical traffic.

Run:  PYTHONPATH=src python benchmarks/table_hybrid.py
Writes results/table_hybrid.csv (gated by check_regression.py).
"""
from __future__ import annotations

import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get_config
from repro.core import latency as lat_mod
from repro.serving.continuous import LatencyProfile
from repro.serving.fleet import FleetRouter, _synthetic_eps, pool_candidates
from repro.serving.metrics import summarize
from repro.serving.traffic import SimRequest

from common import write_table, RESULTS

LAT_MODEL = "gemma3-4b"           # window 1024, 5 local : 1 global
COMPANION = "qwen2.5-14b"         # the slow, high-quality anchor engine
AVG_BITS = 8.0
CONTEXTS = (256, 1024, 4096, 16384)
LANES = 4

PROMPT = 8192                     # far past the window: windows pay most
MAX_NEW = 64
N_REQS = 48
SEED = 23
QUALITY = {COMPANION: 0.94, LAT_MODEL: 0.80}


def dense_equiv(cfg):
    """The same stack with its windows stripped: every layer priced (and
    paged) as full attention — the pre-hybrid clock."""
    return dataclasses.replace(cfg, sliding_window=None,
                               local_global_ratio=0,
                               name=cfg.name + "-dense-equiv")


def microbench(cfg):
    """kind=attn rows: windowed vs dense-equivalent modeled costs."""
    dcfg = dense_equiv(cfg)
    profiles = {"windowed": LatencyProfile(cfg, AVG_BITS),
                "dense": LatencyProfile(dcfg, AVG_BITS)}
    cfgs = {"windowed": cfg, "dense": dcfg}
    rows = []
    for name in ("windowed", "dense"):
        for ctx in CONTEXTS:
            attn_s = lat_mod.paged_attn_step_s(cfgs[name], n_lanes=LANES,
                                               context=ctx)
            step_s = profiles[name].step_s(LANES, ctx)
            kv = lat_mod.paged_attn_hbm_bytes(cfgs[name], n_lanes=LANES,
                                              context=ctx)
            rows.append(["attn", name, ctx,
                         cfg.sliding_window if name == "windowed" else "",
                         f"{attn_s * 1e6:.2f}", f"{step_s * 1e6:.2f}",
                         f"{kv / 1024:.0f}", "", "", ""])
    return rows


def fleet_goodput(cfg):
    """kind=fleet rows: identical traffic through a pool whose
    gemma3-class engine is priced windowed vs dense."""
    qw = get_config(COMPANION)
    out_rows, goodputs = [], {}
    for label, g3cfg in (("hybrid-pool", cfg),
                         ("dense-pool", dense_equiv(cfg))):
        cands = pool_candidates(
            [(COMPANION, qw, _synthetic_eps(qw), 0.4),
             (LAT_MODEL, g3cfg, _synthetic_eps(g3cfg), 0.4)],
            prompt_len=PROMPT, gen_tokens=MAX_NEW)
        router = FleetRouter(cands,
                             quality=lambda c: QUALITY[c.model_name],
                             slots=LANES, policy="drop")
        # deadline scale: the windowed gemma3 service time — identical
        # across pools so the streams are comparable request-for-request
        svc = LatencyProfile(cfg, AVG_BITS).service_s(PROMPT, MAX_NEW)
        rng = np.random.default_rng(SEED)
        t, arrivals = 0.0, []
        for i in range(N_REQS):
            t += rng.exponential(svc / (0.55 * 2 * LANES))
            arrivals.append(SimRequest(
                rid=i, cls_name="chat", t_arrive=t, prompt_len=PROMPT,
                max_new=MAX_NEW,
                deadline_s=svc * float(rng.uniform(1.4, 2.6))))
        retired = router.run(arrivals)
        rep = summarize(retired, horizon_s=max(r.t_finish or t
                                               for r in retired))
        toks = sum(r.tokens_done for r in retired if not r.dropped)
        out_rows.append(["fleet", label, "", "", "", "", "",
                         f"{rep.goodput:.1f}", f"{rep.p99_s * 1e3:.1f}",
                         toks])
        goodputs[label] = rep.goodput
    return out_rows, goodputs


def main(verbose: bool = True):
    cfg = get_config(LAT_MODEL)
    rows = microbench(cfg)

    # acceptance: windowed never above dense; strictly below past the window
    by = {(r[1], r[2]): r for r in rows}
    for ctx in CONTEXTS:
        w, d = by[("windowed", ctx)], by[("dense", ctx)]
        for i, colname in ((4, "attn_us"), (5, "step_us"), (6, "kv_kib")):
            assert float(w[i]) <= float(d[i]), (colname, ctx)
            if ctx > cfg.sliding_window:
                assert float(w[i]) < float(d[i]), \
                    f"windowed {colname} not strictly below dense at {ctx}"

    fleet_rows, goodputs = fleet_goodput(cfg)
    assert goodputs["hybrid-pool"] >= goodputs["dense-pool"], goodputs
    rows += fleet_rows

    if verbose:
        for r in rows:
            if r[0] == "attn":
                print(f"{r[1]:9s} ctx={r[2]:6d} attn={r[4]:>10s}us "
                      f"step={r[5]:>10s}us kv={r[6]:>8s}KiB")
            else:
                print(f"{r[1]:11s} goodput={r[7]} p99={r[8]}ms "
                      f"tokens={r[9]}")
    write_table(os.path.join(RESULTS, "table_hybrid.csv"),
                ["kind", "name", "context", "window", "attn_us", "step_us",
                 "kv_kib", "goodput", "p99_ms", "tokens"],
                rows)
    return rows, goodputs


if __name__ == "__main__":
    main()
