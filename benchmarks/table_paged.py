"""Wave vs. paged-continuous serving on real compute: the fusion table.

Replays one seeded Poisson arrival stream of identical greedy requests
through the two *real-compute* serving paths:

* ``wave``  — the padded-wave :class:`~repro.serving.scheduler.Scheduler`
  discipline: FIFO waves of up to SLOTS arrived requests, one barrier per
  wave (every request inherits the wave's makespan; a freed lane idles
  until the wave drains).  Tokens come from the actual jit'd model; the
  wave clock charges batched prefill plus the padded decode tail on the
  same ``core.latency`` roofline the engines plan with.
* ``paged`` — the :class:`~repro.serving.paged_engine.ContinuousEngine`:
  EDF admission into free decode lanes between real decode steps over the
  block-table KV cache, pages freed on retire.

Both serve every request to its full budget (``policy="serve"``), so the
two paths emit the *same number of real tokens*; the table isolates what
the barrier costs: higher p99 latency and lower goodput at equal work.

Run:  PYTHONPATH=src python benchmarks/table_paged.py [--trace out.json]
Writes results/table_paged.csv.  With ``--trace``, the paged run also
exports a Chrome/Perfetto trace (lanes, pool gauges, request lifecycle on
the analytic clock) that ``python -m repro.obs.check_trace`` audits.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.obs import Tracer, write_chrome
from repro.serving.continuous import LatencyProfile
from repro.serving.engine import ServingEngine
from repro.serving.paged_engine import ContinuousEngine
from repro.serving.scheduler import Request

from common import write_table, RESULTS

SIM_MODEL = "qwen-sim-1.5b"       # real compute at sim scale
LAT_MODEL = "qwen2.5-1.5b"        # the clock: full-scale roofline latency
AVG_BITS = 8.0
SLOTS = 4
PROMPT_LEN = 24                   # one bucket keeps jit compiles bounded
N_REQS = 28
SEED = 3


def make_requests(profile: LatencyProfile):
    """Seeded Poisson arrivals; deadlines a small multiple of the
    uncontended action latency, so queueing (not service) decides SLOs."""
    rng = np.random.default_rng(SEED)
    cfg = get_config(SIM_MODEL)
    svc = profile.service_s(PROMPT_LEN, 8)
    rate_hz = 0.7 * SLOTS / svc          # ~70% of continuous capacity
    t, reqs = 0.0, []
    for i in range(N_REQS):
        t += rng.exponential(1.0 / rate_hz)
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, PROMPT_LEN).astype(np.int32),
            max_new=int(rng.choice([4, 8])),
            deadline_s=float(rng.uniform(1.5, 4.0)) * svc,
            t_arrive=t))
    return reqs


def run_wave(params, cfg, profile, reqs):
    """FIFO padded waves with a barrier, timed on the analytic clock."""
    eng = ServingEngine(params, cfg, max_ctx=64, avg_bits=AVG_BITS)
    queue = sorted(reqs, key=lambda r: r.t_arrive)
    t = 0.0
    while queue:
        if queue[0].t_arrive > t:
            t = queue[0].t_arrive            # engine idles for next arrival
        wave = [r for r in queue if r.t_arrive <= t][:SLOTS]
        queue = [r for r in queue if r not in wave]
        B = len(wave)
        S = max(r.prompt_len for r in wave)
        M = max(r.max_new for r in wave)
        batch = {"tokens": np.stack([r.prompt for r in wave])}
        res = eng.generate(batch, max_new=M)
        new = np.asarray(res.new_tokens)
        # wave cost: batched prefill + the padded decode tail
        t += profile.prefill_s(B * S) + M * profile.step_s(B, S + M // 2)
        for i, r in enumerate(wave):
            r.result_tokens = new[i, :r.max_new]
            r.tokens_done = r.max_new
            r.t_finish = t                   # the barrier: all share it
            r.latency_s = t - r.t_arrive
            r.met_deadline = r.t_finish <= r.deadline_abs
    return reqs


def run_paged(params, cfg, profile, reqs, tracer=None):
    pe = ContinuousEngine(params, cfg, slots=SLOTS, page_size=8,
                          max_ctx=64, policy="serve", profile=profile,
                          tracer=tracer)
    for r in sorted(reqs, key=lambda r: r.t_arrive):
        pe.submit(r)
    pe.run()
    return reqs


def summarize(path, reqs):
    done = [r for r in reqs if r.t_finish is not None and not r.dropped]
    lats = np.asarray([r.latency_s for r in done])
    hit = sum(bool(r.met_deadline) for r in reqs) / len(reqs)
    goodput = sum(r.reward_weight for r in done if r.met_deadline)
    return [path, len(reqs), len(done), int(sum(r.tokens_done for r in done)),
            f"{hit:.3f}", f"{np.percentile(lats, 50) * 1e3:.2f}",
            f"{np.percentile(lats, 99) * 1e3:.2f}", f"{goodput:.1f}"]


def main(verbose: bool = True, trace_path: str = None):
    cfg = get_config(SIM_MODEL)
    profile = LatencyProfile(get_config(LAT_MODEL), AVG_BITS)
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    tracer = Tracer() if trace_path else None
    wave = run_wave(params, cfg, profile, make_requests(profile))
    paged = run_paged(params, cfg, profile, make_requests(profile),
                      tracer=tracer)
    # equal-length prompts: the two disciplines must emit *identical*
    # tokens per request — the comparison is purely about time
    wave_toks = {r.rid: r.result_tokens for r in wave}
    for r in paged:
        assert np.array_equal(wave_toks[r.rid], r.result_tokens), \
            f"request {r.rid}: wave and paged tokens diverged"

    rows = [summarize("wave", wave), summarize("paged", paged)]
    if verbose:
        for row in rows:
            print(f"{row[0]:6s} n={row[1]:3d} served={row[2]:3d} "
                  f"tokens={row[3]:4d} hit={row[4]} p50={row[5]}ms "
                  f"p99={row[6]}ms goodput={row[7]}")
    write_table(os.path.join(RESULTS, "table_paged.csv"),
                ["path", "offered", "served", "tokens", "hit_rate",
                 "p50_ms", "p99_ms", "goodput"], rows)
    if trace_path:
        write_chrome(tracer.events, trace_path)
        if verbose:
            print(f"wrote {len(tracer.events)} trace events -> {trace_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="export the paged run as a Chrome/Perfetto trace")
    main(trace_path=ap.parse_args().trace)
