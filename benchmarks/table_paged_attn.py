"""Fused paged flash-attention vs gather+SDPA: the decode hot-path table.

Models the per-decode-step attention cost of the two paged-KV attention
implementations on the roofline clock (``core.latency``), across context
lengths and lane occupancies, at the full-scale deployment point:

* ``gather`` — the path the fused kernel replaced: materialize each lane's
  whole *padded* block-table extent as a contiguous copy (pool read +
  buffer write), then run dense masked SDPA over it (read it back): ~3x
  the KV HBM traffic, scaled by ``max_ctx`` rather than the lane's actual
  context.
* ``fused``  — the paged flash-attention kernel
  (``kernels/paged_attention.py``): K/V pages stream pool-direct through
  an online softmax; one read of the *actual* context, no materialized
  copy.

Every row pairs the modeled attention time (``attn_us``), the full decode
step it is part of (``step_us`` via ``LatencyProfile.step_s``, which the
admission projections and the FPX router consume), and the modeled KV HBM
bytes (``hbm_kb``).  The table asserts the fused path *strictly dominates*
at every measured (context, lanes) point, and — the part that matters for
the paper's regime — that the win flows through the admission projections
into end-to-end goodput: the same bursty trading stream is replayed
through two analytic continuous batchers whose only difference is the
profile's ``attn_impl``, and the fused engine must meet at least as many
deadlines.

Run:  PYTHONPATH=src python benchmarks/table_paged_attn.py
Writes results/table_paged_attn.csv.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get_config
from repro.core import latency as lat_mod
from repro.serving.continuous import ContinuousBatcher, LatencyProfile
from repro.serving.traffic import SimRequest

from common import write_table, RESULTS

LAT_MODEL = "qwen2.5-1.5b"        # the clock: full-scale roofline latency
AVG_BITS = 8.0
MAX_CTX = 4096                    # padded block-table extent (table width
PAGE = 16                         # x page size) the gather path pays for
CONTEXTS = (64, 256, 1024, 4096)
LANES = (1, 4, 8)

N_REQS = 40
SEED = 17


def microbench(cfg):
    """Modeled per-step attention/step/bytes rows, fused vs gather."""
    profiles = {
        impl: LatencyProfile(cfg, AVG_BITS, attn_impl=impl,
                             padded_ctx=MAX_CTX)
        for impl in ("gather", "fused")
    }
    rows = []
    for impl in ("gather", "fused"):
        for ctx in CONTEXTS:
            for lanes in LANES:
                attn_s = lat_mod.paged_attn_step_s(
                    cfg, n_lanes=lanes, context=ctx, impl=impl,
                    padded_ctx=MAX_CTX)
                step_s = profiles[impl].step_s(lanes, ctx)
                hbm = lat_mod.paged_attn_hbm_bytes(
                    cfg, n_lanes=lanes, context=ctx, impl=impl,
                    padded_ctx=MAX_CTX)
                rows.append([impl, ctx, lanes, f"{attn_s * 1e6:.2f}",
                             f"{step_s * 1e6:.2f}", f"{hbm / 1024:.0f}"])
    return rows


def goodput_flow(cfg):
    """One seeded trading burst through two analytic engines differing only
    in ``attn_impl``: the cheaper fused step must convert into >= goodput
    (admission projects faster steps -> fewer degrades/drops)."""
    fused_ref = LatencyProfile(cfg, AVG_BITS)
    step = fused_ref.step_s(4, 2048)
    svc = fused_ref.prefill_s(2048) + 8 * step
    out = {}
    for impl in ("gather", "fused"):
        rng = np.random.default_rng(SEED)      # identical stream per impl
        profile = LatencyProfile(cfg, AVG_BITS, attn_impl=impl,
                                 padded_ctx=MAX_CTX)
        cb = ContinuousBatcher(profile, slots=4, policy="drop")
        t = 0.0
        reqs = []
        for i in range(N_REQS):
            t += rng.exponential(svc / (0.45 * 4))
            # deadlines are a small multiple of the *fused* uncontended
            # service time: an engine whose projections price the
            # 3x-padded gather step cannot fit as many of them
            reqs.append(SimRequest(
                rid=i, cls_name="trading", t_arrive=t, prompt_len=2048,
                max_new=8,
                deadline_s=svc * float(rng.uniform(1.5, 2.8))))
        for r in reqs:
            cb.submit(r)
        cb.run()
        done = [r for r in reqs if not r.dropped]
        good = sum(r.reward_weight for r in done if r.met_deadline)
        toks = sum(r.tokens_done for r in done)
        out[impl] = (good, toks)
    return out


def main(verbose: bool = True):
    cfg = get_config(LAT_MODEL)
    rows = microbench(cfg)

    # acceptance: fused strictly dominates at every (context, lanes) point
    by = {(r[0], r[1], r[2]): r for r in rows}
    for ctx in CONTEXTS:
        for lanes in LANES:
            g, f = by[("gather", ctx, lanes)], by[("fused", ctx, lanes)]
            assert float(f[3]) < float(g[3]), \
                f"fused attn not below gather at ctx={ctx} lanes={lanes}"
            assert float(f[4]) < float(g[4]), \
                f"fused step not below gather at ctx={ctx} lanes={lanes}"
            assert float(f[5]) < float(g[5]), \
                f"fused bytes not below gather at ctx={ctx} lanes={lanes}"

    flow = goodput_flow(cfg)
    assert flow["fused"][0] >= flow["gather"][0], \
        f"fused goodput {flow['fused'][0]} below gather {flow['gather'][0]}"

    if verbose:
        for r in rows:
            print(f"{r[0]:6s} ctx={r[1]:5d} lanes={r[2]} attn={r[3]:>9s}us "
                  f"step={r[4]:>9s}us hbm={r[5]:>7s}KiB")
        for impl, (good, toks) in flow.items():
            print(f"goodput[{impl}] = {good:.1f} ({toks} tokens)")
    write_table(os.path.join(RESULTS, "table_paged_attn.csv"),
                ["impl", "context", "lanes", "attn_us", "step_us", "hbm_kb"],
                rows)
    return rows, flow


if __name__ == "__main__":
    main()
