"""Serving-fleet table: FPX routing vs static engines under live traffic.

For each traffic mix (trading / chat / mixed) we replay the same seeded
arrival stream through:

* ``fleet-fpx``    — the pool of distinct (model, gamma) operating points
                     routed by ``fpx.select_for_slack`` (the tentpole);
* ``fleet-bandit`` — same pool, routed purely by the per-class
                     ``OnlineSelector`` learning from realized reward;
* ``static-*``     — every single operating point replicated to the same
                     engine count (equal capacity), i.e. the "deploy one
                     quantization setting everywhere" baselines.

Reported: deadline hit-rate, p50/p99 modeled latency, and goodput (reward
earned by on-time actions only).  The paper's claim at traffic scale: on
heterogeneous traffic no single operating point wins — the router beats
every static baseline because tight-budget requests need the small/high-
gamma points while loose-budget requests waste quality on them.

Quality per operating point is an analytic proxy (the sim-scale ladder's
quality ordering with the paper's mild gamma degradation), not a trained
eval — this table isolates the *routing* question, and regenerating the
trained ladder's accuracy table is tables 1/2's job.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serving import FleetRouter, metrics, traffic
from repro.serving.fleet import demo_pool, demo_quality

from common import write_table, RESULTS

HORIZON_S = 20.0
SLOTS = 4


def run_router(cands, arrivals, *, mode: str = "fpx", seed: int = 0):
    router = FleetRouter(cands, quality=demo_quality, slots=SLOTS, mode=mode,
                         seed=seed)
    out = router.run([a.fresh() for a in arrivals])
    return metrics.summarize(out, HORIZON_S)


def main(seed: int = 1, verbose: bool = True):
    cands = demo_pool()
    rows = []
    for mix in traffic.SCENARIOS:
        arrivals = traffic.generate(traffic.scenario(mix), HORIZON_S,
                                    seed=seed)
        reports = {"fleet-fpx": run_router(cands, arrivals, seed=seed),
                   "fleet-bandit": run_router(cands, arrivals, mode="bandit",
                                              seed=seed)}
        for c in cands:
            name = f"static-{c.model_name.replace('qwen2.5-', '')}-g{c.gamma:g}"
            reports[name] = run_router([c] * len(cands), arrivals, seed=seed)
        for name, rep in reports.items():
            rows.append([mix, name] + rep.format_row())
            if verbose:
                print(f"{mix:8s} {name:18s} n={len(arrivals):4d} "
                      f"hit={rep.hit_rate:.3f} p50={rep.p50_s*1e3:7.1f}ms "
                      f"p99={rep.p99_s*1e3:7.1f}ms goodput={rep.goodput:7.1f}")
    write_table(os.path.join(RESULTS, "table_serving.csv"),
                ["mix", "router", "offered", "served", "dropped",
                 "hit_rate", "p50_ms", "p99_ms", "goodput"], rows)
    return rows


if __name__ == "__main__":
    main()
