"""Session serving table: prefix reuse + TTFT-first admission vs cold starts.

The same seeded session traffic — multi-turn conversations over a shared
system prompt, with think-time gaps, streaming TTFT SLOs, and barge-in
cancellation — is replayed through the analytic ``ContinuousBatcher`` at
equal capacity:

* ``sharing``    — prefix cache on: a turn's system prompt and its own
                   previous turns are warm, so admission charges (and the
                   clock pays) only the remainder prefill;
* ``no-sharing`` — the same engine with the cache off: every turn
                   re-prefills its whole accumulated prompt.

Reported per path: offered/served/cancelled counts, completion-deadline
hit rate, TTFT hit rate (first token within the streaming SLO), TTFT
p50/p99, completion p99, and goodput.  The claims the regression gate
re-checks from this CSV: **sharing's TTFT p50 is strictly below
no-sharing's**, and sharing's goodput is at least no-sharing's — reusing
a warm prefix can only remove prefill work.

The clock is the deterministic analytic roofline (same contract as
table_serving/table_chunked), so the CSV is byte-reproducible and
committed as a baseline.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.serving import metrics, traffic
from repro.serving.continuous import ContinuousBatcher, LatencyProfile

from common import write_table, RESULTS

HORIZON_S = 30.0
SLOTS = 4
RATE_HZ = 3.0


def _reward(req) -> None:
    """The fleet's reward rule for a single engine (quality term 1): an
    on-time request earns its weight scaled by the fraction of its token
    budget it streamed — barge-in turns whose first token arrived before
    the deadline earn their partial fraction."""
    if req.met_deadline and not req.dropped and req.max_new:
        req.reward = req.reward_weight * (req.tokens_done / req.max_new)


def run_path(profile, arrivals, *, prefix_cache: bool):
    b = ContinuousBatcher(profile, slots=SLOTS, policy="degrade",
                          prefix_cache=prefix_cache, on_retire=_reward)
    for r in arrivals:
        b.submit(r.fresh())
    b.run()
    done = b.completed + b.dropped
    return metrics.summarize(done, HORIZON_S), done


def main(seed: int = 1, verbose: bool = True):
    # the 14b point at full precision: slow enough that session bursts
    # queue on 4 slots, so TTFT budgets and barge-in actually bite
    profile = LatencyProfile(get_config("qwen2.5-14b"), 16.0)
    arrivals = traffic.generate_sessions(
        [traffic.support_sessions(rate_hz=RATE_HZ)], HORIZON_S, seed=seed)
    rows = []
    for name, on in (("sharing", True), ("no-sharing", False)):
        rep, done = run_path(profile, arrivals, prefix_cache=on)
        tokens = sum(r.tokens_done for r in done)
        rows.append([name, rep.n, rep.served, rep.dropped, rep.cancelled,
                     f"{rep.hit_rate:.3f}", f"{rep.ttft_hit_rate:.3f}",
                     f"{rep.ttft_p50_s * 1e3:.2f}",
                     f"{rep.ttft_p99_s * 1e3:.2f}",
                     f"{rep.p99_s * 1e3:.1f}", f"{rep.goodput:.1f}", tokens])
        if verbose:
            print(f"{name:10s} n={rep.n:4d} served={rep.served:4d} "
                  f"cancelled={rep.cancelled:3d} hit={rep.hit_rate:.3f} "
                  f"ttft_hit={rep.ttft_hit_rate:.3f} "
                  f"ttft_p50={rep.ttft_p50_s*1e3:6.2f}ms "
                  f"p99={rep.p99_s*1e3:7.1f}ms goodput={rep.goodput:7.1f}")
    write_table(os.path.join(RESULTS, "table_sessions.csv"),
                ["path", "offered", "served", "dropped", "cancelled",
                 "hit_rate", "ttft_hit_rate", "ttft_p50_ms", "ttft_p99_ms",
                 "p99_ms", "goodput", "tokens"], rows)
    share = dict(zip([r[0] for r in rows], rows))
    assert float(share["sharing"][7]) < float(share["no-sharing"][7]), \
        "prefix sharing did not cut TTFT p50"
    assert float(share["sharing"][10]) >= float(share["no-sharing"][10]), \
        "prefix sharing lost goodput"
    return rows


if __name__ == "__main__":
    main()
