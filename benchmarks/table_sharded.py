"""Sharded-fleet table: tensor parallelism vs replication at equal chip
capacity, and DCN/ICI-aware routing vs link-blind routing.

All four arms replay the same seeded deadline-tight decision traffic
(dbrx-132b-class engine, analytic clock) through a
:class:`~repro.serving.fleet.FleetRouter` whose operating points are
pinned to :class:`~repro.launch.placement.Placement`\\ s on a simulated
:class:`~repro.launch.placement.Topology`:

* ``sharded-tp8``  — ONE engine spanning all 8 chips of a host
                     tensor-parallel: per-chip compute/bandwidth divide
                     by 8, every forward pays the per-layer all-reduce
                     tax over ICI.  Steps get ~6x faster, so deadlines
                     that are physically unreachable at tp=1 are met.
* ``fallback-tp1`` — the same 8 chips as 8 single-chip replicas: more
                     aggregate throughput, but every replica steps at
                     the full ~30ms/token — the deadline range here is
                     chosen so that pace can only deliver a truncated
                     (degraded) decision.  Equal capacity, lower goodput:
                     the paper's win-fast argument applied to placement.
* ``net-aware``    — a two-engine pool (tp=8 on one host's ICI, tp=16
                     spanning hosts over DCN) routed with the true
                     collective-taxed profiles: the router sees that the
                     DCN-spanning group pays ~60ms/token in all-reduces
                     and steers around it.
* ``net-blind``    — the identical pool priced with the collective-free
                     ``net_blind()`` twins: 16 chips *look* faster than
                     8, so the router prefers the DCN-spanning engine —
                     the physics still bites (applied at dispatch), and
                     the mispricing shows up as goodput lost.

The regression gate re-checks both orderings from the committed CSV:
``sharded-tp8 > fallback-tp1`` (sharding wins at equal capacity) and
``net-aware > net-blind`` (repricing the link wins goodput).

The clock is the deterministic analytic roofline, so the CSV is
byte-reproducible and committed as a baseline.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.launch.placement import Topology, placements_summary
from repro.serving import metrics, traffic
from repro.serving.fleet import (FleetRouter, _synthetic_eps,
                                 pool_candidates)

from common import write_table, RESULTS

ARCH = "dbrx-132b"
HORIZON_S = 15.0
TRAFFIC_SEED = 11
SLOTS = 4

#: deadline window between the tp=8 service time (~0.07s: met with slack)
#: and the tp=1 / tp=16-over-DCN service times (~0.4s / ~0.8s: only a
#: degraded, truncated decision fits)
CLASSES = [
    traffic.TrafficClass("decision", rate_hz=4.0,
                         deadline_range_s=(0.12, 0.28),
                         prompt_range=(64, 128), max_new_range=(8, 16)),
]


def _pool(n_engines: int):
    cfg = get_config(ARCH)
    eps = _synthetic_eps(cfg)
    return pool_candidates([(ARCH, cfg, eps, 0.0)] * n_engines)


def run_arm(placements, topo, *, net_aware: bool = True):
    cands = _pool(len(placements))
    router = FleetRouter(cands, quality=lambda c: 1.0, slots=SLOTS,
                         policy="degrade", placements=placements,
                         topo=topo, net_aware=net_aware)
    arrivals = traffic.generate(CLASSES, HORIZON_S, seed=TRAFFIC_SEED)
    done = router.run([r.fresh() for r in arrivals])
    rep = metrics.summarize(done, HORIZON_S)
    served = [r for r in done if not r.dropped]
    shares = [sum(1 for r in served if r.engine_idx == i)
              for i in range(len(placements))]
    return rep, shares


def main(verbose: bool = True):
    host = Topology(n_hosts=1, chips_per_host=8)
    multi = Topology(n_hosts=2, chips_per_host=8)
    arms = [
        ("sharded-tp8", [host.place_tp(8)], host, True),
        ("fallback-tp1", host.spread(8, tp=1), host, True),
        ("net-aware", [multi.place_tp(8), multi.place_tp(16)], multi, True),
        ("net-blind", [multi.place_tp(8), multi.place_tp(16)], multi, False),
    ]
    rows = []
    for name, placements, topo, aware in arms:
        rep, shares = run_arm(placements, topo, net_aware=aware)
        rows.append([name, len(placements), placements[-1].tp,
                     placements[-1].link, int(aware), rep.n, rep.served,
                     rep.dropped, f"{rep.hit_rate:.3f}",
                     f"{rep.p99_s * 1e3:.1f}", f"{rep.goodput:.1f}",
                     "/".join(str(s) for s in shares)])
        if verbose:
            print(f"{name:13s} engines={len(placements)} "
                  f"({placements_summary(placements, topo)}) "
                  f"hit={rep.hit_rate:.3f} p99={rep.p99_s*1e3:7.1f}ms "
                  f"goodput={rep.goodput:7.1f} shares={shares}")
    write_table(os.path.join(RESULTS, "table_sharded.csv"),
                ["arm", "engines", "max_tp", "max_link", "net_aware",
                 "offered", "served", "dropped", "hit_rate", "p99_ms",
                 "goodput", "engine_shares"], rows)
    by = {r[0]: r for r in rows}
    g = lambda name: float(by[name][10])
    assert g("sharded-tp8") > g("fallback-tp1"), \
        "tensor parallelism did not beat replication at equal capacity"
    assert g("net-aware") > g("net-blind"), \
        "link-aware routing did not beat blind routing"
    # the blind router actually took the bait (used the DCN engine) —
    # otherwise the aware/blind comparison is vacuous
    assert int(by["net-blind"][11].split("/")[1]) > 0, \
        "blind router never chose the DCN-spanning engine"
    return rows


if __name__ == "__main__":
    main()
