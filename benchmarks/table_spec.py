"""Speculative-decoding table: learned per-class draft depth vs baselines.

The speculation axis turns draft depth into an FPX operating point:
``core.latency.speculate_s`` prices a fast-draft / slow-verify round, so
the analytic fleet can *learn* how deep to draft per traffic class
instead of deploying one depth everywhere.  Every arm below replays the
same seeded arrival streams through the same engine count (equal
capacity — 8 engines):

* ``spec-learned`` — the spec-widened pool (4 dense points + k=2/k=4
  variants of the 7b/14b verifiers drafted by the 1.5b point), routed by
  the per-class bandit: draft depth is learned per class.
* ``spec-fpx``     — same pool, routed by the model-based slack rule
  (spec variants win ties at equal quality via their cheaper effective
  per-token time).
* ``dense``        — the always-dense pool replicated to equal capacity.
* ``fixed-k2/k4``  — one draft depth deployed fleet-wide on every large
  verifier (the "pick a k offline" baseline).

Every arm fields the *same* small-engine capacity (two 1.5b + two 3b
engines — the only points that can serve trading's tens-of-ms budgets),
so the deadline-tight class is an apples-to-apples control; the arms
differ only in how their four large verifier engines decode.  The chat
rate is set where dense large-engine throughput saturates, so the
slack-rich class is capacity-limited — exactly the regime where
speculation's cheaper effective per-token time converts into goodput
rather than idle slack.

Reported per (mix, arm): the standard SLO row plus mean inter-token
latency (decode seconds per on-time token) — speculation's per-token win
— and goodput.  The paper-level claim: draft depth is a *latency/
accuracy* control like gamma — slack-rich chat traffic wants deep
drafts (inter-token latency collapses at equal verifier quality), while
deadline-tight trading traffic must stay dense (a draft+verify round
that misses the deadline is worth nothing, so rounds collapse to dense
steps — p99 never degrades).  The learned arm matches or beats both the
always-dense and every fixed-k deployment on goodput.

The CSV is committed and gated by check_regression.py: spec goodput must
hold its margin over dense on the slack-rich class, and spec p99 must
never exceed dense p99 on the deadline-tight class.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serving import FleetRouter, metrics, traffic
from repro.serving.fleet import demo_pool, demo_quality, spec_variants

from common import write_table, RESULTS

HORIZON_S = 60.0
SLOTS = 4
#: chat arrival rate (Hz) — set where the dense large engines saturate,
#: so effective decode throughput (not idle slack) decides goodput
CHAT_RATE = 40.0
SMALL = ("qwen2.5-1.5b", "qwen2.5-3b")


def _classes(mix):
    if mix == "trading":
        return [traffic.trading_class()]
    if mix == "chat":
        return [traffic.chat_class(rate_hz=CHAT_RATE)]
    return [traffic.trading_class(), traffic.chat_class(rate_hz=CHAT_RATE)]


def _pools():
    """Five 8-engine arms with identical small-engine capacity: two 1.5b
    + two 3b engines each, plus four large verifier engines that differ
    only in decode mode (dense / fixed draft depth / a k=2,k=4 ladder
    the per-class bandit learns over)."""
    dense = demo_pool()                     # [1.5b, 3b, 7b, 14b]
    small = [c for c in dense if c.model_name in SMALL]
    big = [c for c in dense if c.model_name not in SMALL]

    def spec_big(k):
        return [c for c in spec_variants(dense, ks=(k,))
                if c.spec is not None]

    learned = small * 2 + spec_big(2) + spec_big(4)
    return {"spec-learned": (learned, "bandit"),
            "spec-fpx": (learned, "fpx"),
            "dense": ((small + big) * 2, "fpx"),
            "fixed-k2": ((small + spec_big(2)) * 2, "fpx"),
            "fixed-k4": ((small + spec_big(4)) * 2, "fpx")}


def _itl_ms(reqs):
    """Mean inter-token latency over served requests (decode time per
    emitted token past the first) — the per-token speed speculation buys."""
    slacks = [metrics.request_slack(r) for r in reqs
              if not r.dropped and r.t_finish is not None]
    itls = [s["itl_s"] for s in slacks if s.get("itl_s") is not None]
    return 1e3 * sum(itls) / len(itls) if itls else float("nan")


def run_arm(cands, arrivals, *, mode, seed=0):
    router = FleetRouter(cands, quality=demo_quality, slots=SLOTS,
                         mode=mode, epsilon=0.05, seed=seed)
    out = router.run([a.fresh() for a in arrivals])
    return metrics.summarize(out, HORIZON_S), _itl_ms(out)


def main(seed: int = 1, verbose: bool = True):
    pools = _pools()
    n_engines = {name: len(p) for name, (p, _) in pools.items()}
    assert len(set(n_engines.values())) == 1, n_engines   # equal capacity
    rows = []
    for mix in traffic.SCENARIOS:
        arrivals = traffic.generate(_classes(mix), HORIZON_S, seed=seed)
        for name, (cands, mode) in pools.items():
            rep, itl = run_arm(cands, arrivals, mode=mode, seed=seed)
            rows.append([mix, name] + rep.format_row() + [f"{itl:.2f}"])
            if verbose:
                print(f"{mix:8s} {name:13s} n={len(arrivals):4d} "
                      f"hit={rep.hit_rate:.3f} p99={rep.p99_s*1e3:7.1f}ms "
                      f"itl={itl:6.2f}ms goodput={rep.goodput:7.1f}")
    write_table(os.path.join(RESULTS, "table_spec.csv"),
                ["mix", "arm", "offered", "served", "dropped", "hit_rate",
                 "p50_ms", "p99_ms", "goodput", "itl_ms"], rows)
    return rows


if __name__ == "__main__":
    main()
