"""HFTBench end-to-end: train a small model ladder, race it on the
simulated exchange at different precisions.

    PYTHONPATH=src python examples/hft_trading.py [--steps 300]
"""
import argparse
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.bench import agents as ag
from repro.bench.hft import HFTBench, run_session
from repro.configs import get_config
from repro.core import assign, calibrate

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
args = ap.parse_args()

env = HFTBench()
teacher = env.teacher

print("# training two model sizes on the market-pattern task ...")
specs = []
for sim_name, full_name in [("qwen-sim-3b", "qwen2.5-3b"),
                            ("qwen-sim-14b", "qwen2.5-14b")]:
    cfg = get_config(sim_name)
    params, acc = ag.train_decision_model(cfg, teacher, steps=args.steps,
                                          batch=32, prompt_len=32)
    print(f"  {sim_name}: train action-accuracy {acc:.3f}")
    rng = np.random.default_rng(5)
    eps = calibrate.calibrate(
        params, cfg, [ag.decision_batch(teacher, rng, batch=4, prompt_len=32)])
    for gamma, bits in [(None, 16), (None, 8), (0.2, None)]:
        if gamma is None:
            policy = None if bits == 16 else {k: bits for k in eps}
            avg, df, tag = float(bits), bits, f"fp{bits}"
        else:
            policy = assign.assign_precision(eps, gamma)
            avg, df, tag = assign.avg_bits(policy), 8, f"fpx{gamma}"
        specs.append(ag.AgentSpec(
            name=f"{sim_name.replace('qwen-sim-','')}-{tag}", sim_cfg=cfg,
            params=params, full_cfg=get_config(full_name), policy=policy,
            default_bits=df, avg_bits=avg))

print("\n# one trading day per configuration:")
print(f"{'agent':16s} {'bits':>5s} {'latency':>9s} {'daily yield':>12s}")
for spec in specs:
    agent = ag.LLMAgent(spec, n_actions=3)
    res = run_session(env, agent, seed=0)
    print(f"{spec.name:16s} {spec.avg_bits:5.1f} "
          f"{agent.latency_s*1e3:7.0f}ms {res['daily_yield']:+11.2f}%")
print("\nThe paper's claim: the best yield comes from the large model with "
      "moderate FPX compression — quality it keeps, latency it sheds.")
