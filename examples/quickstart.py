"""Quickstart: the FPX pipeline in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Build a small Qwen-style model (the zoo works the same at any scale).
2. Run Algorithm-1 calibration: per-linear-layer FP4 sensitivity eps_l.
3. Assign precision at gamma=0.3 (Eq. 7): FP4 to the tolerant 30%.
4. Serve a batch at mixed precision and compare against FP16/FP8/FP4
   on modeled TPU latency and output quality.
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import assign, calibrate, latency
from repro.data import pipeline as dp
from repro.models import transformer
from repro.models.modules import ExecContext

cfg = get_config("qwen-sim-3b")
full_cfg = get_config("qwen2.5-3b")        # latency-model scale
print(f"model: {cfg.name} ({cfg.n_params/1e6:.1f}M params, "
      f"{cfg.n_layers} layers)")

params = transformer.init_params(jax.random.PRNGKey(0), cfg)

# --- 1. calibration (paper Algorithm 1) --------------------------------
cal = [{k: jnp.asarray(v) for k, v in b.items()}
       for b in dp.calibration_batches(cfg, n=2, batch=2, seq=64)]
eps = calibrate.calibrate(params, cfg, cal)
worst = max(eps, key=eps.get)
best = min(eps, key=eps.get)
print(f"calibrated {len(eps)} linear layers: most tolerant {best} "
      f"(eps={eps[best]:.3f}), most sensitive {worst} (eps={eps[worst]:.3f})")

# --- 2. precision assignment (paper Eq. 7) ------------------------------
gamma = 0.3
assignment = assign.assign_precision(eps, gamma)
bits = assign.avg_bits(assignment)
print(f"gamma={gamma}: {sum(1 for b in assignment.values() if b == 4)} layers "
      f"-> FP4, rest FP8; avg bitwidth {bits:.2f}")

# --- 3. quantized inference + the latency ladder ------------------------
eval_b = [{k: jnp.asarray(v) for k, v in b.items()}
          for b in dp.eval_batches(cfg, n=2, batch=2, seq=64)]
for name, ctx, w in [
    ("FP16", ExecContext(), 16),
    ("FP8", ExecContext(default_bits=8), 8),
    (f"FPX g={gamma}", ExecContext(policy=assignment, default_bits=8), bits),
    ("FP4", ExecContext(default_bits=4), 4),
]:
    ppl = calibrate.perplexity(params, cfg, eval_b, ctx=ctx)
    t = latency.decision_latency(full_cfg, w_bits=w)
    print(f"{name:10s}  ppl={ppl:8.2f}   modeled action latency "
          f"{t*1e3:6.1f} ms (TPU v5e, 3B-class)")
print("\nFPX sits between FP8 quality and FP4 speed — that interior point "
      "is what wins the paper's latency-sensitive tasks.")
