"""End-to-end serving driver: batched requests with deadlines through the
FPX-aware engine + scheduler, with an adaptive precision fallback.

    PYTHONPATH=src python examples/serve_batched.py

Serves two request waves: generous deadlines (FP8 policy holds) then tight
deadlines — the FPX controller drops to a higher gamma so the modeled
action latency fits the budget.  This is the paper's "meet any specified
latency target" loop as a deployable serving path.
"""
import sys
sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.core import assign, calibrate, fpx, latency
from repro.data import pipeline as dp
from repro.models import transformer
from repro.models.modules import ExecContext
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import Request, Scheduler

sim = get_config("qwen-sim-7b")
full = get_config("qwen2.5-7b")
params = transformer.init_params(jax.random.PRNGKey(0), sim)

# calibrate once (Algorithm 1)
cal = [{k: jax.numpy.asarray(v) for k, v in b.items()}
       for b in dp.calibration_batches(sim, n=1, batch=2, seq=48)]
eps = calibrate.calibrate(params, sim, cal)

PROMPT, NEW = 32, 8
engine = ServingEngine(params, sim, max_ctx=PROMPT + NEW, latency_cfg=full,
                       ctx=ExecContext(default_bits=8), avg_bits=8.0)
sched = Scheduler(engine, batch_slots=8)
rng = np.random.default_rng(0)


def submit_wave(deadline_ms: float, n: int = 8):
    for rid in range(n):
        sched.submit(Request(
            rid=rid, prompt=rng.integers(0, sim.vocab, PROMPT).astype(np.int32),
            max_new=NEW, deadline_s=deadline_ms / 1e3))


def wave(deadline_ms: float):
    """FPX controller: pick the smallest gamma whose modeled latency fits."""
    for gamma in [round(0.1 * i, 1) for i in range(11)]:
        asn = assign.assign_precision(eps, gamma)
        bits = assign.avg_bits(asn)
        t = latency.decision_latency(full, prompt_len=512, gen_tokens=NEW,
                                     w_bits=bits)
        if t <= deadline_ms / 1e3 or gamma == 1.0:
            engine.set_policy(asn, default_bits=8, avg_bits=bits)
            print(f"deadline {deadline_ms:.0f}ms -> gamma={gamma} "
                  f"(avg {bits:.1f} bits, modeled {t*1e3:.0f}ms)")
            break
    submit_wave(deadline_ms)
    done = sched.run()
    met = sum(bool(r.met_deadline) for r in done)
    print(f"  served {len(done)} requests, {met}/{len(done)} met deadline\n")
    sched.done.clear()


print("# wave 1: generous 120ms deadline (FP8 fits)")
wave(120.0)
print("# wave 2: tight 70ms deadline (forces deeper FP4 compression)")
wave(70.0)
print("# wave 3: 50ms deadline (max compression; may still miss — "
      "the controller reports honestly)")
wave(50.0)
