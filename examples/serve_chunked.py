"""Chunked-prefill serving driver: long prompts without the stall.

    PYTHONPATH=src python examples/serve_chunked.py

Streams one fixed trace — short tight-deadline "tick" requests decoding
while a long prompt arrives — through the paged
:class:`~repro.serving.paged_engine.ContinuousEngine` twice: monolithic
prefill (the long prompt stalls every decode lane) and chunked prefill
(``prefill_chunk``: the prompt is absorbed page-aligned chunks at a time,
decode steps landing in between).  Watch the timeline: under the stall no
tick can finish inside the long prompt's prefill window; chunked, they
retire *during* it.  Greedy tokens are identical either way.

At this sim scale prefill is memory-bound, so tiny chunks re-pay the
weight read many times and the *total* clock time grows — the example
shows the mechanism.  The latency win lives in the compute-bound regime
(long prompts, page-multiple chunks of ~256): ``benchmarks/
table_chunked.py`` measures it — lower trading p99 and higher goodput at
identical tokens.
"""
import sys
sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer
from repro.serving.continuous import LatencyProfile
from repro.serving.paged_engine import ContinuousEngine
from repro.serving.scheduler import Request

sim = get_config("qwen-sim-1.5b")
full = get_config("qwen2.5-1.5b")
params = transformer.init_params(jax.random.PRNGKey(0), sim)
profile = LatencyProfile(full, 8.0)

LONG, SHORT = 96, 8


def trace(rng):
    """Ticks decoding when a long prompt lands mid-stream."""
    svc = profile.service_s(SHORT, 4)
    spec = [("tick", SHORT, 4, 0.0), ("tick", SHORT, 6, 0.1 * svc),
            ("chat", LONG, 4, 0.3 * svc), ("tick", SHORT, 4, 0.5 * svc),
            ("tick", SHORT, 4, 1.5 * svc)]
    return [Request(rid=i, cls_name=cls,
                    prompt=rng.integers(0, sim.vocab, n).astype(np.int32),
                    max_new=new, deadline_s=20.0 * svc, t_arrive=t)
            for i, (cls, n, new, t) in enumerate(spec)]


def run(chunk):
    engine = ContinuousEngine(params, sim, slots=3, page_size=8, max_ctx=128,
                              policy="serve", profile=profile,
                              prefill_chunk=chunk)
    reqs = trace(np.random.default_rng(0))   # same prompts both runs
    for r in reqs:
        engine.submit(r)
    engine.run()
    return reqs


for chunk in (None, 8):
    label = "stall-prefill" if chunk is None else f"chunked (chunk={chunk})"
    reqs = run(chunk)
    print(f"\n== {label} ==")
    print("rid  cls   S  new  arrive_ms  prefill_done_ms  finish_ms  latency_ms")
    for r in reqs:
        print(f"{r.rid:3d} {r.cls_name:5s} {r.prompt_len:3d} {r.max_new:4d} "
              f"{r.t_arrive*1e3:10.2f} {r.t_prefill_done*1e3:16.2f} "
              f"{r.t_finish*1e3:10.2f} {r.latency_s*1e3:11.2f}")
    ticks = [r for r in reqs if r.cls_name == "tick"]
    chat = next(r for r in reqs if r.cls_name == "chat")
    during = [r.rid for r in ticks
              if chat.t_admit < r.t_finish < chat.t_prefill_done]
    print(f"ticks retired during the long prompt's prefill: {during or 'none'}")
