"""Fault injection and failover walkthrough: crashes, breakers, recovery.

    PYTHONPATH=src python examples/serve_faults.py [--trace out.json]

Replays the same seeded workload through the four-engine demo fleet
three ways — fault-free, under a seeded fault schedule with the naive
(stranding) crash handler, and under the identical schedule with
token-exact recovery plus hedged dispatch — and shows what each fault
cost, what the circuit breaker did, and what recovery bought back.

``--trace out.json`` exports the *recovering* run as a Chrome/Perfetto
trace: the fault stream lands on its own track, ENGINE_DOWN/UP and
REQ_REQUEUE on the router's, so an outage reads as a visible hole in an
engine's lanes with the reclaimed work restarting elsewhere.  The trace
is replayed through ``repro.obs.check`` before export — exactly-once
retirement per request (crash re-admissions licensed by their requeues)
and zero page leaks are enforced, not hoped for.

``--live [--pallas]`` swaps the analytic fleet for two real-compute
paged engines and crashes one mid-decode: the victim's redo on the
surviving engine is verified *byte-identical* to a fault-free run
(rid-seeded prompts + position-keyed sampling make recovery exact).
This is the CI fault scenario — traced under both attention
implementations and replayed through ``repro.obs.check_trace``.
"""
import argparse
import sys
sys.path.insert(0, "src")

from collections import Counter

from repro.obs import Tracer, check, write_chrome
from repro.serving import FleetRouter, metrics, traffic
from repro.serving.faults import Fault, FaultInjector, FaultPlan, \
    generate_plan
from repro.serving.fleet import demo_pool, demo_quality as quality

ap = argparse.ArgumentParser()
ap.add_argument("--trace", metavar="OUT.json", default=None,
                help="export the recovering run as a Chrome/Perfetto trace")
ap.add_argument("--live", action="store_true",
                help="run the crash/recovery scenario on two real-compute "
                     "paged engines instead of the analytic fleet")
ap.add_argument("--pallas", action="store_true",
                help="with --live: use the fused Pallas kernels "
                     "(default: jnp fallback)")
args = ap.parse_args()

HORIZON = 20.0


def live_scenario():
    """Two live paged engines; engine 0 crashes mid-decode; the reclaimed
    work re-routes to engine 1 and must reproduce the fault-free tokens
    byte-for-byte."""
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import transformer
    from repro.models.modules import ExecContext
    from repro.obs import trace as tr_mod
    from repro.serving.continuous import LatencyProfile
    from repro.serving.fleet import pool_candidates
    from repro.serving.paged_engine import ContinuousEngine

    sim, full = get_config("qwen-sim-1.5b"), get_config("qwen2.5-1.5b")
    params = transformer.init_params(jax.random.PRNGKey(0), sim)
    profile = LatencyProfile(full, 8.0)
    rng = np.random.default_rng(0)
    eps = {f"L{i}.lin{j}": float(rng.uniform(0.05, 0.9))
           for i in range(full.n_layers) for j in range(4)}
    cands = pool_candidates([("qwen2.5-1.5b", full, eps, 1.0)] * 2)

    def fleet(tracer, injector):
        engines = [ContinuousEngine(params, sim, slots=2, page_size=8,
                                    max_ctx=64, policy="serve",
                                    profile=profile,
                                    ctx=ExecContext(use_pallas=args.pallas),
                                    tracer=tracer.scope(f"eng{i}")
                                    if tracer else None)
                   for i in range(2)]
        return FleetRouter(cands, quality=lambda c: 1.0, engines=engines,
                           tracer=tracer, injector=injector)

    def reqs():
        return [traffic.SimRequest(rid=i, cls_name="t", t_arrive=0.0,
                                   prompt_len=16, max_new=6,
                                   deadline_s=50.0) for i in range(4)]

    impl = "pallas" if args.pallas else "jnp"
    print(f"# live crash/recovery scenario ({impl} attention)")
    base = {r.rid: r for r in fleet(None, None).run(reqs())}
    v = base[0]
    t_crash = v.t_first_token + 0.5 * (v.t_finish - v.t_first_token)
    print(f"# fault-free run done; crashing engine 0 at t={t_crash*1e3:.2f}ms "
          f"(mid-decode of rid 0)")
    tracer = Tracer() if args.trace else None
    inj = FaultInjector(FaultPlan((Fault(t_crash, 0, "crash",
                                         duration_s=0.2),)), tracer=tracer)
    router = fleet(tracer, inj)
    done = {r.rid: r for r in router.run(reqs())}
    exact = all(np.array_equal(base[i].result_tokens, done[i].result_tokens)
                for i in base)
    print(f"# rid 0: attempt {done[0].retries} finished on engine "
          f"{done[0].engine_idx} — tokens byte-identical to fault-free "
          f"run across all {len(base)} rids: {exact}")
    if not exact:
        sys.exit(1)
    if tracer is not None:
        req_q = sum(e.name == tr_mod.REQ_REQUEUE for e in tracer.events)
        findings = check(tracer.events)
        write_chrome(tracer.events, args.trace)
        print(f"wrote {len(tracer.events)} events -> {args.trace} "
              f"({req_q} requeues); "
              f"invariants: {'OK' if not findings else findings}")
        if findings:
            sys.exit(1)


if args.live:
    live_scenario()
    sys.exit(0)

CLASSES = [
    traffic.TrafficClass("agent", rate_hz=3.0, deadline_range_s=(8.0, 15.0),
                         prompt_range=(128, 256), max_new_range=(48, 96),
                         reward_weight=2.0),
    traffic.TrafficClass("interactive", rate_hz=10.0,
                         deadline_range_s=(0.5, 2.0),
                         prompt_range=(64, 128), max_new_range=(8, 16)),
]

plan = generate_plan(4, HORIZON, seed=3, crash_rate=0.15, stall_rate=0.08,
                     slowdown_rate=0.08)
kinds = Counter(f.kind for f in plan.faults)
print(f"# fault schedule: {len(plan)} faults over {HORIZON:.0f}s "
      f"({dict(kinds)})")
for f in plan.faults:
    print(f"  t={f.t:6.2f}s engine {f.engine_idx} {f.kind:13s} "
          f"{f.duration_s:4.1f}s"
          + (f" x{f.factor:.1f}" if f.kind == "slowdown" else ""))

arrivals = traffic.generate(CLASSES, HORIZON, seed=7)
print(f"\n# workload: {len(arrivals)} requests "
      f"({dict(Counter(r.cls_name for r in arrivals))})")


def run(name, *, faulted, recover=True, hedge=None, tracer=None):
    inj = FaultInjector(plan, tracer=tracer) if faulted else None
    router = FleetRouter(demo_pool(), quality=quality, seed=1, tracer=tracer,
                         injector=inj, recover=recover, hedge_delay_s=hedge)
    done = router.run([a.fresh() for a in arrivals])
    rep = metrics.summarize(done, HORIZON)
    print(f"  {name:12s} served {rep.served:3d}/{rep.n}  "
          f"dropped {rep.dropped:3d}  retried {rep.retried:3d}  "
          f"hedged {rep.hedged:3d}  hit {rep.hit_rate:.3f}  "
          f"goodput {rep.goodput:7.1f}")
    return rep, router


print("\n# the same traffic, three fleets:")
ceiling, _ = run("fault-free", faulted=False)
naive, _ = run("naive", faulted=True, recover=False)
tracer = Tracer() if args.trace else None
rec, router = run("recovering", faulted=True, hedge=1.0, tracer=tracer)

print(f"\n# the schedule cost the naive fleet "
      f"{ceiling.goodput - naive.goodput:.1f} goodput; token-exact "
      f"recovery bought back {rec.goodput - naive.goodput:.1f} "
      f"({naive.dropped - rec.dropped} fewer requests stranded)")

if tracer is not None:
    import repro.obs.trace as tr_mod
    downs = [e for e in tracer.events if e.name == tr_mod.ENGINE_DOWN]
    reqs = [e for e in tracer.events if e.name == tr_mod.REQ_REQUEUE]
    print(f"# breaker opened {len(downs)}x "
          f"({dict(Counter(e.args['reason'] for e in downs))}); "
          f"{len(reqs)} requests reclaimed and re-routed")
    findings = check(tracer.events)
    write_chrome(tracer.events, args.trace)
    print(f"wrote {len(tracer.events)} events -> {args.trace} "
          f"(load at https://ui.perfetto.dev); "
          f"invariants: {'OK' if not findings else findings}")
    if findings:
        sys.exit(1)
