"""Fleet serving walkthrough: deadline-aware routing over live traffic.

    PYTHONPATH=src python examples/serve_fleet.py [--trace out.json]

Builds a four-engine pool spanning the FPX grid's speed/quality range,
replays a bursty mixed workload (HFT-style tick reactions + chat turns)
through it, and shows where the router sends each traffic class, what the
drop/degrade admission policy does under bursts, and how the fleet's
goodput compares with deploying any single operating point everywhere.

``--trace out.json`` exports the routed run as a Chrome/Perfetto trace —
each engine becomes its own Perfetto process (lanes + queue), with the
router's dispatch/retire stream on top — and the per-class summary grows
the slack attribution: how much of each class's latency was queue wait
vs. prefill vs. decode.
"""
import argparse
import sys
sys.path.insert(0, "src")

from collections import Counter

from repro.obs import Tracer, check, write_chrome
from repro.serving import FleetRouter, metrics, traffic
from repro.serving.fleet import demo_pool, demo_quality as quality

ap = argparse.ArgumentParser()
ap.add_argument("--trace", metavar="OUT.json", default=None,
                help="export a Chrome/Perfetto trace of the routed run")
args = ap.parse_args()

HORIZON = 20.0

cands = demo_pool()
print("# fleet operating points (model, gamma -> avg bits, base action "
      "latency):")
for c in cands:
    print(f"  {c.model_name:14s} gamma={c.gamma:3.1f}  "
          f"{c.avg_bits:.1f} bits  {c.latency_s*1e3:6.1f} ms")

arrivals = traffic.generate(traffic.scenario("mixed"), HORIZON, seed=7)
n_cls = Counter(r.cls_name for r in arrivals)
print(f"\n# workload: {len(arrivals)} requests over {HORIZON:.0f}s of "
      f"simulated time ({dict(n_cls)})")

tracer = Tracer() if args.trace else None
router = FleetRouter(cands, quality=quality, slots=4, tracer=tracer)
done = router.run([a.fresh() for a in arrivals])

print("\n# where each traffic class was routed:")
for cls in sorted(n_cls):
    use = Counter(r.engine_idx for r in done if r.cls_name == cls)
    parts = ", ".join(f"{cands[i].model_name}-g{cands[i].gamma:g}: {n}"
                      for i, n in use.most_common())
    print(f"  {cls:8s} -> {parts}")

rep = metrics.summarize(done, HORIZON)
print(f"\n# fleet SLOs: hit-rate {rep.hit_rate:.3f}, "
      f"p50 {rep.p50_s*1e3:.1f} ms, p99 {rep.p99_s*1e3:.1f} ms, "
      f"dropped {rep.dropped}, degraded {rep.degraded}, "
      f"goodput {rep.goodput:.1f}")
print(f"#   streaming: ttft p50 {rep.ttft_p50_s*1e3:.1f} ms / "
      f"p99 {rep.ttft_p99_s*1e3:.1f} ms, itl p50 {rep.itl_p50_s*1e3:.2f} ms")
print("#   per-class slack attribution (mean ms: queue / prefill / decode):")
for nm, sub in (rep.per_class or {}).items():
    print(f"    {nm:8s} hit {sub.hit_rate:.3f}  p99 {sub.p99_s*1e3:7.1f} ms  "
          f"goodput {sub.goodput:7.1f}  "
          f"slack {sub.queue_s*1e3:6.2f} / {sub.prefill_s*1e3:6.2f} / "
          f"{sub.decode_s*1e3:6.2f}")

print("\n# versus deploying one operating point fleet-wide (equal capacity):")
for c in cands:
    r = FleetRouter([c] * len(cands), quality=quality, slots=4)
    s = metrics.summarize(r.run([a.fresh() for a in arrivals]), HORIZON)
    print(f"  static {c.model_name:14s} g={c.gamma:3.1f}  "
          f"hit {s.hit_rate:.3f}  goodput {s.goodput:7.1f}")
print(f"  fleet router                        "
      f"hit {rep.hit_rate:.3f}  goodput {rep.goodput:7.1f}")

if args.trace:
    findings = check(tracer.events)
    write_chrome(tracer.events, args.trace)
    print(f"\nwrote {len(tracer.events)} events -> {args.trace} "
          f"(load at https://ui.perfetto.dev); "
          f"invariants: {'OK' if not findings else findings}")
    if findings:
        sys.exit(1)
