"""Paged continuous serving driver: the no-barrier engine on real compute.

    PYTHONPATH=src python examples/serve_paged.py [--trace out.json]
                                                  [--pallas]

Streams one seeded arrival trace of greedy requests through both
real-compute serving disciplines — the padded-wave scheduler and the paged
:class:`~repro.serving.paged_engine.ContinuousEngine` — and prints the
per-request timeline.  Watch the paged side admit late arrivals into lanes
(and pages) freed by earlier retirements while long requests are still
decoding; the wave side makes everyone in a wave wait for its slowest
member plus the barrier.

``--trace out.json`` exports the run as a Chrome/Perfetto trace (open at
https://ui.perfetto.dev — one track per lane plus the pool gauges) and
prints the slack attribution: where each served request's time actually
went.  ``--pallas`` runs the fused Pallas kernels instead of the jnp
fallback (same tokens, same clock — the trace invariants must hold on
both implementations).
"""
import argparse
import sys
sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer
from repro.models.modules import ExecContext
from repro.obs import Tracer, check, write_chrome
from repro.serving import metrics
from repro.serving.continuous import LatencyProfile
from repro.serving.paged_engine import ContinuousEngine
from repro.serving.scheduler import Request

ap = argparse.ArgumentParser()
ap.add_argument("--trace", metavar="OUT.json", default=None,
                help="export a Chrome/Perfetto trace of the run")
ap.add_argument("--pallas", action="store_true",
                help="use the fused Pallas kernels (default: jnp fallback)")
args = ap.parse_args()

sim = get_config("qwen-sim-1.5b")
full = get_config("qwen2.5-1.5b")
params = transformer.init_params(jax.random.PRNGKey(0), sim)
profile = LatencyProfile(full, 8.0)

PROMPT = 24
rng = np.random.default_rng(0)


def trace():
    """Short/long interleaved arrivals: the barrier's worst case."""
    svc = profile.service_s(PROMPT, 8)
    spec = [(0.0, 2), (0.0, 16), (0.3 * svc, 2), (0.6 * svc, 2),
            (0.9 * svc, 16), (1.2 * svc, 2)]
    return [Request(rid=i,
                    prompt=rng.integers(0, sim.vocab, PROMPT).astype(np.int32),
                    max_new=new, deadline_s=4.0 * svc, t_arrive=t)
            for i, (t, new) in enumerate(spec)]


tracer = Tracer() if args.trace else None
engine = ContinuousEngine(params, sim, slots=2, page_size=8, max_ctx=64,
                          policy="serve", profile=profile,
                          ctx=ExecContext(use_pallas=args.pallas),
                          tracer=tracer)
reqs = trace()
for r in reqs:
    engine.submit(r)
engine.run()

print("rid  new  arrive_ms  admit_ms  finish_ms  latency_ms  pages")
pages = {rid: pg for rid, pg in engine.admissions}
for r in reqs:
    print(f"{r.rid:3d} {r.max_new:4d} {r.t_arrive*1e3:10.2f} "
          f"{r.t_admit*1e3:9.2f} {r.t_finish*1e3:10.2f} "
          f"{r.latency_s*1e3:11.2f}  {pages[r.rid]}")
reused = [ (a, b) for a, pa in pages.items() for b, pb in pages.items()
           if a < b and set(pa) & set(pb) ]
print(f"\npage reuse across requests: {reused or 'none'} "
      f"(mid-flight admissions, no wave barrier)")
print(f"all {len(reqs)} served, "
      f"{sum(bool(r.met_deadline) for r in reqs)} met their deadline; "
      f"pool fully returned: {engine.cache.free_pages == engine.cache.n_pages - 1}")

rep = metrics.summarize(reqs, max(r.t_finish for r in reqs))
print(f"\n# streaming SLOs: ttft p50 {rep.ttft_p50_s*1e3:.2f} ms / "
      f"p99 {rep.ttft_p99_s*1e3:.2f} ms, "
      f"itl p50 {rep.itl_p50_s*1e3:.3f} ms / p99 {rep.itl_p99_s*1e3:.3f} ms")
print(f"# slack attribution (mean per served request): "
      f"queue {rep.queue_s*1e3:.2f} ms, prefill {rep.prefill_s*1e3:.2f} ms, "
      f"decode {rep.decode_s*1e3:.2f} ms")

if args.trace:
    findings = check(tracer.events)
    write_chrome(tracer.events, args.trace)
    print(f"\nwrote {len(tracer.events)} events -> {args.trace} "
          f"(load at https://ui.perfetto.dev); "
          f"invariants: {'OK' if not findings else findings}")
    if findings:
        sys.exit(1)
