"""StreetFighter: a real-time duel between a fast-compressed and a slow
full-precision agent.

    PYTHONPATH=src python examples/street_fighter.py [--steps 300]
"""
import argparse
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.bench import agents as ag
from repro.bench.streetfighter import SFGame, play_match, N_ACTIONS
from repro.configs import get_config
from repro.core import assign, calibrate

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--matches", type=int, default=11)
args = ap.parse_args()

game = SFGame()
teacher = game.teacher

cfg = get_config("qwen-sim-7b")
params, acc = ag.train_decision_model(cfg, teacher, steps=args.steps,
                                      batch=32, prompt_len=24)
print(f"# trained qwen-sim-7b: action accuracy {acc:.3f}")

rng = np.random.default_rng(5)
eps = calibrate.calibrate(
    params, cfg, [ag.decision_batch(teacher, rng, batch=4, prompt_len=24)])
full = get_config("qwen2.5-7b")

fp16 = ag.LLMAgent(ag.AgentSpec(
    name="7b-fp16", sim_cfg=cfg, params=params, full_cfg=full), n_actions=N_ACTIONS)
asn = assign.assign_precision(eps, 0.3)
fpx = ag.LLMAgent(ag.AgentSpec(
    name="7b-fpx0.3", sim_cfg=cfg, params=params, full_cfg=full,
    policy=asn, default_bits=8, avg_bits=assign.avg_bits(asn)),
    n_actions=N_ACTIONS)

print(f"#  fp16 latency {fp16.latency_s*1e3:.0f}ms vs "
      f"fpx(0.3) latency {fpx.latency_s*1e3:.0f}ms")
wins = sum(play_match(fpx, fp16, rounds=1, seed=s) == 0
           for s in range(args.matches))
print(f"# FPX wins {wins}/{args.matches} matches vs FP16 "
      f"({100*wins/args.matches:.0f}% winrate)")
print("Street Fighter is latency-dominant: the FP16 7B (316ms) misses the "
      "~200ms action cadence; FPX compression (139ms) fits it — the same "
      "model wins by punching on time (paper Table 2, bottom).")
