"""Train a ~100M-param model for a few hundred steps on the synthetic LM
corpus — the training-substrate end-to-end driver.

    PYTHONPATH=src python examples/train_small.py [--steps 300] [--arch ...]

Default arch is a ~100M dense model (qwen-100m below); any assigned
architecture id works with --reduced for its smoke-scale variant.
"""
import argparse
import sys
import time
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.data import pipeline as dp
from repro.training.optim import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step

QWEN_100M = ModelConfig(
    name="qwen-100m", arch_type="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048, vocab=8192,
    ffn_kind="swiglu", rope_theta=10000.0, tie_embeddings=True,
    source="examples/train_small")

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=100)
ap.add_argument("--arch", default=None)
ap.add_argument("--reduced", action="store_true")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=256)
args = ap.parse_args()

cfg = QWEN_100M if args.arch is None else get_config(args.arch)
if args.reduced:
    cfg = cfg.reduced()
print(f"# {cfg.name}: {cfg.n_params/1e6:.1f}M params")

params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
opt_cfg = AdamWConfig(lr=6e-4, warmup_steps=args.steps // 10,
                      total_steps=args.steps)
step = jax.jit(make_train_step(cfg, opt_cfg))
stream = dp.lm_stream(cfg, batch=args.batch, seq=args.seq)

t0 = time.time()
for i in range(args.steps):
    b = {k: jnp.asarray(v) for k, v in next(stream).items()}
    params, opt, m = step(params, opt, b)
    if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
        print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
              f"acc {float(m['accuracy']):.3f}  "
              f"({(time.time()-t0)/(i+1):.2f}s/step)")
print("# done — loss should be well below ln(vocab) = "
      f"{jnp.log(cfg.vocab):.2f}")
