"""FPX: adaptive mixed-precision inference for latency-sensitive LLM agents.

Reproduction of "Win Fast or Lose Slow" (NeurIPS 2025) as a multi-pod
JAX/Pallas framework.  Entry points:

    repro.configs.get_config("<arch>")     # the 10 assigned architectures
    repro.core.{quant,calibrate,assign,fpx,latency}   # the paper's method
    repro.models.transformer               # forward / prefill / decode
    repro.serving.engine.ServingEngine     # FPX-aware batched serving
    repro.bench.{hft,streetfighter}        # the two benchmarks
    repro.launch.{mesh,dryrun,train,serve} # distribution + launchers
"""
__version__ = "0.1.0"
