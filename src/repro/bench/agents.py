"""LLM decision agents for the benchmarks.

An agent = a sim-scale model from the zoo (the executable stand-in for a
Qwen2.5 checkpoint, DESIGN.md §7) + an FPX precision assignment + the
analytic TPU latency of the *full-scale* model it represents.

The causal chain the paper studies is preserved end to end:
  model size        -> decision accuracy (capacity vs the Teacher function)
  FPX gamma         -> real quantization noise in the forward pass
  avg bitwidth      -> modeled action latency
  latency           -> decayed fills (HFT) / stale whiffs (SF)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench.env import ACTION_BASE, Teacher
from repro.configs.base import ModelConfig
from repro.core import latency as lat_mod
from repro.models import transformer
from repro.models.modules import ExecContext
from repro.training.optim import AdamWConfig, adamw_init, adamw_update


# ---------------------------------------------------------------------------
# Decision-supervision training (the sim ladder's "pretraining")
# ---------------------------------------------------------------------------

def decision_batch(teacher: Teacher, rng: np.random.Generator, *,
                   batch: int, prompt_len: int) -> Dict[str, np.ndarray]:
    feats = rng.integers(0, teacher.n_values, (batch, teacher.n_features))
    toks = teacher.encode(feats, prompt_len + 1)
    labels = teacher.label(feats)
    toks[:, prompt_len] = ACTION_BASE + labels      # target action token
    mask = np.zeros_like(toks, dtype=np.float32)
    mask[:, prompt_len] = 1.0                        # loss only on the action
    return {"tokens": toks, "mask": mask}


def train_decision_model(cfg: ModelConfig, teacher: Teacher, *,
                         steps: int = 1500, batch: int = 64,
                         prompt_len: int = 32, lr: float = 2e-3,
                         seed: int = 0, log_every: int = 0):
    """Supervised training: prompt -> correct action token.  Returns
    (params, final_accuracy)."""
    from repro.training.train_step import make_train_step

    params = transformer.init_params(jax.random.PRNGKey(seed), cfg)
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(steps // 20, 10),
                          total_steps=steps, weight_decay=0.01)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    opt = adamw_init(params)
    rng = np.random.default_rng(seed + 1)
    acc = 0.0
    for i in range(steps):
        b = decision_batch(teacher, rng, batch=batch, prompt_len=prompt_len)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, m = step_fn(params, opt, b)
        acc = float(m["accuracy"])
        if log_every and (i % log_every == 0 or i == steps - 1):
            print(f"  [{cfg.name}] step {i}: loss={float(m['loss']):.3f} "
                  f"action-acc={acc:.3f}")
    return params, acc


def eval_decision_accuracy(params, cfg: ModelConfig, teacher: Teacher, *,
                           ctx: Optional[ExecContext] = None,
                           n: int = 512, prompt_len: int = 32,
                           n_actions: int = 3, seed: int = 99) -> float:
    ctx = ctx or ExecContext()
    rng = np.random.default_rng(seed)
    feats = rng.integers(0, teacher.n_values, (n, teacher.n_features))
    toks = jnp.asarray(teacher.encode(feats, prompt_len))
    labels = teacher.label(feats)
    logits = transformer.forward(params, cfg, {"tokens": toks}, ctx,
                                 unroll=True)
    act_logits = logits[:, -1, ACTION_BASE:ACTION_BASE + n_actions]
    pred = np.asarray(act_logits.argmax(-1))
    return float((pred == labels).mean())


# ---------------------------------------------------------------------------
# The agent
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AgentSpec:
    name: str
    sim_cfg: ModelConfig                 # executable model
    params: Any
    full_cfg: ModelConfig                # latency-model scale
    policy: Optional[Dict[str, int]] = None
    default_bits: int = 16
    avg_bits: float = 16.0
    gamma: float = 0.0
    prompt_len_real: int = 512           # the paper's observation prompts
    gen_tokens: int = 16                 # action phrase length


class LLMAgent:
    """decide(obs) -> (action, latency_s); scoring jitted once per policy."""

    def __init__(self, spec: AgentSpec, *, n_actions: int = 3,
                 hw: lat_mod.Hardware = lat_mod.V5E,
                 latency_floor_s: float = 0.0,
                 latency_override_s: Optional[float] = None):
        self.spec = spec
        self.n_actions = n_actions
        ctx = ExecContext(policy=spec.policy, default_bits=spec.default_bits)
        cfg = spec.sim_cfg

        def score(params, tokens):
            logits = transformer.forward(params, cfg, {"tokens": tokens},
                                         ctx, unroll=True)
            return logits[:, -1, ACTION_BASE:ACTION_BASE + n_actions]

        self._score = jax.jit(score)
        if latency_override_s is not None:
            self.latency_s = latency_override_s
        else:
            self.latency_s = lat_mod.decision_latency(
                spec.full_cfg, prompt_len=spec.prompt_len_real,
                gen_tokens=spec.gen_tokens, w_bits=spec.avg_bits, hw=hw)
        self.latency_s = max(self.latency_s, latency_floor_s)

    def decide(self, obs: Dict[str, Any]) -> Tuple[int, float]:
        toks = jnp.asarray(obs["tokens"])[None, :]
        act = int(np.asarray(self._score(self.spec.params, toks)).argmax())
        return act, self.latency_s
