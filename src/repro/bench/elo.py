"""ELO rating (Elo, 1967) — the paper's StreetFighter metric."""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple


def expected(ra: float, rb: float) -> float:
    return 1.0 / (1.0 + 10 ** ((rb - ra) / 400.0))


def update(ra: float, rb: float, score_a: float, k: float = 16.0
           ) -> Tuple[float, float]:
    ea = expected(ra, rb)
    return ra + k * (score_a - ea), rb + k * ((1 - score_a) - (1 - ea))


def tournament(names: Sequence[str], play, *, rounds_per_pair: int = 40,
               k: float = 16.0, base: float = 0.0,
               seed: int = 0) -> Dict[str, float]:
    """Round-robin: ``play(i, j, round)`` returns 1.0 if i wins else 0.0.

    The paper reports ELO *deltas* around 0 (Table 1/3); ``base=0``
    matches that convention."""
    ratings = {n: base for n in names}
    for i in range(len(names)):
        for j in range(i + 1, len(names)):
            for r in range(rounds_per_pair):
                s = play(i, j, seed * 100_000 + r)
                ratings[names[i]], ratings[names[j]] = update(
                    ratings[names[i]], ratings[names[j]], s, k)
    return ratings
