"""The latency-sensitive agent decision task contract (paper Sec. 3.1).

    r = sum_t R(a_{t+Dt} | E_{t+Dt})          (paper Eq. 5)

The environment *advances while the agent thinks*: ``step`` takes the
action AND the inference latency ``Dt`` that produced it, and scores the
action against the environment state at execution time — not at
observation time.  Both benchmarks implement this contract.

Observations are token sequences (the "prompt"); hidden task-relevant
structure is embedded in feature tokens via a random *teacher* function
that agents must learn to decode — the executable analogue of "correctly
interpreting market conditions / game state" (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np

# token-protocol layout within the sim vocab (512)
PAD, BOS = 0, 1
ACTION_BASE = 2           # action ids occupy [2, 2+n_actions)
FEAT_BASE = 16            # feature tokens start here


@dataclasses.dataclass
class Teacher:
    """Random ground-truth decision function over K categorical features.

    A *chained lookup*: ``s_0 = f_0; s_i = T_i[s_{i-1}, f_i]; label = s_K
    mod n_classes`` with random tables T_i.  Function composition of depth
    K needs circuit depth ~K: shallow models plateau, deeper/wider models
    keep climbing — the capacity-graded difficulty the paper's Qwen ladder
    supplies.  (A smooth random-MLP teacher is NOT capacity-graded: every
    sim-scale model saturates it — measured before switching.)  The deep
    composition is also fragile to weight noise, which is what makes FP4
    quantization visibly costly.

    ``hidden``/``temperature`` kept for config compatibility: ``hidden``
    scales nothing here; chain length = n_features."""
    n_features: int
    n_values: int
    n_classes: int
    seed: int = 0
    hidden: int = 64
    temperature: float = 0.5

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.tables = rng.integers(
            0, self.n_values,
            size=(self.n_features, self.n_values, self.n_values))

    def label(self, feats: np.ndarray) -> np.ndarray:
        """feats: (..., K) ints -> (...) class labels."""
        f = np.atleast_2d(feats)
        state = f[..., 0].copy()
        for i in range(1, self.n_features):
            state = self.tables[i][state, f[..., i]]
        out = state % self.n_classes
        return out.reshape(feats.shape[:-1]) if feats.ndim > 1 else out[0]

    def logits(self, feats: np.ndarray) -> np.ndarray:
        lab = self.label(feats)
        return np.eye(self.n_classes)[lab] / max(self.temperature, 1e-3)

    def encode(self, feats: np.ndarray, prompt_len: int) -> np.ndarray:
        """Feature ints -> token prompt (BOS + feature tokens + PAD)."""
        toks = FEAT_BASE + feats * 1 + \
            (np.arange(feats.shape[-1]) * self.n_values)
        out = np.full((*feats.shape[:-1], prompt_len), PAD, np.int32)
        out[..., 0] = BOS
        k = feats.shape[-1]
        out[..., 1:1 + k] = toks
        return out


class LatencySensitiveEnv:
    """Abstract env: observe -> (think for Dt) -> act against evolved state."""

    n_actions: int = 3

    def reset(self, seed: int = 0) -> Dict[str, Any]:
        raise NotImplementedError

    def observe(self) -> Dict[str, Any]:
        """Returns {"tokens": (prompt_len,) int32, ...context...}."""
        raise NotImplementedError

    def step(self, action: int, latency_s: float) -> Tuple[float, bool, Dict]:
        """Apply ``action`` computed with ``latency_s`` thinking time.
        Returns (reward, done, info).  The env advances by ``latency_s``
        before the action lands."""
        raise NotImplementedError
