"""HFTBench: high-frequency trading benchmark (paper Sec. 3.2).

Mechanics per the paper:
  * per-second market tape with transient bid-ask gap events ("arbitrage
    windows", Appendix A) that decay linearly in seconds;
  * inference triggers only when the margin exceeds threshold b (2%);
  * the exchange fills faster agents at better prices — a **linearly
    decaying price-advantage model of response time**;
  * a cooling window t (1 minute) between evaluations;
  * metric: cumulative **daily yield** on $10,000 starting capital.

The Polygon.io NVDA/AMZN 2024-08-05 tape is license-gated; the generator
reproduces its statistics (GBM mid price + Poisson gap events with
seconds-scale linear decay, cf. paper Fig. 3).  Whether a gap is a real
opportunity (and its direction: buy-side or sell-side) is encoded in the
observation's feature tokens through the Teacher function — reading the
tape correctly is exactly what separates the model ladder (paper: "smaller
LLMs often fail to capture such complex financial patterns").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.bench.env import LatencySensitiveEnv, Teacher

HOLD, BUY, SELL = 0, 1, 2


@dataclasses.dataclass
class HFTConfig:
    day_seconds: int = 6 * 3600 + 1800        # 6.5h trading session
    margin_threshold: float = 0.02            # b = 2%
    cooling_s: float = 60.0                   # t = 1 min
    initial_cash: float = 10_000.0
    gap_rate_per_min: float = 1.2             # arbitrage windows per minute
    gap_edge: Tuple[float, float] = (0.02, 0.045)  # initial mispricing range
    decay_s: Tuple[float, float] = (1.0, 3.0)      # linear decay horizon
    trap_frac: float = 0.35       # fraction of windows that are traps (HOLD)
    position_frac: float = 0.25   # capital per trade
    fee: float = 2e-4             # per-side transaction cost
    n_features: int = 8   # chain length (Teacher hops)
    n_values: int = 8
    prompt_len: int = 32
    teacher_seed: int = 7
    teacher_hidden: int = 96
    teacher_temp: float = 0.4


class HFTBench(LatencySensitiveEnv):
    n_actions = 3

    def __init__(self, cfg: Optional[HFTConfig] = None):
        self.cfg = cfg or HFTConfig()
        self.teacher = Teacher(self.cfg.n_features, self.cfg.n_values,
                               n_classes=3, seed=self.cfg.teacher_seed,
                               hidden=self.cfg.teacher_hidden,
                               temperature=self.cfg.teacher_temp)

    # ------------------------------------------------------------------
    def reset(self, seed: int = 0) -> Dict[str, Any]:
        c = self.cfg
        self.rng = np.random.default_rng(seed)
        self.cash = c.initial_cash
        self.t = 0.0
        self.last_trade_t = -1e9
        # schedule gap events over the session
        n_ev = self.rng.poisson(c.gap_rate_per_min * c.day_seconds / 60)
        times = np.sort(self.rng.uniform(0, c.day_seconds, n_ev))
        self.events = []
        for et in times:
            feats = self.rng.integers(0, c.n_values, c.n_features)
            cls = int(self.teacher.label(feats))          # 0 HOLD-trap,1 BUY,2 SELL
            edge = self.rng.uniform(*c.gap_edge)
            decay = self.rng.uniform(*c.decay_s)
            self.events.append(dict(t=et, feats=feats, cls=cls, edge=edge,
                                    decay=decay))
        self.ev_i = 0
        self.trades = 0
        return {"events": len(self.events)}

    # ------------------------------------------------------------------
    def next_window(self) -> Optional[Dict[str, Any]]:
        """Advance to the next tradable arbitrage window (margin > b and
        outside the cooling window); None when the session is over."""
        c = self.cfg
        while self.ev_i < len(self.events):
            ev = self.events[self.ev_i]
            if ev["t"] < self.last_trade_t + c.cooling_s or ev["edge"] < c.margin_threshold:
                self.ev_i += 1
                continue
            self.t = ev["t"]
            self._cur = ev
            return self.observe()
        return None

    def observe(self) -> Dict[str, Any]:
        ev = self._cur
        toks = self.teacher.encode(ev["feats"], self.cfg.prompt_len)
        return {"tokens": toks, "edge": ev["edge"], "t": self.t,
                "cash": self.cash}

    # ------------------------------------------------------------------
    def step(self, action: int, latency_s: float) -> Tuple[float, bool, Dict]:
        """Execute against the decayed window (paper's queue-position model):
        captured edge = edge * max(0, 1 - Dt/decay) when the direction is
        right; wrong-direction trades pay the (decayed-to-0) adverse edge;
        HOLD is always 0."""
        c = self.cfg
        ev = self._cur
        self.ev_i += 1
        pnl = 0.0
        if action != HOLD:
            self.trades += 1
            self.last_trade_t = ev["t"]
            frac_left = max(0.0, 1.0 - latency_s / ev["decay"])
            stake = self.cash * c.position_frac
            if action == ev["cls"]:
                # right side: capture whatever edge is left after Dt
                pnl = stake * (ev["edge"] * frac_left - 2 * c.fee)
            elif ev["cls"] == HOLD:
                # trap: the "gap" was noise about to revert — full giveback
                pnl = -stake * (ev["edge"] + 2 * c.fee)
            else:
                # wrong side of a real move: the adverse fill does NOT decay
                # (you bought what was about to drop) — the asymmetry is what
                # makes quality matter as much as speed (paper Sec. 3.2)
                pnl = -stake * (ev["edge"] + 2 * c.fee)
            self.cash += pnl
        done = self.ev_i >= len(self.events) or self.cash <= 0
        return pnl, done, {"cash": self.cash, "edge": ev["edge"]}

    # ------------------------------------------------------------------
    def daily_yield(self) -> float:
        return 100.0 * (self.cash - self.cfg.initial_cash) / self.cfg.initial_cash


def run_session(env: HFTBench, agent, *, seed: int = 0,
                max_events: Optional[int] = None) -> Dict[str, Any]:
    """Drive one trading day: agent.decide(obs) -> (action, latency_s)."""
    env.reset(seed)
    n = 0
    while True:
        obs = env.next_window()
        if obs is None:
            break
        action, latency = agent.decide(obs)
        _, done, _ = env.step(action, latency)
        n += 1
        if done or (max_events and n >= max_events):
            break
    return {"daily_yield": env.daily_yield(), "trades": env.trades,
            "windows": n, "cash": env.cash}
