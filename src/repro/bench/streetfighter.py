"""StreetFighter benchmark: real-time frame-stepped combat (paper Sec. 3.3).

The DIAMBRA ROM emulator is license/hardware-gated; this engine reproduces
the latency-relevant mechanics:

  * the game advances every FRAME (50 ms) regardless of whether an agent has
    responded — while a model thinks, its fighter idles (vulnerable);
  * each action takes a fixed in-game duration once it lands (~200 ms
    slots: the paper's "effective frame rate of around 5 actions/sec" —
    latency below one slot yields no further benefit, exactly the paper's
    observed floor);
  * actions are computed from the observation at decision *start*; by the
    time they execute, range/opponent state may have changed and the move
    whiffs — the core latency penalty;
  * combat triangle: attack beats idle/approach, block beats attack,
    grab-range heavy beats block... the *correct* counter given the visible
    state pattern is the Teacher label the models must learn (the paper's
    "well-prompted small LLMs can produce effective actions").

Matches are scored by remaining-HP win/loss; ELO across pairings
(bench.elo) reproduces the paper's Table 1/3 protocol.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.bench.env import Teacher

IDLE, APPROACH, ATTACK, BLOCK, HEAVY = 0, 1, 2, 3, 4
N_ACTIONS = 5

#: damage dealt by (my action, opponent action) when in range
_DMG = np.zeros((N_ACTIONS, N_ACTIONS))
_DMG[ATTACK, IDLE] = 8;   _DMG[ATTACK, APPROACH] = 8
_DMG[ATTACK, ATTACK] = 5  # trade
_DMG[ATTACK, HEAVY] = 7   # light beats slow heavy startup
_DMG[HEAVY, IDLE] = 14;  _DMG[HEAVY, APPROACH] = 14
_DMG[HEAVY, BLOCK] = 10   # heavy cracks block
_DMG[BLOCK, ATTACK] = 0   # blocked


@dataclasses.dataclass
class SFConfig:
    frame_s: float = 0.05            # 20 fps simulation
    action_slot_s: float = 0.2       # ~5 actions/sec cap (paper Sec. 5.3)
    max_hp: float = 100.0
    round_time_s: float = 60.0
    n_features: int = 8   # chain length (Teacher hops)
    n_values: int = 6
    prompt_len: int = 24
    teacher_seed: int = 21
    teacher_hidden: int = 96
    teacher_temp: float = 0.4


class SFGame:
    """Two-agent real-time duel."""

    def __init__(self, cfg: Optional[SFConfig] = None):
        self.cfg = cfg or SFConfig()
        # teacher maps visible state pattern -> best-response action
        self.teacher = Teacher(self.cfg.n_features, self.cfg.n_values,
                               n_classes=N_ACTIONS, seed=self.cfg.teacher_seed,
                               hidden=self.cfg.teacher_hidden,
                               temperature=self.cfg.teacher_temp)

    def reset(self, seed: int = 0):
        c = self.cfg
        self.rng = np.random.default_rng(seed)
        self.hp = [c.max_hp, c.max_hp]
        self.t = 0.0
        self.next_decision = [0.0, 0.0]   # when each side may act next
        self.situation = self._new_situation()
        return self.observe(0), self.observe(1)

    def _new_situation(self):
        """A 'situation' is the current engagement pattern; its feature
        vector determines which action the teacher deems correct.  It
        mutates over time — the source of staleness penalties."""
        feats = self.rng.integers(0, self.cfg.n_values, self.cfg.n_features)
        return {"feats": feats, "born": self.t,
                "ttl": self.rng.uniform(0.25, 0.8)}   # situations change fast

    def observe(self, side: int) -> Dict[str, Any]:
        toks = self.teacher.encode(self.situation["feats"], self.cfg.prompt_len)
        return {"tokens": toks, "t": self.t, "hp": tuple(self.hp),
                "side": side}

    def _advance(self, dt: float):
        self.t += dt
        if self.t - self.situation["born"] > self.situation["ttl"]:
            self.situation = self._new_situation()

    def play(self, agent0, agent1, *, seed: int = 0,
             max_decisions: int = 400) -> Dict[str, Any]:
        """Run one round.  Each agent: decide(obs) -> (action, latency_s).

        Timeline per side: observe at t; think for latency; action lands at
        t + latency (floored to the action-slot cadence); scored against the
        situation at landing time."""
        self.reset(seed)
        c = self.cfg
        agents = (agent0, agent1)
        decisions = 0
        pend: list = [None, None]     # (land_t, action, obs_situation_id)
        while self.t < c.round_time_s and min(self.hp) > 0 and \
                decisions < max_decisions:
            # let both sides decide when free
            for s in (0, 1):
                if pend[s] is None and self.t >= self.next_decision[s]:
                    obs = self.observe(s)
                    a, lat = agents[s].decide(obs)
                    # the game consumes inputs on the action-slot grid: any
                    # latency below one slot lands on the same boundary
                    # (paper Sec. 5.3: no benefit past ~5 actions/sec)
                    raw = self.t + max(lat, 1e-3)
                    land = np.ceil(raw / c.action_slot_s) * c.action_slot_s
                    pend[s] = (land, int(a), self.situation["feats"].copy())
                    decisions += 1
            # advance to next landing
            lands = [p[0] for p in pend if p is not None]
            if not lands:
                self._advance(c.frame_s)
                continue
            t_next = min(lands)
            while self.t < t_next:
                self._advance(min(c.frame_s, t_next - self.t))
            # resolve all landings at this instant
            acts = {s: None for s in (0, 1)}
            for s in (0, 1):
                if pend[s] is not None and pend[s][0] <= self.t + 1e-9:
                    acts[s] = pend[s]
                    pend[s] = None
            cur = self.situation["feats"]
            best_now = int(self.teacher.label(cur))
            for s in (0, 1):
                if acts[s] is None:
                    continue
                _, a, obs_feats = acts[s]
                stale = not np.array_equal(obs_feats, cur)
                opp = 1 - s
                if stale:
                    # the situation changed while thinking: the move whiffs
                    # and the recovery frames are punished
                    self.hp[s] -= 4.0
                elif a == best_now:
                    # the teacher's label is the true best response: only
                    # the correct counter connects (anything else is
                    # deflected) — this is what "decision quality" means here
                    self.hp[opp] -= 8.0
                # ready to decide again as soon as the action has landed
                self.next_decision[s] = self.t
        w = 0 if self.hp[0] > self.hp[1] else (1 if self.hp[1] > self.hp[0] else -1)
        return {"winner": w, "hp": tuple(self.hp), "t": self.t,
                "decisions": decisions}


def play_match(agent0, agent1, *, rounds: int = 3, seed: int = 0,
               cfg: Optional[SFConfig] = None) -> int:
    """Best-of-n with side alternation; returns 0/1 winner.

    Sides swap each round and exact ties split by seed parity — otherwise
    identical agents would systematically "lose" by slot order."""
    game = SFGame(cfg)
    wins = [0, 0]
    hp_sum = [0.0, 0.0]
    for r in range(rounds):
        flip = (seed + r) % 2 == 1
        a, b = (agent1, agent0) if flip else (agent0, agent1)
        res = game.play(a, b, seed=seed * 1000 + r)
        hp = res["hp"][::-1] if flip else res["hp"]
        if hp[0] != hp[1]:
            wins[0 if hp[0] > hp[1] else 1] += 1
        hp_sum[0] += hp[0]
        hp_sum[1] += hp[1]
    if wins[0] != wins[1]:
        return 0 if wins[0] > wins[1] else 1
    if hp_sum[0] != hp_sum[1]:
        return 0 if hp_sum[0] > hp_sum[1] else 1
    return seed % 2
