"""Checkpointing: msgpack-framed numpy tensor store (no orbax offline).

Layout: a single ``.ckpt`` file holding a manifest (tree structure, dtypes,
shapes) followed by raw little-endian tensor payloads.  Restore is
sharding-aware: pass ``sharding_tree`` (or a single sharding) to place
tensors as they load — on the dry-run meshes this is how a real deployment
would stream a checkpoint into a sharded model.
"""
from __future__ import annotations

import io
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, tree: Any, *, step: Optional[int] = None) -> None:
    leaves, treedef = _flatten(tree)
    manifest = {
        "treedef": str(treedef),
        "step": step,
        "tensors": [{"dtype": str(np.asarray(l).dtype),
                     "shape": list(np.asarray(l).shape)} for l in leaves],
    }
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(manifest))
        for leaf in leaves:
            arr = np.ascontiguousarray(np.asarray(leaf))
            f.write(msgpack.packb(arr.tobytes()))
    os.replace(tmp, path)


def restore(path: str, like: Any, *, sharding_tree: Any = None) -> Any:
    """``like``: a pytree (of arrays or ShapeDtypeStructs) giving structure."""
    leaves_like, treedef = _flatten(like)
    shardings = None
    if sharding_tree is not None:
        shardings = jax.tree_util.tree_leaves(
            sharding_tree, is_leaf=lambda x: hasattr(x, "device_set") or x is None)
        if len(shardings) == 1:
            shardings = shardings * len(leaves_like)

    unpacker_leaves = []
    with open(path, "rb") as f:
        unpacker = msgpack.Unpacker(f, raw=False, max_buffer_size=1 << 31)
        manifest = next(iter(unpacker))
        for i, meta in enumerate(manifest["tensors"]):
            buf = next(iter(unpacker))
            arr = np.frombuffer(buf, dtype=np.dtype(meta["dtype"])).reshape(meta["shape"])
            want = leaves_like[i]
            assert tuple(arr.shape) == tuple(want.shape), (arr.shape, want.shape)
            if shardings is not None and shardings[i] is not None:
                arr = jax.device_put(arr, shardings[i])
            else:
                arr = jnp.asarray(arr)
            unpacker_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, unpacker_leaves)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(f.split("_")[1].split(".")[0])
             for f in os.listdir(ckpt_dir)
             if f.startswith("step_") and f.endswith(".ckpt")]
    return max(steps) if steps else None


def save_step(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step}.ckpt")
    save(path, tree, step=step)
    return path


def restore_step(ckpt_dir: str, step: int, like: Any, **kw) -> Any:
    return restore(os.path.join(ckpt_dir, f"step_{step}.ckpt"), like, **kw)
