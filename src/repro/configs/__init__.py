"""Config registry: ``get_config("<arch-id>")`` for every assigned arch."""
from repro.configs.base import ModelConfig, InputShape, INPUT_SHAPES

from repro.configs.xlstm_1_3b import CONFIG as _xlstm
from repro.configs.llama_3_2_vision_11b import CONFIG as _llama_vision
from repro.configs.gemma_7b import CONFIG as _gemma7b
from repro.configs.dbrx_132b import CONFIG as _dbrx
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.gemma3_4b import CONFIG as _gemma3_4b
from repro.configs.granite_moe_1b_a400m import CONFIG as _granite
from repro.configs.gemma3_12b import CONFIG as _gemma3_12b
from repro.configs.starcoder2_15b import CONFIG as _starcoder2
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.qwen_sim import QWEN_FULL, QWEN_SIM, SIM_TO_FULL

#: The 10 architectures assigned to this paper.
ASSIGNED = {
    "xlstm-1.3b": _xlstm,
    "llama-3.2-vision-11b": _llama_vision,
    "gemma-7b": _gemma7b,
    "dbrx-132b": _dbrx,
    "hymba-1.5b": _hymba,
    "gemma3-4b": _gemma3_4b,
    "granite-moe-1b-a400m": _granite,
    "gemma3-12b": _gemma3_12b,
    "starcoder2-15b": _starcoder2,
    "seamless-m4t-medium": _seamless,
}

REGISTRY = {**ASSIGNED, **QWEN_FULL, **QWEN_SIM}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = ["ModelConfig", "InputShape", "INPUT_SHAPES", "ASSIGNED",
           "REGISTRY", "QWEN_FULL", "QWEN_SIM", "SIM_TO_FULL", "get_config"]
