"""Model configuration schema + input-shape suite + reduced smoke variants."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    source: str = ""                  # citation (paper / model card)

    ffn_kind: str = "swiglu"          # swiglu | geglu | gelu
    rope_theta: float = 10000.0
    qk_norm: bool = False
    norm_plus_one: bool = False       # gemma-style (1+g) RMSNorm
    embed_scale: bool = False         # gemma multiplies embeddings by sqrt(d)
    tie_embeddings: bool = True

    # attention pattern
    sliding_window: Optional[int] = None   # window for local layers
    local_global_ratio: int = 0            # e.g. 5 => 5 local : 1 global; 0 => all global
    attn_bias: bool = False

    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 2.0

    # ssm / hybrid (mamba branch)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4

    # xlstm
    slstm_every: int = 0              # every k-th block is sLSTM (7:1 -> 8)
    mlstm_proj_factor: float = 2.0

    # vlm
    cross_attn_every: int = 0         # every k-th layer is a cross-attn layer
    vision_tokens: int = 1601
    vision_dim: int = 0               # 0 => d_model

    # audio / enc-dec
    encdec: bool = False
    n_enc_layers: int = 0
    audio_frames: int = 4096

    @property
    def d_inner(self) -> int:
        return self.d_model * self.ssm_expand

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode (bounded or linear per-token state)."""
        return self.arch_type in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, dff, L = self.d_model, self.d_ff, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.arch_type == "ssm":
            di = int(d * self.mlstm_proj_factor)
            per_m = d * 2 * di + 3 * di * di + di * 2 * self.n_heads + di * d
            per_s = d * 4 * d * 2 + d * int(d * 8 / 3) * 2
            n_s = L // self.slstm_every if self.slstm_every else 0
            return emb + (L - n_s) * per_m + n_s * per_s
        attn = d * self.n_heads * self.head_dim * 2 + \
            d * self.n_kv_heads * self.head_dim * 2
        if self.n_experts:
            ffn = self.n_experts * (3 if self.ffn_kind != "gelu" else 2) * d * dff \
                + d * self.n_experts
        else:
            ffn = (3 if self.ffn_kind != "gelu" else 2) * d * dff
        per = attn + ffn
        if self.arch_type == "hybrid":
            di = self.d_inner
            per += d * 2 * di + di * (64 + 2 * self.ssm_state) + 64 * di + di * d
        if self.cross_attn_every:
            per += (attn // self.cross_attn_every)
        total = emb + L * per
        if self.encdec:
            total += self.n_enc_layers * (attn + ffn)
        return total

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.n_experts:
            return self.n_params
        d, dff, L = self.d_model, self.d_ff, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * self.n_heads * self.head_dim * 2 + \
            d * self.n_kv_heads * self.head_dim * 2
        ffn = self.top_k * (3 if self.ffn_kind != "gelu" else 2) * d * dff
        return emb + L * (attn + ffn)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2-ish layers, d_model<=512, <=4 experts."""
        changes = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2),
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=min(self.head_dim, 64),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
        )
        if self.n_experts:
            changes["n_experts"] = min(self.n_experts, 4)
            changes["top_k"] = min(self.top_k, 2)
        if self.slstm_every:
            changes["n_layers"] = 2
            changes["slstm_every"] = 2     # 1 mLSTM + 1 sLSTM
            changes["n_heads"] = 2
        if self.cross_attn_every:
            changes["n_layers"] = 2
            changes["cross_attn_every"] = 2
            changes["vision_tokens"] = 16
            changes["vision_dim"] = 0
        if self.encdec:
            changes["n_enc_layers"] = 2
            changes["audio_frames"] = 16
        if self.local_global_ratio:
            changes["local_global_ratio"] = 1  # 1 local : 1 global in 2 layers
        if self.sliding_window:
            # never *grow* the window past the original (a config could
            # legitimately carry a tiny window), and keep it >= 1: the
            # paged serving path sizes window-group page demand as
            # ceil(window/page_size) + 1 for *any* page size — no
            # divisibility requirement — but a zero/negative window would
            # mask away a query's own position and break the live-page
            # bound.  The smoke window deliberately stays un-aligned to
            # typical page sizes so reduced configs exercise the
            # window-spans-a-page-boundary paths.
            changes["sliding_window"] = max(1, min(self.sliding_window, 8))
        if self.n_kv_heads > min(self.n_heads, 4):
            changes["n_kv_heads"] = changes["n_heads"]
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
