"""dbrx-132b [moe]: 16 experts top-4, fine-grained [hf:databricks/dbrx-base]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    arch_type="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,           # per-expert FFN width
    vocab=100352,
    source="hf:databricks/dbrx-base",
    ffn_kind="swiglu",
    rope_theta=500000.0,
    tie_embeddings=False,
    n_experts=16,
    top_k=4,
    capacity_factor=2.0,  # dbrx is dropless; cf=2 makes drops negligible (DESIGN.md)
)
