"""gemma3-4b [dense]: 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt family scaling]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    arch_type="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab=262144,
    source="hf:google/gemma-3-1b-pt",
    ffn_kind="geglu",
    norm_plus_one=True,
    embed_scale=True,
    qk_norm=True,
    tie_embeddings=True,
    sliding_window=1024,
    local_global_ratio=5,    # 5 sliding-window layers : 1 global layer
    rope_theta=1000000.0,
)
