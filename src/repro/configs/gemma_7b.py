"""gemma-7b [dense]: GeGLU, head_dim=256 [arXiv:2403.08295]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    arch_type="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,        # 7b is MHA (MQA is the 2b variant)
    head_dim=256,
    d_ff=24576,
    vocab=256000,
    source="arXiv:2403.08295",
    ffn_kind="geglu",
    norm_plus_one=True,
    embed_scale=True,
    tie_embeddings=True,
)
