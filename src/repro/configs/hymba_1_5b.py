"""hymba-1.5b [hybrid]: parallel attn + mamba heads per block [arXiv:2411.13676].

Each block runs an attention branch and a mamba (selective-SSM) branch on the
same input in parallel and mean-combines their normalized outputs.  Most
layers use sliding-window attention; layers {0, mid, last} are global
full-attention (per the paper).  Meta-tokens are not modeled (DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    source="arXiv:2411.13676",
    ffn_kind="swiglu",
    tie_embeddings=True,
    sliding_window=1024,
    local_global_ratio=0,   # hybrid uses explicit global set {first, mid, last}
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
)
