"""llama-3.2-vision-11b [vlm]: cross-attn image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision].

The ViT vision encoder + projector is the allowed modality-frontend stub:
``input_specs`` feeds precomputed patch embeddings (B, 1601, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    arch_type="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    ffn_kind="swiglu",
    rope_theta=500000.0,
    tie_embeddings=False,
    cross_attn_every=5,   # layers 4, 9, ... gain gated cross-attn to image tokens
    vision_tokens=1601,
    vision_dim=0,         # projector output width == d_model
)
