"""The paper's own model family: Qwen2.5 {1.5B, 3B, 7B, 14B} (arXiv:2412.15115).

Two variants are provided:

* ``QWEN_FULL``  — the real architecture shapes, used for latency modeling
  (Table 4 ladder) and dry-run analysis.
* ``QWEN_SIM``   — proportionally scaled-down "sim-scale" models that are
  actually *trained and run* inside HFTBench / StreetFighter on CPU.  The
  widths keep the real family's ordering (bigger => more capacity), so the
  paper's causal chain (model size x precision -> quality; bits -> latency)
  is preserved while remaining executable in this container (DESIGN.md §7).
"""
from repro.configs.base import ModelConfig


def _qwen(name, n_layers, d_model, n_heads, n_kv_heads, d_ff, vocab=151936):
    return ModelConfig(
        name=name, arch_type="dense", n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=n_kv_heads, head_dim=d_model // n_heads,
        d_ff=d_ff, vocab=vocab, source="arXiv:2412.15115",
        ffn_kind="swiglu", rope_theta=1000000.0, tie_embeddings=True,
        attn_bias=True,
    )


QWEN_FULL = {
    "qwen2.5-1.5b": _qwen("qwen2.5-1.5b", 28, 1536, 12, 2, 8960),
    "qwen2.5-3b": _qwen("qwen2.5-3b", 36, 2048, 16, 2, 11008),
    "qwen2.5-7b": _qwen("qwen2.5-7b", 28, 3584, 28, 4, 18944),
    "qwen2.5-14b": _qwen("qwen2.5-14b", 48, 5120, 40, 8, 13824),
}

# sim-scale: ~1000x fewer params, same relative ordering and depth ratios.
QWEN_SIM = {
    "qwen-sim-1.5b": _qwen("qwen-sim-1.5b", 4, 48, 4, 2, 128, vocab=512),
    "qwen-sim-3b": _qwen("qwen-sim-3b", 5, 64, 4, 2, 192, vocab=512),
    "qwen-sim-7b": _qwen("qwen-sim-7b", 6, 96, 4, 2, 256, vocab=512),
    "qwen-sim-14b": _qwen("qwen-sim-14b", 8, 128, 4, 2, 384, vocab=512),
}

#: Map a sim model to the full model whose latency it represents.
SIM_TO_FULL = {
    "qwen-sim-1.5b": "qwen2.5-1.5b",
    "qwen-sim-3b": "qwen2.5-3b",
    "qwen-sim-7b": "qwen2.5-7b",
    "qwen-sim-14b": "qwen2.5-14b",
}
