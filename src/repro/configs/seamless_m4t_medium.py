"""seamless-m4t-medium [audio]: encoder-decoder, multimodal [arXiv:2308.11596].

The mel-spectrogram + conv feature extractor is the allowed modality-frontend
stub: ``input_specs`` feeds precomputed frame embeddings (B, frames, 1024) to
the transformer encoder; the 12-layer decoder cross-attends to encoder output.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    n_layers=12,          # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,        # MHA
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    source="arXiv:2308.11596",
    ffn_kind="gelu",
    tie_embeddings=True,
    encdec=True,
    n_enc_layers=12,
    audio_frames=4096,
)
