"""starcoder2-15b [dense]: GQA + RoPE + 4096 sliding window [arXiv:2402.19173]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    arch_type="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    source="arXiv:2402.19173",
    ffn_kind="gelu",
    attn_bias=True,
    tie_embeddings=True,
    sliding_window=4096,   # the real model's SWA => bounded cache, long_500k eligible
    rope_theta=100000.0,
)
