"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks, 7:1 ratio [arXiv:2405.04517]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,            # mLSTM heads
    n_kv_heads=4,
    head_dim=1024,        # d_inner(=2*d_model) / n_heads
    d_ff=0,               # mLSTM blocks carry their own up/down projection
    vocab=50304,
    source="arXiv:2405.04517",
    slstm_every=8,        # blocks 7, 15, ... are sLSTM => 7:1 mLSTM:sLSTM
    mlstm_proj_factor=2.0,
    tie_embeddings=True,
)
