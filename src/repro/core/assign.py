"""Precision assignment (paper Eq. 7).

Given per-layer calibration errors eps_l and a compression ratio gamma,
assign FP4 to the gamma*L most quantization-tolerant linear layers and FP8
to the rest:

    S_gamma = argmin_{|S| = gamma L} sum_{l in S} eps_l
    delta(l) = 4 if l in S_gamma else 8

Also provides the translation from unrolled layer names ("L{g}.L{s}.rel")
to the per-segment policy arrays that ride through scanned stacks
("super/local_inner/rel" -> (G, R) bit arrays) — see transformer.py.
"""
from __future__ import annotations

import re
from typing import Dict, Iterable, Optional, Set, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import segment_layout

#: Layers never demoted below 8 bits: tiny matmuls with outsized quality
#: impact (MoE router) and the output head.
PINNED_PATTERNS = (r"\.router$", r"^lm_head$", r"\.xgate$")


def is_pinned(name: str) -> bool:
    return any(re.search(p, name) for p in PINNED_PATTERNS)


def assign_precision(eps: Dict[str, float], gamma: float,
                     pinned: Optional[Set[str]] = None) -> Dict[str, int]:
    """delta(l) in {4, 8} per layer name.  gamma in [0, 1]."""
    assert 0.0 <= gamma <= 1.0, gamma
    names = sorted(eps)
    eligible = [n for n in names if not is_pinned(n) and
                (pinned is None or n not in pinned)]
    k = int(round(gamma * len(names)))
    k = min(k, len(eligible))
    by_err = sorted(eligible, key=lambda n: eps[n])
    s_gamma = set(by_err[:k])
    return {n: (4 if n in s_gamma else 8) for n in names}


def avg_bits(assignment: Dict[str, int]) -> float:
    """Paper's "Bitwidth Avg" column."""
    if not assignment:
        return 16.0
    return float(np.mean(list(assignment.values())))


# ---------------------------------------------------------------------------
# Unrolled name -> scanned policy-array slot
# ---------------------------------------------------------------------------

_NAME = re.compile(r"^L(?P<a>\d+)(?:\.L(?P<b>\d+))?\.(?P<rel>.+)$|"
                   r"^Lx(?P<xg>\d+)\.(?P<xrel>.+)$")


def name_to_slot(cfg: ModelConfig, name: str) -> Tuple[str, Tuple[int, ...]]:
    """Map an unrolled calibration name to (policy_key, index)."""
    m = _NAME.match(name)
    if not m:
        return name, ()              # un-prefixed (lm_head etc.): static key
    if m.group("xg") is not None:    # VLM cross-KV precompute scan
        return f"cross/{m.group('xrel')}", (int(m.group("xg")),)
    a = int(m.group("a"))
    b = m.group("b")
    rel = m.group("rel")
    t = cfg.arch_type

    if t == "ssm":
        if b is not None:
            return f"super/mlstm_inner/{rel}", (a, int(b))
        return f"super/{rel}", (a,)
    if t == "vlm":
        ce = cfg.cross_attn_every
        G = cfg.n_layers // ce
        if b is not None:
            return f"groups/self_inner/{rel}", (a, int(b))
        if a < G:                     # cross block inside group a
            return f"groups/{rel}", (a,)
        return f"tail/{rel}", (a - G * ce,)
    if t == "hybrid":
        for seg, idxs in segment_layout(cfg):
            if a in idxs:
                return f"{seg}/{rel}", (idxs.index(a),)
        raise KeyError(name)
    if t == "audio":
        seg = "enc" if rel.startswith("enc") else "dec"
        return f"{seg}/{rel}", (a,)
    if cfg.local_global_ratio:
        sb = cfg.local_global_ratio + 1
        G = cfg.n_layers // sb
        if b is not None:
            return f"super/local_inner/{rel}", (a, int(b))
        if a < G:
            return f"super/{rel}", (a,)
        return f"tail/{rel}", (a - G * sb,)
    return f"layers/{rel}", (a,)


def build_policy(cfg: ModelConfig, assignment: Dict[str, int],
                 default_bits: int = 8) -> Dict[str, object]:
    """Convert a per-name assignment into a scanned-forward policy dict.

    Returns {policy_key: (…)-shaped int array} plus static int entries.
    Unfilled slots default to ``default_bits``."""
    slots: Dict[str, Dict[Tuple[int, ...], int]] = {}
    static: Dict[str, int] = {}
    for nm, bits in assignment.items():
        key, idx = name_to_slot(cfg, nm)
        if not idx:
            static[key] = bits
            continue
        slots.setdefault(key, {})[idx] = bits

    policy: Dict[str, object] = dict(static)
    for key, entries in slots.items():
        ndim = len(next(iter(entries)))
        shape = tuple(max(i[d] for i in entries) + 1 for d in range(ndim))
        arr = np.full(shape, default_bits, dtype=np.int32)
        for idx, bits in entries.items():
            arr[idx] = bits
        policy[key] = arr
    return policy
