"""Offline calibration (paper Sec. 4.2, Algorithm 1).

Runs FP16 inference on a held-out LM calibration set, simulating each linear
layer's FP4 execution on the same inputs (the rest of the network stays
FP16), and records the relative quantization error

    eps_l = ||A_l^fp16 - A_l^fp4||_2 / ||A_l^fp16||_2.

The capture happens inside ``modules.quant_linear`` (ExecContext.collect),
so it covers every linear in every architecture — attention projections,
FFNs, MoE expert stacks, SSM projections, cross-attention — with zero
per-arch code.  Wikitext-2 is license-gated offline; the calibration stream
is a synthetic LM corpus with matched statistics (see data.pipeline).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.models.modules import ExecContext


def calibrate(params, cfg: ModelConfig, batches: Iterable[Dict[str, jax.Array]],
              ) -> Dict[str, float]:
    """Return eps_l per linear-layer name (unrolled names: ``L{i}.<rel>``)."""
    collect: Dict[str, List[jax.Array]] = {}
    ctx = ExecContext(default_bits=16, collect=collect)
    for batch in batches:
        transformer.forward(params, cfg, batch, ctx, unroll=True)
    return {k: float(jnp.mean(jnp.stack(v))) for k, v in collect.items()}


def perplexity(params, cfg: ModelConfig, batches: Iterable[Dict[str, jax.Array]],
               ctx: Optional[ExecContext] = None, unroll: bool = True) -> float:
    """Token perplexity of (optionally quantized) model on an eval stream.

    Used for the paper's Table-2 PPL column and as the FPX controller's
    quality signal."""
    ctx = ctx or ExecContext()
    total_nll, total_tok = 0.0, 0
    for batch in batches:
        logits = transformer.forward(params, cfg, batch, ctx, unroll=unroll)
        tokens = batch["tokens"]
        logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
        tgt = tokens[:, 1:]
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        total_nll += float(nll.sum())
        total_tok += int(tgt.size)
    return float(jnp.exp(total_nll / max(total_tok, 1)))
