"""FPX: the adaptive mixed-precision controller (paper Sec. 4).

Joins the pieces: calibration (eps_l) -> precision assignment (S_gamma) ->
latency model -> candidate grid over (model size x gamma).  Two selection
modes, matching the paper's usage:

* ``select_for_budget`` — "meet any specified latency target": pick the
  candidate with the best predicted quality whose predicted action latency
  fits the budget.
* ``OnlineSelector`` — the adaptive loop for dynamic environments: an
  epsilon-greedy bandit over the candidate grid driven by realized task
  rewards (the paper reports the best-performing setting per task after a
  gamma sweep; the bandit automates that sweep online).
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core import assign as assign_mod
from repro.core import latency as lat_mod
from repro.core.latency import Hardware, V5E

GAMMA_GRID = tuple(round(0.1 * i, 1) for i in range(11))   # paper Sec. 5.1


@dataclasses.dataclass
class Candidate:
    """One point on the FPX grid: a model at a compression ratio gamma."""
    model_name: str
    cfg: ModelConfig                   # latency-model config (full scale)
    gamma: float
    assignment: Dict[str, int]        # per-layer delta(l) from Eq. 7
    avg_bits: float
    latency_s: float                  # predicted action latency
    quality: Optional[float] = None   # e.g. -PPL or eval score (higher=better)

    @property
    def policy(self) -> Dict[str, int]:
        return dict(self.assignment)


def make_grid(models: Sequence[Tuple[str, ModelConfig, Dict[str, float]]],
              *, gammas: Sequence[float] = GAMMA_GRID,
              prompt_len: int = 512, gen_tokens: int = 16,
              hw: Hardware = V5E) -> List[Candidate]:
    """Build the (model x gamma) candidate grid.

    ``models``: (name, latency_cfg, eps_l calibration dict) triples."""
    grid = []
    for name, cfg, eps in models:
        for g in gammas:
            a = assign_mod.assign_precision(eps, g)
            bits = assign_mod.avg_bits(a)
            t = lat_mod.decision_latency(cfg, prompt_len=prompt_len,
                                         gen_tokens=gen_tokens,
                                         w_bits=bits, hw=hw)
            grid.append(Candidate(model_name=name, cfg=cfg, gamma=g,
                                  assignment=a, avg_bits=bits, latency_s=t))
    return grid


def select_for_budget(grid: Sequence[Candidate], budget_s: float,
                      quality: Callable[[Candidate], float]) -> Candidate:
    """Best predicted quality under a hard latency budget.

    Falls back to the fastest candidate when nothing fits (the paper's
    "win fast" regime: a timely mediocre action beats a late good one)."""
    feasible = [c for c in grid if c.latency_s <= budget_s]
    if not feasible:
        return min(grid, key=lambda c: c.latency_s)
    return max(feasible, key=quality)


def select_for_slack(grid: Sequence[Candidate], deadline_s: float,
                     waits_s: Sequence[float],
                     quality: Callable[[Candidate], float]) -> int:
    """``select_for_budget`` for a loaded fleet: each candidate carries a
    queue wait, so the effective latency held against the deadline is
    ``service + wait`` (the request's *remaining slack* after queueing).
    Quality ties break toward the least-loaded candidate, which makes a
    pool of identical engines degrade gracefully into least-loaded
    round-robin.  Returns the index into ``grid``."""
    adj = [dataclasses.replace(c, latency_s=c.latency_s + w)
           for c, w in zip(grid, waits_s)]
    pick = select_for_budget(adj, deadline_s,
                             lambda c: (quality(c), -c.latency_s))
    return adj.index(pick)


def pareto_frontier(grid: Sequence[Candidate],
                    quality: Callable[[Candidate], float]) -> List[Candidate]:
    """Latency/quality Pareto set (Figure 1a)."""
    pts = sorted(grid, key=lambda c: c.latency_s)
    out, best_q = [], -math.inf
    for c in pts:
        q = quality(c)
        if q > best_q:
            out.append(c)
            best_q = q
    return out


class OnlineSelector:
    """Epsilon-greedy bandit over the candidate grid, driven by task reward.

    The paper sweeps gamma offline and deploys the best setting per task;
    this selector performs the same search online so an agent adapts its
    (model size, gamma) to "real-time demands" (paper abstract)."""

    def __init__(self, grid: Sequence[Candidate], *, epsilon: float = 0.15,
                 seed: int = 0, prior_quality: Optional[Callable] = None):
        self.grid = list(grid)
        self.eps = epsilon
        self.rng = random.Random(seed)
        self.counts = [0] * len(self.grid)
        self.means = [0.0] * len(self.grid)
        if prior_quality is not None:
            # warm-start with the latency-model + PPL prior
            self.means = [prior_quality(c) for c in self.grid]

    def choose(self) -> int:
        if self.rng.random() < self.eps:
            return self.rng.randrange(len(self.grid))
        return max(range(len(self.grid)), key=lambda i: self.means[i])

    def update(self, idx: int, reward: float) -> None:
        self.counts[idx] += 1
        n = self.counts[idx]
        self.means[idx] += (reward - self.means[idx]) / n

    def best(self) -> Candidate:
        return self.grid[max(range(len(self.grid)), key=lambda i: self.means[i])]
