"""FPX: the adaptive mixed-precision controller (paper Sec. 4).

Joins the pieces: calibration (eps_l) -> precision assignment (S_gamma) ->
latency model -> candidate grid over (model size x gamma).  Two selection
modes, matching the paper's usage:

* ``select_for_budget`` — "meet any specified latency target": pick the
  candidate with the best predicted quality whose predicted action latency
  fits the budget.
* ``OnlineSelector`` — the adaptive loop for dynamic environments: an
  epsilon-greedy bandit over the candidate grid driven by realized task
  rewards (the paper reports the best-performing setting per task after a
  gamma sweep; the bandit automates that sweep online).
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core import assign as assign_mod
from repro.core import latency as lat_mod
from repro.core.latency import Hardware, V5E

GAMMA_GRID = tuple(round(0.1 * i, 1) for i in range(11))   # paper Sec. 5.1


@dataclasses.dataclass(frozen=True)
class SpecPoint:
    """The speculation axis of the FPX grid: fast-draft / slow-verify
    decoding at draft depth ``k``.

    A candidate with a ``SpecPoint`` decodes in rounds: a cheap draft
    (the same weights at ``draft_bits``, or a smaller model named by
    ``draft_name`` in the analytic fleet) proposes ``k`` tokens, the
    candidate verifies them in one chunked forward, and an accept/reject
    sampler keeps the leading run that matches the verifier — so quality
    is the *verifier's* (greedy output is token-identical to dense
    decode), while throughput scales with the modeled per-token
    acceptance probability ``accept``.  ``core.latency.speculate_s``
    prices a round; expected emitted tokens per round is
    ``sum_{i=0..k} accept^i`` (every round emits at least the verifier's
    own token, at most ``k + 1`` with the bonus draw).
    """
    k: int
    accept: float = 0.8
    draft_bits: float = 4.0
    draft_name: Optional[str] = None   # analytic cross-model draft point

    def expected_tokens(self) -> float:
        return lat_mod.spec_expected_tokens(self.k, self.accept)


@dataclasses.dataclass
class Candidate:
    """One point on the FPX grid: a model at a compression ratio gamma,
    optionally decoding speculatively (``spec`` — the third grid axis,
    learned per traffic class by the router's ``OnlineSelector``)."""
    model_name: str
    cfg: ModelConfig                   # latency-model config (full scale)
    gamma: float
    assignment: Dict[str, int]        # per-layer delta(l) from Eq. 7
    avg_bits: float
    latency_s: float                  # predicted action latency
    quality: Optional[float] = None   # e.g. -PPL or eval score (higher=better)
    spec: Optional[SpecPoint] = None  # None = dense decode

    @property
    def policy(self) -> Dict[str, int]:
        return dict(self.assignment)


def make_grid(models: Sequence[Tuple[str, ModelConfig, Dict[str, float]]],
              *, gammas: Sequence[float] = GAMMA_GRID,
              prompt_len: int = 512, gen_tokens: int = 16,
              hw: Hardware = V5E) -> List[Candidate]:
    """Build the (model x gamma) candidate grid.

    ``models``: (name, latency_cfg, eps_l calibration dict) triples."""
    grid = []
    for name, cfg, eps in models:
        for g in gammas:
            a = assign_mod.assign_precision(eps, g)
            bits = assign_mod.avg_bits(a)
            t = lat_mod.decision_latency(cfg, prompt_len=prompt_len,
                                         gen_tokens=gen_tokens,
                                         w_bits=bits, hw=hw)
            grid.append(Candidate(model_name=name, cfg=cfg, gamma=g,
                                  assignment=a, avg_bits=bits, latency_s=t))
    return grid


def select_for_budget(grid: Sequence[Candidate], budget_s: float,
                      quality: Callable[[Candidate], float]) -> Candidate:
    """Best predicted quality under a hard latency budget.

    Falls back to the fastest candidate when nothing fits (the paper's
    "win fast" regime: a timely mediocre action beats a late good one)."""
    feasible = [c for c in grid if c.latency_s <= budget_s]
    if not feasible:
        return min(grid, key=lambda c: c.latency_s)
    return max(feasible, key=quality)


def select_for_slack(grid: Sequence[Candidate], deadline_s: float,
                     waits_s: Sequence[float],
                     quality: Callable[[Candidate], float]) -> int:
    """``select_for_budget`` for a loaded fleet: each candidate carries a
    queue wait, so the effective latency held against the deadline is
    ``service + wait`` (the request's *remaining slack* after queueing).
    Quality ties break toward the least-loaded candidate, which makes a
    pool of identical engines degrade gracefully into least-loaded
    round-robin.  Returns the index into ``grid``.

    Selection is index-based throughout: a pool may contain *duplicate*
    operating points (replicated engines) whose adjusted candidates
    compare equal, and an equality search (the old ``adj.index(pick)``)
    would always resolve to the first replica — silently mis-routing
    every pick of the later ones.  When nothing fits the deadline the
    pick degrades to the fastest effective candidate (wait + service):
    the paper's win-fast regime, never an error."""
    adj = [dataclasses.replace(c, latency_s=c.latency_s + w)
           for c, w in zip(grid, waits_s)]
    idxs = range(len(adj))
    feasible = [i for i in idxs if adj[i].latency_s <= deadline_s]
    if not feasible:
        return min(idxs, key=lambda i: (adj[i].latency_s, i))
    return max(feasible,
               key=lambda i: (quality(adj[i]), -adj[i].latency_s, -i))


def pareto_frontier(grid: Sequence[Candidate],
                    quality: Callable[[Candidate], float]) -> List[Candidate]:
    """Latency/quality Pareto set (Figure 1a)."""
    pts = sorted(grid, key=lambda c: c.latency_s)
    out, best_q = [], -math.inf
    for c in pts:
        q = quality(c)
        if q > best_q:
            out.append(c)
            best_q = q
    return out


class OnlineSelector:
    """Epsilon-greedy bandit over the candidate grid, driven by task reward.

    The paper sweeps gamma offline and deploys the best setting per task;
    this selector performs the same search online so an agent adapts its
    (model size, gamma) to "real-time demands" (paper abstract)."""

    def __init__(self, grid: Sequence[Candidate], *, epsilon: float = 0.15,
                 seed: int = 0, prior_quality: Optional[Callable] = None,
                 prior_weight: int = 1):
        self.grid = list(grid)
        self.eps = epsilon
        self.rng = random.Random(seed)
        self.counts = [0] * len(self.grid)
        self.means = [0.0] * len(self.grid)
        if prior_quality is not None:
            # warm-start with the latency-model + PPL prior; the prior
            # counts as ``prior_weight`` pseudo-observations so early
            # unlucky draws temper it instead of erasing it
            self.means = [prior_quality(c) for c in self.grid]
            self.counts = [int(prior_weight)] * len(self.grid)

    def choose(self, waits_s: Optional[Sequence[float]] = None, *,
               feasible: Optional[Sequence[bool]] = None,
               tol: float = 0.05) -> int:
        """Epsilon-greedy draw.  ``waits_s`` (one queue wait per candidate,
        e.g. engine backlogs) makes exploitation *load-aware*: among arms
        whose learned mean is within ``tol`` (relative) of the best, pick
        the least loaded.  Statistically equivalent arms — replicas of one
        operating point, or adjacent draft depths of the same verifier —
        then share load instead of the favorite saturating while its
        equals idle.

        ``feasible`` (one flag per arm) restricts the draw to arms whose
        predicted ``wait + service`` still meets the request's deadline:
        the bandit learns *quality*, but feasibility is known from the
        latency model, so a saturated favorite spills to the next-best
        arm instead of collecting guaranteed-zero rewards.  When no arm
        is feasible the draw falls back to the least-loaded arm — the
        paper's "win fast" regime."""
        idxs = list(range(len(self.grid)))
        if feasible is not None:
            idxs = [i for i in idxs if feasible[i]]
            if not idxs:
                if waits_s is not None:
                    return min(range(len(self.grid)),
                               key=lambda i: (waits_s[i], i))
                idxs = list(range(len(self.grid)))
        if self.rng.random() < self.eps:
            return self.rng.choice(idxs)
        best = max(self.means[i] for i in idxs)
        if waits_s is None:
            return next(i for i in idxs if self.means[i] == best)
        near = [i for i in idxs
                if self.means[i] >= best - tol * abs(best) - 1e-12]
        return min(near, key=lambda i: (waits_s[i], i))

    def update(self, idx: int, reward: float) -> None:
        self.counts[idx] += 1
        n = self.counts[idx]
        self.means[idx] += (reward - self.means[idx]) / n

    def best(self) -> Candidate:
        return self.grid[max(range(len(self.grid)), key=lambda i: self.means[i])]
