"""Analytic TPU latency model (the reproduction's stand-in for wall-clock).

The paper measures end-to-end action latency on RTX 5090s (Table 4).  This
container is CPU-only with TPU v5e as the deployment target, so latency is
*derived* from a per-layer roofline:

    t_layer = max(flops / peak(bits),  bytes(bits) / hbm_bw) + overhead

Weights at b bits move b/16 of the FP16 bytes — the first-order effect that
makes FP8 ~2x and FP4 ~4x faster in the paper's memory-bound decode regime.
8-bit (and in-kernel-dequantized 4-bit) matmuls run at the int8 MXU rate
(2x bf16).  W4A16-int adds a VPU dequant term, reproducing the paper's
observation that it loses to FP8 except at 14B (Table 4).

The same model drives HFTBench/StreetFighter agents and the FPX controller.
Per the paper (Sec. 4.1), the FP8->FP4 latency gain is uniform across linear
layers, so mixed-precision latency interpolates linearly in gamma.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs.base import ModelConfig

# TPU v5e hardware constants (per chip)
PEAK_BF16 = 197e12        # FLOP/s
PEAK_INT8 = 394e12        # FLOP/s (MXU int8 = 2x bf16)
HBM_BW = 819e9            # B/s
ICI_BW = 50e9             # B/s per link
VPU_DEQ = 5e11            # elem/s: VPU int4->bf16 dequant (W4A16 penalty)
DEQ_CALL_OVERHEAD = 20e-6  # s per linear: separate dequant kernel dispatch
LAYER_OVERHEAD = 4e-6     # s: per-block dispatch/fusion overhead
DCN_BW = 25e9             # B/s per host NIC (data-center network hop)
ICI_LAT_S = 1e-6          # s per ICI message (intra-host, chip-to-chip)
DCN_LAT_S = 25e-6         # s per DCN message (host-to-host)


@dataclasses.dataclass(frozen=True)
class Hardware:
    peak_bf16: float = PEAK_BF16
    peak_int8: float = PEAK_INT8
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW
    vpu_deq: float = VPU_DEQ
    layer_overhead: float = LAYER_OVERHEAD
    n_chips: int = 1
    #: interconnect terms (see :func:`xfer_s` / :func:`allreduce_s`):
    #: ICI is the intra-host chip fabric, DCN the between-hosts network
    dcn_bw: float = DCN_BW
    ici_lat_s: float = ICI_LAT_S
    dcn_lat_s: float = DCN_LAT_S


V5E = Hardware()


def _link(link: str, hw: Hardware) -> tuple:
    if link == "ici":
        return hw.ici_bw, hw.ici_lat_s
    if link == "dcn":
        return hw.dcn_bw, hw.dcn_lat_s
    raise ValueError(f"unknown link {link!r} (want 'ici' or 'dcn')")


def xfer_s(nbytes: float, link: str = "ici", hw: Hardware = V5E) -> float:
    """Point-to-point transfer time of ``nbytes`` over one ``link`` hop.

    The clock contract's interconnect term: ``latency + bytes /
    bandwidth``.  ``link="ici"`` is the intra-host chip fabric,
    ``link="dcn"`` the host-to-host network — what cross-host dispatch
    (prompt tokens out, response tokens back) costs the router."""
    if nbytes <= 0:
        return 0.0
    bw, lat = _link(link, hw)
    return lat + nbytes / bw


def allreduce_s(nbytes: float, n_chips: int, link: str = "ici",
                hw: Hardware = V5E) -> float:
    """Ring all-reduce of ``nbytes`` across ``n_chips`` over ``link``:
    ``2 * (n-1)/n`` traversals of the payload plus ``2 * (n-1)`` hop
    latencies (reduce-scatter + all-gather phases)."""
    if n_chips <= 1 or nbytes <= 0:
        return 0.0
    bw, lat = _link(link, hw)
    return 2.0 * (n_chips - 1) / n_chips * nbytes / bw \
        + 2.0 * (n_chips - 1) * lat


def tp_collective_s(cfg: ModelConfig, n_tokens: int, tp: int,
                    link: str = "ici", hw: Hardware = V5E) -> float:
    """Per-forward collective tax of ``tp``-way tensor parallelism: two
    all-reduces of the ``(n_tokens, d_model)`` bf16 activations per layer
    (the partial attention outputs after the o-projection, and the FFN
    down-projection's partial sums).  This is the term that makes a TP
    group spanning a DCN hop catastrophically slower than the same group
    on one host's ICI — the mispricing the fleet router must see."""
    if tp <= 1 or n_tokens <= 0:
        return 0.0
    per_layer = allreduce_s(n_tokens * cfg.d_model * 2.0, tp, link, hw)
    return 2.0 * cfg.n_layers * per_layer


def _bytes_per_weight(w_bits: int) -> float:
    return w_bits / 8.0


def _peak(w_bits: int, a_bits: int, hw: Hardware) -> float:
    if max(w_bits, a_bits) <= 8:
        return hw.peak_int8
    return hw.peak_bf16


def linear_time(d_in: int, d_out: int, n_tokens: int, *, w_bits: int,
                a_bits: Optional[int] = None, hw: Hardware = V5E,
                dequant_to_16: bool = False) -> float:
    """Roofline time for one (n_tokens, d_in) @ (d_in, d_out) matmul."""
    a_bits = a_bits if a_bits is not None else w_bits
    flops = 2.0 * n_tokens * d_in * d_out
    w_bytes = d_in * d_out * _bytes_per_weight(w_bits)
    a_bytes = n_tokens * (d_in + d_out) * (a_bits / 8.0)
    peak = hw.peak_bf16 if dequant_to_16 else _peak(w_bits, a_bits, hw)
    t_compute = flops / (peak * hw.n_chips)
    t_mem = (w_bytes + a_bytes) / (hw.hbm_bw * hw.n_chips)
    t = max(t_compute, t_mem)
    if dequant_to_16:
        # W4A16-int: a separate dequant pass per linear (paper Table 4's
        # "dequantization overhead").  Dominated by the fixed dispatch cost,
        # which is why the penalty hurts small models relatively more.
        t += DEQ_CALL_OVERHEAD + (d_in * d_out) / (hw.vpu_deq * hw.n_chips) * 0.01
    return t


def _per_layer_linears(cfg: ModelConfig):
    """(d_in, d_out, mult) triples for one block of each segment kind.

    mult scales token count (MoE expert FFNs process top_k x tokens)."""
    d, hd = cfg.d_model, cfg.head_dim
    q = cfg.n_heads * hd
    kv = cfg.n_kv_heads * hd
    attn = [(d, q, 1.0), (d, kv, 1.0), (d, kv, 1.0), (q, d, 1.0)]
    if cfg.arch_type == "ssm":
        di = int(d * cfg.mlstm_proj_factor)
        return attn_free_xlstm(cfg, d, di)
    if cfg.n_experts:
        ff = [(d, cfg.n_experts, 1.0)]           # router
        n_ff = 3 if cfg.ffn_kind != "gelu" else 2
        ff += [(d, cfg.d_ff, float(cfg.top_k))] * (n_ff - 1)
        ff += [(cfg.d_ff, d, float(cfg.top_k))]
    else:
        n_ff = 3 if cfg.ffn_kind != "gelu" else 2
        ff = [(d, cfg.d_ff, 1.0)] * (n_ff - 1) + [(cfg.d_ff, d, 1.0)]
    out = attn + ff
    if cfg.arch_type == "hybrid":
        di = cfg.d_inner
        dt_rank = max(8, d // 16)
        out += [(d, 2 * di, 1.0), (di, dt_rank + 2 * cfg.ssm_state, 1.0),
                (dt_rank, di, 1.0), (di, d, 1.0)]
    return out


def attn_free_xlstm(cfg: ModelConfig, d: int, di: int):
    return [(d, 2 * di, 1.0), (di, di, 1.0), (di, di, 1.0), (di, di, 1.0),
            (di, 2 * cfg.n_heads, 1.0), (di, d, 1.0)]


def attn_layer_groups(cfg: ModelConfig) -> list:
    """``(n_layers, window)`` attention layer groups of one stack.

    The single definition of "which layers attend over how much context"
    shared by :func:`step_latency` and the paged-attention cost models
    below — a windowed (local) group's effective context is
    ``min(context, window)``, a global group's is ``context``.  This is
    the per-layer-group pricing that makes sliding-window stacks
    (starcoder2-class uniform windows, gemma3-class local:global hybrids)
    project cheaper decode steps, which admission projections, the
    analytic batcher, and the fleet router all inherit.

    Attention-free stacks (ssm) have no groups.  Hybrid (hymba-class)
    stacks keep the historical all-windowed pricing: their three global
    layers are a fixed small minority and the hybrid arch is not yet on
    the paged path (see ``transformer.paged_decode_step``)."""
    if cfg.arch_type == "ssm":
        return []
    W = cfg.sliding_window
    if not W:
        return [(cfg.n_layers, None)]
    if cfg.local_global_ratio:
        sb = cfg.local_global_ratio + 1
        n_global = cfg.n_layers // sb
        return [(cfg.n_layers - n_global, W), (n_global, None)]
    return [(cfg.n_layers, W)]


def step_latency(cfg: ModelConfig, *, n_tokens: int, context: int = 0,
                 w_bits: float = 16, a_bits: Optional[int] = None,
                 hw: Hardware = V5E, dequant_to_16: bool = False) -> float:
    """One forward step: ``n_tokens`` new tokens against ``context`` cache.

    ``w_bits`` may be fractional (mixed precision): time interpolates
    linearly between the bracketing integer widths — per the paper, the
    FP8/FP4 latency delta is uniform across layers, so gamma-mixing is
    exactly linear interpolation."""
    if w_bits not in (4, 8, 16):
        lo, hi = (4, 8) if w_bits < 8 else (8, 16)
        frac = (w_bits - lo) / (hi - lo)
        t_lo = step_latency(cfg, n_tokens=n_tokens, context=context,
                            w_bits=lo, a_bits=a_bits, hw=hw)
        t_hi = step_latency(cfg, n_tokens=n_tokens, context=context,
                            w_bits=hi, a_bits=a_bits, hw=hw)
        return frac * t_hi + (1 - frac) * t_lo

    w_bits = int(w_bits)
    total = 0.0
    linears = _per_layer_linears(cfg)
    for (d_in, d_out, mult) in linears:
        total += cfg.n_layers * linear_time(
            d_in, d_out, max(1, int(n_tokens * mult)), w_bits=w_bits,
            a_bits=a_bits, hw=hw, dequant_to_16=dequant_to_16)
    # attention over the KV cache (always 16-bit mechanics, per the paper)
    if cfg.arch_type != "ssm" and context:
        for n_l, window in attn_layer_groups(cfg):
            if not n_l:
                continue
            c_eff = min(context, window) if window else context
            kb = _kv_cache_bytes(cfg, c_eff)
            fl = _attn_flops(cfg, n_tokens, c_eff)
            total += n_l * max(fl / (hw.peak_bf16 * hw.n_chips),
                               kb * n_tokens / (hw.hbm_bw * hw.n_chips))
    # embedding + head
    total += linear_time(cfg.d_model, cfg.vocab, n_tokens,
                         w_bits=max(8, w_bits), hw=hw)
    total += cfg.n_layers * hw.layer_overhead
    return total


def _kv_cache_bytes(cfg: ModelConfig, context: int) -> float:
    """HBM bytes of one layer's K+V for ``context`` tokens (16-bit
    mechanics, per the paper — attention math never quantizes).  Shared by
    :func:`step_latency` and the paged-attention cost models below: the
    fused/gather pricing difference is computed by subtraction, so the two
    sides must agree on this formula byte-for-byte."""
    return 2.0 * context * cfg.n_kv_heads * cfg.head_dim * 2.0


def _attn_flops(cfg: ModelConfig, n_tokens: int, context: int) -> float:
    """Score + combine flops of ``n_tokens`` queries over ``context`` keys,
    one layer (shared with :func:`step_latency` — see
    :func:`_kv_cache_bytes`)."""
    return 4.0 * n_tokens * context * cfg.n_heads * cfg.head_dim


def _paged_eff_traffic(impl: str, context: int, padded_ctx: Optional[int],
                       window: Optional[int] = None) -> tuple:
    """(effective context, traffic multiplier) of a paged-attention impl
    for one attention layer group — the single definition both the
    step-time and the HBM-bytes models dispatch on, so the two columns of
    ``table_paged_attn`` cannot desynchronize.

    ``window``: the group's sliding window, if any.  The fused kernel
    reads only the retained in-window pages of a local layer
    (``serving.kv_cache`` frees out-of-window pages mid-flight), so its
    effective context is ``min(context, window)``.  The gather path
    materializes the whole *padded block-table extent* regardless — the
    table keeps full logical width even for window groups (freed entries
    point at the dummy page) — so a window buys it nothing."""
    if impl == "fused":
        return (min(context, window) if window else context), 1.0
    if impl == "gather":
        return max(context, padded_ctx or context), 3.0
    raise ValueError(f"unknown paged-attention impl {impl!r}")


def paged_attn_step_s(cfg: ModelConfig, *, n_lanes: int, context: int,
                      impl: str = "fused", padded_ctx: Optional[int] = None,
                      hw: Hardware = V5E) -> float:
    """Per-decode-step attention cost of the *paged* serving path.

    ``impl="fused"``: the flash paged-attention kernel — each lane's K/V
    pages are read once, straight from the pool, and only the lane's
    *actual* ``context`` tokens move.  This equals the attention term
    already inside :func:`step_latency`, so profiles priced "fused" are
    unchanged from the historical clock.

    ``impl="gather"``: the gather+SDPA path the fused kernel replaces —
    the whole *padded* table extent (``padded_ctx``, i.e. block-table
    width x page size) is materialized as a contiguous copy (pool read +
    buffer write) and then re-read by the dense masked SDPA: ~3x the HBM
    traffic, scaled by the padding rather than the context.  Its score
    flops also run over every padded slot.

    Both implementations price per attention layer *group*
    (:func:`attn_layer_groups`): sliding-window layers cost the fused
    kernel only ``min(context, window)`` — the lever that makes
    gemma3-class and starcoder2-class stacks cheap on the paged path.
    """
    if cfg.arch_type == "ssm" or context <= 0:
        return 0.0
    total = 0.0
    for n_l, window in attn_layer_groups(cfg):
        if not n_l:
            continue
        eff, traffic = _paged_eff_traffic(impl, context, padded_ctx, window)
        fl = _attn_flops(cfg, n_lanes, eff)
        kb = _kv_cache_bytes(cfg, eff) * n_lanes * traffic
        total += n_l * max(fl / (hw.peak_bf16 * hw.n_chips),
                           kb / (hw.hbm_bw * hw.n_chips))
    return total


def paged_attn_hbm_bytes(cfg: ModelConfig, *, n_lanes: int, context: int,
                         impl: str = "fused",
                         padded_ctx: Optional[int] = None) -> float:
    """Modeled per-decode-step K/V HBM bytes of the paged attention path,
    summed over layers — the quantity the fused kernel exists to shrink
    (see :func:`paged_attn_step_s` for the two implementations; windowed
    layer groups move only their retained ``min(context, window)`` tokens
    on the fused path)."""
    if cfg.arch_type == "ssm" or context <= 0:
        return 0.0
    total = 0.0
    for n_l, window in attn_layer_groups(cfg):
        eff, traffic = _paged_eff_traffic(impl, context, padded_ctx, window)
        total += n_l * _kv_cache_bytes(cfg, eff) * n_lanes * traffic
    return total


def chunk_attn_s(cfg: ModelConfig, *, chunk: int, context: int,
                 hw: Hardware = V5E) -> float:
    """Attention-over-prior-pages cost of absorbing a ``chunk``-token
    prefill chunk against ``context`` already-written tokens (per lane):
    each layer streams the lane's existing K/V once (flash semantics) and
    pays the chunk x context score/combine flops.  Zero for the first
    chunk — the length-aware term that makes chunked-prefill pricing grow
    with how much of the prompt is already in the pages, exactly like the
    kernel's work does.  Sliding-window layer groups stream only their
    retained ``min(context, window)`` prior tokens."""
    if cfg.arch_type == "ssm" or context <= 0:
        return 0.0
    total = 0.0
    for n_l, window in attn_layer_groups(cfg):
        if not n_l:
            continue
        c_eff = min(context, window) if window else context
        fl = _attn_flops(cfg, chunk, c_eff)
        kb = _kv_cache_bytes(cfg, c_eff)
        total += n_l * max(fl / (hw.peak_bf16 * hw.n_chips),
                           kb / (hw.hbm_bw * hw.n_chips))
    return total


def resume_prefill_s(cfg: ModelConfig, *, n_new: int, context: int = 0,
                     w_bits: float = 16.0, hw: Hardware = V5E) -> float:
    """Prefill charge for absorbing ``n_new`` prompt tokens on top of
    ``context`` tokens already resident in the request's pages — the
    shared pricing of a chunked-prefill chunk *and* of a prefix-cache
    hit's remainder.  The skipped/absorbed prefix costs nothing here (its
    compute already happened, possibly in another request's prefill); the
    remainder pays its own weight-read (:func:`step_latency`) plus the
    length-aware attend over the adopted pages (:func:`chunk_attn_s`).
    ``context=0`` degrades to a plain monolithic prefill."""
    t = step_latency(cfg, n_tokens=n_new, w_bits=w_bits, hw=hw)
    if context:
        t += chunk_attn_s(cfg, chunk=n_new, context=context, hw=hw)
    return t


def spec_expected_tokens(k: int, accept: float) -> float:
    """Expected tokens emitted by one fast-draft / slow-verify round at
    draft depth ``k`` and per-token acceptance probability ``accept``:
    the verifier's own token always lands, plus the leading run of
    accepted drafts — ``sum_{i=0..k} accept^i``, between 1 (nothing
    accepted) and ``k + 1`` (full accept + bonus)."""
    a = min(max(accept, 0.0), 1.0)
    return sum(a ** i for i in range(k + 1))


def speculate_round_s(cfg: ModelConfig, *, k: int, n_lanes: int = 1,
                      context: int = 0, w_bits: float = 16,
                      draft_bits: float = 4.0,
                      draft_cfg: Optional[ModelConfig] = None,
                      hw: Hardware = V5E) -> float:
    """One speculative round: ``k`` draft decode steps (the draft
    operating point — same weights at ``draft_bits``, or a smaller
    ``draft_cfg`` in the cross-model fleet form) followed by the
    verifier's single chunked forward over ``[t0, d1..dk]``.

    The verify pays one weight read for ``n_lanes * (k + 1)`` tokens of
    linears — this is the speculation dividend: in the memory-bound
    decode regime the verifier prices ``k + 1`` tokens at roughly one
    dense step — plus flash chunk attention over each lane's written
    context (:func:`chunk_attn_s`, fused-kernel semantics)."""
    dcfg = draft_cfg or cfg
    t = 0.0
    for j in range(k):
        t += step_latency(dcfg, n_tokens=n_lanes, context=context + j,
                          w_bits=draft_bits, hw=hw)
    t += step_latency(cfg, n_tokens=n_lanes * (k + 1), w_bits=w_bits, hw=hw)
    t += n_lanes * chunk_attn_s(cfg, chunk=k + 1, context=context, hw=hw)
    return t


def speculate_s(cfg: ModelConfig, *, k: int, accept: float,
                n_lanes: int = 1, context: int = 0, w_bits: float = 16,
                draft_bits: float = 4.0,
                draft_cfg: Optional[ModelConfig] = None,
                hw: Hardware = V5E) -> float:
    """Effective per-token decode time under speculation — the
    :func:`step_latency` analog admission projections hold against
    deadlines: one round advances every lane ``spec_expected_tokens``
    tokens, so the effective inter-token time is ``round /
    E[tokens]``.  Above the break-even acceptance rate this is *below*
    the dense step time; below it, speculation is priced honestly as a
    loss (the deadline-aware policy then collapses to dense)."""
    return speculate_round_s(cfg, k=k, n_lanes=n_lanes, context=context,
                             w_bits=w_bits, draft_bits=draft_bits,
                             draft_cfg=draft_cfg, hw=hw) \
        / spec_expected_tokens(k, accept)


def decision_latency(cfg: ModelConfig, *, prompt_len: int = 512,
                     gen_tokens: int = 16, w_bits: float = 16,
                     hw: Hardware = V5E, dequant_to_16: bool = False) -> float:
    """End-to-end action latency: prefill the observation prompt, then
    autoregressively emit the action tokens.  This is what the paper's
    Table 4 per-action milliseconds measure."""
    t = step_latency(cfg, n_tokens=prompt_len, w_bits=w_bits, hw=hw,
                     dequant_to_16=dequant_to_16)
    for i in range(gen_tokens):
        t += step_latency(cfg, n_tokens=1, context=prompt_len + i,
                          w_bits=w_bits, hw=hw, dequant_to_16=dequant_to_16)
    return t


def gamma_to_avg_bits(gamma: float, base_bits: int = 8) -> float:
    """Paper's "Bitwidth Avg": gamma of the layers at 4 bits, rest at 8."""
    return 4.0 * gamma + base_bits * (1.0 - gamma)


def quant_ladder(cfg: ModelConfig, *, prompt_len: int = 512,
                 gen_tokens: int = 16, hw: Hardware = V5E) -> Dict[str, float]:
    """The paper's Table-4 scheme ladder, in seconds."""
    return {
        "FP16": decision_latency(cfg, prompt_len=prompt_len,
                                 gen_tokens=gen_tokens, w_bits=16, hw=hw),
        "FP8": decision_latency(cfg, prompt_len=prompt_len,
                                gen_tokens=gen_tokens, w_bits=8, hw=hw),
        "W4A16(int)": decision_latency(cfg, prompt_len=prompt_len,
                                       gen_tokens=gen_tokens, w_bits=4,
                                       hw=hw, dequant_to_16=True),
        "FP4": decision_latency(cfg, prompt_len=prompt_len,
                                gen_tokens=gen_tokens, w_bits=4, hw=hw),
    }
