"""Floating-point quantization primitives (paper Eq. 1-2).

Implements the paper's FP quantization exactly:

    Q(X) = round(X / scale_X),   scale_X = max|X| / range_b   if max|X| > range_b
                                           1                   otherwise

with range_b = 240 for FP8 (E4M3, clipped per the paper) and 6 for FP4
(E2M1). ``round`` here means round-to-nearest representable value of the
target FP format, which is what the hardware cast performs.

Two execution styles are provided:

* ``fake_quant`` — quantize-dequantize in one step.  Used for calibration
  (Algorithm 1), for CPU-side evaluation of quantized models, and inside
  scanned layer stacks where the bitwidth is a traced per-layer value.
* ``quantize``/``dequantize`` + ``QTensor`` — materialized low-bit storage
  (fp8 as ``float8_e4m3fn``; fp4 as packed uint8 codes, two per byte) used by
  the serving engine and the Pallas kernels, where the HBM byte footprint is
  the point.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Format definitions
# ---------------------------------------------------------------------------

#: Paper Sec. 2.1: dynamic range used for rescaling.
FP8_RANGE = 240.0
FP4_RANGE = 6.0

#: E2M1 representable magnitudes (sign handled separately).
FP4_POS_GRID = np.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], dtype=np.float32)
#: Full signed grid, index = 4-bit code (sign bit + 3 magnitude bits).
#: code layout: code & 0x7 indexes magnitude, code & 0x8 is the sign bit.
FP4_GRID = np.concatenate([FP4_POS_GRID, -FP4_POS_GRID]).astype(np.float32)
#: Midpoints between successive magnitudes, for round-to-nearest(-even-ish).
_FP4_MIDPOINTS = (FP4_POS_GRID[1:] + FP4_POS_GRID[:-1]) / 2.0

RANGES = {4: FP4_RANGE, 8: FP8_RANGE, 16: None}


def _compute_scale(x: jax.Array, range_b: float, axis=None) -> jax.Array:
    """Absmax scale: max|X| / range_b (guarding all-zero tensors).

    NOTE (DESIGN.md §2): paper Eq. 1 as written only rescales when
    max|X| > range_b, which would leave real LLM weights (std ~1e-2) on the
    coarse end of the E2M1 grid and destroy the model at any gamma —
    contradicting the paper's own working results.  Hardware FP4/FP8 kernels
    (and SVDQuant, which the paper builds on) use bidirectional absmax
    scaling; we follow the hardware semantics."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    amax = amax.astype(jnp.float32)
    scale = jnp.where(amax > 0.0, amax / range_b, 1.0)
    return scale


def round_to_fp4_grid(x: jax.Array) -> jax.Array:
    """Round values (already scaled into [-6, 6]) to the E2M1 grid."""
    sign = jnp.sign(x)
    mag = jnp.clip(jnp.abs(x), 0.0, FP4_RANGE)
    # bucketize against midpoints -> index into FP4_POS_GRID
    idx = jnp.searchsorted(jnp.asarray(_FP4_MIDPOINTS), mag, side="right")
    return sign * jnp.asarray(FP4_POS_GRID)[idx]


def round_to_fp8_grid(x: jax.Array) -> jax.Array:
    """Round values to E4M3 via hardware cast semantics, clipped to ±240."""
    x = jnp.clip(x, -FP8_RANGE, FP8_RANGE)
    return x.astype(jnp.float8_e4m3fn).astype(jnp.float32)


def fake_quant(x: jax.Array, bits: int, axis=None) -> jax.Array:
    """Quantize-dequantize ``x`` at ``bits`` (static python int) precision."""
    if bits >= 16:
        return x
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    scale = _compute_scale(xf, RANGES[bits], axis=axis)
    xs = xf / scale
    q = round_to_fp4_grid(xs) if bits == 4 else round_to_fp8_grid(xs)
    return (q * scale).astype(orig_dtype)


def fake_quant_dynamic(x: jax.Array, bits: jax.Array, axis=None) -> jax.Array:
    """``fake_quant`` where ``bits`` is a traced scalar in {4, 8, 16}.

    Used inside ``lax.scan`` over layer stacks, where the FPX assignment
    differs per layer but the code path must be trace-static.  Both grids are
    evaluated (elementwise, cheap vs. the matmul they feed) and selected.
    """
    q4 = fake_quant(x, 4, axis=axis)
    q8 = fake_quant(x, 8, axis=axis)
    bits = jnp.asarray(bits)
    return jnp.where(bits <= 4, q4, jnp.where(bits <= 8, q8, x))


# ---------------------------------------------------------------------------
# Materialized low-bit storage
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """A quantized tensor: low-bit payload + fp32 scale + static metadata."""

    data: jax.Array          # fp8: float8_e4m3fn, same shape; fp4: packed uint8
    scale: jax.Array         # fp32 scalar or per-axis
    bits: int                # 4 or 8 (static)
    shape: tuple             # logical (unpacked) shape
    axis: Optional[int]      # per-channel axis, or None for per-tensor

    def tree_flatten(self):
        return (self.data, self.scale), (self.bits, self.shape, self.axis)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, scale = children
        bits, shape, axis = aux
        return cls(data, scale, bits, shape, axis)

    @property
    def nbytes_payload(self) -> int:
        n = int(np.prod(self.shape))
        return n if self.bits == 8 else (n + 1) // 2


def fp4_encode(x_scaled: jax.Array) -> jax.Array:
    """Map scaled values to 4-bit codes (sign bit | magnitude index)."""
    sign = (x_scaled < 0).astype(jnp.uint8)
    mag = jnp.clip(jnp.abs(x_scaled), 0.0, FP4_RANGE)
    idx = jnp.searchsorted(jnp.asarray(_FP4_MIDPOINTS), mag, side="right")
    return (sign << 3) | idx.astype(jnp.uint8)


def fp4_decode(codes: jax.Array) -> jax.Array:
    """Map 4-bit codes back to E2M1 grid values (fp32)."""
    return jnp.asarray(FP4_GRID)[codes.astype(jnp.int32)]


def fp4_pack(codes: jax.Array) -> jax.Array:
    """Pack pairs of 4-bit codes along the last axis into uint8."""
    assert codes.shape[-1] % 2 == 0, "last dim must be even to pack"
    lo = codes[..., 0::2]
    hi = codes[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def fp4_unpack(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`fp4_pack`."""
    lo = packed & 0x0F
    hi = (packed >> 4) & 0x0F
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)


def quantize(x: jax.Array, bits: int, axis: Optional[int] = None) -> QTensor:
    """Materialize ``x`` at ``bits`` precision (paper Eq. 1)."""
    assert bits in (4, 8), bits
    xf = x.astype(jnp.float32)
    reduce_axes = None if axis is None else tuple(
        a for a in range(x.ndim) if a != (axis % x.ndim)
    )
    scale = _compute_scale(xf, RANGES[bits], axis=reduce_axes)
    if axis is None:
        scale = scale.reshape(())
    xs = xf / scale
    if bits == 8:
        data = jnp.clip(xs, -FP8_RANGE, FP8_RANGE).astype(jnp.float8_e4m3fn)
    else:
        data = fp4_pack(fp4_encode(xs))
    return QTensor(data=data, scale=scale, bits=bits, shape=tuple(x.shape), axis=axis)


def dequantize(q: QTensor, dtype=jnp.float32) -> jax.Array:
    if q.bits == 8:
        vals = q.data.astype(jnp.float32)
    else:
        vals = fp4_decode(fp4_unpack(q.data)).reshape(q.shape)
    return (vals * q.scale).astype(dtype)


def quant_matmul_ref(x: jax.Array, w: jax.Array, x_bits: int, w_bits: int) -> jax.Array:
    """Paper Eq. 2: XW ~= scale_X * scale_W * Q(X) Q(W)   (pure-jnp oracle)."""
    if x_bits >= 16 and w_bits >= 16:
        return x @ w
    xq = fake_quant(x, x_bits) if x_bits < 16 else x
    wq = fake_quant(w, w_bits) if w_bits < 16 else w
    return (xq.astype(jnp.float32) @ wq.astype(jnp.float32)).astype(x.dtype)


def relative_error(a_ref: jax.Array, a_q: jax.Array) -> jax.Array:
    """Paper Eq. 6: ||A_fp16 - A_fp4||_2 / ||A_fp16||_2."""
    num = jnp.linalg.norm((a_ref - a_q).astype(jnp.float32).reshape(-1))
    den = jnp.linalg.norm(a_ref.astype(jnp.float32).reshape(-1))
    return num / jnp.maximum(den, 1e-12)
