"""Synthetic LM data pipeline.

Wikitext-2 (the paper's calibration set) and real pretraining corpora are
license/network-gated in this container; this module generates a *learnable*
synthetic language with matched roles:

* a random order-2 Markov process over the vocabulary with sparse transition
  structure and power-law (Zipf) unigram marginals — enough structure that
  a bigger/longer-trained model genuinely reaches lower perplexity (the
  property the paper's model ladder depends on);
* deterministic given a seed, so calibration/eval splits are reproducible.

Batches are dicts matching the model zoo's input contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class SynthLM:
    vocab: int
    branch: int = 8            # out-degree of each (a, b) context
    seed: int = 0
    zipf_a: float = 1.2

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v, b = self.vocab, self.branch
        # per-context successor sets + logits (contexts hashed to save memory)
        self.n_ctx = min(v * 8, 1 << 16)
        self.succ = rng.integers(0, v, size=(self.n_ctx, b), dtype=np.int32)
        probs = rng.dirichlet(np.full(b, 0.5), size=self.n_ctx)
        self.cum = np.cumsum(probs, axis=1).astype(np.float32)
        # Zipf restarts
        ranks = np.arange(1, v + 1, dtype=np.float64)
        pz = ranks ** -self.zipf_a
        self.p_restart = (pz / pz.sum()).astype(np.float64)

    def _ctx_id(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        return ((a.astype(np.int64) * 1000003 + b) % self.n_ctx).astype(np.int64)

    def sample(self, rng: np.random.Generator, batch: int, seq: int,
               p_noise: float = 0.05) -> np.ndarray:
        out = np.empty((batch, seq), dtype=np.int32)
        out[:, 0] = rng.choice(self.vocab, size=batch, p=self.p_restart)
        out[:, 1] = rng.choice(self.vocab, size=batch, p=self.p_restart)
        u = rng.random(size=(batch, seq))
        noise = rng.random(size=(batch, seq)) < p_noise
        rand_tok = rng.choice(self.vocab, size=(batch, seq), p=self.p_restart)
        for t in range(2, seq):
            cid = self._ctx_id(out[:, t - 2], out[:, t - 1])
            k = (self.cum[cid] < u[:, t, None]).sum(axis=1).clip(0, self.branch - 1)
            nxt = self.succ[cid, k]
            out[:, t] = np.where(noise[:, t], rand_tok[:, t], nxt)
        return out


def lm_stream(cfg: ModelConfig, *, batch: int, seq: int, seed: int = 0,
              extra_inputs: bool = True) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite iterator of training batches for any assigned architecture."""
    lang = SynthLM(vocab=cfg.vocab, seed=seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        b: Dict[str, np.ndarray] = {"tokens": lang.sample(rng, batch, seq)}
        if extra_inputs and cfg.arch_type == "vlm":
            b["vision"] = rng.standard_normal(
                (batch, cfg.vision_tokens, cfg.vision_dim or cfg.d_model),
                dtype=np.float32) * 0.1
        if extra_inputs and cfg.arch_type == "audio":
            b["audio"] = rng.standard_normal(
                (batch, cfg.audio_frames, cfg.d_model),
                dtype=np.float32) * 0.1
        yield b


def take(stream: Iterator, n: int):
    return [next(stream) for _ in range(n)]


def calibration_batches(cfg: ModelConfig, *, n: int = 4, batch: int = 2,
                        seq: int = 128, seed: int = 1234):
    """Held-out calibration stream (paper Sec. 4.2's Wikitext-2 role)."""
    return take(lm_stream(cfg, batch=batch, seq=seq, seed=seed), n)


def eval_batches(cfg: ModelConfig, *, n: int = 4, batch: int = 2,
                 seq: int = 128, seed: int = 987):
    return take(lm_stream(cfg, batch=batch, seq=seq, seed=seed), n)
