"""FP8 (E4M3) matmul Pallas kernel — the paper's FP8 inference path on TPU.

TPU adaptation (DESIGN.md §2): the RTX-5090 FP8 tensor-core GEMM maps to an
MXU GEMM over e4m3-quantized operands with fp32 accumulation and a scalar
(per-tensor) scale product applied at the epilogue.  BlockSpecs tile M/N/K
into 128-aligned VMEM blocks; the K grid axis is innermost and accumulates
into a VMEM scratch buffer so each output tile is written exactly once.

Validated CPU-side with ``interpret=True`` against ``ref.fp8_matmul_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM, BN, BK = 128, 128, 128


def _fp8_matmul_kernel(sx_ref, sw_ref, x_ref, w_ref, o_ref, acc_ref, *,
                       n_k: int):
    """Grid (M/BM, N/BN, K/BK); K is the innermost (sequential) axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU matmul on the quantized payloads, fp32 accumulation
    xb = x_ref[...].astype(jnp.float32)
    wb = w_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(xb, wb, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        scale = sx_ref[0] * sw_ref[0]
        o_ref[...] = (acc_ref[...] * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fp8_matmul(x_q: jax.Array, w_q: jax.Array, sx: jax.Array, sw: jax.Array,
               *, interpret: bool = True) -> jax.Array:
    """x_q: (M, K) float8_e4m3fn; w_q: (K, N) float8_e4m3fn; scalar scales.

    Returns (M, N) fp32.  M, N, K must be multiples of the block sizes
    (ops.quant_matmul pads arbitrary shapes)."""
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2, (x_q.shape, w_q.shape)
    assert M % BM == 0 and N % BN == 0 and K % BK == 0, (M, N, K)
    n_k = K // BK

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(M // BM, N // BN, n_k),
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, k, *_: (i, k)),
            pl.BlockSpec((BK, BN), lambda i, j, k, *_: (k, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k, *_: (i, j)),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_fp8_matmul_kernel, n_k=n_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(sx.reshape(1), sw.reshape(1), x_q, w_q)
