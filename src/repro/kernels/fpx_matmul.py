"""FPX FP4 matmul Pallas kernel — the paper's FP4 path, TPU-native.

The Blackwell FP4 tensor-core GEMM has no direct MXU analogue; the TPU
translation (DESIGN.md §2) keeps the *insight* — weights live in HBM at
4 bits, halving the dominant byte traffic of memory-bound decode vs FP8 —
and performs the E2M1 dequantization inside VMEM:

  HBM:  W packed as uint8, two E2M1 codes per byte along N  (K, N/2)
  VMEM: per (BK, BN/2) tile -> unpack nibbles -> 16-entry E2M1 LUT ->
        fp32 tile -> MXU matmul against the activation tile
  epilogue: multiply by scale_X * scale_W (paper Eq. 2)

Activations arrive FP8-quantized (e4m3 payload + scalar scale), matching the
paper's W4A4/W4A8 kernel family; pass a bf16/f32 ``x_q`` with ``sx = 1`` for
a W4A16 variant.

The LUT is realized as a vectorized select over the magnitude bits
(values m * 2^e), which lowers to VPU ops on TPU — no gather needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM, BN, BK = 128, 128, 128


def _decode_e2m1(codes: jax.Array) -> jax.Array:
    """4-bit code (sign|m2|m1|m0) -> E2M1 value, via arithmetic select.

    grid: [0, .5, 1, 1.5, 2, 3, 4, 6] for magnitude index 0..7."""
    #   idx:  0    1    2    3    4    5    6    7
    #   val:  0.0  0.5  1.0  1.5  2.0  3.0  4.0  6.0
    # for m >= 2:  val = 2^(m//2 - 1) * (1.5 if m odd else 1.0)
    mag = (codes & 0x7).astype(jnp.int32)
    sign = jnp.where((codes & 0x8) != 0, -1.0, 1.0)
    val = jnp.where(mag == 0, 0.0,
                    jnp.where(mag == 1, 0.5,
                              jnp.exp2((mag // 2 - 1).astype(jnp.float32)) *
                              jnp.where(mag % 2 == 1, 1.5, 1.0)))
    return sign * val


def _fpx_matmul_kernel(sx_ref, sw_ref, x_ref, wp_ref, o_ref, acc_ref, *,
                       n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # unpack the (BK, BN/2) byte tile into a (BK, BN) fp32 weight tile
    packed = wp_ref[...]
    lo = packed & 0x0F
    hi = (packed >> 4) & 0x0F
    w_tile = jnp.stack([_decode_e2m1(lo), _decode_e2m1(hi)], axis=-1)
    w_tile = w_tile.reshape(packed.shape[0], packed.shape[1] * 2)

    xb = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(xb, w_tile, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[...] = (acc_ref[...] * (sx_ref[0] * sw_ref[0])).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fpx_matmul(x_q: jax.Array, w_packed: jax.Array, sx: jax.Array,
               sw: jax.Array, *, interpret: bool = True) -> jax.Array:
    """x_q: (M, K) e4m3/bf16/f32; w_packed: (K, N/2) uint8; scalar scales.

    Returns (M, N) fp32."""
    M, K = x_q.shape
    K2, N_half = w_packed.shape
    N = N_half * 2
    assert K == K2
    assert M % BM == 0 and N % BN == 0 and K % BK == 0, (M, N, K)
    n_k = K // BK

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(M // BM, N // BN, n_k),
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, k, *_: (i, k)),
            pl.BlockSpec((BK, BN // 2), lambda i, j, k, *_: (k, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k, *_: (i, j)),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_fpx_matmul_kernel, n_k=n_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(sx.reshape(1), sw.reshape(1), x_q, w_packed)
