"""Jit'd wrappers dispatching quantized matmuls to the Pallas kernels.

``quant_matmul(x, w, x_bits, w_bits)`` is what ``modules.quant_linear`` calls
when ``ExecContext.use_pallas`` is set: it quantizes per paper Eq. 1, pads to
the kernels' 128-aligned tiles, runs the (interpret-mode on CPU) kernel, and
unpads.  Numerics match ``ref.quant_matmul_ref`` / ``core.quant`` exactly —
the property tests sweep shapes and dtypes over this equivalence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels import fp8_matmul as _fp8
from repro.kernels import fpx_matmul as _fpx
from repro.kernels import paged_gather as _pg


def _pad_to(x: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def quant_matmul(x: jax.Array, w: jax.Array, *, x_bits: int = 8,
                 w_bits: int = 8, interpret: bool = True) -> jax.Array:
    """(…, K) @ (K, N) with FPX quantization of both operands.

    x may have leading batch dims; they are flattened into M."""
    orig_dtype = x.dtype
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]

    if w_bits >= 16 and x_bits >= 16:
        return (x2 @ w).reshape(*lead, N).astype(orig_dtype)

    # quantize activations.  FP4 activations are rounded on the E2M1 grid
    # but carried as an e4m3 payload (E2M1 values are exactly representable
    # in e4m3, and the MXU consumes 8-bit operands) — numerically identical
    # to the paper's A4, TPU-native in layout.
    if x_bits == 4:
        sx = quant._compute_scale(x2.astype(jnp.float32), quant.FP4_RANGE)
        x_pay = quant.round_to_fp4_grid(
            x2.astype(jnp.float32) / sx).astype(jnp.float8_e4m3fn)
    elif x_bits < 16:
        xq = quant.quantize(x2, 8)
        x_pay, sx = xq.data, xq.scale
    else:
        x_pay, sx = x2.astype(jnp.float32), jnp.float32(1.0)

    BM, BN, BK = _fp8.BM, _fp8.BN, _fp8.BK
    x_pad = _pad_to(x_pay, BM, BK)

    if w_bits == 4:
        wq = quant.quantize(w, 4)            # packed (K, N/2) uint8
        w_pad = _pad_to(wq.data, BK, BN // 2)
        out = _fpx.fpx_matmul(x_pad, w_pad, jnp.float32(sx),
                              jnp.float32(wq.scale), interpret=interpret)
    else:
        wq = quant.quantize(w, 8)
        w_pad = _pad_to(wq.data, BK, BN)
        out = _fp8.fp8_matmul(x_pad, w_pad, jnp.float32(sx),
                              jnp.float32(wq.scale), interpret=interpret)

    out = out[:M, :N]
    return out.reshape(*lead, N).astype(orig_dtype)


def gather_pages(pool: jax.Array, block_tables: jax.Array, *,
                 use_pallas: bool = False, interpret: bool = True) -> jax.Array:
    """Materialize paged K/V as a contiguous per-lane context.

    pool: (n_pages, page_size, n_kv_heads, head_dim); block_tables: (B, P)
    int32 page ids.  Returns (B, P * page_size, n_kv_heads, head_dim).  The
    Pallas path flattens the head dims into one lane axis so each page is a
    2-D VMEM tile, and runs the scalar-prefetch gather kernel (interpret
    mode on CPU); the default path is the jnp take the XLA CPU backend
    already fuses well."""
    n_pages, ps, H, D = pool.shape
    B, P = block_tables.shape
    if use_pallas:
        flat = _pg.paged_gather(pool.reshape(n_pages, ps, H * D),
                                block_tables, interpret=interpret)
        return flat.reshape(B, P * ps, H, D)
    return jnp.take(pool, block_tables, axis=0).reshape(B, P * ps, H, D)
