"""Jit'd wrappers dispatching quantized matmuls to the Pallas kernels.

``quant_matmul(x, w, x_bits, w_bits)`` is what ``modules.quant_linear`` calls
when ``ExecContext.use_pallas`` is set: it quantizes per paper Eq. 1, pads to
the kernels' 128-aligned tiles, runs the (interpret-mode on CPU) kernel, and
unpads.  Numerics match ``ref.quant_matmul_ref`` / ``core.quant`` exactly —
the property tests sweep shapes and dtypes over this equivalence.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.kernels import fp8_matmul as _fp8
from repro.kernels import fpx_matmul as _fpx
from repro.kernels import paged_attention as _pa
from repro.kernels import paged_gather as _pg
from repro.kernels import paged_scatter as _ps


def _pad_to(x: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def quant_matmul(x: jax.Array, w: jax.Array, *, x_bits: int = 8,
                 w_bits: int = 8, interpret: bool = True) -> jax.Array:
    """(…, K) @ (K, N) with FPX quantization of both operands.

    x may have leading batch dims; they are flattened into M."""
    orig_dtype = x.dtype
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]

    if w_bits >= 16 and x_bits >= 16:
        return (x2 @ w).reshape(*lead, N).astype(orig_dtype)

    # quantize activations.  FP4 activations are rounded on the E2M1 grid
    # but carried as an e4m3 payload (E2M1 values are exactly representable
    # in e4m3, and the MXU consumes 8-bit operands) — numerically identical
    # to the paper's A4, TPU-native in layout.
    if x_bits == 4:
        sx = quant._compute_scale(x2.astype(jnp.float32), quant.FP4_RANGE)
        x_pay = quant.round_to_fp4_grid(
            x2.astype(jnp.float32) / sx).astype(jnp.float8_e4m3fn)
    elif x_bits < 16:
        xq = quant.quantize(x2, 8)
        x_pay, sx = xq.data, xq.scale
    else:
        x_pay, sx = x2.astype(jnp.float32), jnp.float32(1.0)

    BM, BN, BK = _fp8.BM, _fp8.BN, _fp8.BK
    x_pad = _pad_to(x_pay, BM, BK)

    if w_bits == 4:
        wq = quant.quantize(w, 4)            # packed (K, N/2) uint8
        w_pad = _pad_to(wq.data, BK, BN // 2)
        out = _fpx.fpx_matmul(x_pad, w_pad, jnp.float32(sx),
                              jnp.float32(wq.scale), interpret=interpret)
    else:
        wq = quant.quantize(w, 8)
        w_pad = _pad_to(wq.data, BK, BN)
        out = _fp8.fp8_matmul(x_pad, w_pad, jnp.float32(sx),
                              jnp.float32(wq.scale), interpret=interpret)

    out = out[:M, :N]
    return out.reshape(*lead, N).astype(orig_dtype)


def gather_pages(pool: jax.Array, block_tables: jax.Array, *,
                 use_pallas: bool = False, interpret: bool = True) -> jax.Array:
    """Materialize paged K/V as a contiguous per-lane context.

    pool: (n_pages, page_size, n_kv_heads, head_dim); block_tables: (B, P)
    int32 page ids.  Returns (B, P * page_size, n_kv_heads, head_dim).  The
    Pallas path flattens the head dims into one lane axis so each page is a
    2-D VMEM tile, and runs the scalar-prefetch gather kernel (interpret
    mode on CPU); the default path is the jnp take the XLA CPU backend
    already fuses well."""
    n_pages, ps, H, D = pool.shape
    B, P = block_tables.shape
    if use_pallas:
        flat = _pg.paged_gather(pool.reshape(n_pages, ps, H * D),
                                block_tables, interpret=interpret)
        return flat.reshape(B, P * ps, H, D)
    return jnp.take(pool, block_tables, axis=0).reshape(B, P * ps, H, D)


def paged_attend(q: jax.Array, kpool: jax.Array, vpool: jax.Array,
                 block_tables: jax.Array, pos: jax.Array, *, scale: float,
                 use_pallas: bool = False, interpret: bool = True,
                 window: Optional[int] = None) -> jax.Array:
    """Attention of per-lane queries over their block-table paged context.

    q: (B, Sq, H, D) post-RoPE queries at global positions ``pos[b] ..
    pos[b] + Sq - 1``; kpool/vpool: (n_pages, page_size, Hkv, D) shared
    pools already holding this step's K/V writes; block_tables: (B, P)
    int32; pos: (B,) int32.  Returns (B, Sq, H, D).  ``Sq == 1`` is a
    decode step, ``Sq > 1`` a prefill chunk (causal within the chunk, full
    attend over earlier pages) — the mask is ``slot <= pos[b] + row``
    either way.

    ``window``: static sliding-window size of this layer group (None =
    full attention).  Adds the validity term ``slot > pos[b] + row -
    window`` on both paths, so a local layer attends over only its
    retained in-window slots — freed out-of-window table entries point at
    the dummy page and fall entirely under this mask.

    The Pallas path runs the fused flash kernel
    (:func:`repro.kernels.paged_attention.paged_flash_attend`): pages are
    read straight out of the pool via the scalar-prefetched block table
    and folded page-by-page into an online softmax — the gathered
    contiguous context is never materialized.  The jnp default path
    reproduces the historical gather+SDPA semantics exactly (one *fused*
    take over both pools stacked, then ``attention._sdpa`` itself), so it
    remains the bit-for-bit reference the engine token-identity tests
    were built on."""
    # deferred import: attention lazily imports this module inside its
    # paged branches, so the cycle never bites — and calling the real
    # _sdpa keeps the fallback incapable of drifting from the dense paths
    from repro.models.attention import _sdpa

    B, Sq = q.shape[:2]
    ps, Hkv, D = kpool.shape[1:]
    _, P = block_tables.shape
    if use_pallas:
        return _pa.paged_flash_attend(q, kpool, vpool, block_tables, pos,
                                      scale=float(scale),
                                      interpret=interpret,
                                      window=window)
    # one gather for both pools: a single take over the (2, n_pages, ...)
    # stacked view instead of two per-layer gathers.  The stack is a copy
    # XLA may materialize; measured on the CPU backend it loses ~20% at
    # toy pool sizes and wins ~40% at chat-scale pools, and this fallback
    # is the reference path — deployment perf is the fused kernel's.
    kv = jnp.take(jnp.stack([kpool, vpool]), block_tables, axis=1)
    ck = kv[0].reshape(B, P * ps, Hkv, D)
    cv = kv[1].reshape(B, P * ps, Hkv, D)
    slot = jnp.arange(P * ps)
    qpos = pos[:, None] + jnp.arange(Sq)[None, :]            # (B, Sq)
    mask = slot[None, None, :] <= qpos[:, :, None]           # (B, Sq, S)
    if window is not None:
        mask &= slot[None, None, :] > qpos[:, :, None] - window
    mask = mask[:, None]                                     # (B,1,Sq,S)
    return _sdpa(q, ck, cv, jnp.broadcast_to(mask, (B, 1, Sq, P * ps)),
                 scale)


def scatter_chunk(pool: jax.Array, block_tables: jax.Array, pos: jax.Array,
                  chunk: jax.Array, *, use_pallas: bool = False,
                  interpret: bool = True,
                  skip_page: Optional[int] = None) -> jax.Array:
    """Write a prefill chunk's K (or V) into block-table pages.

    pool: (n_pages, page_size, n_kv_heads, head_dim); block_tables: (B, P)
    int32; pos: (B,) int32 start positions; chunk: (B, C, n_kv_heads,
    head_dim) — token ``i`` of lane ``b`` lands at logical position
    ``pos[b] + i`` (page ``block_tables[b, (pos[b]+i) // page_size]``, row
    ``(pos[b]+i) % page_size``).  Returns the updated pool.  Lanes must own
    disjoint pages (they do, by ``serving.kv_cache`` allocation), so the
    scatter is collision-free.

    ``skip_page``: table entries equal to this page id are *not* written —
    the write-side window-validity mask.  Sliding-window layer groups park
    retired (out-of-window) table entries on the reserved dummy page
    (``serving.kv_cache.DUMMY_PAGE``); several lanes' retired entries alias
    the same physical page, so unsuppressed writes there would collide
    order-dependently under the Pallas kernel's in-place pool aliasing.
    Note every *in-chunk* position must still be written even when it is
    already out of the window of the chunk's final query: each chunk row
    is attended by at least its own (and its successors') in-chunk
    queries, so only whole retired pages — never row sub-ranges — are
    skippable.  The serving engine keeps all of a chunk's own pages
    retained while the chunk is absorbed, so with it this mask only ever
    fires for callers scattering into stale tables.

    The Pallas path additionally requires every ``pos[b]`` to be
    page-aligned — the chunk then decomposes into whole-page row runs and
    runs the scalar-prefetch scatter kernel (``kernels.paged_scatter``,
    interpret mode on CPU) with the head dims flattened to one lane axis.
    The serving engine guarantees alignment by using chunk sizes that are
    multiples of the page size; the jnp default path takes any offset."""
    n_pages, ps, H, D = pool.shape
    B, C = chunk.shape[:2]
    lpos = pos[:, None] + jnp.arange(C)[None, :]            # (B, C) logical
    if not use_pallas:
        pid = jnp.take_along_axis(block_tables, lpos // ps, axis=1)
        vals = chunk.astype(pool.dtype)
        if skip_page is not None:
            # keep the skipped rows at their current pool values (a read-
            # modify-write, so the jnp path stays deterministic and
            # bit-identical to the Pallas path's suppression)
            keep = (pid == skip_page)[..., None, None]
            vals = jnp.where(keep, pool[pid, lpos % ps], vals)
        return pool.at[pid, lpos % ps].set(vals)
    if not isinstance(pos, jax.core.Tracer):
        # concrete call (tests, eager use): enforce the documented
        # precondition — an unaligned start would floor to the page below
        # and silently blend onto the wrong rows.  Traced calls rely on
        # the engine's prefill_chunk % page_size == 0 validation.
        assert not np.any(np.asarray(pos) % ps), \
            f"Pallas scatter_chunk needs page-aligned starts, got {pos}"
    npg = -(-C // ps)
    pad = npg * ps - C
    first = pos // ps                                       # aligned starts
    page_ids = jnp.take_along_axis(
        block_tables, first[:, None] + jnp.arange(npg)[None, :], axis=1)
    n_valid = jnp.clip(C - jnp.arange(npg)[None, :] * ps, 0, ps) \
        .astype(jnp.int32) * jnp.ones((B, 1), jnp.int32)
    if skip_page is not None:
        # retired destinations (window-freed table entries aliased to the
        # dummy page): zero their valid-row count so the kernel writes the
        # existing page back untouched
        n_valid = jnp.where(page_ids == skip_page, 0, n_valid)
    ck = chunk.reshape(B, C, H * D)
    if pad:
        ck = jnp.pad(ck, ((0, 0), (0, pad), (0, 0)))
    out = _ps.paged_scatter(pool.reshape(n_pages, ps, H * D).astype(pool.dtype),
                            ck.reshape(B, npg, ps, H * D).astype(pool.dtype),
                            page_ids.astype(jnp.int32), n_valid,
                            interpret=interpret)
    return out.reshape(n_pages, ps, H, D)
