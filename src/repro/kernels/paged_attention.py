"""Fused paged flash-attention Pallas kernel — the decode hot path.

The gather kernel (``kernels.paged_gather``) materializes each lane's whole
block-table context as a contiguous ``(B, P*page_size, ...)`` buffer before
a dense masked SDPA runs over it.  That costs ~3x the necessary HBM traffic
(write the gathered copy, read it back, on top of the unavoidable pool
read) and always pays for the *padded* table extent ``P * page_size`` even
when a lane holds ten tokens of a 256-token table.  For the per-token
decode step — the innermost loop of the serving stack, run once per layer
per token per lane — that padding tax is the single largest avoidable HBM
cost in the system.

This kernel fuses the gather into the attention itself.  The grid is
``(B, P)``: one cell per (lane, table page).  The block table rides in SMEM
via ``PrefetchScalarGridSpec`` and *drives the K/V BlockSpec index_maps*,
so each cell DMAs exactly one K page and one V page HBM->VMEM straight out
of the shared pool — the gathered context never exists.  Within a lane the
pages stream in logical order and an online-softmax (flash-style ``m``/
``l``/``acc`` scratch carried across the inner grid dimension) folds each
page into the running attention state; the final cell normalizes and
writes the lane's output.  Per-lane validity is masked from the prefetched
``pos``: slot ``p*page_size + r`` participates iff it is ``<= pos[b] + i``
for query row ``i`` — and, for sliding-window layer groups (``window=W``),
additionally ``> pos[b] + i - W``, so local layers attend over only the
retained in-window pages (out-of-window pages are freed back to the pool
by ``serving.kv_cache`` and their table entries point at the dummy page).
The causal-only mask also makes idle lanes (whole table pointing at
the reserved dummy page, ``pos = 0``) safe: they attend to slot 0 of the
dummy page and produce finite garbage the engine discards, exactly like
the gather path.

One kernel body serves both serving entry points:

* **decode** (``Sq = 1``): one fresh query per lane at position ``pos[b]``.
* **chunked prefill** (``Sq = C``): the chunk's queries at global positions
  ``pos[b] .. pos[b] + C - 1``, causal within the chunk and full attend
  over the lane's previously written pages (the chunk's K/V were already
  scattered into the pool by ``kernels.paged_scatter``, so page ``p``
  carries them when the grid reaches it).

GQA grouping happens in-kernel: queries fold to ``(Hkv, Sq*group, D)`` so
scores are one batched ``dot_general`` per page against the ``(Hkv, ps,
D)`` page tile — no repeated K/V.  Numerics: scores, softmax and the
output accumulate in fp32 (matching ``attention._sdpa``'s
``preferred_element_type`` contract); online softmax is mathematically
identical to the dense masked softmax, so greedy outputs agree with the
gather+SDPA path.  Validated CPU-side with ``interpret=True`` against the
pure-jnp oracle ``ref.paged_attend_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: matches attention._sdpa's masked-logit fill — finite, so a fully-masked
#: page keeps m/l well-defined without NaN-producing (-inf) - (-inf).
_MASK_VAL = -1e30


def _attend_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale: float,
                   window: "int | None"):
    """Grid (B, P): fold page ``bt[b, p]`` into lane ``b``'s running
    attention state; normalize and emit on the lane's last page.

    The page selection happened in the BlockSpec index_maps (scalar
    prefetch) — the body only sees the (1, ps, Hkv, D) page tiles.  The
    ``m``/``l``/``acc`` scratch persists across the inner grid dimension
    (pages run sequentially per lane), which is what makes the online
    softmax exact.

    ``window``: static sliding-window size of this layer group, or None
    for full attention.  Window validity is masked from the prefetched
    per-lane ``pos`` exactly like causality: slot ``p*ps + r`` is visible
    to query row ``i`` iff ``pos[b] + i - window < slot <= pos[b] + i``.
    Pages whose whole extent is out of window were already freed back to
    the pool by ``serving.kv_cache`` (their table entries point at the
    reserved dummy page) — the mask is what makes attending "over only
    the retained pages" sound: a dummy or stale page under the window
    horizon contributes nothing."""
    del bt_ref
    b = pl.program_id(0)
    p = pl.program_id(1)
    n_pg = pl.num_programs(1)
    _, Sq, H, D = q_ref.shape
    ps, Hkv = k_ref.shape[1], k_ref.shape[2]
    G = H // Hkv

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _MASK_VAL)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # queries (Sq, H, D) -> (Hkv, Sq*G, D): kv-head becomes the batch dim
    # of one grouped dot per page; row i*G+g is query position i, head
    # kv*G+g of the original layout.
    q = q_ref[0].astype(jnp.float32)
    qg = q.reshape(Sq, Hkv, G, D).transpose(1, 0, 2, 3).reshape(Hkv, Sq * G, D)
    k = k_ref[0].astype(jnp.float32).transpose(1, 0, 2)      # (Hkv, ps, D)
    v = v_ref[0].astype(jnp.float32).transpose(1, 0, 2)

    s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    # validity: slot p*ps + r is visible to query row i iff <= pos[b] + i
    slot = p * ps + jax.lax.broadcasted_iota(jnp.int32, (Sq * G, ps), 1)
    qrow = jax.lax.broadcasted_iota(jnp.int32, (Sq * G, ps), 0) // G
    ok = slot <= pos_ref[b] + qrow
    if window is not None:
        ok &= slot > pos_ref[b] + qrow - window
    s = jnp.where(ok[None], s, _MASK_VAL)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new[..., None])
    # a fully-masked page leaves m at _MASK_VAL, where exp(s - m) == 1 for
    # every masked slot — zero them explicitly so such pages contribute
    # nothing (the first real page then resets the state via alpha == 0).
    pexp = jnp.where(ok[None], pexp, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jax.lax.dot_general(
        pexp, v, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p == n_pg - 1)
    def _finish():
        out = acc_ref[...] / l_ref[...][..., None]           # (Hkv, Sq*G, D)
        o_ref[0] = out.reshape(Hkv, Sq, G, D).transpose(1, 0, 2, 3) \
            .reshape(Sq, H, D).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret", "window"))
def paged_flash_attend(q: jax.Array, kpool: jax.Array, vpool: jax.Array,
                       block_tables: jax.Array, pos: jax.Array, *,
                       scale: float, interpret: bool = True,
                       window: "int | None" = None) -> jax.Array:
    """q: (B, Sq, H, D) post-RoPE queries at global positions
    ``pos[b] .. pos[b] + Sq - 1``; kpool/vpool: (n_pages, page_size, Hkv,
    D) shared pools *already holding* the step's K/V writes;
    block_tables: (B, P) int32 page ids; pos: (B,) int32.

    Returns (B, Sq, H, D): softmax(q k^T * scale) v over each lane's valid
    slots (``pos[b] + row - window < slot <= pos[b] + row``; ``window``
    None = full causal), never materializing the gathered context.  Page
    ids must be < n_pages (idle lanes — and the freed out-of-window table
    entries of sliding-window layer groups — point at the reserved dummy
    page, never out of range)."""
    B, Sq, H, D = q.shape
    n_pages, ps, Hkv, _ = kpool.shape
    _, P = block_tables.shape
    G = H // Hkv

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, Sq, H, D), lambda b, p, bt, pos: (b, 0, 0, 0)),
            pl.BlockSpec((1, ps, Hkv, D),
                         lambda b, p, bt, pos: (bt[b, p], 0, 0, 0)),
            pl.BlockSpec((1, ps, Hkv, D),
                         lambda b, p, bt, pos: (bt[b, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Sq, H, D),
                               lambda b, p, bt, pos: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, Sq * G), jnp.float32),      # running max m
            pltpu.VMEM((Hkv, Sq * G), jnp.float32),      # running denom l
            pltpu.VMEM((Hkv, Sq * G, D), jnp.float32),   # unnormalized out
        ],
    )
    return pl.pallas_call(
        functools.partial(_attend_kernel, scale=scale, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, D), q.dtype),
        interpret=interpret,
    )(block_tables, pos, q, kpool, vpool)
