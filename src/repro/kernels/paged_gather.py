"""Paged KV-cache gather Pallas kernel — block-table reads for paged decode.

Paged serving keeps K/V in fixed-size pages inside one shared pool
(``serving.kv_cache``); a decode step must materialize each lane's logical
context ``pool[block_table[b]]`` as a contiguous (B, P*page_size, ...) view
before attention.  On TPU this is the classic scalar-prefetch pattern: the
block table rides in SMEM via ``PrefetchScalarGridSpec`` and *drives the
BlockSpec index_map*, so the pages are DMA'd HBM->VMEM directly into their
destination slots — the gather costs one page-sized copy per (lane, page)
grid cell and never touches pages the lane does not own.

The pool's trailing (n_kv_heads, head_dim) dims are flattened to one lane
axis by the ops-layer wrapper (``ops.gather_pages``) so the page block is a
well-tiled 2-D (page_size, E) VMEM tile.  Validated CPU-side with
``interpret=True`` against the pure-jnp oracle ``ref.gather_pages_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(bt_ref, pool_ref, o_ref):
    """Grid (B, P): copy page ``bt[b, p]`` into out slot (b, p).

    The page selection happened in the BlockSpec index_map (scalar
    prefetch), so the body is a straight VMEM copy."""
    del bt_ref
    o_ref[0, 0] = pool_ref[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_gather(pool: jax.Array, block_tables: jax.Array, *,
                 interpret: bool = True) -> jax.Array:
    """pool: (n_pages, page_size, E); block_tables: (B, P) int32 page ids.

    Returns (B, P, page_size, E): lane b's pages in logical order.  Page ids
    must be < n_pages (idle lanes point at a reserved dummy page, never at
    out-of-range ids)."""
    n_pages, ps, E = pool.shape
    B, P = block_tables.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, ps, E), lambda b, p, bt: (bt[b, p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, ps, E), lambda b, p, bt: (b, p, 0, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, P, ps, E), pool.dtype),
        interpret=interpret,
    )(block_tables, pool)
