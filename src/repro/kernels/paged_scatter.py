"""Paged KV-cache chunk scatter Pallas kernel — block-table writes for
chunked prefill.

The gather kernel (``kernels.paged_gather``) reads a lane's pages into a
contiguous context; this is its write-side twin.  Chunked prefill absorbs a
prompt ``chunk_size`` tokens at a time (``serving.paged_engine``), and each
absorbed chunk must land in the lane's pages: token ``pos[b] + i`` goes to
page ``block_tables[b, (pos[b] + i) // page_size]``, row
``(pos[b] + i) % page_size``.

When the chunk start is page-aligned (the engine guarantees this by making
the chunk size a multiple of the page size), the scatter is page-granular:
chunk page ``j`` of lane ``b`` is one contiguous run of rows for pool page
``block_tables[b, pos[b] // page_size + j]``.  That is again the TPU
scalar-prefetch pattern, now on the *output* side: the destination page ids
ride in SMEM and drive the out-BlockSpec index_map, the pool aliases
input->output so untouched pages keep their data, and each grid cell
blends the chunk's valid rows over the existing page (the final chunk of a
prompt may fill only part of its last page).

The ops-layer wrapper (``ops.scatter_chunk``) flattens the trailing
(n_kv_heads, head_dim) dims to one lane axis so each page is a well-tiled
2-D (page_size, E) VMEM tile, and precomputes the per-(lane, chunk-page)
destination ids and valid-row counts.  Validated CPU-side with
``interpret=True`` against the pure-jnp oracle ``ref.scatter_chunk_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_kernel(pid_ref, nvalid_ref, chunk_ref, pool_ref, o_ref):
    """Grid (B, n_chunk_pages): blend chunk page (b, j) over pool page
    ``pid[b, j]``.

    The destination page selection happened in the out-BlockSpec index_map
    (scalar prefetch); the body keeps rows past the chunk's valid count
    from the existing page so a partially-filled final page preserves
    whatever the pool already held there."""
    del pid_ref
    b = pl.program_id(0)
    j = pl.program_id(1)
    n = nvalid_ref[b, j]
    ps, E = pool_ref.shape[1], pool_ref.shape[2]
    rows = jax.lax.broadcasted_iota(jnp.int32, (ps, E), 0)
    o_ref[0] = jnp.where(rows < n, chunk_ref[0, 0], pool_ref[0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_scatter(pool: jax.Array, chunk: jax.Array, page_ids: jax.Array,
                  n_valid: jax.Array, *, interpret: bool = True) -> jax.Array:
    """pool: (n_pages, page_size, E); chunk: (B, n_chunk_pages, page_size, E)
    page-aligned chunk rows; page_ids: (B, n_chunk_pages) int32 destination
    pages; n_valid: (B, n_chunk_pages) int32 rows of each chunk page that
    carry real tokens (page_size except possibly the last).

    Returns the pool with the chunk written.  Destination ids must be
    distinct across grid cells (lanes own disjoint pages; a chunk's pages
    are distinct) — the pool is aliased in-place, so colliding writes would
    be order-dependent.  The one sanctioned exception: cells with
    ``n_valid == 0`` write their page back untouched, so suppressed
    destinations (``ops.scatter_chunk(skip_page=...)`` — window-retired
    table entries parked on the serving layer's dummy page) may alias the
    same physical page across any number of cells and stay
    deterministic."""
    n_pages, ps, E = pool.shape
    B, npg = page_ids.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, npg),
        in_specs=[
            pl.BlockSpec((1, 1, ps, E), lambda b, j, pid, nv: (b, j, 0, 0)),
            pl.BlockSpec((1, ps, E), lambda b, j, pid, nv: (pid[b, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ps, E),
                               lambda b, j, pid, nv: (pid[b, j], 0, 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={3: 0},     # pool (after the 2 scalar operands
        interpret=interpret,             # and chunk) donates to the output
    )(page_ids, n_valid, chunk, pool)
