"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's test sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant


def fp8_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Paper Eq. 2 at (8, 8): scale_X scale_W Q(X) Q(W), fp32 accumulation."""
    xq = quant.quantize(x, 8)
    wq = quant.quantize(w, 8)
    return (xq.data.astype(jnp.float32) @ wq.data.astype(jnp.float32)) \
        * xq.scale * wq.scale


def fp4_matmul_ref(x: jax.Array, w: jax.Array, x_bits: int = 8) -> jax.Array:
    """FP4 weights (E2M1 grid), FP8 (or fp32) activations."""
    wq = quant.quantize(w, 4)
    w_deq = quant.dequantize(wq)
    if x_bits >= 16:
        xv, sx = x.astype(jnp.float32), 1.0
    else:
        xq = quant.quantize(x, 8)
        xv, sx = xq.data.astype(jnp.float32), xq.scale
    return (xv @ w_deq) * sx


def quant_matmul_ref(x: jax.Array, w: jax.Array, x_bits: int, w_bits: int) -> jax.Array:
    return quant.quant_matmul_ref(x, w, x_bits, w_bits)


def gather_pages_ref(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Paged-KV gather oracle: pool (n_pages, ps, ...), tables (B, P) ->
    (B, P, ps, ...) — lane b's pages in logical order."""
    return jnp.take(pool, block_tables, axis=0)


def paged_attend_ref(q: jax.Array, kpool: jax.Array, vpool: jax.Array,
                     block_tables: jax.Array, pos: jax.Array,
                     scale: float, window=None) -> jax.Array:
    """Paged-attention oracle: gather each lane's context through its block
    table, then plain masked softmax attention in fp64-free, loop-free jnp.

    q: (B, Sq, H, D) queries at global positions pos[b] + row; pools:
    (n_pages, ps, Hkv, D); block_tables: (B, P); pos: (B,).  Query row i of
    lane b attends slots <= pos[b] + i — and, with a sliding ``window``,
    only slots > pos[b] + i - window (GQA: query head h reads kv head
    h // (H // Hkv)).  Deliberately the *direct* computation — no online
    softmax, no shared code with the kernel under test."""
    B, Sq, H, D = q.shape
    ps = kpool.shape[1]
    Hkv = kpool.shape[2]
    P = block_tables.shape[1]
    ck = jnp.take(kpool, block_tables, axis=0).reshape(B, P * ps, Hkv, D)
    cv = jnp.take(vpool, block_tables, axis=0).reshape(B, P * ps, Hkv, D)
    # expand kv heads to query heads (GQA), fp32 throughout
    rep = H // Hkv
    ck = jnp.repeat(ck, rep, axis=2).astype(jnp.float32)
    cv = jnp.repeat(cv, rep, axis=2).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), ck) * scale
    qpos = pos[:, None] + jnp.arange(Sq)[None, :]
    slot = jnp.arange(P * ps)[None, None, :]
    mask = slot <= qpos[:, :, None]                           # (B,Sq,S)
    if window is not None:
        mask &= slot > qpos[:, :, None] - window
    logits = jnp.where(mask[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, cv)
    return out.astype(q.dtype)


def scatter_chunk_ref(pool: jax.Array, block_tables: jax.Array,
                      pos: jax.Array, chunk: jax.Array) -> jax.Array:
    """Chunk-scatter oracle: token i of lane b goes to logical position
    pos[b] + i — page block_tables[b, (pos[b]+i) // ps], row (pos[b]+i) % ps."""
    ps = pool.shape[1]
    C = chunk.shape[1]
    lpos = pos[:, None] + jnp.arange(C)[None, :]
    pid = jnp.take_along_axis(block_tables, lpos // ps, axis=1)
    return pool.at[pid, lpos % ps].set(chunk.astype(pool.dtype))
