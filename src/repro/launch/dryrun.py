"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**specs).compile()`` must succeed on the
single-pod 16x16=256-chip mesh and the 2x16x16=512-chip multi-pod mesh for
every assigned architecture and input shape, using ShapeDtypeStruct stand-ins
(no allocation).  Outputs feed EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
# The VERY FIRST lines, before ANY other import: jax locks the device count
# on first backend init, and the dry-run needs 512 host placeholder devices.
# An explicit forced count in the environment wins (the simulated-mesh CI
# pass runs at 8 devices and imports this module for run_one(mesh=...)).
import os
if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import re
import sys
import time
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, INPUT_SHAPES, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.launch import shardings as sh
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.models.modules import ExecContext
from repro.training.optim import AdamWConfig, adamw_init
from repro.training.train_step import make_train_step

SKIP_LONG = {
    # pure full-attention archs: no windowed/recurrent variant in the source
    # model => no sub-quadratic long_500k decode (DESIGN.md §6)
    "gemma-7b", "llama-3.2-vision-11b", "dbrx-132b",
    "granite-moe-1b-a400m", "seamless-m4t-medium",
}


def skip_reason(arch: str, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and arch in SKIP_LONG:
        return "full-attention arch: long_500k requires sub-quadratic decode"
    return None


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no device allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Model-input ShapeDtypeStructs for one (arch, input-shape) pair."""
    B = shape.global_batch
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        batch = {"token": sds((B, 1), jnp.int32)}
    else:
        batch = {"tokens": sds((B, shape.seq_len), jnp.int32)}
    if cfg.arch_type == "vlm" and shape.kind != "decode":
        batch["vision"] = sds((B, cfg.vision_tokens,
                               cfg.vision_dim or cfg.d_model), dtype)
    if cfg.arch_type == "audio" and shape.kind != "decode":
        batch["audio"] = sds((B, cfg.audio_frames, cfg.d_model), dtype)
    return batch


def cache_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: transformer.init_decode_cache(cfg, shape.global_batch,
                                              shape.seq_len, dtype,
                                              start_pos=shape.seq_len - 1))


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def build_step(cfg: ModelConfig, shape: InputShape, mesh, *,
               dtype=jnp.bfloat16, param_dtype=None, remat: bool = False,
               policy: Optional[Dict[str, Any]] = None,
               sharding_policy: str = "baseline",
               constrain_acts: bool = False,
               moe_expert_parallel: bool = False):
    """Returns (jitted_fn, example_args) ready to ``.lower(*args)``."""
    act_spec = None
    if constrain_acts:
        act_spec = P(*sh.batch_spec(mesh, shape.global_batch, sharding_policy),
                     None, None)
    moe_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ctx = ExecContext(policy=policy, default_bits=16, act_spec=act_spec,
                      moe_mesh=mesh if moe_expert_parallel else None,
                      moe_data_axes=moe_axes)
    params_shape = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg, param_dtype or dtype),
        jax.random.PRNGKey(0))
    p_sh = sh.param_shardings(params_shape, mesh, sharding_policy)
    tok_sh = sh.token_sharding(mesh, shape.global_batch, sharding_policy)
    batch = input_specs(cfg, shape, dtype)
    batch_sh = {k: tok_sh if v.dtype == jnp.int32 else
                NamedSharding(mesh, P(*sh.batch_spec(mesh, shape.global_batch,
                                                     sharding_policy),
                                      None, None))
                for k, v in batch.items()}

    if shape.kind == "train":
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        o_sh = sh.param_shardings(opt_shape, mesh, sharding_policy)
        step = make_train_step(cfg, AdamWConfig(), ctx, remat=remat)
        fn = jax.jit(step,
                     in_shardings=(p_sh, o_sh, batch_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
        return fn, (params_shape, opt_shape, batch)

    if shape.kind == "prefill":
        def prefill_fn(params, b):
            return transformer.prefill(params, cfg, b, ctx,
                                       cache_len=shape.seq_len)
        fn = jax.jit(prefill_fn, in_shardings=(p_sh, batch_sh),
                     out_shardings=None)
        return fn, (params_shape, batch)

    # decode
    cache_shape = cache_specs(cfg, shape, dtype)
    c_sh = sh.cache_shardings(cache_shape, mesh,
                              global_batch=shape.global_batch,
                              seq_shard=(shape.global_batch == 1))

    def decode_fn(params, b, cache):
        return transformer.decode_step(params, cfg, b, cache, ctx)

    fn = jax.jit(decode_fn, in_shardings=(p_sh, batch_sh, c_sh),
                 out_shardings=(None, c_sh), donate_argnums=(2,))
    return fn, (params_shape, batch, cache_shape)


# ---------------------------------------------------------------------------
# Collective-byte accounting (for §Roofline; cost_analysis lacks it)
#
# XLA's cost_analysis() counts while-loop (lax.scan) bodies ONCE, and the
# scan-over-layers design puts most collectives inside loops.  This analyzer
# parses the *compiled* (SPMD-partitioned) HLO, builds the while-loop nesting
# from `known_trip_count` annotations, and multiplies each computation's
# collective bytes by its loop multiplier.  Shapes in the compiled module are
# per-device, so the totals are per-chip traffic — divide by link bandwidth
# for the roofline collective term.
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(bf16|f32|f16|f8e4m3fn|s32|u32|s8|u8|pred|f64|s64)"
                       r"\[([\d,]*)\]")
_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "f8e4m3fn": 1, "pred": 1, "s64": 8}
_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute")


def _shape_bytes(segment: str) -> int:
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        nbytes += n * _DTYPE_BYTES.get(dt, 4)
    return nbytes


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Loop-aware per-chip collective byte totals from compiled SPMD HLO."""
    # 1. split into computations (headers start at column 0)
    comp_lines: Dict[str, list] = {}
    cur = None
    entry = None
    head = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
    for line in hlo_text.splitlines():
        if line and not line[0].isspace():
            m = head.match(line)
            if m:
                cur = m.group(2)
                comp_lines[cur] = []
                if m.group(1):
                    entry = cur
                continue
        if cur is not None:
            comp_lines[cur].append(line)

    # 2. while ops: body computation + trip count
    body_re = re.compile(r"body=%?([\w\.\-]+)")
    trip_re = re.compile(r'known_trip_count[^0-9]*(\d+)')
    children: Dict[str, list] = {}          # comp -> [(body, trips)]
    for cname, lines in comp_lines.items():
        for line in lines:
            if " while(" not in line:
                continue
            bm = body_re.search(line)
            if not bm:
                continue
            tm = trip_re.search(line)
            trips = int(tm.group(1)) if tm else 1
            children.setdefault(cname, []).append((bm.group(1), trips))

    # 3. multipliers via BFS from entry
    mult: Dict[str, int] = {}
    if entry is not None:
        stack = [(entry, 1)]
        while stack:
            c, m = stack.pop()
            mult[c] = mult.get(c, 0) + m
            for (b, t) in children.get(c, []):
                stack.append((b, m * t))
    # computations never reached via while nesting (fusions etc.) run at the
    # multiplier of wherever they're called from; collectives only occur at
    # while-body / entry level in XLA SPMD output, so default those to 1.

    # 4. collective bytes x multiplier
    out: Dict[str, int] = {}
    for cname, lines in comp_lines.items():
        m = mult.get(cname, 1)
        for line in lines:
            for kind in _KINDS:
                if f" {kind}(" in line or f"{kind}-start(" in line:
                    seg = line.split("=", 1)[0] + "=" + \
                        line.split("=", 1)[1].split(kind)[0]
                    out[kind] = out.get(kind, 0) + _shape_bytes(seg) * m
                    break
    return out


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            remat: bool = False, verbose: bool = True,
            sharding_policy: str = "baseline",
            constrain_acts: bool = False,
            moe_expert_parallel: bool = False,
            w8: bool = False, mesh=None) -> Dict[str, Any]:
    """``mesh``: explicit mesh override (e.g. a small simulated mesh from
    :func:`repro.launch.mesh.sim_mesh`) — the smoke tests compile on an
    8-device mesh instead of forcing 512 placeholder devices."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    reason = skip_reason(arch, shape_name)
    if reason:
        return {"arch": arch, "shape": shape_name, "skipped": reason}

    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        fn, args = build_step(cfg, shape, mesh, remat=remat,
                              sharding_policy=sharding_policy,
                              constrain_acts=constrain_acts,
                              moe_expert_parallel=moe_expert_parallel,
                              param_dtype=jnp.float8_e4m3fn if w8 else None)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        # collectives live in the SPMD-partitioned (compiled) module
        coll = collective_bytes(compiled.as_text())

        try:
            mem = compiled.memory_analysis()
            mem_d = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            }
        except Exception as e:          # pragma: no cover
            mem_d = {"error": str(e)}
        try:
            cost = compiled.cost_analysis()
            # jax returned a one-dict-per-device *list* here historically
            # and a plain dict in current releases — accept both (the
            # list form drifted this launcher: `cost.get` on a list)
            if isinstance(cost, (list, tuple)):
                cost = cost[0] if cost else {}
            cost_d = {"flops": cost.get("flops"),
                      "bytes_accessed": cost.get("bytes accessed")}
        except Exception as e:          # pragma: no cover
            cost_d = {"error": str(e)}

    res = {
        "arch": arch, "shape": shape_name, "policy": sharding_policy,
        "constrained": constrain_acts,
        "mesh": list(mesh.devices.shape), "multi_pod": multi_pod,
        "n_devices": int(np.prod(mesh.devices.shape)),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "collective_bytes": coll,
        "memory": mem_d, "cost": cost_d,
    }
    if verbose:
        print(json.dumps(res))
    return res


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--policy", default="baseline",
                    choices=["baseline", "megatron", "fsdp"])
    ap.add_argument("--constrain", action="store_true",
                    help="pin batch sharding on the residual stream")
    ap.add_argument("--moe-ep", action="store_true",
                    help="explicit expert-parallel shard_map MoE")
    ap.add_argument("--w8", action="store_true",
                    help="FPX serving variant: weights stored as e4m3 "
                         "(half the HBM/collective bytes of bf16)")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    args = ap.parse_args(argv)

    pairs = []
    if args.all:
        for a in ASSIGNED:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    results = []
    for a, s in pairs:
        try:
            r = run_one(a, s, multi_pod=args.multi_pod, remat=args.remat,
                        sharding_policy=args.policy,
                        constrain_acts=args.constrain,
                        moe_expert_parallel=args.moe_ep, w8=args.w8)
        except Exception as e:          # record, keep going
            r = {"arch": a, "shape": s, "multi_pod": args.multi_pod,
                 "error": f"{type(e).__name__}: {e}"}
            print(json.dumps(r))
        results.append(r)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(r) + "\n")

    n_err = sum(1 for r in results if "error" in r)
    print(f"# dry-run complete: {len(results)} pairs, {n_err} errors",
          file=sys.stderr)
    if n_err:
        sys.exit(1)


if __name__ == "__main__":
    main()
