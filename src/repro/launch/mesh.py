"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is pure data parallelism across pods (gradient all-reduce crosses the
pod axis; all other collectives stay intra-pod).

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests run on 1 CPU device; only dryrun.py
forces 512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh: jax.sharding.Mesh):
    """Mesh axes over which the batch dimension shards."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
