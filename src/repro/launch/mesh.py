"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is pure data parallelism across pods (gradient all-reduce crosses the
pod axis; all other collectives stay intra-pod).

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests run on 1 CPU device; only dryrun.py
forces 512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh: jax.sharding.Mesh):
    """Mesh axes over which the batch dimension shards."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def sim_device_count() -> int:
    """Devices available for a simulated mesh (CI forces 8 CPU devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; tier-1 runs see
    1 and the sharded paths skip)."""
    return jax.device_count()


def sim_mesh(n_model: int = 2, *, n_data: int = 1):
    """(data, model) mesh over simulated host devices, or ``None`` when the
    process doesn't have ``n_data * n_model`` devices.

    This is how the serving stack places a tensor-parallel engine in CI:
    the same axis names as :func:`make_production_mesh`, so the
    :mod:`repro.launch.shardings` FSDP x TP rules apply unchanged, but
    built from however many host devices ``XLA_FLAGS`` conjured — the
    keras-jax ``distribution_lib_test`` trick that makes multi-chip
    placement differential-testable on one CPU."""
    need = n_data * n_model
    if jax.device_count() < need or need < 2:
        return None
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         devices=jax.devices()[:need])
