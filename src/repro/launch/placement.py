"""Fleet placement on a (simulated) multi-host topology.

The fleet router's candidates are *operating points* (model, gamma); this
module pins each one to hardware: which host its engine lives on, how many
chips it spans (tensor parallelism), and which link its collectives cross.
Two physical facts flow from a placement into the clock contract
(:mod:`repro.core.latency`):

* **Dispatch hops.**  A request arrives at the ingress host; serving it on
  another host moves the prompt over DCN before prefill can start and the
  response back after the last token (:meth:`Topology.dispatch`).  The
  router stamps both on the request (``t_ready`` / ``net_out_s``) so
  engine admission gates on prompt arrival and the deadline shrinks by
  the return hop.
* **Collective link.**  A tensor-parallel group confined to one host
  all-reduces over ICI; a group that *spans* hosts pays every per-layer
  all-reduce over DCN — three orders of magnitude more latency per hop.
  :meth:`Topology.place_tp` picks the link honestly, and
  :class:`~repro.serving.continuous.LatencyProfile` prices it into every
  prefill/step/service projection.  A router that ignores the link
  ("net-blind") believes a DCN-spanning engine is as fast as an ICI one,
  overloads it, and misses deadlines — the mispricing
  ``benchmarks/table_sharded.py`` measures.

Everything here is host-side arithmetic: no jax, no devices — placements
feed :class:`~repro.serving.fleet.FleetRouter` pricing whether the engines
are analytic or live.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core.latency import Hardware, V5E, xfer_s

#: wire bytes per prompt/response token (int32 token ids)
TOKEN_BYTES = 4


@dataclasses.dataclass(frozen=True)
class Placement:
    """One engine's seat in the fleet: ``tp`` chips on ``host`` (or
    spanning hosts when ``link == "dcn"``), collectives over ``link``."""
    host: int = 0
    tp: int = 1
    link: str = "ici"

    def __post_init__(self):
        assert self.tp >= 1, self.tp
        assert self.link in ("ici", "dcn"), self.link


@dataclasses.dataclass(frozen=True)
class Topology:
    """The (simulated) machine the fleet is placed on."""
    n_hosts: int = 1
    chips_per_host: int = 8
    #: host requests arrive at (and responses leave from)
    ingress_host: int = 0
    hw: Hardware = V5E

    def dispatch(self, p: Placement, prompt_len: int,
                 max_new: int) -> Tuple[float, float, str]:
        """(inbound_s, outbound_s, link) of serving a request on ``p``:
        the prompt's DCN hop ingress->host before prefill can start, and
        the response's hop back — both zero for an engine co-located with
        the ingress."""
        if p.host == self.ingress_host:
            return 0.0, 0.0, "local"
        return (xfer_s(prompt_len * TOKEN_BYTES, "dcn", self.hw),
                xfer_s(max_new * TOKEN_BYTES, "dcn", self.hw), "dcn")

    def place_tp(self, tp: int, host: int = 0) -> Placement:
        """Seat a ``tp``-way engine honestly: on one host's ICI fabric
        when it fits, spanning hosts over DCN when it doesn't (the case
        a link-blind router misprices)."""
        assert 1 <= tp <= self.n_hosts * self.chips_per_host, tp
        link = "ici" if tp <= self.chips_per_host else "dcn"
        return Placement(host=host, tp=tp, link=link)

    def spread(self, n_engines: int, tp: int = 1) -> List[Placement]:
        """Round-robin ``n_engines`` single-host engines across hosts —
        the equal-capacity fallback arm (every engine past the ingress
        host pays dispatch hops)."""
        per_host = max(1, self.chips_per_host // max(tp, 1))
        out: List[Placement] = []
        for i in range(n_engines):
            host = (i // per_host) % self.n_hosts
            out.append(self.place_tp(tp, host=host))
        return out


def placements_summary(placements: List[Placement],
                       topo: Optional[Topology]) -> str:
    """One-line human summary for benchmark logs."""
    if not placements:
        return "co-located (no topology)"
    parts = [f"host{p.host}:tp{p.tp}/{p.link}" for p in placements]
    hosts = f"{topo.n_hosts} hosts" if topo else "untopologized"
    return f"{hosts}: " + " ".join(parts)
