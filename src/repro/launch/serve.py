"""Serving launcher: batched requests through the FPX-aware engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen-sim-3b \
      --requests 32 --gamma 0.3

Loads (or initializes) a model, applies the FPX assignment at the requested
gamma (running Algorithm-1 calibration first), and drives the scheduler over
a synthetic request stream, reporting modeled TPU latency per wave.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config, SIM_TO_FULL
from repro.core import assign as assign_mod
from repro.core import calibrate as calib_mod
from repro.data import pipeline as dp
from repro.models import transformer
from repro.models.modules import ExecContext
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import Request, Scheduler


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen-sim-3b")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--gamma", type=float, default=0.0)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=8)
    ap.add_argument("--deadline-ms", type=float, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    if args.ckpt:
        params = ckpt.restore(args.ckpt, params)

    # FPX: calibrate -> assign -> serve at delta(l)
    policy, default_bits, avg_bits = None, 16, 16.0
    if args.gamma >= 0.0:
        eps = calib_mod.calibrate(params, cfg,
                                  dp.calibration_batches(cfg, n=2, seq=64))
        assignment = assign_mod.assign_precision(eps, args.gamma)
        policy, default_bits = assignment, 8
        avg_bits = assign_mod.avg_bits(assignment)
        print(f"# FPX gamma={args.gamma}: avg bits {avg_bits:.2f} over "
              f"{len(assignment)} linear layers")

    lat_cfg = get_config(SIM_TO_FULL[args.arch]) if args.arch in SIM_TO_FULL else cfg
    engine = ServingEngine(params, cfg,
                           ctx=ExecContext(policy=policy,
                                           default_bits=default_bits),
                           max_ctx=args.prompt_len + args.max_new,
                           latency_cfg=lat_cfg, avg_bits=avg_bits)
    sched = Scheduler(engine, batch_slots=args.batch_slots)

    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32)
        sched.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new,
                             deadline_s=(args.deadline_ms or 0) / 1e3 or None))
    done = sched.run()

    met = [r for r in done if r.met_deadline]
    print(f"# served {len(done)} requests; modeled latency "
          f"{done[0].latency_s*1e3:.1f} ms/action"
          + (f"; {len(met)}/{len(done)} met deadline" if args.deadline_ms else ""))


if __name__ == "__main__":
    main()
