"""Serving launcher: one entry point over all three serving paths.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen-sim-3b \
      --requests 32 --gamma 0.3                       # wave scheduler
  PYTHONPATH=src python -m repro.launch.serve --path paged \
      --arch qwen-sim-1.5b --deadline-ms 500          # paged engine
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --path sharded \
      --arch dbrx-132b --tp 2                         # tensor-parallel

Loads (or initializes) a model, optionally applies the FPX assignment at
the requested gamma (running Algorithm-1 calibration first — ``--gamma``
omitted serves the FP16 baseline), and drives the chosen serving path
over a synthetic request stream, reporting modeled latency.

``--path sharded`` places the engine on a simulated (1, tp) device mesh
(:func:`repro.launch.mesh.sim_mesh`): params under the FSDP x TP rules,
paged KV pools head-sharded over the "model" axis, per-forward all-reduce
tax priced on the clock.  Requires ``jax.device_count() >= tp`` — set
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before* launch.
"""
from __future__ import annotations

import argparse
from typing import Optional, Sequence

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config, SIM_TO_FULL
from repro.core import assign as assign_mod
from repro.core import calibrate as calib_mod
from repro.data import pipeline as dp
from repro.models import transformer
from repro.models.modules import ExecContext


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="qwen-sim-3b")
    ap.add_argument("--path", choices=("wave", "paged", "sharded"),
                    default="wave",
                    help="wave scheduler, paged continuous engine, or "
                         "tensor-parallel paged engine on a simulated mesh")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--gamma", type=float, default=None,
                    help="FPX gamma (omit = FP16 baseline, no assignment)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=8,
                    help="wave batch slots / continuous decode lanes")
    ap.add_argument("--deadline-ms", type=float, default=None)
    ap.add_argument("--tp", type=int, default=2,
                    help="model-axis shards for --path sharded")
    return ap


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_argparser().parse_args(argv)

    cfg = get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    if args.ckpt:
        params = ckpt.restore(args.ckpt, params)

    # FPX: calibrate -> assign -> serve at delta(l).  --gamma omitted is
    # the FP16 baseline (the drifted launcher quantized unconditionally:
    # its default gamma 0.0 passed a `>= 0.0` gate that was always true)
    policy, default_bits, avg_bits = None, 16, 16.0
    if args.gamma is not None:
        eps = calib_mod.calibrate(params, cfg,
                                  dp.calibration_batches(cfg, n=2, seq=64))
        assignment = assign_mod.assign_precision(eps, args.gamma)
        policy, default_bits = assignment, 8
        avg_bits = assign_mod.avg_bits(assignment)
        print(f"# FPX gamma={args.gamma}: avg bits {avg_bits:.2f} over "
              f"{len(assignment)} linear layers")

    lat_cfg = get_config(SIM_TO_FULL[args.arch]) \
        if args.arch in SIM_TO_FULL else cfg
    ctx = ExecContext(policy=policy, default_bits=default_bits)
    deadline_s = args.deadline_ms / 1e3 if args.deadline_ms else None

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=args.prompt_len)
               .astype(np.int32) for _ in range(args.requests)]

    if args.path == "wave":
        from repro.serving.engine import ServingEngine
        from repro.serving.scheduler import Request, Scheduler
        engine = ServingEngine(params, cfg, ctx=ctx,
                               max_ctx=args.prompt_len + args.max_new,
                               latency_cfg=lat_cfg, avg_bits=avg_bits)
        sched = Scheduler(engine, batch_slots=args.batch_slots)
        for rid, prompt in enumerate(prompts):
            sched.submit(Request(rid=rid, prompt=prompt,
                                 max_new=args.max_new,
                                 deadline_s=deadline_s))
        done = sched.run()
    else:
        from repro.serving.paged_engine import ContinuousEngine
        from repro.serving.scheduler import Request
        mesh = None
        if args.path == "sharded":
            from repro.launch.mesh import sim_mesh
            mesh = sim_mesh(args.tp)
            if mesh is None:
                print(f"# need {args.tp} devices for --path sharded, have "
                      f"{jax.device_count()} — set XLA_FLAGS="
                      "--xla_force_host_platform_device_count=8 before "
                      "launch")
                return 2
            print(f"# sharded: tp={args.tp} over {jax.device_count()} "
                  "simulated devices")
        page_size = 16
        max_ctx = -(-(args.prompt_len + args.max_new) // page_size) \
            * page_size
        eng = ContinuousEngine(params, cfg, slots=args.batch_slots,
                               page_size=page_size, max_ctx=max_ctx,
                               policy="serve" if deadline_s is None
                               else "degrade",
                               latency_cfg=lat_cfg, avg_bits=avg_bits,
                               ctx=ctx, mesh=mesh)
        reqs = [Request(rid=rid, prompt=prompt, max_new=args.max_new,
                        deadline_s=deadline_s)
                for rid, prompt in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        done = [r for r in reqs if not r.dropped]

    met = [r for r in done if r.met_deadline]
    lat = [r.latency_s for r in done if r.latency_s is not None]
    print(f"# served {len(done)}/{args.requests} requests; modeled latency "
          f"{1e3 * (sum(lat) / len(lat) if lat else 0.0):.1f} ms/action"
          + (f"; {len(met)}/{len(done)} met deadline"
             if args.deadline_ms else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
