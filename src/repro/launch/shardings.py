"""Sharding policy: FSDP x TP rules for params, activations, and caches.

Baseline (paper-faithful "model parallelism" analogue, adapted to TPU):

* weights: last dim on "model" (tensor parallel), second-to-last on "data"
  (FSDP/ZeRO-3 style) — dims that don't divide the axis stay unsharded;
* MoE expert stacks: leading expert dim on "model" (expert parallel), d_in
  on "data";
* batch: ("pod","data") for train / large-batch decode;
* long_500k (batch=1): KV-cache *sequence* axis shards on "data"
  (sequence-parallel decode attention) and the token is replicated.

``param_shardings`` walks any pytree-of-arrays (or ShapeDtypeStructs) and
returns a matching tree of NamedShardings — used by dryrun, train, serve.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, InputShape


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _fits(dim: int, mesh: Mesh, axis: str) -> bool:
    n = _axis_size(mesh, axis)
    return n > 1 and dim % n == 0 and dim >= n


#: Row-parallel linears (output projections): contraction dim carries the
#: "model" shard so the preceding col-parallel activation is consumed
#: locally (partial sums + one all-reduce), Megatron-style.
_ROW_PARALLEL = ("['o']", "['down']", "['out_proj']", "['ffn_down']",
                 "['dt_proj']")


def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
               policy: str = "baseline") -> P:
    """Spec for one parameter under a named sharding policy.

    baseline  — naive FSDP x TP: every matrix (…, d_in, d_out) ->
                P(…, "data", "model").  The paper-faithful starting point;
                §Perf measures its collective pathology.
    megatron  — role-aware TP: col-parallel in-projections, row-parallel
                out-projections, vocab-sharded embedding/head; "data" axis
                used for ZeRO-style storage sharding of the non-TP dim.
    fsdp      — no tensor parallelism: weights sharded over both axes for
                storage only; batch is sharded over ("data","model").
    """
    nd = len(shape)
    if nd <= 1:
        return P()
    spec = [None] * nd
    is_moe = ".moe." in path or "['moe']" in path
    is_embed = "['embed']" in path or "['emb']" in path

    if policy == "fsdp":
        # storage-only sharding: biggest dims over both axes
        if _fits(shape[nd - 1], mesh, "model"):
            spec[nd - 1] = "model"
        if _fits(shape[nd - 2], mesh, "data"):
            spec[nd - 2] = "data"
        return P(*spec)

    if is_moe and nd >= 3 and "router" not in path:
        # expert-parallel: experts on "model"
        e_dim = nd - 3
        if _fits(shape[e_dim], mesh, "model"):
            spec[e_dim] = "model"
        if policy == "megatron":
            if _fits(shape[nd - 1], mesh, "data"):
                spec[nd - 1] = "data"
        elif _fits(shape[nd - 2], mesh, "data"):
            spec[nd - 2] = "data"
        return P(*spec)

    if policy == "megatron":
        if is_embed:
            # vocab-sharded embedding, d_model UNSHARDED: sharding d on a
            # batch axis makes GSPMD replicate the batch instead (measured
            # in §Perf iteration 1) — the d axis must stay free.
            if _fits(shape[nd - 2], mesh, "model"):
                spec[nd - 2] = "model"
            return P(*spec)
        if "lm_head" in path:
            if _fits(shape[nd - 1], mesh, "model"):
                spec[nd - 1] = "model"
            return P(*spec)
        row = any(tag in path for tag in _ROW_PARALLEL)
        tp_dim = nd - 2 if row else nd - 1
        st_dim = nd - 1 if row else nd - 2
        if _fits(shape[tp_dim], mesh, "model"):
            spec[tp_dim] = "model"
        if _fits(shape[st_dim], mesh, "data"):
            spec[st_dim] = "data"
        return P(*spec)

    # baseline: TP last, FSDP -2
    if _fits(shape[nd - 1], mesh, "model"):
        spec[nd - 1] = "model"
    if _fits(shape[nd - 2], mesh, "data"):
        spec[nd - 2] = "data"
    return P(*spec)


def _tree_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        yield jax.tree_util.keystr(path), leaf


def param_shardings(tree: Any, mesh: Mesh, policy: str = "baseline") -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        shape = tuple(leaf.shape) if hasattr(leaf, "shape") else ()
        out.append(NamedSharding(mesh, param_spec(name, shape, mesh, policy)))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Activations / batch / cache shardings
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh, global_batch: int, policy: str = "baseline") -> P:
    names = ("pod", "data", "model") if policy == "fsdp" else ("pod", "data")
    axes = [a for a in names if a in mesh.axis_names]
    n = int(np.prod([mesh.shape[a] for a in axes]))
    if global_batch % n == 0 and global_batch >= n:
        return P(tuple(axes))
    if global_batch % _axis_size(mesh, "data") == 0 and \
            global_batch >= _axis_size(mesh, "data"):
        return P("data")
    return P()          # batch too small to shard (long_500k): replicate


def token_sharding(mesh: Mesh, global_batch: int,
                   policy: str = "baseline") -> NamedSharding:
    return NamedSharding(mesh, P(*batch_spec(mesh, global_batch, policy), None))


def cache_shardings(cache_tree: Any, mesh: Mesh, *, global_batch: int,
                    seq_shard: bool) -> Any:
    """KV caches: (..., B, S, kv, hd) — B on batch axes when divisible;
    for batch=1 long-context decode, shard S on "data" instead (sequence
    parallelism) and kv-heads on "model" when divisible."""
    bspec = batch_spec(mesh, global_batch)
    b_axes = []
    for el in bspec:
        if isinstance(el, (tuple, list)):
            b_axes.extend(el)
        elif el is not None:
            b_axes.append(el)
    b_axes = tuple(b_axes)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        shape = tuple(leaf.shape)
        nd = len(shape)
        spec = [None] * nd
        if "'k'" in name or "'v'" in name:
            # (..., B, S, kv, hd)
            b_i, s_i, kv_i = nd - 4, nd - 3, nd - 2
            if b_axes and shape[b_i] % _mesh_prod(mesh, b_axes) == 0:
                spec[b_i] = b_axes if len(b_axes) > 1 else b_axes[0]
            elif seq_shard and _fits(shape[s_i], mesh, "data"):
                spec[s_i] = "data"
            if _fits(shape[kv_i], mesh, "model"):
                spec[kv_i] = "model"
            elif _fits(shape[s_i], mesh, "model") and spec[s_i] is None:
                # kv heads don't divide the model axis: shard the sequence
                # instead (flash-decode style partial attention — keeps the
                # cache fully local, §Perf decode iteration)
                spec[s_i] = "model"
        elif "'pos'" in name:
            pass
        else:
            # SSM / mLSTM states (stack..., B, feat...): batch + widest feature
            if b_axes:
                for i in range(nd):
                    if shape[i] == global_batch and \
                            global_batch % _mesh_prod(mesh, b_axes) == 0:
                        spec[i] = b_axes if len(b_axes) > 1 else b_axes[0]
                        break
            feat = [(s, i) for i, s in enumerate(shape) if spec[i] is None]
            if feat:
                s_max, i_max = max(feat)
                if _fits(s_max, mesh, "model"):
                    spec[i_max] = "model"
        out.append(NamedSharding(mesh, P(*spec)))
    return jax.tree_util.tree_unflatten(treedef, out)


def _mesh_prod(mesh: Mesh, axes) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def paged_pool_spec(cfg: ModelConfig, mesh: Mesh) -> P:
    """Spec for one paged-KV pool group, shape ``(layers, pages,
    page_size, kv_heads, head_dim)`` (:class:`repro.serving.kv_cache.
    PagedKVCache`): kv-heads shard on "model" when they divide the axis —
    each chip owns its heads' pages and the fused paged-attention kernel
    runs per shard, GSPMD all-gathering the per-head partial outputs into
    the row-parallel o-projection.  Heads that don't divide replicate (the
    pool is the *decode* hot path; a mis-shard here silently multiplies
    HBM traffic)."""
    if _fits(cfg.n_kv_heads, mesh, "model"):
        return P(None, None, None, "model", None)
    return P()


def paged_pool_shardings(cfg: ModelConfig, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, paged_pool_spec(cfg, mesh))


def logits_sharding(mesh: Mesh, global_batch: int) -> NamedSharding:
    return NamedSharding(mesh, P(*batch_spec(mesh, global_batch), None, "model"))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
