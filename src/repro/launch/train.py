"""Training launcher.

CPU/host mode runs real steps on the 1-device mesh (examples, smoke-scale);
``--mesh production`` builds the sharded train step exactly as dryrun.py
does and executes it on the 512-placeholder-device host platform (slow but
real — useful for numerically validating the sharded program at tiny scale).

  PYTHONPATH=src python -m repro.launch.train --arch qwen-sim-3b --steps 200
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.data import pipeline as dp
from repro.models.modules import ExecContext
from repro.training.optim import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant of the arch")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--remat", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"# {cfg.name}: ~{cfg.n_params/1e6:.1f}M params "
          f"({cfg.n_active_params/1e6:.1f}M active)")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 10),
                          total_steps=args.steps)
    params, opt_state = init_train_state(jax.random.PRNGKey(args.seed), cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, ExecContext(),
                                      remat=args.remat))

    stream = dp.lm_stream(cfg, batch=args.batch, seq=args.seq, seed=args.seed)
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d}  loss {float(m['loss']):.4f}  "
                  f"acc {float(m['accuracy']):.3f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  "
                  f"{(time.time()-t0)/(i+1):.2f}s/step")
        if args.ckpt_dir and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            path = ckpt.save_step(args.ckpt_dir, i + 1, params)
            print(f"# checkpoint -> {path}")
    if args.ckpt_dir:
        print(f"# final checkpoint -> {ckpt.save_step(args.ckpt_dir, args.steps, params)}")


if __name__ == "__main__":
    main()
