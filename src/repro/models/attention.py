"""Attention: GQA + RoPE + sliding-window / local:global + cross-attn + KV cache.

All projections route through :func:`modules.quant_linear` so FPX precision
assignment covers them.  Attention *mechanics* (softmax, RoPE, cache update)
stay full precision, exactly as the paper prescribes (Sec. 4.1).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import modules
from repro.models.modules import ExecContext, join


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _rope_inv_freq(head_dim: int, theta: float) -> jax.Array:
    """Inverse-frequency table ``1 / theta^(i/half)``, cached per
    (head_dim, theta): every layer of every decode step used to recompute
    this identical constant — hoisting it shares one table across
    layers/steps (and across traces, where it embeds as the same
    constant).  ``ensure_compile_time_eval`` keeps the cached table a
    concrete array even when first touched inside a jit trace (a cached
    tracer would leak into later traces)."""
    half = head_dim // 2
    with jax.ensure_compile_time_eval():
        return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32)
                                / half))


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float = 10000.0,
                 dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """positions: (..., S) int -> cos/sin of shape (..., S, head_dim//2)."""
    freq = _rope_inv_freq(head_dim, float(theta))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def attn_init(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
              qk_norm: bool = False, bias: bool = False, d_kv_in: Optional[int] = None,
              dtype=jnp.float32) -> Dict[str, Any]:
    """d_kv_in: source dim for K/V (cross-attention memory width)."""
    ks = jax.random.split(key, 4)
    d_kv_in = d_kv_in or d_model
    p = {
        "q": modules.linear_init(ks[0], d_model, n_heads * head_dim, bias, dtype),
        "k": modules.linear_init(ks[1], d_kv_in, n_kv_heads * head_dim, bias, dtype),
        "v": modules.linear_init(ks[2], d_kv_in, n_kv_heads * head_dim, bias, dtype),
        "o": modules.linear_init(ks[3], n_heads * head_dim, d_model, bias, dtype),
    }
    if qk_norm:
        p["q_norm"] = modules.rmsnorm_init(head_dim, dtype)
        p["k_norm"] = modules.rmsnorm_init(head_dim, dtype)
    return p


# ---------------------------------------------------------------------------
# Core score/combine
# ---------------------------------------------------------------------------

def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: Optional[jax.Array],
          scale: float) -> jax.Array:
    """q: (B,Sq,H,D) k/v: (B,Skv,Hkv,D) grouped-query attention.

    Score math accumulates in fp32 via ``preferred_element_type`` WITHOUT
    casting the operands — materializing an fp32 copy of a 32k-token KV
    cache doubles its HBM/interconnect footprint (§Perf decode iteration)."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    qg = q.reshape(B, Sq, Hkv, group, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        # mask: (B, 1, Sq, Skv) or (Sq, Skv) bool, True = attend
        if mask.ndim == 2:
            mask = mask[None, None]
        logits = jnp.where(mask[:, :, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, D)


def causal_mask(sq: int, skv: int, window: Optional[int] = None,
                offset: int = 0) -> jax.Array:
    """True where query i (global pos offset+i) may attend key j.

    ``window``: sliding-window size (attend to keys within the last
    ``window`` positions, inclusive of self)."""
    qpos = jnp.arange(sq) + offset
    kpos = jnp.arange(skv)
    m = kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > (qpos[:, None] - window)
    return m


# ---------------------------------------------------------------------------
# Paged decode (block-table KV cache)
# ---------------------------------------------------------------------------

def _paged_decode_attend(q: jax.Array, k: jax.Array, v: jax.Array,
                         cache: Dict[str, jax.Array], *, scale: float,
                         rope_theta: float, ctx: ExecContext,
                         window: Optional[int] = None,
                         ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One paged decode step for one layer.

    q/k/v: freshly projected (B, 1, H|Hkv, D) for the current token of each
    lane.  ``cache`` holds this layer's slice of the shared page pool plus
    the (lane-shared-across-layers) block tables and per-lane positions.
    Writes lane b's K/V at logical position ``pos[b]`` (page
    ``block_tables[b, pos[b] // page_size]``, slot ``pos[b] % page_size``),
    then attends over the lane's paged context with a per-lane validity
    mask ``slot <= pos[b]`` (plus ``slot > pos[b] - window`` for
    sliding-window layer groups, whose out-of-window pages the cache has
    freed) via :func:`repro.kernels.ops.paged_attend` —
    the fused flash kernel reads K/V pages straight from the pool when
    ``ctx.use_pallas``; the jnp path gathers and runs dense masked SDPA
    (the historical semantics)."""
    from repro.kernels import ops as kernel_ops

    kpool, vpool = cache["kpool"], cache["vpool"]
    bt = cache["block_tables"]                     # (B, P) int32
    pos = cache["pos"]                             # (B,)  int32
    ps = kpool.shape[1]

    cos, sin = rope_cos_sin(pos[:, None], q.shape[-1], rope_theta)  # (B,1,D/2)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    pid = jnp.take_along_axis(bt, (pos // ps)[:, None], axis=1)[:, 0]  # (B,)
    within = pos % ps
    # distinct live lanes own distinct pages, so the scatter is collision-free
    # (idle lanes all hit the reserved dummy page — last write wins, unused)
    kpool = kpool.at[pid, within].set(k[:, 0].astype(kpool.dtype))
    vpool = vpool.at[pid, within].set(v[:, 0].astype(vpool.dtype))

    out = kernel_ops.paged_attend(q, kpool, vpool, bt, pos, scale=scale,
                                  use_pallas=ctx.use_pallas, window=window)
    return out, {"kpool": kpool, "vpool": vpool, "block_tables": bt,
                 "pos": pos + 1}


def _paged_prefill_attend(q: jax.Array, k: jax.Array, v: jax.Array,
                          cache: Dict[str, jax.Array], *, scale: float,
                          rope_theta: float, ctx: ExecContext,
                          window: Optional[int] = None,
                          ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One chunked-prefill step for one layer: absorb a prompt chunk into
    the paged cache.

    q/k/v: freshly projected (B, C, H|Hkv, D) for a chunk of each lane's
    prompt, occupying global positions ``pos[b] .. pos[b] + C - 1``.  The
    chunk's post-RoPE K (and V) are scattered into the lanes' block-table
    pages (``kernels.paged_scatter`` when ``ctx.use_pallas``), then each
    lane's *whole* written context — prior chunks plus this one — is
    attended causally through :func:`repro.kernels.ops.paged_attend`
    (fused flash kernel over the pool pages when ``ctx.use_pallas``; jnp
    gather + dense masked SDPA otherwise): the query at global position p
    sees exactly the slots <= p, so the result is mathematically identical
    to a monolithic prefill of the same prompt."""
    from repro.kernels import ops as kernel_ops

    C = q.shape[1]
    kpool, vpool = cache["kpool"], cache["vpool"]
    bt = cache["block_tables"]                     # (B, P) int32
    pos = cache["pos"]                             # (B,)  int32: chunk start

    qpos = pos[:, None] + jnp.arange(C)[None, :]            # (B, C)
    cos, sin = rope_cos_sin(qpos, q.shape[-1], rope_theta)  # (B, C, D/2)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    # skip_page: window groups park retired table entries on the dummy
    # page (id 0); suppressing writes there keeps the in-place Pallas
    # scatter deterministic.  The serving engine keeps every page of the
    # chunk's own span live, so this only fires for stale tables.
    skip = None if window is None else 0
    # The Pallas scatter requires page-aligned chunk starts; verify
    # chunks (speculative decode) begin mid-page, so their ExecContext
    # sets ``unaligned_scatter`` to route the scatter through the jnp
    # path while the attend below stays fused.
    scatter_pallas = ctx.use_pallas and not ctx.unaligned_scatter
    kpool = kernel_ops.scatter_chunk(kpool, bt, pos, k,
                                     use_pallas=scatter_pallas,
                                     skip_page=skip)
    vpool = kernel_ops.scatter_chunk(vpool, bt, pos, v,
                                     use_pallas=scatter_pallas,
                                     skip_page=skip)

    out = kernel_ops.paged_attend(q, kpool, vpool, bt, pos, scale=scale,
                                  use_pallas=ctx.use_pallas, window=window)
    return out, {"kpool": kpool, "vpool": vpool, "block_tables": bt,
                 "pos": pos + C}


# ---------------------------------------------------------------------------
# Forward (self-attention, train/prefill + decode with cache)
# ---------------------------------------------------------------------------

def attn_apply(params, x: jax.Array, *, n_heads: int, n_kv_heads: int,
               head_dim: int, ctx: ExecContext, name: str,
               rope_theta: float = 10000.0,
               positions: Optional[jax.Array] = None,
               sliding_window: Optional[int] = None,
               cache: Optional[Dict[str, jax.Array]] = None,
               qk_norm: bool = False,
               query_scale: Optional[float] = None,
               ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Self-attention.

    Without ``cache``: causal prefill/train over the full sequence.
    With ``cache`` ({"k","v": (B, S_cache, Hkv, D), "pos": ()-int}): decode —
    ``x`` is (B, 1, d), new K/V written at ``pos`` (ring-buffer write for
    sliding-window caches), attends to all valid cache entries.

    With a *paged* cache ({"kpool","vpool": (n_pages, page_size, Hkv, D),
    "block_tables": (B, P)-int32, "pos": (B,)-int32}): paged decode —
    each lane has its own position and its own page list into a shared
    pool; new K/V are scattered into lane b's page at ``pos[b]`` and the
    lane attends over its block-table context via ``ops.paged_attend``
    (the fused paged flash-attention kernel when ``ctx.use_pallas`` —
    pages stream pool-direct through an online softmax; jnp gather + dense
    masked SDPA otherwise).  Lanes whose
    table points at the reserved dummy page are idle; their outputs are
    garbage and must be discarded by the caller.  With a paged cache and
    ``x`` longer than one token, this is a *prefill chunk*: positions
    ``pos[b] .. pos[b]+S-1`` are absorbed in one causal pass over the
    lane's already-written pages plus the chunk (chunked prefill — see
    :func:`repro.models.transformer.prefill_chunk`).
    """
    B, S, _ = x.shape
    q = modules.quant_linear(params["q"], x, name=join(name, "q"), ctx=ctx)
    k = modules.quant_linear(params["k"], x, name=join(name, "k"), ctx=ctx)
    v = modules.quant_linear(params["v"], x, name=join(name, "v"), ctx=ctx)
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv_heads, head_dim)
    v = v.reshape(B, S, n_kv_heads, head_dim)

    if qk_norm:
        q = modules.rmsnorm(params["q_norm"], q)
        k = modules.rmsnorm(params["k_norm"], k)

    scale = query_scale if query_scale is not None else head_dim ** -0.5

    if cache is None:
        if positions is None:
            positions = jnp.arange(S)
        cos, sin = rope_cos_sin(positions, head_dim, rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        mask = causal_mask(S, S, window=sliding_window)
        out = _sdpa(q, k, v, mask, scale)
        new_cache = None
    elif "kpool" in cache:
        # paged cache: S == 1 is a decode step, S > 1 a prefill chunk —
        # both write at per-lane positions through per-lane block tables.
        # ``sliding_window`` marks this layer as part of a windowed group:
        # the kernels mask validity to the window and the cache frees
        # out-of-window pages mid-flight.
        if S > 1:
            out, new_cache = _paged_prefill_attend(q, k, v, cache,
                                                   scale=scale,
                                                   rope_theta=rope_theta,
                                                   ctx=ctx,
                                                   window=sliding_window)
        else:
            out, new_cache = _paged_decode_attend(q, k, v, cache,
                                                  scale=scale,
                                                  rope_theta=rope_theta,
                                                  ctx=ctx,
                                                  window=sliding_window)
    else:
        # decode: S == 1
        pos = cache["pos"]  # global position of this token (traced scalar)
        S_cache = cache["k"].shape[1]
        cos, sin = rope_cos_sin(pos[None][None], head_dim, rope_theta)  # (1,1,D/2)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        # ring-buffer write index (== pos for full caches)
        widx = pos % S_cache
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), widx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), widx, axis=1)
        slot = jnp.arange(S_cache)
        if sliding_window is not None and S_cache <= sliding_window:
            # ring buffer sized to the window: every written slot is in-window
            valid = slot <= jnp.minimum(pos, S_cache - 1)
            mask = valid[None, None, None, :]
        else:
            valid = slot <= pos
            if sliding_window is not None:
                valid &= slot > pos - sliding_window
            mask = valid[None, None, None, :]
        out = _sdpa(q, ck, cv, jnp.broadcast_to(mask, (B, 1, 1, S_cache)), scale)
        new_cache = {"k": ck, "v": cv, "pos": pos + 1}

    y = modules.quant_linear(params["o"],
                             out.reshape(B, S, n_heads * head_dim).astype(x.dtype),
                             name=join(name, "o"), ctx=ctx)
    return y, new_cache


# ---------------------------------------------------------------------------
# Cross-attention (VLM image layers, enc-dec decoder)
# ---------------------------------------------------------------------------

def cross_attn_apply(params, x: jax.Array, memory_kv: Tuple[jax.Array, jax.Array],
                     *, n_heads: int, n_kv_heads: int, head_dim: int,
                     ctx: ExecContext, name: str) -> jax.Array:
    """x: (B, Sq, d); memory_kv: precomputed (k, v) each (B, Skv, Hkv, D).

    Cross-attn K/V are computed once from the encoder/vision memory and
    reused every decode step (standard enc-dec caching)."""
    B, S, _ = x.shape
    q = modules.quant_linear(params["q"], x, name=join(name, "q"), ctx=ctx)
    q = q.reshape(B, S, n_heads, head_dim)
    k, v = memory_kv
    out = _sdpa(q, k, v, None, head_dim ** -0.5)
    return modules.quant_linear(params["o"],
                                out.reshape(B, S, n_heads * head_dim).astype(x.dtype),
                                name=join(name, "o"), ctx=ctx)


def cross_attn_kv(params, memory: jax.Array, *, n_kv_heads: int, head_dim: int,
                  ctx: ExecContext, name: str) -> Tuple[jax.Array, jax.Array]:
    B, Skv, _ = memory.shape
    k = modules.quant_linear(params["k"], memory, name=join(name, "k"), ctx=ctx)
    v = modules.quant_linear(params["v"], memory, name=join(name, "v"), ctx=ctx)
    return (k.reshape(B, Skv, n_kv_heads, head_dim),
            v.reshape(B, Skv, n_kv_heads, head_dim))


def init_kv_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype=dtype),
        "v": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype=dtype),
        "pos": jnp.zeros((), dtype=jnp.int32),
    }
