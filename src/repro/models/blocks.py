"""Per-architecture transformer blocks (pre-norm residual structure).

Every block exposes ``*_init(key, cfg, dtype)`` and an apply that threads an
optional decode cache and an optional prefill KV capture.  Blocks are
stack-friendly: all apply fns are written to run under ``lax.scan`` over a
stacked leading layer axis.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, ffn, modules, moe, ssm, xlstm
from repro.models.modules import ExecContext, join


# ---------------------------------------------------------------------------
# Dense / MoE blocks
# ---------------------------------------------------------------------------

def dense_block_init(key, cfg, dtype=jnp.float32, cross: bool = False) -> Dict[str, Any]:
    ks = jax.random.split(key, 2)
    p = {
        "attn_norm": modules.rmsnorm_init(cfg.d_model, dtype),
        "attn": attention.attn_init(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qk_norm=cfg.qk_norm, bias=cfg.attn_bias,
            d_kv_in=(cfg.vision_dim or cfg.d_model) if cross else None,
            dtype=dtype),
        "ffn_norm": modules.rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.n_experts:
        p["moe"] = moe.moe_init(ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts,
                                cfg.ffn_kind, dtype)
    else:
        p["ffn"] = ffn.ffn_init(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_kind, dtype)
    if cross:
        p["xgate"] = {"g": jnp.zeros((), dtype)}   # tanh-gated cross-attn (llama-vision)
    return p


def _ffn_or_moe(p, h, cfg, ctx, name):
    if cfg.n_experts:
        if ctx.moe_mesh is not None:
            return moe.moe_apply_expert_parallel(
                p["moe"], h, n_experts=cfg.n_experts, top_k=cfg.top_k,
                kind=cfg.ffn_kind, ctx=ctx, name=join(name, "moe"),
                capacity_factor=cfg.capacity_factor, mesh=ctx.moe_mesh,
                data_axes=ctx.moe_data_axes)
        return moe.moe_apply(p["moe"], h, n_experts=cfg.n_experts,
                             top_k=cfg.top_k, kind=cfg.ffn_kind, ctx=ctx,
                             name=join(name, "moe"),
                             capacity_factor=cfg.capacity_factor)
    return ffn.ffn_apply(p["ffn"], h, kind=cfg.ffn_kind, ctx=ctx,
                         name=join(name, "ffn"))


def dense_block_apply(p, h, *, cfg, ctx: ExecContext, name: str = "block",
                      window: Optional[int] = None,
                      positions=None, cache=None, return_kv: bool = False,
                      ) -> Tuple[jax.Array, Any]:
    """Standard block: h += attn(norm(h)); h += ffn(norm(h)).

    Returns (h, aux) where aux is the new cache (decode), the captured
    prefill KV (return_kv), or None.
    """
    h = modules.constrain(h, ctx)
    a_in = modules.rmsnorm(p["attn_norm"], h, plus_one=cfg.norm_plus_one)
    a, new_cache = attention.attn_apply(
        p["attn"], a_in, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, ctx=ctx, name=join(name, "attn"),
        rope_theta=cfg.rope_theta, positions=positions,
        sliding_window=window, cache=cache, qk_norm=cfg.qk_norm)
    h = h + a
    f_in = modules.rmsnorm(p["ffn_norm"], h, plus_one=cfg.norm_plus_one)
    h = h + _ffn_or_moe(p, f_in, cfg, ctx, name)

    aux = new_cache
    if return_kv and cache is None:
        # recompute K/V shards for the prefill cache (cheap vs attention itself)
        k = modules.quant_linear(p["attn"]["k"], a_in, name=join(name, "attn", "k"), ctx=ctx)
        v = modules.quant_linear(p["attn"]["v"], a_in, name=join(name, "attn", "v"), ctx=ctx)
        B, S, _ = a_in.shape
        k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            k = modules.rmsnorm(p["attn"]["k_norm"], k)
        pos = positions if positions is not None else jnp.arange(S)
        cos, sin = attention.rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)
        k = attention.apply_rope(k, cos, sin)
        aux = {"k": k, "v": v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)}
    return h, aux


def cross_block_apply(p, h, memory_kv, *, cfg, ctx: ExecContext,
                      name: str = "xblock") -> jax.Array:
    """Gated cross-attention block (llama-3.2-vision image layers /
    enc-dec decoder cross layers)."""
    a_in = modules.rmsnorm(p["attn_norm"], h, plus_one=cfg.norm_plus_one)
    a = attention.cross_attn_apply(
        p["attn"], a_in, memory_kv, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim, ctx=ctx,
        name=join(name, "attn"))
    if "xgate" in p:
        a = a * jnp.tanh(p["xgate"]["g"]).astype(a.dtype)
    h = h + a
    f_in = modules.rmsnorm(p["ffn_norm"], h, plus_one=cfg.norm_plus_one)
    return h + _ffn_or_moe(p, f_in, cfg, ctx, name)


# ---------------------------------------------------------------------------
# Hybrid (hymba): parallel attention + mamba heads
# ---------------------------------------------------------------------------

def hybrid_block_init(key, cfg, dtype=jnp.float32) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    dt_rank = max(8, cfg.d_model // 16)
    return {
        "norm": modules.rmsnorm_init(cfg.d_model, dtype),
        "attn": attention.attn_init(ks[0], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim, dtype=dtype),
        "ssm": ssm.ssm_init(ks[1], cfg.d_model, cfg.d_inner, cfg.ssm_state,
                            dt_rank, cfg.ssm_conv, dtype),
        "attn_out_norm": modules.rmsnorm_init(cfg.d_model, dtype),
        "ssm_out_norm": modules.rmsnorm_init(cfg.d_model, dtype),
        "ffn_norm": modules.rmsnorm_init(cfg.d_model, dtype),
        "ffn": ffn.ffn_init(ks[2], cfg.d_model, cfg.d_ff, cfg.ffn_kind, dtype),
    }


def hybrid_block_apply(p, h, *, cfg, ctx: ExecContext, name: str = "block",
                       window: Optional[int] = None, positions=None,
                       cache=None, return_kv: bool = False) -> Tuple[jax.Array, Any]:
    """Hymba fused block: attn and SSM branches see the same normed input;
    outputs are per-branch normalized and mean-combined (arXiv:2411.13676)."""
    h = modules.constrain(h, ctx)
    x_in = modules.rmsnorm(p["norm"], h)
    attn_cache = None if cache is None else cache.get("attn")
    ssm_state = None if cache is None else cache.get("ssm")

    a, new_attn = attention.attn_apply(
        p["attn"], x_in, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, ctx=ctx, name=join(name, "attn"),
        rope_theta=cfg.rope_theta, positions=positions,
        sliding_window=window, cache=attn_cache)
    dt_rank = max(8, cfg.d_model // 16)
    s, new_ssm = ssm.ssm_apply(
        p["ssm"], x_in, d_inner=cfg.d_inner, state_dim=cfg.ssm_state,
        dt_rank=dt_rank, conv_dim=cfg.ssm_conv, ctx=ctx,
        name=join(name, "ssm"), state=ssm_state)

    mixed = 0.5 * (modules.rmsnorm(p["attn_out_norm"], a) +
                   modules.rmsnorm(p["ssm_out_norm"], s))
    h = h + mixed
    f_in = modules.rmsnorm(p["ffn_norm"], h)
    h = h + ffn.ffn_apply(p["ffn"], f_in, kind=cfg.ffn_kind, ctx=ctx,
                          name=join(name, "ffn"))

    if cache is not None:
        return h, {"attn": new_attn, "ssm": new_ssm}
    if return_kv:
        B, S, _ = x_in.shape
        k = modules.quant_linear(p["attn"]["k"], x_in, name=join(name, "attn", "k"), ctx=ctx)
        v = modules.quant_linear(p["attn"]["v"], x_in, name=join(name, "attn", "v"), ctx=ctx)
        k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
        pos = positions if positions is not None else jnp.arange(S)
        cos, sin = attention.rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)
        k = attention.apply_rope(k, cos, sin)
        return h, {"attn": {"k": k, "v": v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)},
                   "ssm": new_ssm}
    return h, None


# ---------------------------------------------------------------------------
# xLSTM blocks
# ---------------------------------------------------------------------------

def mlstm_block_init(key, cfg, dtype=jnp.float32) -> Dict[str, Any]:
    return {
        "norm": modules.rmsnorm_init(cfg.d_model, dtype),
        "cell": xlstm.mlstm_init(key, cfg.d_model, cfg.n_heads,
                                 cfg.mlstm_proj_factor, dtype),
    }


def mlstm_block_apply(p, h, *, cfg, ctx, name="block", state=None):
    h = modules.constrain(h, ctx)
    x_in = modules.rmsnorm(p["norm"], h)
    y, new_state = xlstm.mlstm_apply(
        p["cell"], x_in, n_heads=cfg.n_heads,
        proj_factor=cfg.mlstm_proj_factor, ctx=ctx,
        name=join(name, "mlstm"), state=state)
    return h + y, new_state


def slstm_block_init(key, cfg, dtype=jnp.float32) -> Dict[str, Any]:
    return {
        "norm": modules.rmsnorm_init(cfg.d_model, dtype),
        "cell": xlstm.slstm_init(key, cfg.d_model, dtype),
    }


def slstm_block_apply(p, h, *, cfg, ctx, name="block", state=None):
    h = modules.constrain(h, ctx)
    x_in = modules.rmsnorm(p["norm"], h)
    y, new_state = xlstm.slstm_apply(p["cell"], x_in, ctx=ctx,
                                     name=join(name, "slstm"), state=state)
    return h + y, new_state


# ---------------------------------------------------------------------------
# Encoder-decoder (seamless) blocks
# ---------------------------------------------------------------------------

def enc_block_init(key, cfg, dtype=jnp.float32) -> Dict[str, Any]:
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": modules.rmsnorm_init(cfg.d_model, dtype),
        "attn": attention.attn_init(ks[0], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim, dtype=dtype),
        "ffn_norm": modules.rmsnorm_init(cfg.d_model, dtype),
        "ffn": ffn.ffn_init(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_kind, dtype),
    }


def enc_block_apply(p, h, *, cfg, ctx, name="enc"):
    """Bidirectional encoder block (no causal mask)."""
    h = modules.constrain(h, ctx)
    a_in = modules.rmsnorm(p["attn_norm"], h)
    B, S, _ = a_in.shape
    q = modules.quant_linear(p["attn"]["q"], a_in, name=join(name, "attn", "q"), ctx=ctx)
    k = modules.quant_linear(p["attn"]["k"], a_in, name=join(name, "attn", "k"), ctx=ctx)
    v = modules.quant_linear(p["attn"]["v"], a_in, name=join(name, "attn", "v"), ctx=ctx)
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    pos = jnp.arange(S)
    cos, sin = attention.rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)
    q, k = attention.apply_rope(q, cos, sin), attention.apply_rope(k, cos, sin)
    out = attention._sdpa(q, k, v, None, cfg.head_dim ** -0.5)
    a = modules.quant_linear(p["attn"]["o"], out.reshape(B, S, -1).astype(h.dtype),
                             name=join(name, "attn", "o"), ctx=ctx)
    h = h + a
    f_in = modules.rmsnorm(p["ffn_norm"], h)
    return h + ffn.ffn_apply(p["ffn"], f_in, kind=cfg.ffn_kind, ctx=ctx,
                             name=join(name, "ffn"))


def dec_block_init(key, cfg, dtype=jnp.float32) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    return {
        "attn_norm": modules.rmsnorm_init(cfg.d_model, dtype),
        "attn": attention.attn_init(ks[0], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim, dtype=dtype),
        "xattn_norm": modules.rmsnorm_init(cfg.d_model, dtype),
        "xattn": attention.attn_init(ks[1], cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim, dtype=dtype),
        "ffn_norm": modules.rmsnorm_init(cfg.d_model, dtype),
        "ffn": ffn.ffn_init(ks[2], cfg.d_model, cfg.d_ff, cfg.ffn_kind, dtype),
    }


def dec_block_apply(p, h, memory_kv, *, cfg, ctx, name="dec",
                    positions=None, cache=None, return_kv=False):
    """Decoder block: causal self-attn (+cache) -> cross-attn to encoder -> FFN."""
    h = modules.constrain(h, ctx)
    a_in = modules.rmsnorm(p["attn_norm"], h)
    a, new_cache = attention.attn_apply(
        p["attn"], a_in, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, ctx=ctx, name=join(name, "attn"),
        rope_theta=cfg.rope_theta, positions=positions, cache=cache)
    h = h + a
    x_in = modules.rmsnorm(p["xattn_norm"], h)
    x = attention.cross_attn_apply(
        p["xattn"], x_in, memory_kv, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim, ctx=ctx,
        name=join(name, "xattn"))
    h = h + x
    f_in = modules.rmsnorm(p["ffn_norm"], h)
    h = h + ffn.ffn_apply(p["ffn"], f_in, kind=cfg.ffn_kind, ctx=ctx,
                          name=join(name, "ffn"))

    aux = new_cache
    if return_kv and cache is None:
        B, S, _ = a_in.shape
        k = modules.quant_linear(p["attn"]["k"], a_in, name=join(name, "attn", "k"), ctx=ctx)
        v = modules.quant_linear(p["attn"]["v"], a_in, name=join(name, "attn", "v"), ctx=ctx)
        k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
        pos = positions if positions is not None else jnp.arange(S)
        cos, sin = attention.rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)
        k = attention.apply_rope(k, cos, sin)
        aux = {"k": k, "v": v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)}
    return h, aux
