"""Feed-forward blocks: SwiGLU (llama/qwen), GeGLU (gemma), GELU (classic)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import modules
from repro.models.modules import ExecContext, join


def ffn_init(key, d_model: int, d_ff: int, kind: str = "swiglu",
             dtype=jnp.float32) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "gate": modules.linear_init(ks[0], d_model, d_ff, dtype=dtype),
            "up": modules.linear_init(ks[1], d_model, d_ff, dtype=dtype),
            "down": modules.linear_init(ks[2], d_ff, d_model, dtype=dtype),
        }
    return {  # plain gelu MLP (starcoder2, seamless)
        "up": modules.linear_init(ks[0], d_model, d_ff, dtype=dtype),
        "down": modules.linear_init(ks[1], d_ff, d_model, dtype=dtype),
    }


def ffn_apply(params, x: jax.Array, *, kind: str, ctx: ExecContext,
              name: str) -> jax.Array:
    if kind in ("swiglu", "geglu"):
        g = modules.quant_linear(params["gate"], x, name=join(name, "gate"), ctx=ctx)
        u = modules.quant_linear(params["up"], x, name=join(name, "up"), ctx=ctx)
        act = jax.nn.silu(g.astype(jnp.float32)) if kind == "swiglu" else \
            jax.nn.gelu(g.astype(jnp.float32), approximate=True)
        h = (act * u.astype(jnp.float32)).astype(x.dtype)
    else:
        u = modules.quant_linear(params["up"], x, name=join(name, "up"), ctx=ctx)
        h = jax.nn.gelu(u.astype(jnp.float32), approximate=True).astype(x.dtype)
    return modules.quant_linear(params["down"], h, name=join(name, "down"), ctx=ctx)
