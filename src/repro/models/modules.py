"""Composable pure-JAX module primitives.

No flax in this container: modules are (init, apply) function pairs over
nested-dict pytree params.  Every matrix multiply in every architecture goes
through :func:`quant_linear`, which is where the paper's FPX precision
assignment plugs in — the ``ExecContext`` carries a per-linear-layer bitwidth
policy, an optional activation collector (for Algorithm-1 calibration), and
kernel-dispatch flags.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant


# ---------------------------------------------------------------------------
# Execution context
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExecContext:
    """Carries cross-cutting execution state through a model forward pass.

    Attributes:
      policy: maps linear-layer name -> bits.  Values may be python ints
        (static dispatch; required for materialized/kernel paths) or traced
        scalars (dynamic dispatch inside scanned stacks).  Missing names fall
        back to ``default_bits``.
      default_bits: precision for linears not named in ``policy``.
      act_bits: activation precision (paper quantizes activations to the same
        width as the weights of the consuming linear; ``None`` follows the
        weight bits, 16 disables activation quantization).
      collect: if not None, a dict that receives {name: (input, output_ref)}
        for Algorithm-1 calibration.  Only usable outside jit.
      use_pallas: dispatch quantized matmuls to the Pallas kernels
        (interpret-mode on CPU) instead of the jnp reference path.
      deterministic: disables dropout-like stochasticity (always True here).
    """

    policy: Optional[Dict[str, Any]] = None
    default_bits: int = 16
    act_bits: Optional[int] = None
    collect: Optional[Dict[str, Any]] = None
    use_pallas: bool = False
    compute_dtype: Any = jnp.float32
    name_prefix: str = ""   # set per-layer in unrolled mode ("L{i}")
    #: PartitionSpec pinned onto the residual stream at every block boundary.
    #: Without it GSPMD may trade batch sharding for contraction parallelism
    #: and all-reduce full activations (measured in EXPERIMENTS.md §Perf).
    act_spec: Any = None
    #: When set (a Mesh), MoE layers run the explicit expert-parallel
    #: shard_map path instead of the gather formulation (§Perf MoE iter).
    moe_mesh: Any = None
    moe_data_axes: Any = ("data",)
    #: Chunked paged writes may start mid-page (speculative verify chunks
    #: begin wherever the lane's write position sits).  The Pallas chunk
    #: scatter requires page-aligned positions, so this flag keeps the
    #: fused attend while forcing the jnp scatter for the (tiny, <= k+1
    #: token) unaligned chunk.
    unaligned_scatter: bool = False

    def full_name(self, name: str) -> str:
        return join(self.name_prefix, name)

    def bits_for(self, name: str):
        if self.policy is not None:
            full = self.full_name(name)
            if full in self.policy:
                return self.policy[full]
            if name in self.policy:
                return self.policy[name]
        return self.default_bits


DEFAULT_CTX = ExecContext()


def constrain(x: jax.Array, ctx: "ExecContext") -> jax.Array:
    """Apply the context's activation sharding constraint (no-op if unset)."""
    if ctx.act_spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.act_spec)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def _normal_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    if scale is None:
        scale = fan_in ** -0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def linear_init(key, d_in: int, d_out: int, bias: bool = False,
                dtype=jnp.float32) -> Dict[str, jax.Array]:
    p = {"w": _normal_init(key, (d_in, d_out), dtype=dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"emb": _normal_init(key, (vocab, d), scale=d ** -0.5, dtype=dtype)}


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype=dtype)}


def layernorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype=dtype), "b": jnp.zeros((d,), dtype=dtype)}


# ---------------------------------------------------------------------------
# Apply functions
# ---------------------------------------------------------------------------

def _is_static_bits(bits) -> bool:
    return isinstance(bits, int)


def quant_linear(params: Dict[str, jax.Array], x: jax.Array, *,
                 name: str, ctx: ExecContext = DEFAULT_CTX) -> jax.Array:
    """The universal linear layer: ``y = Q(x) Q(W) * scales (+ b)``.

    This is the surface FPX operates on (paper Sec. 4.1: only matmul
    operators are precision-controlled; everything else stays untouched).
    """
    w = params["w"]
    bits = ctx.bits_for(name)
    act_bits = ctx.act_bits if ctx.act_bits is not None else bits

    if ctx.collect is not None:
        # Algorithm-1 calibration: the net runs FP16; this layer's FP4
        # execution is simulated on the same inputs and the relative error
        # eps_l = ||A_fp16 - A_fp4|| / ||A_fp16|| is recorded (paper Eq. 6).
        xf = x.astype(jnp.float32)
        wf = w.astype(jnp.float32)
        a16 = xf @ wf
        a4 = quant.fake_quant(xf, 4) @ quant.fake_quant(wf, 4)
        ctx.collect.setdefault(ctx.full_name(name), []).append(
            quant.relative_error(a16, a4))

    orig_dtype = x.dtype
    if _is_static_bits(bits):
        if bits >= 16:
            y = x @ w.astype(x.dtype)
        elif ctx.use_pallas:
            from repro.kernels import ops  # local import: keep kernels optional
            y = ops.quant_matmul(x, w, x_bits=act_bits if act_bits < 16 else 16,
                                 w_bits=bits)
        else:
            xq = quant.fake_quant(x, act_bits) if act_bits < 16 else x
            wq = quant.fake_quant(w, bits)
            y = (xq.astype(jnp.float32) @ wq.astype(jnp.float32)).astype(orig_dtype)
    else:
        # Traced per-layer bits (scanned stacks): dynamic fake-quant select.
        wq = quant.fake_quant_dynamic(w, bits)
        xq = quant.fake_quant_dynamic(x, bits) if ctx.act_bits is None else x
        y = (xq.astype(jnp.float32) @ wq.astype(jnp.float32)).astype(orig_dtype)

    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def embedding_lookup(params, ids: jax.Array) -> jax.Array:
    return params["emb"][ids]


def rmsnorm(params, x: jax.Array, eps: float = 1e-6,
            plus_one: bool = False) -> jax.Array:
    """RMSNorm; ``plus_one`` uses the gemma-style (1+g) parameterization."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xn = xf * jax.lax.rsqrt(var + eps)
    g = params["g"].astype(jnp.float32)
    g = 1.0 + g if plus_one else g
    return (xn * g).astype(dt)


def layernorm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xn = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xn * params["g"].astype(jnp.float32)
            + params["b"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Name utilities (FPX policies key on these)
# ---------------------------------------------------------------------------

def join(*parts: str) -> str:
    return ".".join(p for p in parts if p)


def collect_linear_names(params: Any, prefix: str = "") -> List[str]:
    """Walk a param pytree and return the names of all linear layers
    (subtrees containing a 2D+ ``w``)."""
    names = []
    if isinstance(params, dict):
        if "w" in params and hasattr(params["w"], "ndim") and params["w"].ndim >= 2:
            names.append(prefix)
        for k, v in params.items():
            if k in ("w", "b"):
                continue
            names.extend(collect_linear_names(v, join(prefix, str(k))))
    elif isinstance(params, (list, tuple)):
        for i, v in enumerate(params):
            names.extend(collect_linear_names(v, join(prefix, str(i))))
    return names
