"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch.

Gather/scatter dispatch (no (B,S,E,C) one-hot einsum — that tensor is
O(tokens x experts x capacity) and does not survive 4k x 256 batches).
Tokens are routed to (expert, slot) coordinates; expert FFNs run as stacked
batched matmuls ("grouped GEMM") over (E, C, d) blocks; results scatter-add
back with gate weights.  Under pjit, experts shard on the "model" mesh axis
and tokens on "data", so dispatch/combine lower to all-to-all-style
collectives.

Capacity semantics are GShard-style (tokens beyond capacity drop, gates
renormalized).  DBRX/granite are dropless in their reference impls; with
capacity_factor >= 2 drops are negligible — recorded in DESIGN.md.

FPX note: the stacked per-expert projections count as one *named* linear each
("gate"/"up"/"down") — the grouped-GEMM kernel runs all experts of one
projection at one precision, matching how a hardware kernel would batch them.
The router linear is pinned to >= 8 bits by the assignment policy (tiny
matmul, outsized quality impact).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.models import modules
from repro.models.modules import ExecContext, join


def moe_init(key, d_model: int, d_ff: int, n_experts: int, kind: str = "swiglu",
             dtype=jnp.float32) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)

    def estack(k, d_in, d_out):
        kk = jax.random.split(k, n_experts)
        return {"w": jnp.stack([
            modules._normal_init(kk[i], (d_in, d_out), dtype=dtype)
            for i in range(n_experts)])}

    p = {
        "router": modules.linear_init(ks[0], d_model, n_experts, dtype=dtype),
        "gate": estack(ks[1], d_model, d_ff),
        "up": estack(ks[2], d_model, d_ff),
        "down": estack(ks[3], d_ff, d_model),
    }
    if kind not in ("swiglu", "geglu"):
        del p["gate"]
    return p


def _expert_matmul(params, x: jax.Array, *, name: str, ctx: ExecContext) -> jax.Array:
    """x: (E, C, d_in) @ stacked w: (E, d_in, d_out) -> (E, C, d_out)."""
    w = params["w"]
    bits = ctx.bits_for(name)
    if ctx.collect is not None:
        xf = x.astype(jnp.float32)
        wf = w.astype(jnp.float32)
        a16 = jnp.einsum("ecd,edf->ecf", xf, wf)
        a4 = jnp.einsum("ecd,edf->ecf", quant.fake_quant(xf, 4),
                        quant.fake_quant(wf, 4))
        ctx.collect.setdefault(ctx.full_name(name), []).append(
            quant.relative_error(a16, a4))
    if isinstance(bits, int):
        if bits >= 16:
            return jnp.einsum("ecd,edf->ecf", x, w.astype(x.dtype))
        act_bits = ctx.act_bits if ctx.act_bits is not None else bits
        xq = quant.fake_quant(x, act_bits) if act_bits < 16 else x
        wq = quant.fake_quant(w, bits)
        return jnp.einsum("ecd,edf->ecf", xq.astype(jnp.float32),
                          wq.astype(jnp.float32)).astype(x.dtype)
    wq = quant.fake_quant_dynamic(w, bits)
    xq = quant.fake_quant_dynamic(x, bits)
    return jnp.einsum("ecd,edf->ecf", xq.astype(jnp.float32),
                      wq.astype(jnp.float32)).astype(x.dtype)


def moe_apply(params, x: jax.Array, *, n_experts: int, top_k: int,
              kind: str, ctx: ExecContext, name: str,
              capacity_factor: float = 2.0,
              return_aux: bool = False):
    """x: (B, S, d) -> (B, S, d) [, aux load-balance loss]."""
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)

    logits = modules.quant_linear(params["router"], xf,
                                  name=join(name, "router"), ctx=ctx)
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # (T, E)
    gate_w, expert_ids = jax.lax.top_k(gates, top_k)              # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(round(top_k * S * capacity_factor / n_experts)) * B)

    # --- dispatch coordinates -------------------------------------------
    flat_expert = expert_ids.reshape(-1)                  # (T*k,)
    onehot = jax.nn.one_hot(flat_expert, n_experts, dtype=jnp.int32)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - 1)      # (T*k, E)
    slot = jnp.take_along_axis(pos_in_expert, flat_expert[:, None], axis=1)[:, 0]
    keep = slot < capacity                                # capacity drop
    token_idx = jnp.repeat(jnp.arange(T), top_k)

    # scatter token ids into (E, C); dropped -> sentinel row T (zero pad)
    safe_e = jnp.where(keep, flat_expert, 0)
    safe_s = jnp.where(keep, slot, capacity)  # out-of-range slot is ignored via mode="drop"
    dispatch = jnp.full((n_experts, capacity), T, dtype=jnp.int32)
    dispatch = dispatch.at[safe_e, safe_s].set(
        jnp.where(keep, token_idx, T), mode="drop")

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    expert_in = xpad[dispatch]                            # (E, C, d)

    # --- expert FFN (grouped GEMM) --------------------------------------
    if kind in ("swiglu", "geglu"):
        g = _expert_matmul(params["gate"], expert_in, name=join(name, "gate"), ctx=ctx)
        u = _expert_matmul(params["up"], expert_in, name=join(name, "up"), ctx=ctx)
        act = jax.nn.silu(g.astype(jnp.float32)) if kind == "swiglu" else \
            jax.nn.gelu(g.astype(jnp.float32), approximate=True)
        h = (act * u.astype(jnp.float32)).astype(x.dtype)
    else:
        u = _expert_matmul(params["up"], expert_in, name=join(name, "up"), ctx=ctx)
        h = jax.nn.gelu(u.astype(jnp.float32), approximate=True).astype(x.dtype)
    expert_out = _expert_matmul(params["down"], h, name=join(name, "down"), ctx=ctx)

    # --- combine ----------------------------------------------------------
    flat_gate = gate_w.reshape(-1)                        # (T*k,)
    contrib = expert_out[safe_e, jnp.clip(safe_s, 0, capacity - 1)]  # (T*k, d)
    contrib = contrib * (flat_gate * keep)[:, None].astype(expert_out.dtype)
    out = jnp.zeros((T, d), dtype=expert_out.dtype).at[token_idx].add(contrib)
    out = out.reshape(B, S, d).astype(x.dtype)

    if return_aux:
        # Switch-style load-balance loss: E * sum_e f_e * P_e
        me = gates.mean(axis=0)                           # (E,)
        ce = jax.nn.one_hot(expert_ids[:, 0], n_experts).mean(axis=0)
        aux = n_experts * jnp.sum(me * ce)
        return out, aux
    return out


# ---------------------------------------------------------------------------
# Expert-parallel shard_map variant (§Perf MoE iteration)
#
# The gather formulation above lets GSPMD pick the collectives; with tokens
# on "data" and experts on "model" it all-gathers the FULL token set per
# layer (~token_bytes per chip per layer — measured 4+ TB/step for dbrx
# train_4k).  This variant makes the parallelism explicit: tokens are
# already replicated across the model axis (batch shards live on "data"),
# so each model shard routes the tokens it sees into its LOCAL experts with
# zero dispatch communication and the per-token contributions are summed
# with one psum over "model" — (T_loc, d) bytes instead of (T, d) x E/chip.
# ---------------------------------------------------------------------------

def moe_apply_expert_parallel(params, x: jax.Array, *, n_experts: int,
                              top_k: int, kind: str, ctx: ExecContext,
                              name: str, capacity_factor: float,
                              mesh, data_axes=("data",),
                              model_axis: str = "model"):
    """x: (B, S, d) with batch sharded over ``data_axes`` and experts over
    ``model_axis``.  Returns (B, S, d) with the same sharding."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n_model = mesh.shape[model_axis]
    assert n_experts % n_model == 0, (n_experts, n_model)
    e_loc = n_experts // n_model

    x_spec = P(data_axes, None, None)
    router_spec = jax.tree.map(lambda _: P(None, None), params["router"])
    estack_spec = jax.tree.map(lambda _: P(model_axis, None, None),
                               {k: v for k, v in params.items()
                                if k != "router"})

    def body(router_p, experts_p, x_loc):
        B, S, d = x_loc.shape
        T = B * S
        xf = x_loc.reshape(T, d)
        j = jax.lax.axis_index(model_axis)

        logits = modules.quant_linear(router_p, xf,
                                      name=join(name, "router"), ctx=ctx)
        gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        gate_w, expert_ids = jax.lax.top_k(gates, top_k)
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

        capacity = max(1, int(round(top_k * T * capacity_factor / n_experts)))

        # local routing: global expert id e is ours iff e // e_loc == j
        flat_e = expert_ids.reshape(-1)
        local_e = flat_e - j * e_loc
        is_local = (flat_e >= j * e_loc) & (flat_e < (j + 1) * e_loc)
        onehot = jnp.where(is_local[:, None],
                           jax.nn.one_hot(local_e, e_loc, dtype=jnp.int32), 0)
        slot = (jnp.cumsum(onehot, axis=0) - 1)
        slot = jnp.take_along_axis(slot, jnp.clip(local_e, 0, e_loc - 1)[:, None],
                                   axis=1)[:, 0]
        keep = is_local & (slot < capacity)
        token_idx = jnp.repeat(jnp.arange(T), top_k)

        safe_e = jnp.where(keep, local_e, 0)
        safe_s = jnp.where(keep, slot, capacity)
        dispatch = jnp.full((e_loc, capacity), T, dtype=jnp.int32)
        dispatch = dispatch.at[safe_e, safe_s].set(
            jnp.where(keep, token_idx, T), mode="drop")

        xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
        expert_in = xpad[dispatch]                        # (E_loc, C, d)

        if kind in ("swiglu", "geglu"):
            g = _expert_matmul(experts_p["gate"], expert_in,
                               name=join(name, "gate"), ctx=ctx)
            u = _expert_matmul(experts_p["up"], expert_in,
                               name=join(name, "up"), ctx=ctx)
            act = jax.nn.silu(g.astype(jnp.float32)) if kind == "swiglu" \
                else jax.nn.gelu(g.astype(jnp.float32), approximate=True)
            h = (act * u.astype(jnp.float32)).astype(x_loc.dtype)
        else:
            u = _expert_matmul(experts_p["up"], expert_in,
                               name=join(name, "up"), ctx=ctx)
            h = jax.nn.gelu(u.astype(jnp.float32),
                            approximate=True).astype(x_loc.dtype)
        expert_out = _expert_matmul(experts_p["down"], h,
                                    name=join(name, "down"), ctx=ctx)

        flat_gate = gate_w.reshape(-1)
        contrib = expert_out[safe_e, jnp.clip(safe_s, 0, capacity - 1)]
        contrib = contrib * (flat_gate * keep)[:, None].astype(expert_out.dtype)
        out = jnp.zeros((T, d), expert_out.dtype).at[token_idx].add(contrib)
        out = jax.lax.psum(out, model_axis)               # combine shards
        return out.reshape(B, S, d).astype(x_loc.dtype)

    experts_p = {k: v for k, v in params.items() if k != "router"}
    fn = shard_map(body, mesh=mesh,
                   in_specs=(router_spec, estack_spec, x_spec),
                   out_specs=x_spec, check_rep=False)
    return fn(params["router"], experts_p, x)
