"""Selective state-space (Mamba-style) mixer — the SSM branch of hymba.

Recurrence (per channel c, state dim N):
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t
    y_t = C_t . h_t + D * x_t

Prefill/train uses a chunked associative scan (TPU-friendly: the full
(B,S,d,N) state history never materializes — only (B,chunk,d,N) per chunk).
Decode is a single fused state update, O(1) in sequence length, which is why
the hybrid/SSM architectures are the ones that run ``long_500k``.

Projections (in/x/dt/out) are FPX-quantizable linears; the scan itself stays
fp32 (paper Sec 4.1 carve-out for non-matmul ops).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import modules
from repro.models.modules import ExecContext, join

CHUNK = 128


def ssm_init(key, d_model: int, d_inner: int, state_dim: int, dt_rank: int,
             conv_dim: int = 4, dtype=jnp.float32) -> Dict[str, Any]:
    ks = jax.random.split(key, 7)
    p = {
        "in_proj": modules.linear_init(ks[0], d_model, 2 * d_inner, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_dim, d_inner)) * 0.1).astype(dtype),
        "x_proj": modules.linear_init(ks[2], d_inner, dt_rank + 2 * state_dim, dtype=dtype),
        "dt_proj": modules.linear_init(ks[3], dt_rank, d_inner, bias=True, dtype=dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, state_dim + 1, dtype=jnp.float32),
                                  (d_inner, 1))),          # (d_inner, N)
        "D": jnp.ones((d_inner,), dtype=jnp.float32),
        "out_proj": modules.linear_init(ks[4], d_inner, d_model, dtype=dtype),
    }
    return p


def _scan_chunk(carry_h, chunk):
    """Associative scan within a chunk; carry_h: (B, d, N)."""
    a, bx = chunk  # a: (B, L, d, N) decay; bx: (B, L, d, N) input drive

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    a_c, b_c = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h = a_c * carry_h[:, None] + b_c                     # (B, L, d, N)
    return h[:, -1], h


def ssm_apply(params, x: jax.Array, *, d_inner: int, state_dim: int,
              dt_rank: int, conv_dim: int, ctx: ExecContext, name: str,
              state: Optional[Dict[str, jax.Array]] = None,
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B, S, d_model).  With ``state`` ({"h": (B,d,N), "conv": (B,K-1,d)}):
    single-token decode; returns (y, new_state)."""
    B, S, _ = x.shape
    xz = modules.quant_linear(params["in_proj"], x, name=join(name, "in_proj"), ctx=ctx)
    xi, z = jnp.split(xz, 2, axis=-1)                    # (B, S, d_inner)

    # depthwise causal conv1d
    K = conv_dim
    if state is None:
        pad = jnp.zeros((B, K - 1, d_inner), xi.dtype)
        xc = jnp.concatenate([pad, xi], axis=1)
        new_conv = xc[:, -(K - 1):] if K > 1 else None
    else:
        xc = jnp.concatenate([state["conv"].astype(xi.dtype), xi], axis=1)
        new_conv = xc[:, -(K - 1):] if K > 1 else None
    conv_w = params["conv_w"].astype(jnp.float32)        # (K, d_inner)
    xconv = sum(xc[:, i:i + S].astype(jnp.float32) * conv_w[i]
                for i in range(K))                       # (B, S, d_inner)
    u = jax.nn.silu(xconv)

    # input-dependent dt, B, C
    dbc = modules.quant_linear(params["x_proj"], u.astype(x.dtype),
                               name=join(name, "x_proj"), ctx=ctx)
    dt, Bm, Cm = jnp.split(dbc.astype(jnp.float32),
                           [dt_rank, dt_rank + state_dim], axis=-1)
    dt = modules.quant_linear(params["dt_proj"], dt.astype(x.dtype),
                              name=join(name, "dt_proj"), ctx=ctx)
    dt = jax.nn.softplus(dt.astype(jnp.float32))          # (B, S, d_inner)

    A = -jnp.exp(params["A_log"])                         # (d_inner, N)
    decay = jnp.exp(dt[..., None] * A)                    # (B, S, d, N)
    drive = (dt * u)[..., None] * Bm[:, :, None, :]       # (B, S, d, N)

    if state is None:
        h0 = jnp.zeros((B, d_inner, state_dim), jnp.float32)
        n_chunks = max(1, S // CHUNK)
        if S % CHUNK == 0 and S > CHUNK:
            dec_c = decay.reshape(B, n_chunks, CHUNK, d_inner, state_dim)
            drv_c = drive.reshape(B, n_chunks, CHUNK, d_inner, state_dim)

            def step(h, ins):
                a, bx = ins
                return _scan_chunk(h, (a, bx))

            hT, hist = jax.lax.scan(
                step, h0, (dec_c.transpose(1, 0, 2, 3, 4),
                           drv_c.transpose(1, 0, 2, 3, 4)))
            h_all = hist.transpose(1, 0, 2, 3, 4).reshape(B, S, d_inner, state_dim)
        else:
            hT, h_all = _scan_chunk(h0, (decay, drive))
        y = jnp.einsum("bsdn,bsn->bsd", h_all, Cm)
        new_state = {"h": hT, "conv": new_conv}
    else:
        h = state["h"] * decay[:, 0] + drive[:, 0]        # (B, d, N)
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None]
        new_state = {"h": h, "conv": new_conv}

    y = y + params["D"] * u
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = modules.quant_linear(params["out_proj"], y.astype(x.dtype),
                               name=join(name, "out_proj"), ctx=ctx)
    return out, new_state


def init_ssm_state(batch: int, d_inner: int, state_dim: int, conv_dim: int,
                   dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {
        "h": jnp.zeros((batch, d_inner, state_dim), jnp.float32),
        "conv": jnp.zeros((batch, conv_dim - 1, d_inner), dtype),
    }
