"""Model assembly for all assigned architectures.

Layer stacks are organized into *segments* so that every architecture scans
over homogeneous stacked blocks (flat HLO regardless of depth — critical for
the 512-device dry-run):

  dense uniform      [("layers", L)]                              scan L
  gemma3 5:1         [("super", G x (R local + 1 global)), ("tail", T local)]
  vlm cross-every-k  [("groups", G x (R self + 1 cross))]
  xlstm 7:1          [("super", G x (R mlstm + 1 slstm))]
  hybrid (hymba)     [("g0",1), ("runA", n), ("g1",1), ("runB", m), ("g2",1)]
  enc-dec            [("enc", E)] + [("dec", L)]

``segment_layout(cfg)`` exposes the segment -> global-layer-index map; the
FPX assignment uses it to turn per-layer bit decisions into per-segment
policy arrays that ride through ``lax.scan`` as xs.

Three modes: ``forward`` (full causal logits: training + scoring),
``prefill`` (logits for last position + decode cache), ``decode_step``
(one token + cache -> next logits + cache).

``unroll=True`` replaces scans with python loops and prefixes layer names
("L{i}.") — required by Algorithm-1 calibration to tell layers apart.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, blocks, modules
from repro.models.modules import ExecContext, join


# ---------------------------------------------------------------------------
# Segment layout
# ---------------------------------------------------------------------------

def segment_layout(cfg: ModelConfig) -> List[Tuple[str, List[int]]]:
    """Ordered (segment_key, [global layer indices]) pairs."""
    L = cfg.n_layers
    if cfg.arch_type == "ssm":
        sb = cfg.slstm_every
        G = L // sb
        segs = [("mlstm", []), ("slstm", [])]
        for g in range(G):
            segs[0][1].extend(range(g * sb, g * sb + sb - 1))
            segs[1][1].append(g * sb + sb - 1)
        return segs
    if cfg.arch_type == "vlm":
        ce = cfg.cross_attn_every
        G = L // ce
        segs = [("self", []), ("cross", [])]
        for g in range(G):
            segs[0][1].extend(range(g * ce, g * ce + ce - 1))
            segs[1][1].append(g * ce + ce - 1)
        tail = list(range(G * ce, L))
        if tail:
            segs.append(("tail", tail))
        return segs
    if cfg.arch_type == "hybrid":
        mid = L // 2
        glob = sorted({0, mid, L - 1})
        runs: List[List[int]] = []
        cur: List[int] = []
        for i in range(L):
            if i in glob:
                if cur:
                    runs.append(cur)
                    cur = []
            else:
                cur.append(i)
        if cur:
            runs.append(cur)
        segs = [("global", glob)]
        for j, r in enumerate(runs):
            segs.append((f"run{j}", r))
        return segs
    if cfg.arch_type == "audio":
        return [("enc", list(range(cfg.n_enc_layers))),
                ("dec", list(range(L)))]
    if cfg.local_global_ratio:
        sb = cfg.local_global_ratio + 1
        G = L // sb
        segs = [("local", []), ("global", [])]
        for g in range(G):
            segs[0][1].extend(range(g * sb, g * sb + sb - 1))
            segs[1][1].append(g * sb + sb - 1)
        tail = list(range(G * sb, L))
        if tail:
            segs.append(("tail", tail))
        return segs
    return [("layers", list(range(L)))]


# ---------------------------------------------------------------------------
# Paged layer groups
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PagedGroup:
    """One attention layer group of the paged serving path.

    ``layers``: global layer indices in stack order (the order the group's
    per-layer page pools are stacked in).  ``window``: the group's sliding
    window, or None for full attention.  Sliding-window groups get their
    own block tables in :class:`repro.serving.kv_cache.PagedKVCache`, with
    out-of-window pages freed back to the pool mid-flight."""
    name: str
    layers: Tuple[int, ...]
    window: Optional[int]


def paged_supported(cfg: ModelConfig) -> bool:
    """Whether the paged continuous path can serve this stack: every
    dense/moe attention layout — uniform, uniform-windowed
    (starcoder2-class), and local:global (gemma3-class).  SSM/hybrid/
    enc-dec/VLM segments keep contiguous caches (see ROADMAP)."""
    return cfg.arch_type in ("dense", "moe")


def _check_paged_supported(cfg: ModelConfig) -> None:
    if not paged_supported(cfg):
        raise NotImplementedError(
            "paged decode supports dense/moe attention stacks (uniform, "
            f"sliding-window, local:global), not {cfg.name} "
            f"(arch_type={cfg.arch_type})")


def paged_layer_groups(cfg: ModelConfig) -> List[PagedGroup]:
    """The layer groups a paged KV cache partitions this stack into —
    group names match :func:`segment_layout` segment keys, so the paged
    entry points route each segment through its group's block tables."""
    _check_paged_supported(cfg)
    W = cfg.sliding_window
    layout = dict(segment_layout(cfg))
    if "layers" in layout:
        return [PagedGroup("layers", tuple(layout["layers"]), W)]
    groups = [PagedGroup("local", tuple(layout["local"]), W),
              PagedGroup("global", tuple(layout["global"]), None)]
    if layout.get("tail"):
        groups.append(PagedGroup("tail", tuple(layout["tail"]), W))
    return groups


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _stack(key, n, init_fn):
    keys = jax.random.split(key, n)
    ps = [init_fn(keys[i]) for i in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Any]:
    k_emb, k_blocks, k_head, k_extra = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": modules.embedding_init(k_emb, cfg.vocab, cfg.d_model, dtype),
        "final_norm": modules.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = modules.linear_init(k_head, cfg.d_model, cfg.vocab,
                                                dtype=dtype)

    t = cfg.arch_type
    if t == "ssm":
        sb = cfg.slstm_every
        G = cfg.n_layers // sb
        km, ks = jax.random.split(k_blocks)
        params["blocks"] = {
            "mlstm": _stack(km, G * (sb - 1),
                            lambda k: blocks.mlstm_block_init(k, cfg, dtype)),
            "slstm": _stack(ks, G,
                            lambda k: blocks.slstm_block_init(k, cfg, dtype)),
        }
        params["blocks"]["mlstm"] = jax.tree.map(
            lambda x: x.reshape(G, sb - 1, *x.shape[1:]), params["blocks"]["mlstm"])
    elif t == "vlm":
        ce = cfg.cross_attn_every
        G = cfg.n_layers // ce
        k1, k2 = jax.random.split(k_blocks)
        self_stack = _stack(k1, G * (ce - 1),
                            lambda k: blocks.dense_block_init(k, cfg, dtype))
        params["blocks"] = {
            "self": jax.tree.map(lambda x: x.reshape(G, ce - 1, *x.shape[1:]),
                                 self_stack),
            "cross": _stack(k2, G,
                            lambda k: blocks.dense_block_init(k, cfg, dtype,
                                                              cross=True)),
        }
    elif t == "hybrid":
        layout = dict(segment_layout(cfg))
        keys = jax.random.split(k_blocks, len(layout))
        params["blocks"] = {}
        for kk, (seg, idxs) in zip(keys, layout.items()):
            if not idxs:
                continue
            params["blocks"][seg] = _stack(
                kk, len(idxs), lambda k: blocks.hybrid_block_init(k, cfg, dtype))
    elif t == "audio":
        k1, k2 = jax.random.split(k_blocks)
        params["blocks"] = {
            "enc": _stack(k1, cfg.n_enc_layers,
                          lambda k: blocks.enc_block_init(k, cfg, dtype)),
            "dec": _stack(k2, cfg.n_layers,
                          lambda k: blocks.dec_block_init(k, cfg, dtype)),
        }
        params["enc_norm"] = modules.rmsnorm_init(cfg.d_model, dtype)
    elif cfg.local_global_ratio:
        sb = cfg.local_global_ratio + 1
        G = cfg.n_layers // sb
        tail = cfg.n_layers - G * sb
        k1, k2, k3 = jax.random.split(k_blocks, 3)
        local_stack = _stack(k1, G * (sb - 1),
                             lambda k: blocks.dense_block_init(k, cfg, dtype))
        params["blocks"] = {
            "local": jax.tree.map(lambda x: x.reshape(G, sb - 1, *x.shape[1:]),
                                  local_stack),
            "global": _stack(k2, G, lambda k: blocks.dense_block_init(k, cfg, dtype)),
        }
        if tail:
            params["blocks"]["tail"] = _stack(
                k3, tail, lambda k: blocks.dense_block_init(k, cfg, dtype))
    else:
        params["blocks"] = {
            "layers": _stack(k_blocks, cfg.n_layers,
                             lambda k: blocks.dense_block_init(k, cfg, dtype)),
        }
    return params


# ---------------------------------------------------------------------------
# Policy plumbing
# ---------------------------------------------------------------------------

def _seg_policy(ctx: ExecContext, seg: str):
    """Split ctx.policy into (static ints, per-layer arrays) for a segment.

    Policy keys are either relative ("block.attn.q.w" -> applies everywhere)
    or segment-scoped ("<seg>/<rel>" with an array over that segment)."""
    static, arrays = {}, {}
    if ctx.policy:
        for k, v in ctx.policy.items():
            if "/" in k:
                s, rel = k.split("/", 1)
                if s == seg:
                    arrays[rel] = jnp.asarray(v)
            else:
                static[k] = v
    return static, arrays


def _step_ctx(ctx: ExecContext, static, arr_slice, prefix="") -> ExecContext:
    pol = dict(static)
    pol.update(arr_slice)
    # nest prefixes so unrolled nested stacks get unique names (L{g}.L{s}.*)
    full_prefix = join(ctx.name_prefix, prefix) if prefix else ctx.name_prefix
    return dataclasses.replace(ctx, policy=pol, name_prefix=full_prefix)


# ---------------------------------------------------------------------------
# Scan / unroll driver
# ---------------------------------------------------------------------------

def _run_stack(body, h, stacked, n: int, *, ctx: ExecContext, seg: str,
               unroll: bool, xs_extra=None, layer_ids: Optional[List[int]] = None):
    """Run ``body(h, params_i, ctx_i, extra_i) -> (h, y_i)`` over a stack.

    Returns (h, ys) with ys stacked (or a list when unrolled)."""
    static, arrays = _seg_policy(ctx, seg)
    if unroll:
        ys = []
        for i in range(n):
            p_i = jax.tree.map(lambda x: x[i], stacked)
            e_i = None if xs_extra is None else jax.tree.map(lambda x: x[i], xs_extra)
            sl = {k: v[i] for k, v in arrays.items()}
            gid = layer_ids[i] if layer_ids else i
            ctx_i = _step_ctx(ctx, static, sl, prefix=f"L{gid}")
            h, y = body(h, p_i, ctx_i, e_i)
            ys.append(y)
        if ys and ys[0] is not None:
            ys = jax.tree.map(lambda *t: jnp.stack(t), *ys)
        else:
            ys = None
        return h, ys

    def scan_body(carry, xs):
        p_i, sl, e_i = xs
        ctx_i = _step_ctx(ctx, static, sl)
        return body(carry, p_i, ctx_i, e_i)

    xs = (stacked, arrays if arrays else {k: jnp.zeros((n,)) for k in ()}, xs_extra)
    # jax.lax.scan needs consistent pytrees; use empty dict when no arrays
    h, ys = jax.lax.scan(scan_body, h, xs, length=n)
    return h, ys


# ---------------------------------------------------------------------------
# Decode-cache construction
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      dtype=jnp.bfloat16, start_pos: Optional[int] = None,
                      ) -> Any:
    """Zero-initialized decode cache matching what ``decode_step`` expects.

    ``cache_len`` is the max context; sliding-window segments allocate
    ``min(window, cache_len)`` ring buffers — the reason sub-quadratic archs
    can serve long_500k.  ``start_pos`` sets the write position (e.g. the
    prefill length for dry-run decode specs)."""
    pos0 = jnp.asarray(0 if start_pos is None else start_pos, jnp.int32)

    def kvc(stack_dims, s_len):
        shape = (*stack_dims, batch, s_len, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "pos": jnp.broadcast_to(pos0, stack_dims)}

    W = cfg.sliding_window
    local_len = min(W, cache_len) if W else cache_len
    t = cfg.arch_type
    if t == "ssm":
        from repro.models import xlstm as _x
        sb = cfg.slstm_every
        G = cfg.n_layers // sb
        R = sb - 1
        d_inner = int(cfg.d_model * cfg.mlstm_proj_factor)
        hd = d_inner // cfg.n_heads

        def bc(x, dims):
            return jnp.broadcast_to(x, (*dims, *x.shape))
        mst = _x.init_mlstm_state(batch, cfg.n_heads, hd)
        sst = _x.init_slstm_state(batch, cfg.d_model)
        return {
            "mlstm": jax.tree.map(lambda x: bc(x, (G, R)), mst),
            "slstm": jax.tree.map(lambda x: bc(x, (G,)), sst),
        }
    if t == "vlm":
        ce = cfg.cross_attn_every
        G = cfg.n_layers // ce
        R = ce - 1
        return {
            "self": kvc((G, R), cache_len),
            "cross_kv": {
                "k": jnp.zeros((G, batch, cfg.vision_tokens, cfg.n_kv_heads,
                                cfg.head_dim), dtype),
                "v": jnp.zeros((G, batch, cfg.vision_tokens, cfg.n_kv_heads,
                                cfg.head_dim), dtype),
            },
        }
    if t == "hybrid":
        from repro.models import ssm as _s
        cache = {}
        for seg, idxs in segment_layout(cfg):
            if not idxs:
                continue
            s_len = cache_len if seg == "global" else local_len
            st = _s.init_ssm_state(batch, cfg.d_inner, cfg.ssm_state,
                                   cfg.ssm_conv, dtype)
            n = len(idxs)
            cache[seg] = {
                "attn": kvc((n,), s_len),
                "ssm": jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (n, *x.shape)), st),
            }
        return cache
    if t == "audio":
        return {
            "self": kvc((cfg.n_layers,), cache_len),
            "cross_kv": {
                "k": jnp.zeros((cfg.n_layers, batch, cfg.audio_frames,
                                cfg.n_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((cfg.n_layers, batch, cfg.audio_frames,
                                cfg.n_kv_heads, cfg.head_dim), dtype),
            },
        }
    if cfg.local_global_ratio:
        sb = cfg.local_global_ratio + 1
        G = cfg.n_layers // sb
        R = sb - 1
        tail = cfg.n_layers - G * sb
        cache = {"local": kvc((G, R), local_len), "global": kvc((G,), cache_len)}
        cache["tail"] = kvc((tail,), local_len) if tail else None
        return cache
    return {"layers": kvc((cfg.n_layers,), local_len)}


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed(params, cfg: ModelConfig, tokens: jax.Array,
          ctx: ExecContext = modules.DEFAULT_CTX) -> jax.Array:
    h = modules.embedding_lookup(params["embed"], tokens)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return modules.constrain(h, ctx)


def unembed(params, cfg: ModelConfig, h: jax.Array, ctx: ExecContext) -> jax.Array:
    h = modules.rmsnorm(params["final_norm"], h, plus_one=cfg.norm_plus_one)
    if cfg.tie_embeddings:
        w = params["embed"]["emb"]
        bits = ctx.bits_for("lm_head")
        if isinstance(bits, int) and bits < 16:
            from repro.core import quant
            w = quant.fake_quant(w, bits)
        return jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32),
                          w.astype(jnp.float32))
    return modules.quant_linear(params["lm_head"], h, name="lm_head",
                                ctx=ctx).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Forward dispatch
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            ctx: ExecContext = modules.DEFAULT_CTX, *,
            unroll: bool = False) -> jax.Array:
    """Full causal forward -> logits (B, S, vocab). Train / scoring path."""
    h, _ = _backbone(params, cfg, batch, ctx, mode="full", unroll=unroll)
    return unembed(params, cfg, h, ctx)


def prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            ctx: ExecContext = modules.DEFAULT_CTX, *,
            unroll: bool = False,
            cache_len: Optional[int] = None,
            raw_kv: bool = False) -> Tuple[jax.Array, Any]:
    """Causal forward that also returns the decode cache.

    ``cache_len``: total decode-context budget; full (non-windowed) caches
    are padded to it so subsequent ``decode_step`` calls have free slots.
    Returns (last-position logits (B, 1, V), cache).

    ``raw_kv``: return each segment's captured K/V exactly as written —
    one slot per prompt position, no padding, no sliding-window
    ring-buffer slicing/rotation — keyed by segment.  This is what the
    paged serving engine scatters into block-table pages
    (``serving.kv_cache.write_prefill``): the paged path addresses
    *logical* positions, so the wave path's ring layout would be wrong
    for it.  Dense/moe stacks only."""
    if raw_kv:
        _check_paged_supported(cfg)
    h, cache = _backbone(params, cfg, batch, ctx, mode="prefill",
                         unroll=unroll, cache_len=cache_len, raw_kv=raw_kv)
    logits = unembed(params, cfg, h[:, -1:], ctx)
    return logits, cache


def decode_step(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                cache: Any, ctx: ExecContext = modules.DEFAULT_CTX, *,
                unroll: bool = False) -> Tuple[jax.Array, Any]:
    """One-token decode: batch["token"] (B, 1) + cache -> (logits (B,1,V), cache)."""
    h, new_cache = _backbone(params, cfg, batch, ctx, mode="decode",
                             unroll=unroll, cache=cache)
    return unembed(params, cfg, h, ctx), new_cache


def _paged_stack_dims(cfg: ModelConfig, name: str) -> Tuple[int, ...]:
    """Leading stack dims of segment ``name``'s per-layer caches — must
    mirror how ``init_params`` nests the segment's parameter stacks so
    ``_run_stack`` slices params and caches in lockstep."""
    if name == "layers":
        return (cfg.n_layers,)
    sb = cfg.local_global_ratio + 1
    G = cfg.n_layers // sb
    if name == "local":
        return (G, sb - 1)
    if name == "global":
        return (G,)
    return (cfg.n_layers - G * sb,)                    # tail


def _paged_seg_cache(cfg: ModelConfig, cache: Dict[str, Any], B: int,
                     ) -> Dict[str, Any]:
    """Map the engine's grouped cache pytree ({"pos": (B,), "groups":
    {name: {"kpool","vpool","block_tables"}}}) to the per-segment
    per-layer cache stacks ``_dense_backbone``'s decode mode slices: each
    layer of a segment sees its own pool slice plus the group-shared
    block table and per-lane positions."""
    pos = cache["pos"]
    out: Dict[str, Any] = {}
    for g in paged_layer_groups(cfg):
        gc = cache["groups"][g.name]
        dims = _paged_stack_dims(cfg, g.name)
        kp, vp = gc["kpool"], gc["vpool"]
        bt = gc["block_tables"]
        out[g.name] = {
            "kpool": kp.reshape(*dims, *kp.shape[1:]),
            "vpool": vp.reshape(*dims, *vp.shape[1:]),
            "block_tables": jnp.broadcast_to(bt, (*dims, *bt.shape)),
            "pos": jnp.broadcast_to(pos, (*dims, B)),
        }
    return out


def _paged_new_cache(cfg: ModelConfig, cache: Dict[str, Any], ys,
                     n_written: int) -> Dict[str, Any]:
    """Collect the updated pools a paged step returned (per-segment
    per-layer cache stacks) back into the engine's grouped pytree.  Block
    tables and positions stay host-managed."""
    groups = {}
    for g in paged_layer_groups(cfg):
        y = ys[g.name]
        kp, vp = y["kpool"], y["vpool"]
        # collapse nested stack dims (e.g. local's (G, R)) to flat layers
        groups[g.name] = {
            "kpool": kp.reshape(len(g.layers), *kp.shape[-4:]),
            "vpool": vp.reshape(len(g.layers), *vp.shape[-4:]),
            "block_tables": cache["groups"][g.name]["block_tables"],
        }
    return {"pos": cache["pos"] + n_written, "groups": groups}


def _paged_step(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                cache: Dict[str, jax.Array], ctx: ExecContext, *,
                unroll: bool) -> Tuple[jax.Array, Any]:
    """Shared body of :func:`paged_decode_step` / :func:`prefill_chunk`:
    one pass of the dense/moe backbone in decode mode over the grouped
    paged cache — each segment (uniform "layers", or gemma3-style
    local/global/tail) routes through its own group's block tables, with
    that group's sliding window masked in-kernel."""
    _check_paged_supported(cfg)
    tok = batch["token"] if "token" in batch else batch["tokens"]
    B, n = tok.shape
    seg_cache = _paged_seg_cache(cfg, cache, B)
    h, ys = _dense_backbone(params, cfg, batch, ctx, mode="decode",
                            unroll=unroll, cache=seg_cache)
    return h, _paged_new_cache(cfg, cache, ys, n)


def paged_decode_step(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                      cache: Dict[str, jax.Array],
                      ctx: ExecContext = modules.DEFAULT_CTX, *,
                      unroll: bool = True) -> Tuple[jax.Array, Any]:
    """One batched decode step against a *paged* KV cache.

    ``batch["token"]``: (B, 1) — one current token per decode lane.
    ``cache``: {"pos": (B,) int32, "groups": {name: {"kpool", "vpool":
    (n_group_layers, n_pages, page_size, Hkv, D), "block_tables": (B, P)
    int32}}} — one group per attention layer group
    (:func:`paged_layer_groups`).  Unlike
    :func:`decode_step`, lanes are independent requests: each has its own
    position and its own page lists, which is what lets the paged serving
    engine admit/retire requests between steps with no wave barrier.
    Per-layer attention runs through ``ops.paged_attend`` — with
    ``ctx.use_pallas`` the fused paged flash-attention kernel reads K/V
    pages straight from the pool and never materializes the gathered
    context; sliding-window groups (starcoder2-class uniform windows,
    gemma3-class local layers) carry their window into the kernels'
    validity mask and attend over only their retained in-window pages.

    Every dense/moe attention stack is supported; ssm / hybrid / enc-dec
    / vlm segments keep their contiguous caches (see ROADMAP).
    """
    h, new_cache = _paged_step(params, cfg, batch, cache, ctx,
                               unroll=unroll)
    return unembed(params, cfg, h, ctx), new_cache


def prefill_chunk(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                  cache: Dict[str, jax.Array],
                  ctx: ExecContext = modules.DEFAULT_CTX, *,
                  unroll: bool = True) -> Tuple[jax.Array, Any]:
    """Absorb one chunk of a prompt into a *paged* KV cache.

    ``batch["tokens"]``: (B, C) — the next C prompt tokens of each lane,
    occupying global positions ``cache["pos"][b] .. pos[b] + C - 1``.
    ``cache``: the same pytree as :func:`paged_decode_step`.  Each layer
    attends causally over the lane's already-written pages plus the chunk
    (through its group's block table, window-masked for local groups) and
    scatters the chunk's K/V into its block-table pages, so calling
    this over a prompt's chunks in order leaves the cache exactly as a
    monolithic prefill + page write would, while letting the serving
    engine run decode steps for other lanes *between* chunks (chunked
    prefill — the ROADMAP's head-of-line-blocking fix).

    Returns (last-position logits (B, 1, V), updated cache with
    ``pos + C``) — the final chunk's logits supply the request's first
    output token, the same contract as :func:`prefill`.
    """
    h, new_cache = _paged_step(params, cfg, batch, cache, ctx,
                               unroll=unroll)
    return unembed(params, cfg, h[:, -1:], ctx), new_cache


def verify_chunk(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                 cache: Dict[str, jax.Array],
                 ctx: ExecContext = modules.DEFAULT_CTX, *,
                 unroll: bool = True) -> Tuple[jax.Array, Any]:
    """Verify a speculative draft: one paged chunk call, *all* logits.

    ``batch["tokens"]``: (B, C) — each lane's last committed token
    followed by its k draft tokens (C = k + 1), occupying global
    positions ``pos[b] .. pos[b] + C - 1``.  The same machinery as
    :func:`prefill_chunk` — the chunk's K/V are scattered into the
    lanes' block-table pages *before* the fused attend (so the verifier
    overwrites whatever the draft pass wrote at those positions), and
    each position attends causally over the lane's written context plus
    the chunk prefix.  The only contract difference: logits for *every*
    chunk position come back, ``(B, C, V)`` — l_0..l_k for the
    accept/reject sampler — instead of just the last.

    Verify chunks start wherever the lane's write position sits, which
    is rarely page-aligned: callers pass a ctx with
    ``unaligned_scatter=True`` so the chunk scatter takes the jnp path
    (the attend stays fused).  Rejected positions need no undo — the
    host simply advances ``pos`` by the number of emitted tokens, and
    the next chunk's scatter-before-attend overwrites the stale slots.
    """
    h, new_cache = _paged_step(params, cfg, batch, cache, ctx,
                               unroll=unroll)
    return unembed(params, cfg, h, ctx), new_cache


def raw_prefill_group_kv(cfg: ModelConfig, raw_cache: Dict[str, Any],
                         lane: int = 0) -> Dict[str, Dict[str, jax.Array]]:
    """Flatten the per-segment raw prefill K/V (``prefill(...,
    raw_kv=True)``) of one batch lane into per-group (n_group_layers, S,
    Hkv, D) arrays, in the group's stack order — the shape
    ``serving.kv_cache.write_prefill`` scatters into pages."""
    out = {}
    for g in paged_layer_groups(cfg):
        y = raw_cache[g.name]                # {"k","v"}: (*stack, B, S, Hkv, D)
        k = y["k"].reshape(len(g.layers), *y["k"].shape[-4:])
        v = y["v"].reshape(len(g.layers), *y["v"].shape[-4:])
        out[g.name] = {"k": k[:, lane], "v": v[:, lane]}
    return out


# ---------------------------------------------------------------------------
# Backbones
# ---------------------------------------------------------------------------

def _backbone(params, cfg, batch, ctx, *, mode: str, unroll: bool, cache=None,
              cache_len: Optional[int] = None, raw_kv: bool = False):
    t = cfg.arch_type
    kw = dict(mode=mode, unroll=unroll, cache=cache, cache_len=cache_len)
    if t == "ssm":
        return _xlstm_backbone(params, cfg, batch, ctx, **kw)
    if t == "vlm":
        return _vlm_backbone(params, cfg, batch, ctx, **kw)
    if t == "hybrid":
        return _hybrid_backbone(params, cfg, batch, ctx, **kw)
    if t == "audio":
        return _encdec_backbone(params, cfg, batch, ctx, **kw)
    return _dense_backbone(params, cfg, batch, ctx, raw_kv=raw_kv, **kw)


def _attn_seg_body(cfg, window, mode, hybrid=False):
    """Build a scan body for a dense/moe/hybrid attention segment."""
    apply = blocks.hybrid_block_apply if hybrid else blocks.dense_block_apply

    def body(h, p_i, ctx_i, extra_i):
        if mode == "decode":
            h, new_c = apply(p_i, h, cfg=cfg, ctx=ctx_i, window=window,
                             cache=extra_i)
            return h, new_c
        h, aux = apply(p_i, h, cfg=cfg, ctx=ctx_i, window=window,
                       return_kv=(mode == "prefill"))
        return h, aux

    return body


def _localize_kv(kv, window: int, seq: int):
    """Convert full prefill K/V (B,S,kv,hd) to a ring-buffer window cache."""
    W = min(window, seq)
    out = jax.tree.map(lambda x: x[:, -W:], kv)
    shift = seq % W
    return jax.tree.map(lambda x: jnp.roll(x, shift, axis=1), out)


def _finish_prefill_cache(kv, *, window: Optional[int], seq: int,
                          cache_len: Optional[int] = None):
    """kv: stacked {"k","v"} per layer (leading layer dims) -> decode cache.

    Pads up to the decode budget: full caches to ``cache_len``; windowed
    caches to min(window, cache_len) ring buffers (slot = pos % size)."""
    if kv is None:
        return None
    target = cache_len if cache_len is not None else seq
    if window is not None and seq > window:
        # keep only the last `window` positions, rotated so that slot layout
        # matches the decode ring-buffer convention (slot = pos % window)
        def loc(x):  # x: (..., B, S, kv, hd); S is axis -3
            xw = jax.lax.slice_in_dim(x, x.shape[-3] - window, x.shape[-3],
                                      axis=x.ndim - 3)
            return jnp.roll(xw, seq % window, axis=x.ndim - 3)
        kv = jax.tree.map(loc, kv)
    else:
        size = min(window, target) if window is not None else target
        if size > seq:
            def pad(x):  # pad S axis (axis -3) with zeros at the end
                widths = [(0, 0)] * x.ndim
                widths[x.ndim - 3] = (0, size - seq)
                return jnp.pad(x, widths)
            kv = jax.tree.map(pad, kv)
    pos = jnp.array(seq, jnp.int32)
    # broadcast a per-layer pos over the stack dims
    def mkpos(k):
        stack_dims = k.shape[:-4]  # (..., B, S, kv, hd)
        return jnp.broadcast_to(pos, stack_dims).astype(jnp.int32)
    sample = kv["k"]
    return {"k": kv["k"], "v": kv["v"], "pos": mkpos(sample)}


def _dense_backbone(params, cfg, batch, ctx, *, mode, unroll, cache=None,
        cache_len=None, raw_kv=False):
    if mode == "decode":
        # "token": one-token decode; "tokens": a multi-token paged prefill
        # chunk (the paged branch of attn_apply takes S > 1)
        tok = batch["token"] if "token" in batch else batch["tokens"]
        h = embed(params, cfg, tok, ctx)
    else:
        h = embed(params, cfg, batch["tokens"], ctx)
    S = h.shape[1] if mode != "decode" else None
    blocks_p = params["blocks"]
    layout = dict(segment_layout(cfg))

    if "layers" in blocks_p:
        window = cfg.sliding_window
        body = _attn_seg_body(cfg, window, mode)
        n = cfg.n_layers
        extra = cache["layers"] if mode == "decode" else None
        h, ys = _run_stack(body, h, blocks_p["layers"], n, ctx=ctx,
                           seg="layers", unroll=unroll, xs_extra=extra,
                           layer_ids=layout["layers"])
        if mode == "decode":
            return h, {"layers": ys}
        if mode == "prefill":
            if raw_kv:
                return h, {"layers": ys}
            return h, {"layers": _finish_prefill_cache(ys, window=window, seq=S, cache_len=cache_len)}
        return h, None

    # gemma3-style local/global superblocks
    sb = cfg.local_global_ratio + 1
    G = cfg.n_layers // sb
    R = sb - 1
    W = cfg.sliding_window
    local_p, global_p = blocks_p["local"], blocks_p["global"]
    tail_p = blocks_p.get("tail")

    local_body = _attn_seg_body(cfg, W, mode)
    global_body = _attn_seg_body(cfg, None, mode)

    def super_body(h, p_i, ctx_i, extra_i):
        lp, gp = p_i
        le = ge = None
        if extra_i is not None:
            le, ge = extra_i
        h, lys = _run_stack(local_body, h, lp, R, ctx=ctx_i, seg="local_inner",
                            unroll=unroll, xs_extra=le)
        h, gy = global_body(h, gp, ctx_i, ge)
        return h, (lys, gy)

    extra = None
    if mode == "decode":
        extra = (cache["local"], cache["global"])
    h, ys = _run_stack(super_body, h, (local_p, global_p), G, ctx=ctx,
                       seg="super", unroll=unroll, xs_extra=extra)

    tail_ys = None
    if tail_p is not None:
        n_tail = len(layout["tail"])
        te = cache["tail"] if mode == "decode" else None
        h, tail_ys = _run_stack(local_body, h, tail_p, n_tail, ctx=ctx,
                                seg="tail", unroll=unroll, xs_extra=te,
                                layer_ids=layout["tail"])

    if mode == "decode":
        lys, gys = ys
        out = {"local": lys, "global": gys, "tail": tail_ys}
        return h, out
    if mode == "prefill":
        lys, gys = ys
        if raw_kv:
            return h, {"local": lys, "global": gys, "tail": tail_ys}
        out = {
            "local": _finish_prefill_cache(lys, window=W, seq=S, cache_len=cache_len),
            "global": _finish_prefill_cache(gys, window=None, seq=S, cache_len=cache_len),
            "tail": _finish_prefill_cache(tail_ys, window=W, seq=S, cache_len=cache_len),
        }
        return h, out
    return h, None


def _vlm_backbone(params, cfg, batch, ctx, *, mode, unroll, cache=None,
        cache_len=None):
    if mode == "decode":
        h = embed(params, cfg, batch["token"], ctx)
    else:
        h = embed(params, cfg, batch["tokens"], ctx)
    S = h.shape[1] if mode != "decode" else None
    ce = cfg.cross_attn_every
    G = cfg.n_layers // ce
    R = ce - 1
    self_p, cross_p = params["blocks"]["self"], params["blocks"]["cross"]

    self_body = _attn_seg_body(cfg, None, mode)

    # Cross-attn K/V from vision memory: computed at prefill/train, reused at
    # decode (stored in the cache — the standard enc-dec/VLM optimization).
    if mode == "decode":
        xkv = cache["cross_kv"]            # stacked (G, B, T, kv, hd)
    else:
        vision = batch["vision"]           # (B, T, d_vision)

        def xkv_one(cp, ctx_i):
            return attention.cross_attn_kv(cp["attn"], vision,
                                           n_kv_heads=cfg.n_kv_heads,
                                           head_dim=cfg.head_dim, ctx=ctx_i,
                                           name="xblock.attn")
        static, arrays = _seg_policy(ctx, "cross")
        if unroll:
            kvs = [xkv_one(jax.tree.map(lambda x: x[i], cross_p),
                           _step_ctx(ctx, static, {k: v[i] for k, v in arrays.items()},
                                     prefix=f"Lx{i}"))
                   for i in range(G)]
            xkv = jax.tree.map(lambda *t: jnp.stack(t), *kvs)
        else:
            def kv_scan(_, xs):
                cp, sl = xs
                return None, xkv_one(cp, _step_ctx(ctx, static, sl))
            _, xkv = jax.lax.scan(kv_scan, None, (cross_p, arrays or {}), length=G)
        xkv = {"k": xkv[0], "v": xkv[1]}

    def super_body(h, p_i, ctx_i, extra_i):
        sp, cp, kv_i = p_i
        se = extra_i
        h, sys_ = _run_stack(self_body, h, sp, R, ctx=ctx_i, seg="self_inner",
                             unroll=unroll, xs_extra=se)
        h = blocks.cross_block_apply(cp, h, (kv_i["k"], kv_i["v"]),
                                     cfg=cfg, ctx=ctx_i)
        return h, sys_

    extra = cache["self"] if mode == "decode" else None
    h, ys = _run_stack(super_body, h, (self_p, cross_p, xkv), G, ctx=ctx,
                       seg="groups", unroll=unroll, xs_extra=extra)

    if mode == "decode":
        return h, {"self": ys, "cross_kv": cache["cross_kv"]}
    if mode == "prefill":
        return h, {"self": _finish_prefill_cache(ys, window=None, seq=S, cache_len=cache_len),
                   "cross_kv": xkv}
    return h, None


def _hybrid_backbone(params, cfg, batch, ctx, *, mode, unroll, cache=None,
        cache_len=None):
    if mode == "decode":
        h = embed(params, cfg, batch["token"], ctx)
    else:
        h = embed(params, cfg, batch["tokens"], ctx)
    S = h.shape[1] if mode != "decode" else None
    layout = segment_layout(cfg)
    W = cfg.sliding_window
    new_cache: Dict[str, Any] = {}

    for seg, idxs in layout:
        if not idxs or seg not in params["blocks"]:
            continue
        window = None if seg == "global" else W
        body = _attn_seg_body(cfg, window, mode, hybrid=True)
        extra = cache[seg] if mode == "decode" else None
        h, ys = _run_stack(body, h, params["blocks"][seg], len(idxs), ctx=ctx,
                           seg=seg, unroll=unroll, xs_extra=extra,
                           layer_ids=idxs)
        if mode == "decode":
            new_cache[seg] = ys
        elif mode == "prefill":
            new_cache[seg] = {
                "attn": _finish_prefill_cache(ys["attn"], window=window, seq=S,
                                              cache_len=cache_len),
                "ssm": ys["ssm"],
            } if ys is not None else None

    if mode in ("decode", "prefill"):
        return h, new_cache
    return h, None


def _xlstm_backbone(params, cfg, batch, ctx, *, mode, unroll, cache=None,
        cache_len=None):
    if mode == "decode":
        h = embed(params, cfg, batch["token"], ctx)
    else:
        h = embed(params, cfg, batch["tokens"], ctx)
    sb = cfg.slstm_every
    G = cfg.n_layers // sb
    R = sb - 1
    m_p, s_p = params["blocks"]["mlstm"], params["blocks"]["slstm"]
    stateful = mode in ("prefill", "decode")

    def m_body(h, p_i, ctx_i, extra_i):
        h, st = blocks.mlstm_block_apply(p_i, h, cfg=cfg, ctx=ctx_i,
                                         state=extra_i)
        return h, (st if stateful else None)

    def super_body(h, p_i, ctx_i, extra_i):
        mp, sp = p_i
        me = se = None
        if extra_i is not None:
            me, se = extra_i
        h, mys = _run_stack(m_body, h, mp, R, ctx=ctx_i, seg="mlstm_inner",
                            unroll=unroll, xs_extra=me)
        h, sst = blocks.slstm_block_apply(sp, h, cfg=cfg, ctx=ctx_i, state=se)
        return h, (mys, sst if stateful else None)

    extra = (cache["mlstm"], cache["slstm"]) if mode == "decode" else None
    h, ys = _run_stack(super_body, h, (m_p, s_p), G, ctx=ctx, seg="super",
                       unroll=unroll, xs_extra=extra)

    if stateful:
        mys, sys_ = ys
        return h, {"mlstm": mys, "slstm": sys_}
    return h, None


def _encdec_backbone(params, cfg, batch, ctx, *, mode, unroll, cache=None,
        cache_len=None):
    # encoder runs at train/prefill; its output memory K/V live in the cache
    if mode == "decode":
        h = embed(params, cfg, batch["token"], ctx)
        xkv = cache["cross_kv"]
    else:
        enc_h = batch["audio"]             # (B, F, d) — frontend stub output

        def enc_body(h, p_i, ctx_i, _):
            return blocks.enc_block_apply(p_i, h, cfg=cfg, ctx=ctx_i), None

        enc_h, _ = _run_stack(enc_body, enc_h, params["blocks"]["enc"],
                              cfg.n_enc_layers, ctx=ctx, seg="enc",
                              unroll=unroll)
        memory = modules.rmsnorm(params["enc_norm"], enc_h)
        h = embed(params, cfg, batch["tokens"], ctx)

        # per-decoder-layer cross K/V from encoder memory
        static, arrays = _seg_policy(ctx, "dec")

        def kv_one(dp, ctx_i):
            return attention.cross_attn_kv(dp["xattn"], memory,
                                           n_kv_heads=cfg.n_kv_heads,
                                           head_dim=cfg.head_dim, ctx=ctx_i,
                                           name="dec.xattn")
        if unroll:
            kvs = [kv_one(jax.tree.map(lambda x: x[i], params["blocks"]["dec"]),
                          _step_ctx(ctx, static,
                                    {k: v[i] for k, v in arrays.items()},
                                    prefix=f"L{i}"))
                   for i in range(cfg.n_layers)]
            xkv = jax.tree.map(lambda *t: jnp.stack(t), *kvs)
        else:
            def kv_scan(_, xs):
                dp, sl = xs
                return None, kv_one(dp, _step_ctx(ctx, static, sl))
            _, xkv = jax.lax.scan(kv_scan, None,
                                  (params["blocks"]["dec"], arrays or {}),
                                  length=cfg.n_layers)
        xkv = {"k": xkv[0], "v": xkv[1]}

    S = h.shape[1] if mode != "decode" else None

    def dec_body(h, p_i, ctx_i, extra_i):
        dp, kv_i = p_i
        if mode == "decode":
            return blocks.dec_block_apply(dp, h, (kv_i["k"], kv_i["v"]),
                                          cfg=cfg, ctx=ctx_i, cache=extra_i)
        return blocks.dec_block_apply(dp, h, (kv_i["k"], kv_i["v"]), cfg=cfg,
                                      ctx=ctx_i, return_kv=(mode == "prefill"))

    extra = cache["self"] if mode == "decode" else None
    h, ys = _run_stack(dec_body, h, (params["blocks"]["dec"], xkv),
                       cfg.n_layers, ctx=ctx, seg="dec", unroll=unroll,
                       xs_extra=extra)

    if mode == "decode":
        return h, {"self": ys, "cross_kv": cache["cross_kv"]}
    if mode == "prefill":
        return h, {"self": _finish_prefill_cache(ys, window=None, seq=S, cache_len=cache_len),
                   "cross_kv": xkv}
    return h, None
