"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar).

mLSTM: per-head matrix memory C in R^{dk x dv} with exponential gating,
  C_t = f_t C_{t-1} + i_t k_t v_t^T,   n_t = f_t n_{t-1} + i_t k_t
  y_t = (C_t^T q_t) / max(|n_t^T q_t|, exp(-m_t))
Prefill/train runs the chunkwise-parallel form (within-chunk attention-like
quadratic term + cross-chunk recurrent state), decode is a single state
update — O(1) per token, which is what makes xlstm run ``long_500k``.

sLSTM: scalar memory with a true recurrent weight R on the hidden state —
inherently sequential, executed with ``lax.scan``.

Stabilization follows the paper: gates live in log space with a running max
tracker m_t; the stored state is the stabilized one (true state = exp(m) x
stored), so exp() never overflows.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import modules
from repro.models.modules import ExecContext, join

MLSTM_CHUNK = 64
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, d_model: int, n_heads: int, proj_factor: float = 2.0,
               dtype=jnp.float32) -> Dict[str, Any]:
    d_inner = int(d_model * proj_factor)
    assert d_inner % n_heads == 0
    ks = jax.random.split(key, 7)
    return {
        "up": modules.linear_init(ks[0], d_model, 2 * d_inner, dtype=dtype),
        "q": modules.linear_init(ks[1], d_inner, d_inner, dtype=dtype),
        "k": modules.linear_init(ks[2], d_inner, d_inner, dtype=dtype),
        "v": modules.linear_init(ks[3], d_inner, d_inner, dtype=dtype),
        "if_gate": modules.linear_init(ks[4], d_inner, 2 * n_heads, bias=True, dtype=dtype),
        "o_norm": modules.rmsnorm_init(d_inner, dtype),
        "down": modules.linear_init(ks[5], d_inner, d_model, dtype=dtype),
    }


def _mlstm_chunk(carry, ins, head_dim: int):
    """Chunkwise-parallel mLSTM step.

    carry: (C, n, m) — C: (B,H,D,D), n: (B,H,D), m: (B,H); stabilized state.
    ins: q,k,v: (B,L,H,D); log_i, log_f: (B,L,H).
    Returns updated carry and y: (B,L,H,D).
    """
    C, n, m = carry
    q, k, v, log_i, log_f = ins
    B, L, H, D = q.shape
    # NOTE: k arrives pre-scaled by head_dim**-0.5 from mlstm_apply; do not
    # rescale q here or the chunk path diverges from the decode recurrence.

    cf = jnp.cumsum(log_f, axis=1).transpose(0, 2, 1)      # (B,H,L)
    li = log_i.transpose(0, 2, 1)                          # (B,H,L)

    # intra-chunk log weights: w[t,s] = cf_t - cf_s + li_s  (s <= t)
    log_D = cf[:, :, :, None] - cf[:, :, None, :] + li[:, :, None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    log_D = jnp.where(tri, log_D, NEG_INF)

    # carry contribution at step t: exp(cf_t + m)
    log_carry = cf + m[:, :, None]                         # (B,H,L)

    m_t = jnp.maximum(jnp.max(log_D, axis=-1), log_carry)  # (B,H,L)
    m_t = jnp.maximum(m_t, NEG_INF)

    Dmat = jnp.exp(log_D - m_t[..., None])                 # (B,H,L,L)
    cw = jnp.exp(log_carry - m_t)                          # (B,H,L)

    qh = q.transpose(0, 2, 1, 3)                           # (B,H,L,D)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)

    scores = (qh @ kh.transpose(0, 1, 3, 2)) * Dmat        # (B,H,L,L)
    num = scores @ vh + jnp.einsum("bhld,bhdv->bhlv", qh, C) * cw[..., None]
    den = jnp.einsum("bhls,bhsd,bhld->bhl", Dmat, kh, qh) + \
        jnp.einsum("bhld,bhd->bhl", qh, n) * cw
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
    y = num / den[..., None]                               # (B,H,L,D)

    # end-of-chunk carry update
    log_wend = cf[:, :, -1:] - cf + li                     # (B,H,L)
    m_end = jnp.maximum(cf[:, :, -1] + m, jnp.max(log_wend, axis=-1))
    w_end = jnp.exp(log_wend - m_end[:, :, None])          # (B,H,L)
    cdec = jnp.exp(cf[:, :, -1] + m - m_end)               # (B,H)
    C_new = cdec[..., None, None] * C + jnp.einsum("bhs,bhsd,bhsv->bhdv",
                                                   w_end, kh, vh)
    n_new = cdec[..., None] * n + jnp.einsum("bhs,bhsd->bhd", w_end, kh)
    return (C_new, n_new, m_end), y.transpose(0, 2, 1, 3)  # (B,L,H,D)


def mlstm_apply(params, x: jax.Array, *, n_heads: int, proj_factor: float,
                ctx: ExecContext, name: str,
                state: Optional[Dict[str, jax.Array]] = None,
                ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, d_model = x.shape
    d_inner = int(d_model * proj_factor)
    head_dim = d_inner // n_heads

    uz = modules.quant_linear(params["up"], x, name=join(name, "up"), ctx=ctx)
    u, z = jnp.split(uz, 2, axis=-1)                       # (B,S,d_inner)

    q = modules.quant_linear(params["q"], u, name=join(name, "q"), ctx=ctx)
    k = modules.quant_linear(params["k"], u, name=join(name, "k"), ctx=ctx)
    v = modules.quant_linear(params["v"], u, name=join(name, "v"), ctx=ctx)
    q = q.reshape(B, S, n_heads, head_dim).astype(jnp.float32)
    k = k.reshape(B, S, n_heads, head_dim).astype(jnp.float32) * head_dim ** -0.5
    v = v.reshape(B, S, n_heads, head_dim).astype(jnp.float32)

    gif = modules.quant_linear(params["if_gate"], u, name=join(name, "if_gate"),
                               ctx=ctx).astype(jnp.float32)
    log_i, f_pre = jnp.split(gif, 2, axis=-1)              # (B,S,H)
    log_f = jax.nn.log_sigmoid(f_pre + 3.0)                # bias toward remember

    if state is None:
        C0 = jnp.zeros((B, n_heads, head_dim, head_dim), jnp.float32)
        n0 = jnp.zeros((B, n_heads, head_dim), jnp.float32)
        m0 = jnp.full((B, n_heads), NEG_INF, jnp.float32)
        L = MLSTM_CHUNK
        if S % L == 0 and S > L:
            nc = S // L

            def resh(t):
                return jnp.moveaxis(t.reshape(B, nc, L, *t.shape[2:]), 1, 0)

            def step(c, ins):
                return _mlstm_chunk(c, ins, head_dim)

            (C, n, m), ys = jax.lax.scan(
                step, (C0, n0, m0),
                (resh(q), resh(k), resh(v), resh(log_i), resh(log_f)))
            y = jnp.moveaxis(ys, 0, 1).reshape(B, S, n_heads, head_dim)
        else:
            (C, n, m), y = _mlstm_chunk((C0, n0, m0),
                                        (q, k, v, log_i, log_f), head_dim)
        new_state = {"C": C, "n": n, "m": m}
    else:
        C, n, m = state["C"], state["n"], state["m"]
        li, lf = log_i[:, 0], log_f[:, 0]                  # (B,H)
        m_new = jnp.maximum(lf + m, li)
        fw = jnp.exp(lf + m - m_new)
        iw = jnp.exp(li - m_new)
        qh, kh, vh = q[:, 0], k[:, 0], v[:, 0]             # (B,H,D)
        C = fw[..., None, None] * C + iw[..., None, None] * (
            kh[..., :, None] * vh[..., None, :])
        n = fw[..., None] * n + iw[..., None] * kh
        num = jnp.einsum("bhd,bhdv->bhv", qh, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qh, n)),
                          jnp.exp(-m_new))
        y = (num / den[..., None])[:, None]                # (B,1,H,D)
        new_state = {"C": C, "n": n, "m": m_new}

    y = y.reshape(B, S, d_inner)
    y = modules.rmsnorm(params["o_norm"], y)
    y = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = modules.quant_linear(params["down"], y, name=join(name, "down"), ctx=ctx)
    return out, new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, d_model: int, dtype=jnp.float32) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    d_ff = int(d_model * 8 / 3) // 128 * 128 or d_model
    return {
        "wx": modules.linear_init(ks[0], d_model, 4 * d_model, bias=True, dtype=dtype),
        "r": modules.linear_init(ks[1], d_model, 4 * d_model, dtype=dtype),
        "o_norm": modules.rmsnorm_init(d_model, dtype),
        "ffn_up": modules.linear_init(ks[2], d_model, d_ff, dtype=dtype),
        "ffn_down": modules.linear_init(ks[3], d_ff, d_model, dtype=dtype),
    }


def slstm_apply(params, x: jax.Array, *, ctx: ExecContext, name: str,
                state: Optional[Dict[str, jax.Array]] = None,
                ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Scalar-memory LSTM with exponential gating and recurrent weights.

    state: {"c","n","h","m": (B, d)}.  Sequential over time — the recurrent
    matrix R couples h_{t-1} into the gates, so no parallel form exists; this
    is the paper's own trade-off for sLSTM blocks.
    """
    B, S, d = x.shape
    wx_all = modules.quant_linear(params["wx"], x, name=join(name, "wx"),
                                  ctx=ctx).astype(jnp.float32)  # (B,S,4d)
    rw = params["r"]["w"].astype(jnp.float32)                   # (d, 4d)

    if state is None:
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.zeros((B, d), jnp.float32)
        h0 = jnp.zeros((B, d), jnp.float32)
        m0 = jnp.full((B, d), NEG_INF, jnp.float32)
    else:
        c0, n0, h0, m0 = state["c"], state["n"], state["h"], state["m"]

    def cell(carry, wx_t):
        c, n, h, m = carry
        pre = wx_t + h @ rw                                # (B, 4d)
        zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
        zt = jnp.tanh(zt)
        log_i = it
        log_f = jax.nn.log_sigmoid(ft + 3.0)
        m_new = jnp.maximum(log_f + m, log_i)
        iw = jnp.exp(log_i - m_new)
        fw = jnp.exp(log_f + m - m_new)
        c = fw * c + iw * zt
        n = jnp.maximum(fw * n + iw, jnp.exp(-m_new))
        h = jax.nn.sigmoid(ot) * (c / n)
        return (c, n, h, m_new), h

    (c, n, h, m), hs = jax.lax.scan(cell, (c0, n0, h0, m0),
                                    wx_all.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)              # (B,S,d)
    y = modules.rmsnorm(params["o_norm"], y)
    u = modules.quant_linear(params["ffn_up"], y, name=join(name, "ffn_up"), ctx=ctx)
    u = jax.nn.gelu(u.astype(jnp.float32), approximate=True).astype(y.dtype)
    out = modules.quant_linear(params["ffn_down"], u, name=join(name, "ffn_down"), ctx=ctx)
    new_state = {"c": c, "n": n, "h": h, "m": m}
    return out, new_state


def init_mlstm_state(batch: int, n_heads: int, head_dim: int) -> Dict[str, jax.Array]:
    return {
        "C": jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
        "n": jnp.zeros((batch, n_heads, head_dim), jnp.float32),
        "m": jnp.full((batch, n_heads), NEG_INF, jnp.float32),
    }


def init_slstm_state(batch: int, d_model: int) -> Dict[str, jax.Array]:
    return {
        "c": jnp.zeros((batch, d_model), jnp.float32),
        "n": jnp.zeros((batch, d_model), jnp.float32),
        "h": jnp.zeros((batch, d_model), jnp.float32),
        "m": jnp.full((batch, d_model), NEG_INF, jnp.float32),
    }
