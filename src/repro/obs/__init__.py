"""Observability: clock-native tracing, streaming metrics, trace audits.

The serving stack's load-bearing abstraction is the analytic clock
(``core.latency`` roofline seconds) — admission, routing, and every
committed benchmark price against it.  This package makes *where those
seconds go* observable:

* :mod:`~repro.obs.trace` — typed span/instant/counter events on the
  analytic clock (wall-clock recorded alongside), a zero-overhead-when-
  disabled :data:`~repro.obs.trace.NULL` tracer, and per-engine track
  scoping.  Every serving path emits: request lifecycle (arrive ->
  queue -> admit -> prefill chunks -> first token -> tokens ->
  finish/drop/degrade), engine step composition, and the page pool's
  alloc/free/reserve lifecycle.
* :mod:`~repro.obs.export` — Chrome ``trace_event`` JSON (open in
  Perfetto: one process per engine, one thread per lane/pool/queue) and
  the modeled-vs-wall :func:`~repro.obs.export.drift_report`.
* :mod:`~repro.obs.sink` — a streaming metrics sink with seeded
  reservoir percentiles feeding the extended
  :class:`~repro.serving.metrics.SLOReport` (TTFT / inter-token p50/p99,
  per-class queue/prefill/decode slack attribution).
* :mod:`~repro.obs.check_trace` — replays any event stream and asserts
  the stack's conservation laws (page conservation under refcounted
  sharing — shared pages free only at refcount zero, freeing a page you
  merely reference is a finding — reservation non-negativity, per-lane
  clock monotonicity, exactly-once retirement with cancel as a third
  retirement kind, and speculation commit discipline: every
  ``spec.draft`` committed by exactly one ``spec.accept`` with
  ``accepted <= drafted``), so every traced run doubles as a
  correctness audit.

Wiring: pass ``tracer=Tracer()`` to ``ContinuousEngine``,
``ContinuousBatcher``, ``Scheduler``, or ``FleetRouter`` (the router
scopes one shared tracer per engine), then ``export.write_chrome
(tracer.events, path)`` and/or ``check_trace.check(tracer.events)``.
"""
from repro.obs.check_trace import check, check_file
from repro.obs.export import drift_report, from_chrome, to_chrome, \
    write_chrome
from repro.obs.sink import MetricsSink, Reservoir
from repro.obs.trace import NULL, Event, NullTracer, Tracer

__all__ = [
    "Event", "Tracer", "NullTracer", "NULL", "MetricsSink", "Reservoir",
    "to_chrome", "from_chrome", "write_chrome", "drift_report",
    "check", "check_file",
]
