"""Trace-driven invariant checker: replay an event stream, prove the laws.

The serving stack's correctness properties used to be re-derived ad hoc
per test (the PR 4 aliasing race and the PR 5 page-accounting bugs were
each caught by bespoke harnesses).  This module turns any traced run —
benchmark, example, CI scenario — into a standing audit by replaying its
event stream and asserting the conservation laws the stack promises:

1. **Page conservation under refcounting** (per engine pool, per layer
   group).  A page is allocated only off the free list (exclusive,
   refcount 1); ``page.share`` adds references only to live pages, and a
   holder never holds the same page twice (the prefix cache, pseudo-slot
   -1, may — its entries overlap); every ``page.free`` drops exactly one
   reference held by its emitter — releasing a page the holder does not
   hold (the double-free of a shared page) is an error — and the page
   returns to the free list exactly when the last reference drops.  The
   dummy page (id 0) and out-of-range ids are never allocated; a slot
   never *owns* more pages than its reservation (shared holdings are
   free).  When every admitted request has retired, no lane holds a
   page, every live page is a prefix-cache holding, and
   ``free + live = n_pages - 1`` per group.
2. **Reservation non-negativity.**  After every pool event,
   ``free - sum over slots of (reserved - owned)+ >= 0`` — the invariant
   that makes the sliding window's lazy mid-flight allocation *and* the
   copy-on-write of a shared boundary page deadlock-free (kv_cache's
   "Reservations" contract; CoW pages are part of the reservation).
3. **Clock monotonicity per lane/engine track.**  Step, prefill, and
   token events on one track never move the analytic clock backwards,
   and spans never have negative duration.
4. **Exactly-once retire, attempt-aware.**  Every admitted request
   retires exactly once (finish, drop, or barge-in cancel), never twice;
   a finish implies an admission.  Drops and cancels without admission
   are legal (admission-time policy rejections; barge-in while still
   queued).  Failure recovery widens the budget per *license*, never
   silently: each ``req.requeue`` (a crash reclaimed the attempt — which
   therefore never retires) licenses one extra admission of the same
   rid, and each ``route.hedge`` licenses one extra admission *and* one
   extra terminal (the losing attempt of the pair retires too, flagged
   ``hedge_loser``).  A rid may never exceed
   ``admits <= 1 + requeues + hedges`` or
   ``terminals <= 1 + hedges`` — re-admission without a recorded fault
   event is still the double-admit bug this law existed to catch.
5. **Speculation commit discipline** (per track).  Every ``spec.draft``
   is committed by exactly one ``spec.accept`` before the next round on
   that track begins, with ``0 <= accepted <= drafted`` — a draft token
   can be emitted at most once, and a round is never silently dropped or
   double-committed; at quiescence no round is left dangling.

Run it on an exported Chrome trace (``benchmarks/table_paged.py --trace``
or the examples' ``--trace out.json``):

    PYTHONPATH=src python -m repro.obs.check_trace out.json [...]

Exit 0 = all invariants hold; 1 = findings (one per line on stderr).
``check(events)`` is the library entry point for in-memory streams.
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.obs.trace import (Event, ENGINE_SHARD_STEP, ENGINE_STEP,
                             PAGE_ALLOC, PAGE_COW, PAGE_FREE, PAGE_RESERVE,
                             PAGE_SHARE, POOL_CONFIG, PREFIX_EVICT,
                             PREFIX_INSERT, REQ_ADMIT, REQ_CANCEL, REQ_DROP,
                             REQ_FINISH, REQ_FIRST_TOKEN, REQ_PREFILL,
                             REQ_PREFILL_CHUNK, REQ_REQUEUE, REQ_TOKEN,
                             ROUTE_HEDGE, ROUTE_XFER, SPEC_ACCEPT,
                             SPEC_DRAFT, SPEC_VERIFY, WAVE_STEP)

#: events whose analytic timestamps must be non-decreasing per track
#: (queue spans and arrivals are excluded by design: EDF admission emits
#: them out of arrival order on shared tracks)
_MONOTONIC = {ENGINE_STEP, ENGINE_SHARD_STEP, WAVE_STEP, REQ_PREFILL,
              REQ_PREFILL_CHUNK, REQ_TOKEN, REQ_FIRST_TOKEN, PAGE_ALLOC,
              PAGE_FREE, PAGE_RESERVE, PAGE_SHARE, PAGE_COW, PREFIX_INSERT,
              PREFIX_EVICT, SPEC_DRAFT, SPEC_VERIFY, SPEC_ACCEPT}
_EPS = 1e-12


def _scope(track: str) -> str:
    """Engine scope of a track: everything before the last path component
    ("eng0:m-g1/steps" -> "eng0:m-g1"; unscoped tracks -> "")."""
    return track.rsplit("/", 1)[0] if "/" in track else ""


class _Pool:
    """Replayed page-accounting state of one engine's pool track."""

    def __init__(self, track: str, groups: Dict[str, int], slots: int):
        self.track = track
        self.slots = slots
        self.free: Dict[str, Set[int]] = {
            g: set(range(1, int(n))) for g, n in groups.items()}
        self.n_pages = {g: int(n) for g, n in groups.items()}
        #: (group, slot) -> set of *exclusively* owned page ids (these
        #: count against the slot's reservation)
        self.owned: Dict[Tuple[str, int], Set[int]] = {}
        #: (group, holder) -> {page: reference count} of shared holdings;
        #: holder -1 is the prefix cache, whose overlapping entries may
        #: hold a page more than once
        self.shared: Dict[Tuple[str, int], Dict[int, int]] = {}
        #: group -> {page: total refcount} of live pages
        self.refs: Dict[str, Dict[int, int]] = {g: {} for g in self.free}
        self.reserved: Dict[Tuple[str, int], int] = {}

    def _chk_available(self, errors: List[str], where: str) -> None:
        for g in self.free:
            short = sum(max(0, n - len(self.owned.get((gg, s), ())))
                        for (gg, s), n in self.reserved.items() if gg == g)
            avail = len(self.free[g]) - short
            if avail < 0:
                errors.append(
                    f"{self.track}: reservation accounting negative for "
                    f"group {g!r} after {where} (free {len(self.free[g])}, "
                    f"unmet reservations {short})")

    def apply(self, ev: Event, errors: List[str]) -> None:
        a = ev.args or {}
        g = a.get("group")
        if g not in self.free:
            errors.append(f"{self.track}: {ev.name} for unknown group {g!r}")
            return
        slot = int(a.get("slot", -1))
        if ev.name == PAGE_RESERVE:
            pages = int(a.get("pages", 0))
            if pages:
                self.reserved[(g, slot)] = pages
            else:
                self.reserved.pop((g, slot), None)
                if self.owned.get((g, slot)):
                    errors.append(
                        f"{self.track}: reservation for {g}/slot{slot} "
                        f"cleared while {len(self.owned[(g, slot)])} pages "
                        "still live")
        elif ev.name == PAGE_ALLOC:
            page = int(a.get("page", -1))
            if page == 0:
                errors.append(f"{self.track}: dummy page allocated "
                              f"({g}/slot{slot})")
            elif not 0 < page < self.n_pages[g]:
                errors.append(f"{self.track}: page {page} out of range for "
                              f"group {g!r} (n_pages {self.n_pages[g]})")
            elif page not in self.free[g]:
                errors.append(f"{self.track}: page {g}:{page} allocated "
                              "while not on the free list (double alloc)")
            else:
                self.free[g].discard(page)
                self.refs[g][page] = 1
                own = self.owned.setdefault((g, slot), set())
                own.add(page)
                if len(own) > self.reserved.get((g, slot), 0):
                    errors.append(
                        f"{self.track}: slot {slot} holds {len(own)} pages "
                        f"of {g!r} beyond its reservation "
                        f"({self.reserved.get((g, slot), 0)})")
        elif ev.name == PAGE_SHARE:
            page = int(a.get("page", -1))
            if self.refs[g].get(page, 0) <= 0:
                errors.append(f"{self.track}: page {g}:{page} shared while "
                              f"not live (holder {slot})")
                return
            sh = self.shared.setdefault((g, slot), {})
            if slot >= 0 and (page in sh
                              or page in self.owned.get((g, slot), ())):
                errors.append(f"{self.track}: slot {slot} shares page "
                              f"{g}:{page} it already holds")
                return
            sh[page] = sh.get(page, 0) + 1
            self.refs[g][page] += 1
            want = a.get("refs")
            if want is not None and int(want) != self.refs[g][page]:
                errors.append(
                    f"{self.track}: page {g}:{page} refcount drift on "
                    f"share (emitter says {want}, replay says "
                    f"{self.refs[g][page]})")
        elif ev.name == PAGE_FREE:
            page = int(a.get("page", -1))
            own = self.owned.get((g, slot), set())
            sh = self.shared.get((g, slot), {})
            if page in own:
                own.discard(page)
            elif sh.get(page, 0) > 0:
                sh[page] -= 1
                if not sh[page]:
                    del sh[page]
            else:
                errors.append(
                    f"{self.track}: page {g}:{page} freed by holder {slot} "
                    "that holds no reference (double free of a shared "
                    "page?)")
                return
            self.refs[g][page] -= 1
            want = a.get("refs")
            if want is not None and int(want) != self.refs[g][page]:
                errors.append(
                    f"{self.track}: page {g}:{page} refcount drift on "
                    f"free (emitter says {want}, replay says "
                    f"{self.refs[g][page]})")
            if self.refs[g][page] == 0:
                del self.refs[g][page]
                self.free[g].add(page)
        self._chk_available(errors, f"{ev.name} t={ev.t0:.6f}")

    def live_pages(self) -> int:
        return sum(len(r) for r in self.refs.values())

    def lane_holdings(self) -> int:
        """Pages (counting multiplicity) held by real lanes (slot >= 0) —
        must be 0 at quiescence; prefix-cache holdings may persist."""
        return (sum(len(o) for (g, s), o in self.owned.items() if s >= 0)
                + sum(sum(sh.values())
                      for (g, s), sh in self.shared.items() if s >= 0))

    def conservation(self, errors: List[str]) -> None:
        """free + live == allocatable, and every live page has exactly as
        many references as holders hold — nothing leaks, nothing double
        counts."""
        held: Dict[Tuple[str, int], int] = {}
        for (g, s), own in self.owned.items():
            for p in own:
                held[(g, p)] = held.get((g, p), 0) + 1
        for (g, s), sh in self.shared.items():
            for p, n in sh.items():
                held[(g, p)] = held.get((g, p), 0) + n
        for g in self.free:
            if len(self.free[g]) + len(self.refs[g]) != self.n_pages[g] - 1:
                errors.append(
                    f"{self.track}: group {g!r} conservation broken "
                    f"(free {len(self.free[g])} + live {len(self.refs[g])} "
                    f"!= {self.n_pages[g] - 1})")
            for p, r in self.refs[g].items():
                if held.get((g, p), 0) != r:
                    errors.append(
                        f"{self.track}: page {g}:{p} refcount {r} but "
                        f"{held.get((g, p), 0)} holdings")


def check(events: Sequence[Event]) -> List[str]:
    """Replay ``events`` and return every invariant violation found."""
    errors: List[str] = []
    pools: Dict[str, _Pool] = {}
    last_t: Dict[str, float] = {}
    admits: Dict = {}                     # rid -> admission count
    terminals: Dict = {}                  # rid -> [kind, ...] in order
    requeues: Dict = {}                   # rid -> crash-reclaim licenses
    hedges: Dict = {}                     # rid -> hedge licenses
    spec_pending: Dict[str, int] = {}     # track -> uncommitted drafted
    pool_tp: Dict[str, int] = {}          # engine scope -> pool-config tp
    shard_tp: Dict[str, int] = {}         # engine scope -> shard-step tp

    for ev in events:
        a = ev.args or {}
        # -- clock monotonicity ------------------------------------------
        if ev.name in _MONOTONIC:
            prev = last_t.get(ev.track)
            if prev is not None and ev.t0 < prev - _EPS:
                errors.append(f"{ev.track}: clock moved backwards at "
                              f"{ev.name} ({prev:.9f} -> {ev.t0:.9f})")
            last_t[ev.track] = max(prev or ev.t0, ev.t0)
        if ev.kind == "span" and ev.t1 is not None and ev.t1 < ev.t0 - _EPS:
            errors.append(f"{ev.track}: negative-duration span {ev.name} "
                          f"({ev.t0:.9f} -> {ev.t1:.9f})")
        # -- pool replay -------------------------------------------------
        if ev.name == POOL_CONFIG:
            if ev.track in pools:
                errors.append(f"{ev.track}: duplicate pool.config")
            pools[ev.track] = _Pool(ev.track, a.get("groups", {}),
                                    int(a.get("slots", 0)))
            pool_tp[_scope(ev.track)] = int(a.get("tp", 1))
        elif ev.name in (PAGE_ALLOC, PAGE_FREE, PAGE_RESERVE, PAGE_SHARE):
            pool = pools.get(ev.track)
            if pool is None:
                errors.append(f"{ev.track}: {ev.name} before pool.config")
            else:
                pool.apply(ev, errors)
        # -- tensor-parallel shard discipline ----------------------------
        elif ev.name == ENGINE_SHARD_STEP:
            tp = int(a.get("tp", 0))
            scope = _scope(ev.track)
            if tp < 2:
                errors.append(
                    f"{ev.track}: engine.shard_step with tp={tp} "
                    f"(a sharded step means >= 2 shards; t={ev.t0:.6f})")
            prev_tp = shard_tp.setdefault(scope, tp)
            if tp != prev_tp:
                errors.append(
                    f"{ev.track}: shard count changed mid-run "
                    f"({prev_tp} -> {tp} at t={ev.t0:.6f}) — pages are "
                    "head-sharded at bind time, a tp change would "
                    "orphan every shard's pool slice")
            if float(a.get("collective_s", 0.0)) < 0:
                errors.append(f"{ev.track}: negative collective_s on "
                              f"engine.shard_step at t={ev.t0:.6f}")
        elif ev.name == ROUTE_XFER:
            if a.get("link") not in ("dcn", "ici", "local"):
                errors.append(
                    f"{ev.track}: route.xfer with unknown link "
                    f"{a.get('link')!r} at t={ev.t0:.6f}")
            if float(a.get("in_s", 0.0)) < 0 or float(a.get("out_s",
                                                            0.0)) < 0:
                errors.append(f"{ev.track}: route.xfer with negative "
                              f"transfer time at t={ev.t0:.6f}")
        # -- speculation commit discipline -------------------------------
        elif ev.name == SPEC_DRAFT:
            if ev.track in spec_pending:
                errors.append(
                    f"{ev.track}: spec.draft at t={ev.t0:.6f} while the "
                    "previous round is uncommitted (missing spec.accept)")
            spec_pending[ev.track] = int(a.get("drafted", 0))
        elif ev.name == SPEC_ACCEPT:
            drafted = spec_pending.pop(ev.track, None)
            accepted = int(a.get("accepted", 0))
            if drafted is None:
                errors.append(f"{ev.track}: spec.accept at t={ev.t0:.6f} "
                              "without a pending spec.draft "
                              "(double commit?)")
            elif not 0 <= accepted <= drafted:
                errors.append(
                    f"{ev.track}: spec round committed {accepted} draft "
                    f"tokens but only {drafted} were drafted "
                    f"(t={ev.t0:.6f})")
        # -- request lifecycle -------------------------------------------
        elif ev.name == REQ_ADMIT:
            rid = a.get("rid")
            admits[rid] = admits.get(rid, 0) + 1
        elif ev.name == REQ_REQUEUE:
            rid = a.get("rid")
            requeues[rid] = requeues.get(rid, 0) + 1
        elif ev.name == ROUTE_HEDGE:
            rid = a.get("rid")
            hedges[rid] = hedges.get(rid, 0) + 1
        elif ev.name in (REQ_FINISH, REQ_DROP, REQ_CANCEL):
            rid = a.get("rid")
            kind = {REQ_FINISH: "finish", REQ_DROP: "drop",
                    REQ_CANCEL: "cancel"}[ev.name]
            terminals.setdefault(rid, []).append(kind)
            if kind == "finish" and rid not in admits:
                errors.append(f"request {rid}: finished without admission")

    # per-rid attempt accounting (deferred to the end: a requeue and the
    # re-admission it licenses may share a timestamp, so event order
    # within the fault boundary is not load-bearing)
    for rid in admits:
        allowed = 1 + requeues.get(rid, 0) + hedges.get(rid, 0)
        if admits[rid] > allowed:
            if allowed == 1:
                errors.append(f"request {rid}: admitted twice")
            else:
                errors.append(
                    f"request {rid}: admitted {admits[rid]} times with "
                    f"only {allowed - 1} requeue/hedge licenses")
    for rid, kinds in terminals.items():
        if len(kinds) > 1 + hedges.get(rid, 0):
            errors.append(f"request {rid}: retired twice "
                          f"({kinds[0]} then {kinds[1]})")
    open_rids = set(admits) - set(terminals)
    for rid in sorted(open_rids, key=repr):
        errors.append(f"request {rid}: admitted but never retired")
    for track in sorted(spec_pending):
        errors.append(f"{track}: spec.draft never committed "
                      "(dangling round at end of trace)")
    # per-shard page conservation: a tp-way engine's shards each hold
    # 1/tp of every page's kv-heads, so the page ledger replayed above
    # covers all shards at once *iff* the decode steps ran at the tp the
    # pool was bound with — a mismatch means some shard's slice was
    # allocated under different geometry than it decoded with
    for scope, tp in sorted(shard_tp.items()):
        bound = pool_tp.get(scope)
        if bound is not None and bound != tp:
            errors.append(
                f"{scope or '<root>'}: engine.shard_step tp={tp} but the "
                f"pool was bound with tp={bound} (per-shard page "
                "conservation broken)")
    if not open_rids:                     # quiescent: no request live
        for pool in pools.values():
            if pool.lane_holdings():
                errors.append(
                    f"{pool.track}: {pool.lane_holdings()} lane-held page "
                    "references after every admitted request retired "
                    "(leak; prefix-cache holdings are exempt)")
            pool.conservation(errors)
    return errors


def check_file(path: str) -> List[str]:
    """Audit an exported Chrome trace JSON file."""
    from repro.obs.export import from_chrome
    return check(from_chrome(path))


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="replay a Chrome trace and assert serving invariants")
    ap.add_argument("traces", nargs="+", help="exported trace JSON file(s)")
    args = ap.parse_args(argv)
    failed = False
    for path in args.traces:
        errors = check_file(path)
        if errors:
            failed = True
            for e in errors:
                print(f"TRACE INVARIANT [{path}]: {e}", file=sys.stderr)
        else:
            print(f"{path}: all trace invariants hold")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
