"""Trace exporters: Chrome ``trace_event`` JSON and modeled-vs-wall drift.

:func:`to_chrome` maps the tracer's typed events onto the Chrome trace
format (the JSON flavor Perfetto and ``chrome://tracing`` both load):
spans become complete ``"X"`` events, instants ``"i"``, counters ``"C"``.
Tracks split at their first ``"/"``: the head names the *process* (one
per engine / router), the tail the *thread* (lane, pool group, queue), so
a fleet trace opens as one process row per engine with its lanes and
pools as named threads underneath.  Analytic-clock seconds become
microseconds — Perfetto's native unit — and every typed arg rides along
in ``args``, which is what lets :mod:`repro.obs.check_trace` audit an
exported file as faithfully as the in-memory stream
(:func:`from_chrome` is the exact inverse).

Wall-clock seconds at emission are preserved as ``args._wall_s``;
:func:`drift_report` folds them into per-event-name (modeled, wall)
totals — the measurable modeled-vs-real gap the ROADMAP's calibration
loop (``core/calibrate.py``) needs as input.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.obs.trace import Event

_US = 1e6                       # analytic seconds -> chrome microseconds
_PH = {"span": "X", "instant": "i", "counter": "C"}
_KIND = {v: k for k, v in _PH.items()}


def _split_track(track: str) -> Tuple[str, str]:
    """``"engine0/lane2"`` -> process ``"engine0"``, thread ``"lane2"``."""
    if not track:
        return "main", "main"
    head, _, tail = track.partition("/")
    return head, tail or "main"


def to_chrome(events: Sequence[Event]) -> Dict:
    """The ``{"traceEvents": [...]}`` dict for one event stream."""
    pids: Dict[str, int] = {}
    tids: Dict[Tuple[str, str], int] = {}
    out: List[Dict] = []
    meta: List[Dict] = []
    for ev in events:
        pname, tname = _split_track(ev.track)
        pid = pids.get(pname)
        if pid is None:
            pid = pids[pname] = len(pids) + 1
            meta.append({"ph": "M", "name": "process_name", "pid": pid,
                         "tid": 0, "args": {"name": pname}})
        tid = tids.get((pname, tname))
        if tid is None:
            tid = tids[(pname, tname)] = \
                sum(p == pname for p, _ in tids) + 1
            meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                         "tid": tid, "args": {"name": tname}})
        args = dict(ev.args or {})
        args["_wall_s"] = ev.wall
        rec = {"name": ev.name, "ph": _PH[ev.kind], "ts": ev.t0 * _US,
               "pid": pid, "tid": tid, "cat": "serving", "args": args}
        if ev.kind == "span":
            rec["dur"] = (ev.t1 - ev.t0) * _US
        elif ev.kind == "instant":
            rec["s"] = "t"
        out.append(rec)
    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}


def write_chrome(events: Sequence[Event], path: str) -> None:
    with open(path, "w") as f:
        json.dump(to_chrome(events), f)


def from_chrome(doc: Union[Dict, str]) -> List[Event]:
    """Inverse of :func:`to_chrome`: rebuild the typed event stream from a
    Chrome trace dict or a path to one.  Metadata events are dropped; the
    track is reassembled from the process/thread names."""
    if isinstance(doc, str):
        with open(doc) as f:
            doc = json.load(f)
    pname: Dict[int, str] = {}
    tname: Dict[Tuple[int, int], str] = {}
    events: List[Event] = []
    for rec in doc["traceEvents"]:
        if rec["ph"] == "M":
            if rec["name"] == "process_name":
                pname[rec["pid"]] = rec["args"]["name"]
            elif rec["name"] == "thread_name":
                tname[(rec["pid"], rec["tid"])] = rec["args"]["name"]
            continue
        kind = _KIND.get(rec["ph"])
        if kind is None:
            continue
        p = pname.get(rec["pid"], "main")
        t = tname.get((rec["pid"], rec["tid"]), "main")
        track = "" if (p, t) == ("main", "main") else \
            (p if t == "main" else f"{p}/{t}")
        args = dict(rec.get("args") or {})
        wall = args.pop("_wall_s", 0.0)
        t0 = rec["ts"] / _US
        t1 = t0 + rec["dur"] / _US if kind == "span" else None
        events.append(Event(kind, rec["name"], t0, t1, track,
                            args or None, wall))
    return events


def drift_report(events: Sequence[Event],
                 names: Optional[Sequence[str]] = None) -> Dict[str, Dict]:
    """Aggregate modeled vs. measured time per span name.

    For span events carrying a ``wall_s`` arg (the real-compute engines
    time their jit'd steps), returns per name ``{n, modeled_s, wall_s,
    ratio}`` — ``ratio`` is wall/modeled, the correction factor a
    calibration pass would fit.  Spans without ``wall_s`` aggregate
    modeled time only (``wall_s``/``ratio`` = None).  ``ratio`` is also
    None when the modeled time sums to zero (an instantaneous span — a
    zero-token chunk, a clock stub): there is no finite correction
    factor, and emitting ``inf`` would poison any mean over ratios."""
    agg: Dict[str, Dict] = {}
    for ev in events:
        if ev.kind != "span" or (names is not None and ev.name not in names):
            continue
        a = agg.setdefault(ev.name, {"n": 0, "modeled_s": 0.0,
                                     "wall_s": 0.0, "measured": 0})
        a["n"] += 1
        a["modeled_s"] += ev.dur
        w = (ev.args or {}).get("wall_s")
        if w is not None:
            a["wall_s"] += w
            a["measured"] += 1
    for a in agg.values():
        if a["measured"]:
            a["ratio"] = a["wall_s"] / a["modeled_s"] if a["modeled_s"] \
                else None
        else:
            a["wall_s"] = None
            a["ratio"] = None
        del a["measured"]
    return agg
