"""Streaming metrics over the trace stream: reservoir percentiles -> SLOs.

:class:`MetricsSink` is a tracer sink (``Tracer(sinks=[sink])`` or
``tracer.add_sink(sink)``) that folds retirement events into bounded-size
state as they are emitted — no post-hoc pass over retired request lists,
so it scales to streams far longer than memory would allow if every
request were kept.  Latency, TTFT, and inter-token percentiles come from
seeded reservoir samples (:class:`Reservoir`, algorithm R: a uniform
k-sample over an unbounded stream); counts, goodput, and the slack
attribution (queue / prefill / decode seconds) are exact running sums.

``report()`` produces the same extended :class:`~repro.serving.metrics.
SLOReport` that :func:`repro.serving.metrics.summarize` builds from
retired request lists — one report type, two feeders — so benchmark
tables and live traced runs read identically.  Goodput needs realized
rewards, which only the router knows (``ROUTE_RETIRE``); engine-only
traces report goodput 0 and everything else fully.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.obs.trace import Event, REQ_ARRIVE, REQ_DROP, REQ_FINISH, \
    ROUTE_RETIRE
from repro.serving.metrics import SLOReport


class Reservoir:
    """Seeded uniform k-sample over a stream (Vitter's algorithm R)."""

    def __init__(self, k: int = 1024, seed: int = 0):
        assert k >= 1, k
        self.k = k
        self.n = 0                       # stream length seen
        self.sample: List[float] = []
        self._rng = np.random.default_rng(seed)

    def add(self, x: float) -> None:
        self.n += 1
        if len(self.sample) < self.k:
            self.sample.append(float(x))
            return
        j = int(self._rng.integers(0, self.n))
        if j < self.k:
            self.sample[j] = float(x)

    def percentile(self, q: float) -> float:
        if not self.sample:
            return float("nan")
        return float(np.percentile(np.asarray(self.sample), q))


class _ClassState:
    def __init__(self, k: int, seed: int):
        self.offered = 0
        self.served = 0
        self.dropped = 0
        self.degraded = 0
        self.hits = 0
        self.goodput = 0.0
        self.lat = Reservoir(k, seed)
        self.ttft = Reservoir(k, seed + 1)
        self.itl = Reservoir(k, seed + 2)
        self.queue_s = 0.0
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.attributed = 0              # finishes carrying the attribution

    def report(self, horizon_s: float) -> SLOReport:
        n_attr = max(1, self.attributed)
        return SLOReport(
            n=self.offered, served=self.served, dropped=self.dropped,
            degraded=self.degraded,
            hit_rate=self.hits / self.offered if self.offered else 0.0,
            p50_s=self.lat.percentile(50), p99_s=self.lat.percentile(99),
            goodput=self.goodput,
            goodput_rate=self.goodput / horizon_s if horizon_s else 0.0,
            ttft_p50_s=self.ttft.percentile(50),
            ttft_p99_s=self.ttft.percentile(99),
            itl_p50_s=self.itl.percentile(50),
            itl_p99_s=self.itl.percentile(99),
            queue_s=self.queue_s / n_attr if self.attributed
            else float("nan"),
            prefill_s=self.prefill_s / n_attr if self.attributed
            else float("nan"),
            decode_s=self.decode_s / n_attr if self.attributed
            else float("nan"))


class MetricsSink:
    """Consume ``REQ_ARRIVE / REQ_FINISH / REQ_DROP / ROUTE_RETIRE``
    events into per-class streaming SLO state."""

    def __init__(self, *, reservoir_k: int = 1024, seed: int = 0):
        self.k = reservoir_k
        self.seed = seed
        self._cls: Dict[str, _ClassState] = {}

    def _state(self, cls: Optional[str]) -> _ClassState:
        name = cls or "default"
        st = self._cls.get(name)
        if st is None:
            st = self._cls[name] = _ClassState(
                self.k, self.seed + 10007 * len(self._cls))
        return st

    def __call__(self, ev: Event) -> None:
        if ev.kind != "instant":
            return
        args = ev.args or {}
        if ev.name == REQ_ARRIVE:
            self._state(args.get("cls")).offered += 1
        elif ev.name == REQ_DROP:
            self._state(args.get("cls")).dropped += 1
        elif ev.name == REQ_FINISH:
            st = self._state(args.get("cls"))
            st.served += 1
            st.hits += bool(args.get("met_deadline"))
            st.degraded += bool(args.get("degraded"))
            if args.get("latency_s") is not None:
                st.lat.add(args["latency_s"])
            if args.get("ttft_s") is not None:
                st.ttft.add(args["ttft_s"])
            if args.get("itl_s") is not None:
                st.itl.add(args["itl_s"])
            if args.get("queue_s") is not None:
                st.queue_s += args["queue_s"]
                st.prefill_s += args.get("prefill_s") or 0.0
                st.decode_s += args.get("decode_s") or 0.0
                st.attributed += 1
        elif ev.name == ROUTE_RETIRE:
            self._state(args.get("cls")).goodput += args.get("reward") or 0.0

    def report(self, horizon_s: float = 1.0) -> SLOReport:
        """The fleet-wide extended SLO report, with ``per_class`` splits
        when more than one traffic class was seen."""
        total = _ClassState(self.k, self.seed + 3)
        for st in self._cls.values():
            total.offered += st.offered
            total.served += st.served
            total.dropped += st.dropped
            total.degraded += st.degraded
            total.hits += st.hits
            total.goodput += st.goodput
            total.queue_s += st.queue_s
            total.prefill_s += st.prefill_s
            total.decode_s += st.decode_s
            total.attributed += st.attributed
            for res, sub in ((total.lat, st.lat), (total.ttft, st.ttft),
                             (total.itl, st.itl)):
                for x in sub.sample:
                    res.add(x)
        rep = total.report(horizon_s)
        if len(self._cls) > 1:
            rep.per_class = {nm: st.report(horizon_s)
                             for nm, st in sorted(self._cls.items())}
        return rep
