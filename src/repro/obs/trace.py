"""Clock-native tracing: typed span/instant/counter events on the analytic clock.

Every serving engine in this repo advances the same ``core.latency``
analytic-clock seconds; the tracer is denominated in that clock too, so a
trace of a simulated run *is* the run — queue waits, prefill charges,
decode steps, and page lifecycle all land on one comparable timeline.
Host wall-clock is recorded alongside each event (``Event.wall``), so the
modeled-vs-real gap is itself a measurable signal
(:func:`repro.obs.export.drift_report` aggregates it for
``core/calibrate.py``-style fitting).

Design constraints, in order:

1. **Zero overhead when disabled.**  Engines hold
   ``self.tr = tracer or NULL`` and guard every emission site with
   ``if self.tr:`` — :class:`NullTracer` is falsy, so the disabled path
   costs one truthiness check and never builds an args dict.  The bench
   regression gate holds the committed tables to this: the default
   (untraced) benchmark runs must regenerate bit-identically.
2. **Typed events.**  Emission sites use the ``REQ_* / ENGINE_* / PAGE_*``
   name constants below; :mod:`repro.obs.check_trace` replays them and
   asserts the serving stack's conservation laws, so names and required
   args are a contract, not a convention (see each constant's comment).
3. **Streaming.**  Sinks (e.g. :class:`repro.obs.sink.MetricsSink`)
   observe every event at emission; the in-memory list exists for the
   exporters and tests, not as the only consumption path.

Tracks are ``"/"``-separated paths (``engine0/lane2``, ``pool/local``);
:meth:`Tracer.scope` returns a facade that prefixes tracks, which is how
one tracer observes a whole fleet with per-engine tracks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

# ---------------------------------------------------------------------------
# Typed event names.  Args listed per name are the contract check_trace and
# the metrics sink rely on; emitters may add more.
# ---------------------------------------------------------------------------

#: instant — a request entered the system.  args: rid, cls, prompt_len,
#: max_new, deadline_abs
REQ_ARRIVE = "req.arrive"
#: span arrive->admit — time spent waiting for a lane/pages.  args: rid
REQ_QUEUE = "req.queue"
#: instant — admitted into a lane.  args: rid, n_tok (granted decode
#: budget; may already be degraded below max_new)
REQ_ADMIT = "req.admit"
#: span — one monolithic prefill charge.  args: rid, tokens
REQ_PREFILL = "req.prefill"
#: span — one chunk of a chunked prefill.  args: rid, chunk, absorbed
REQ_PREFILL_CHUNK = "req.prefill.chunk"
#: instant — first output token exists.  args: rid, ttft_s
REQ_FIRST_TOKEN = "req.first_token"
#: instant — one decode token landed.  args: rid
REQ_TOKEN = "req.token"
#: instant — budget trimmed by the degrade policy.  args: rid, from_tok,
#: to_tok
REQ_DEGRADE = "req.degrade"
#: instant — retired successfully.  args: rid, cls, latency_s, tokens,
#: met_deadline, plus the slack attribution queue_s/prefill_s/decode_s
#: and ttft_s/itl_s when known
REQ_FINISH = "req.finish"
#: instant — retired by the drop policy (possibly before admission).
#: args: rid, cls
REQ_DROP = "req.drop"
#: instant — retired by barge-in cancellation (mid-decode, mid-prefill,
#: or while still queued).  A third retirement kind next to finish/drop:
#: check_trace requires exactly one of the three per request.  args: rid,
#: cls, tokens (decode tokens emitted before the cancel), admitted
REQ_CANCEL = "req.cancel"

#: span — one batched decode step.  args: n_active, context, lanes
#: (rids), wall_s (measured host seconds for the real-compute engines)
ENGINE_STEP = "engine.step"
#: instant — a speculative round drafted k tokens per decoding lane.
#: args: k, lanes (rids), drafted (k * len(lanes))
SPEC_DRAFT = "spec.draft"
#: instant — the verifier scored a drafted round in one chunk call.
#: args: lanes (rids), chunk (k + 1)
SPEC_VERIFY = "spec.verify"
#: instant — a drafted round committed.  args: lanes (rids), accepted
#: (draft tokens kept, summed over lanes — at most ``drafted`` of the
#: round's SPEC_DRAFT, the invariant check_trace replays), emitted
#: (tokens written including the verifier's correction/bonus).  Exactly
#: one SPEC_ACCEPT follows each SPEC_DRAFT on its track (exactly-once
#: commit).
SPEC_ACCEPT = "spec.accept"
#: span — one padded wave of the wave scheduler.  args: n, rids
WAVE_STEP = "wave.step"
#: instant — router chose an engine.  args: rid, cls, engine_idx
ROUTE_DISPATCH = "route.dispatch"
#: instant — router saw the retirement + realized reward.  args: rid,
#: cls, engine_idx, reward
ROUTE_RETIRE = "route.retire"
#: instant — the router duplicated a still-queued request to a second
#: engine after the hedge delay elapsed (straggler insurance; the losing
#: attempt is torn down via barge-in cancellation and does not retire the
#: request).  args: rid, cls, from_engine, to_engine, waited_s.
#: track: "router"
ROUTE_HEDGE = "route.hedge"
#: instant — the router priced and applied an interconnect hop for a
#: dispatch: prompt bytes ingress→engine over DCN (or free when
#: co-located), response bytes back.  args: rid, cls, engine_idx, link
#: ("dcn" | "ici" | "local"), in_s (inbound prompt transfer), out_s
#: (outbound response transfer), aware (True = the hop entered the
#: routing projection; the physics applies either way).  track: "router"
ROUTE_XFER = "route.xfer"
#: span — one batched decode step of a tensor-parallel sharded engine,
#: emitted alongside ENGINE_STEP.  args: n_active, tp (model-axis size,
#: constant for the engine's lifetime and >= 2), link ("ici" | "dcn"),
#: collective_s (modeled per-step all-reduce tax).  check_trace audits
#: that tp never changes mid-run and matches the pool config's tp — the
#: per-shard page-conservation guarantee: every shard holds 1/tp of each
#: page's kv-heads, so the *page* ledger is shared and the existing pool
#: replay covers all shards at once.  track: engine-scoped
ENGINE_SHARD_STEP = "engine.shard_step"

#: instant — the fault injector fired one scheduled fault on an engine.
#: args: engine_idx, fault ("crash" | "stall" | "slowdown" |
#: "page_pressure"), plus per-kind fields (duration_s, factor, pages).
#: track: "faults"
FAULT_INJECT = "fault.inject"
#: instant — an engine was declared unhealthy (crashed, or its circuit
#: breaker opened on a detected stall); routing excludes it until
#: ENGINE_UP.  args: engine_idx, reason ("crash" | "stall"), in_flight
#: (requests reclaimed).  track: "router"
ENGINE_DOWN = "engine.down"
#: instant — a down engine recovered (crash window elapsed, or a
#: circuit-breaker probe succeeded) and rejoined the candidate set.
#: args: engine_idx, down_s.  track: "router"
ENGINE_UP = "engine.up"
#: instant — a request reclaimed from a failed engine re-entered the
#: router's queue for another attempt.  check_trace treats this as the
#: license for a later second REQ_ADMIT of the same rid: admission stays
#: exactly-once *per attempt* and final retirement stays exactly-once
#: per request.  args: rid, cls, from_engine, attempt (1-based count of
#: completed attempts), tokens_done.  track: "router"
REQ_REQUEUE = "req.requeue"

#: instant at bind time — pool geometry the invariant checker needs.
#: args: groups ({name: n_pages}), page_size, slots, tp (model-axis
#: shards the pool's kv-heads split over; 1/absent = unsharded).
#: track: "pool"
POOL_CONFIG = "pool.config"
#: instant — a page left the free list into *exclusive* ownership
#: (refcount 1).  args: group, page, slot.  track: "pool"
PAGE_ALLOC = "page.alloc"
#: instant — one reference to a page dropped.  The page returns to the
#: free list only when this was the last reference (args carry ``refs``,
#: the count remaining after the drop; 0 means the page is free again).
#: args: group, page, slot (CACHE_SLOT = the prefix cache's holdings),
#: refs, mid_flight (True = freed by the sliding window while the request
#: is still decoding).  track: "pool"
PAGE_FREE = "page.free"
#: instant — a slot's reservation set (admission) or cleared (retire,
#: pages=0).  args: group, slot, pages.  track: "pool"
PAGE_RESERVE = "page.reserve"
#: instant — a live page gained a reference without leaving anyone's
#: hands: a lane adopted a cached prefix page, or the prefix cache pinned
#: a lane's prompt page.  args: group, page, slot (the *new* holder;
#: CACHE_SLOT for the prefix cache), refs (count after the share).
#: track: "pool"
PAGE_SHARE = "page.share"
#: instant — copy-on-write: a lane about to write a shared page copied it
#: into a fresh exclusive page first (emitted alongside the PAGE_ALLOC of
#: ``to`` and the PAGE_FREE of the reference on ``from``).  args: group,
#: slot, from_page, to_page.  track: "pool"
PAGE_COW = "page.cow"

#: instant — prefix-cache lookup outcome at admission.  args: rid,
#: hit (bool), tokens (prefix length adopted; 0 on miss).  track: "pool"
PREFIX_LOOKUP = "prefix.lookup"
#: instant — a prompt prefix was pinned into the prefix cache.  args:
#: tokens, pages (references taken).  track: "pool"
PREFIX_INSERT = "prefix.insert"
#: instant — an entry was evicted (LRU / pressure).  args: tokens, pages
#: (references released).  track: "pool"
PREFIX_EVICT = "prefix.evict"

#: counters (gauges): one ``value`` float each
CTR_LANES = "lanes.active"
CTR_QUEUE = "queue.depth"
CTR_FREE_PAGES = "pool.free_pages"
CTR_UTIL = "pool.utilization"


@dataclasses.dataclass
class Event:
    """One trace event.  ``t0``/``t1`` are analytic-clock seconds (``t1``
    is None for instants/counters); ``wall`` is host wall-clock seconds at
    emission."""
    kind: str                 # "span" | "instant" | "counter"
    name: str
    t0: float
    t1: Optional[float]
    track: str
    args: Optional[Dict]
    wall: float

    @property
    def dur(self) -> float:
        return 0.0 if self.t1 is None else self.t1 - self.t0


class Tracer:
    """Collects :class:`Event` s and fans them out to sinks."""

    enabled = True

    def __init__(self, *, wall_clock: Callable[[], float] = time.perf_counter,
                 sinks: Sequence[Callable[[Event], None]] = ()):
        self.events: List[Event] = []
        self.sinks: List[Callable[[Event], None]] = list(sinks)
        self._wall = wall_clock

    def __bool__(self) -> bool:          # `if tracer:` guards the hot paths
        return True

    def add_sink(self, sink: Callable[[Event], None]) -> None:
        self.sinks.append(sink)

    def _emit(self, ev: Event) -> None:
        self.events.append(ev)
        for s in self.sinks:
            s(ev)

    def instant(self, name: str, t: float, track: str = "", **args) -> None:
        self._emit(Event("instant", name, t, None, track, args or None,
                         self._wall()))

    def span(self, name: str, t0: float, t1: float, track: str = "",
             **args) -> None:
        self._emit(Event("span", name, t0, t1, track, args or None,
                         self._wall()))

    def counter(self, name: str, t: float, value: float,
                track: str = "") -> None:
        self._emit(Event("counter", name, t, None, track,
                         {"value": float(value)}, self._wall()))

    def scope(self, prefix: str) -> "Tracer":
        """A facade emitting into this tracer with ``prefix/`` prepended to
        every track — per-engine tracks over one shared event stream."""
        return _ScopedTracer(self, prefix)


class _ScopedTracer(Tracer):
    """Track-prefixing view onto a parent tracer (shares its event list)."""

    def __init__(self, parent: Tracer, prefix: str):
        self._parent = parent
        self._prefix = prefix.rstrip("/")
        self.events = parent.events          # shared stream

    def _emit(self, ev: Event) -> None:      # pragma: no cover - via helpers
        self._parent._emit(ev)

    def _track(self, track: str) -> str:
        return f"{self._prefix}/{track}" if track else self._prefix

    def instant(self, name, t, track="", **args):
        self._parent.instant(name, t, self._track(track), **args)

    def span(self, name, t0, t1, track="", **args):
        self._parent.span(name, t0, t1, self._track(track), **args)

    def counter(self, name, t, value, track=""):
        self._parent.counter(name, t, value, self._track(track))

    def scope(self, prefix: str) -> "Tracer":
        return _ScopedTracer(self._parent, self._track(prefix))


class NullTracer:
    """The do-nothing tracer.  Falsy, so ``if self.tr:`` skips every
    emission site without building args; the methods exist anyway so an
    unguarded call is still safe."""

    enabled = False

    def __bool__(self) -> bool:
        return False

    def instant(self, *a, **k) -> None:
        pass

    def span(self, *a, **k) -> None:
        pass

    def counter(self, *a, **k) -> None:
        pass

    def add_sink(self, *a, **k) -> None:
        pass

    def scope(self, prefix: str) -> "NullTracer":
        return self


#: the shared disabled tracer — engines default to this
NULL = NullTracer()
