"""Serving subsystem: from single-engine waves to a deadline-aware fleet.

Two serving paths share this package:

* **Real-compute path** — :mod:`engine` wraps prefill/decode of an actual
  sim-scale model under jit with a swappable FPX precision policy;
  :mod:`scheduler` batches queued requests into padded waves on top of it.
  Latency is *attributed* from the analytic TPU model, tokens are real.

* **Traffic-scale path** — the fleet simulator.  Its contract, end to end:

  - **Clock.**  One global notion of simulated time, denominated in the
    analytic roofline model's seconds (``core.latency``).  Traffic
    timestamps and engine-side prefill/decode costs are drawn from the
    same model, so arrival pressure and service capacity are directly
    comparable numbers.
  - **Traffic** (:mod:`traffic`) draws seeded, replayable request streams:
    per-class arrival processes (Poisson / bursty MMPP), deadline
    distributions, prompt/decode shapes, reward weights.
  - **Continuous batching** (:mod:`continuous`) gives each engine
    operating point ``slots`` decode lanes with earliest-deadline-first
    admission between decode steps, per-request modeled latency, and a
    drop/degrade admission policy for requests that cannot meet their
    deadline.
  - **Fleet** (:mod:`fleet`) routes each request across a pool of
    (model, gamma) operating points via ``fpx.select_for_slack`` —
    best quality whose service time fits the request's remaining
    deadline slack — and feeds realized on-time reward back into a
    per-traffic-class ``fpx.OnlineSelector``.
  - **Metrics** (:mod:`metrics`) reduces retired requests to SLO numbers:
    deadline hit-rate, p50/p99 modeled latency, and goodput (reward from
    on-time actions only).

The two paths meet at the operating point: the same ``fpx.Candidate``
that parameterizes a simulated engine can be applied to a live
``ServingEngine`` via ``set_policy``.  Fusing them fully (admitting real
prompts mid-flight) needs KV-cache paging — tracked in ROADMAP.
"""
from repro.serving.continuous import ContinuousBatcher, LatencyProfile
from repro.serving.engine import GenerationResult, ServingEngine
from repro.serving.fleet import FleetRouter, pool_candidates
from repro.serving.metrics import SLOReport, summarize
from repro.serving.scheduler import Request, Scheduler
from repro.serving.traffic import (SCENARIOS, SimRequest, TrafficClass,
                                   generate, scenario)

__all__ = [
    "ContinuousBatcher", "LatencyProfile", "GenerationResult",
    "ServingEngine", "FleetRouter", "pool_candidates", "SLOReport",
    "summarize", "Request", "Scheduler", "SCENARIOS", "SimRequest",
    "TrafficClass", "generate", "scenario",
]
