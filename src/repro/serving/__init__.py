"""Serving subsystem: from single-engine waves to a deadline-aware fleet.

Three serving paths share this package, all speaking one request contract
(``Request`` for real prompts, ``SimRequest`` for shape-only traffic; both
expose ``rid / prompt_len / max_new / t_arrive / deadline_abs`` plus the
lifecycle fields the engines fill in):

* **Wave path** — :mod:`engine` wraps prefill/decode of an actual
  sim-scale model under jit with a swappable FPX precision policy;
  :mod:`scheduler` batches queued requests into padded waves on top of it.
  Latency is *attributed* from the analytic TPU model, tokens are real.
  Kept as the reference implementation (and the equivalence oracle for the
  paged path); the barrier between waves is its defining limitation.

* **Paged continuous path (the fused path)** — :mod:`kv_cache` breaks the
  dense decode cache into fixed-size pages in shared per-layer-group
  pools with per-request block tables; :mod:`paged_engine`'s
  ``ContinuousEngine``
  admits EDF-ordered requests into free decode lanes *between real decode
  steps*, frees pages the step a request retires, and reuses the analytic
  batcher's drop/degrade admission math on the same ``core.latency``
  clock.  Attention runs through ``ops.paged_attend``
  (``models.attention`` paged branch): the fused paged flash-attention
  kernel (``kernels.paged_attention``) streams K/V pages straight from
  the pool through an online softmax when ``use_pallas`` — the gathered
  context is never materialized — with a jnp gather+SDPA fallback
  otherwise; profiles price the two implementations via
  ``LatencyProfile(attn_impl=...)``.  Greedy outputs are token-identical
  to the wave path — same tokens, no barrier.

  **Hybrid sliding-window stacks** (every dense/moe attention layout:
  uniform, starcoder2-class uniform-windowed, gemma3-class local:global).
  ``transformer.paged_layer_groups`` partitions the stack into attention
  layer groups; each group owns its own pools, free list, and block
  tables in :class:`~repro.serving.kv_cache.PagedKVCache`.  Sliding-
  window groups retain at most ``ceil(window/page_size) + 1`` live pages
  per lane — the paged equivalent of the wave path's contiguous ring
  buffers — allocating pages lazily as the write position advances and
  freeing out-of-window pages back to the pool *mid-flight* (retired
  table entries park on the reserved dummy page; the kernels mask
  validity to ``pos - window < slot <= pos`` per lane, so local layers
  attend over only their retained pages).  Admission sizes page demand
  per group — window-bounded for local groups — so long-decode requests
  on windowed stacks cost the pool a constant handful of pages, and
  ``core.latency`` prices local-layer attention at ``min(context,
  window)`` (``attn_layer_groups``), so admission projections, the
  analytic batcher, and the fleet router all see the cheaper steps.
  Token identity with the contiguous wave path is enforced for every
  servable config x page size x chunk size x kernel implementation by
  the cross-path differential harness (tests/test_hybrid_paged.py);
  ``benchmarks/table_hybrid.py`` measures the windowed-vs-dense KV
  traffic and step time plus the fleet goodput a gemma3-class engine
  earns in the pool.

  **Chunk-interleave contract** (``prefill_chunk=N``, a multiple of the
  page size; mirrored by the analytic batcher): an admitted prompt is
  absorbed N tokens at a time — ``transformer.prefill_chunk`` attends
  over the request's already-written pages plus the chunk and scatters
  the chunk's K/V into its block-table pages (``kernels.paged_scatter``)
  — with one decode step for the already-decoding lanes between chunks,
  so a long prompt never head-of-line-blocks the decode lanes.  Each
  chunk is charged ``prefill_s(N, context=absorbed)`` on the shared clock
  (chunking re-pays the weight read and each later chunk attends over the
  pages already written — both raise total prefill cost; the win is tail
  latency, not throughput); admission projections (``projected_finish`` /
  ``degraded_budget``) take the same ``prefill_chunk`` so drop/degrade
  decisions price the interleave in, and the policy is re-applied when
  the prompt completes because co-resident lanes' real decode charges
  land during the chunked prefill.  Greedy outputs are token-identical
  to the monolithic path for any chunk size.

  **Jit'd sampling layer** (:mod:`sampler`).  Token selection is a
  first-class policy, not engine code: every path — wave prefill and
  decode, paged prefill/chunk/decode, speculative draft and
  accept/reject — ends its jit'd step in ``sampler.sample(policy,
  logits, rids, positions)``, so only ``(slots,)`` int32 token ids ever
  cross to host.  :class:`~repro.serving.sampler.SamplerPolicy`
  (temperature, top-k via ``jax.lax.top_k``, seed; ``temp=0`` is exact
  argmax greedy) draws from lane-keyed counter-style PRNG streams —
  ``fold_in(fold_in(fold_in(key(seed), stream), rid), position)`` — so a
  request's draws are independent of its batch slot and engine, and any
  run is replayable per request.

  **Fast-draft / slow-verify speculative decoding** (fused path only).
  ``ContinuousEngine(speculate=SpecPoint(k, ...))`` turns a decode step
  into a round: the engine self-drafts ``k`` tokens cheaply (same
  weights at ``SpecPoint.draft_bits``, chained paged decode steps), the
  full-precision verifier scores all ``k + 1`` positions in one fused
  chunk call (``transformer.verify_chunk``; its unaligned scatter
  overwrites the draft's K/V, so the cache holds verifier state), and
  the jit'd ``sampler.spec_accept`` keeps the leading
  verifier-consistent run — greedy output is token-identical to dense
  decode for any draft depth and accept pattern (cross-path harness,
  both kernel modes), temperature output preserves the verifier's
  distribution.  Speculation is an FPX axis: ``core.latency.
  speculate_s`` prices a round, admission reserves ``k`` extra cache
  positions and sizes page demand for the verify chunk, and
  ``spec_round_fits`` collapses rounds to dense steps whenever the
  tightest co-resident deadline cannot absorb one — win fast under
  pressure, draft deep under slack.  The analytic batcher mirrors the
  same round math, so :class:`FleetRouter`'s per-class
  ``OnlineSelector`` learns draft depth per traffic class
  (``fleet.spec_variants`` widens a pool along the axis;
  ``benchmarks/table_spec.py`` shows the learned arm beating
  always-dense and every fixed-k deployment on goodput).

  **Sessions, prefix reuse, and TTFT-first serving.**  KV pages are
  refcounted: a holder's claim on a page is *owned* (exclusive, counts
  against its admission reservation) or *shared* (read-only reference),
  pages return to the free list only at refcount zero, and writes into
  a shared page copy-on-write first (the boundary page a tail write can
  need is reserved at admission).  On that substrate a
  :class:`~repro.serving.kv_cache.PrefixCache` (token-hash-keyed,
  byte-verified, LRU-bounded; full-attention stacks only) lets a
  completed prefill publish its pages and later requests adopt the
  longest cached strict prefix — repeated system prompts and a
  session's own earlier turns become near-zero-cost prefills, charged
  ``prefill_s(P - l, context=l)`` on the clock so admission
  projections, the analytic batcher's warm-prefix mirror, and the
  fleet router all see the win.  Session-structured traffic
  (``traffic.generate_sessions``: multi-turn conversations, think-time
  gaps, shared system prompts, streaming TTFT SLOs, seeded barge-in)
  exercises it end to end: admission drops requests whose projected
  first token misses ``ttft_deadline_s``, routing prefers engines that
  can meet it (and discounts warm-prefix service time), and a
  mid-decode cancel retires the lane at the next step boundary keeping
  the partial output while shared pages are unreferenced, not freed.
  Shared-prefix outputs are token-identical to independent prefills in
  both kernel modes (tests/test_sessions.py);
  ``benchmarks/table_sessions.py`` shows sharing cutting TTFT p50 with
  no less goodput at equal capacity.

* **Traffic-scale path** — the fleet simulator.  Its contract, end to end:

  - **Clock.**  One global notion of simulated time, denominated in the
    analytic roofline model's seconds (``core.latency``).  Traffic
    timestamps and engine-side prefill/decode costs are drawn from the
    same model, so arrival pressure and service capacity are directly
    comparable numbers.  Engines drained to a horizon advance their clock
    to it even when idle, so cross-engine backlog comparisons stay fair.
  - **Traffic** (:mod:`traffic`) draws seeded, replayable request streams:
    per-class arrival processes (Poisson / bursty MMPP), deadline
    distributions, prompt/decode shapes, reward weights.
  - **Continuous batching** (:mod:`continuous`) gives each engine
    operating point ``slots`` decode lanes with earliest-deadline-first
    admission between decode steps, per-request modeled latency, and a
    drop/degrade admission policy (shared with the paged engine via
    ``projected_finish`` / ``degraded_budget``) for requests that cannot
    meet their deadline.
  - **Fleet** (:mod:`fleet`) routes each request across a pool of
    (model, gamma) operating points via ``fpx.select_for_slack`` —
    best quality whose service time fits the request's remaining
    deadline slack — and feeds realized on-time reward back into a
    per-traffic-class ``fpx.OnlineSelector``.  The pool may be analytic
    batchers *or* live paged engines (``FleetRouter(engines=...)``): the
    router is agnostic because both speak the same interface.
  - **Metrics** (:mod:`metrics`) reduces retired requests to SLO numbers:
    deadline hit-rate, p50/p99 modeled latency, goodput (reward from
    on-time actions only), TTFT / inter-token percentiles, and the slack
    attribution (queue vs. prefill vs. decode seconds per request).

**Observability** (:mod:`repro.obs`) cuts across all three paths.  Every
engine flavor takes a ``tracer=`` — the wave :class:`Scheduler`, the
analytic :class:`ContinuousBatcher`, the live :class:`ContinuousEngine`,
and :class:`FleetRouter` (which scopes one sub-tracer per engine) — and
emits typed request-lifecycle / engine-step / page-pool events denominated
in the same ``core.latency`` analytic clock, with host wall time recorded
alongside on real-compute spans (``repro.obs.drift_report`` compares the
two).  The default is the falsy ``NullTracer``: every emission site is
behind ``if self.tr:``, so the untraced hot path does no formatting, no
allocation, and stays token- and clock-identical to a tracerless build.
Exporters turn an event stream into a Perfetto-loadable Chrome trace
(``repro.obs.write_chrome`` — one track per lane / queue / pool / engine)
and into streaming SLO reports (``repro.obs.MetricsSink`` — reservoir
percentiles feeding the same extended ``SLOReport``).  The trace is also
an audit surface: ``repro.obs.check_trace`` replays any exported trace
and proves page conservation, reservation non-negativity, per-track clock
monotonicity, exactly-once retirement of every admitted request, and
speculation commit discipline (every ``spec.draft`` committed by exactly
one ``spec.accept`` with ``accepted <= drafted`` before the next round).

The paths meet at the operating point: the same ``fpx.Candidate`` that
parameterizes a simulated engine can be applied to a live engine via its
``ExecContext`` precision policy.  ``benchmarks/table_paged.py`` measures
the fusion: wave vs. paged-continuous on identical requests — same tokens,
lower p99, higher goodput.

A narrative walkthrough of the whole system — a request's life per
path, the clock contract, the page-pool/reservation/refcount model, and
the FPX axes — lives in ``docs/architecture.md``; the benchmark index
is ``docs/benchmarks.md``.
"""
from repro.serving.continuous import (ContinuousBatcher, LatencyProfile,
                                      degraded_budget, projected_finish)
from repro.serving.engine import GenerationResult, ServingEngine
from repro.serving.fleet import FleetRouter, pool_candidates
from repro.serving.kv_cache import PagedKVCache, PrefixCache
from repro.serving.metrics import SLOReport, summarize
from repro.serving.paged_engine import ContinuousEngine
from repro.serving.sampler import GREEDY, SamplerPolicy
from repro.serving.scheduler import Request, Scheduler
from repro.serving.traffic import (SCENARIOS, SessionClass, SimRequest,
                                   TrafficClass, generate,
                                   generate_sessions, scenario,
                                   session_scenario)

__all__ = [
    "ContinuousBatcher", "ContinuousEngine", "LatencyProfile",
    "GenerationResult", "ServingEngine", "FleetRouter", "PagedKVCache",
    "PrefixCache", "pool_candidates", "SLOReport", "summarize",
    "Request", "Scheduler", "SCENARIOS", "SessionClass", "SimRequest",
    "TrafficClass", "generate", "generate_sessions", "scenario",
    "session_scenario", "degraded_budget", "projected_finish", "GREEDY",
    "SamplerPolicy",
]
