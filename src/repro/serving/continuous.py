"""Continuous batching on the analytic-latency clock.

The wave :class:`~repro.serving.scheduler.Scheduler` serves requests in
padded batches with a barrier between waves: every request inherits the
wave's makespan and a free decode slot stays idle until the whole wave
drains.  This module removes the barrier.  A :class:`ContinuousBatcher`
owns ``slots`` decode slots on one engine operating point; requests are
admitted into free slots *between decode steps* (earliest-deadline-first
among arrived requests), run for exactly their own ``max_new`` tokens, and
release the slot the step they finish — the slot is reusable immediately,
mid-flight of everyone else.

Time is simulated: the batcher advances an engine-local clock by the
roofline cost (core.latency) of each prefill and each batched decode step,
so queueing delay, batch-size effects, and per-request service time all
come out of the same analytic model the FPX controller plans with.  Real
token generation stays in engine.py; the published follow-on for marrying
the two is KV-cache paging (see ROADMAP).

Admission control: before a request enters a slot the batcher projects its
finish time.  If the projection already overshoots the deadline the
``policy`` decides — ``"drop"`` rejects it (reward 0, no slot wasted, the
paper's "a late action is worth nothing" regime) and ``"degrade"`` trims
``max_new`` to the largest token budget that still fits, modeling partial
/ truncated actions (and drops only when not even one token fits).

Chunked prefill (``prefill_chunk=N``): instead of stalling the engine for
the whole prompt at admission, the prompt is absorbed ``N`` tokens at a
time with one decode step for the *other* lanes between chunks — the
head-of-line-blocking fix the ROADMAP tracked.  Each chunk is charged the
length-aware ``prefill_s(chunk_len, context=absorbed)`` on the same clock
(chunking re-pays the weight-read per chunk *and* each later chunk
attends over the pages already written, so the total prefill cost rises;
the win is that decode lanes keep landing tokens).  The projections below
take the same ``prefill_chunk`` so admission accounts for both effects.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core import latency as lat_mod
from repro.core.latency import Hardware, V5E
from repro.obs import trace as tr_mod

from repro.serving.traffic import SimRequest

#: bucket decode contexts to this many tokens when memoizing step costs —
#: the roofline varies slowly in context, and it keeps the cache small.
_CTX_BUCKET = 64


class LatencyProfile:
    """Memoized analytic costs of one (model config, avg_bits) point.

    ``attn_impl`` selects how the *paged decode attention* is priced:
    ``"fused"`` (default) models the fused paged flash-attention kernel —
    one pool-direct read of each lane's actual context, which is exactly
    the attention term :func:`repro.core.latency.step_latency` always
    charged, so fused profiles reproduce the historical clock bit-for-bit.
    ``"gather"`` models the gather+SDPA path the kernel replaced: ~3x the
    KV traffic at the *padded* block-table extent (``padded_ctx``), added
    on top.  Engines built on a gather profile project slower steps, so
    admission, degrade budgets and routing all see the difference — the
    kernel's win flows into goodput, not just microbenchmarks.

    ``spec`` (a :class:`repro.core.fpx.SpecPoint`) prices fast-draft /
    slow-verify decoding: :meth:`spec_round_s` is one k-token round
    (draft steps + the verifier's fused chunk call), and :meth:`tok_s`
    becomes the *effective* per-token time ``round / E[tokens]`` — the
    single lever through which admission projections, the analytic
    batcher, and the fleet router all see speculation's throughput.
    ``draft_cfg``: the analytic cross-model form (e.g. 1.5b drafts for
    14b); ``None`` drafts with the same config at ``spec.draft_bits``
    (self-speculation, what the live engine runs).  Speculation pricing
    assumes the fused chunk-attend semantics, so it requires
    ``attn_impl="fused"``.

    ``tp`` (tensor parallelism) shards every matmul across ``tp`` chips:
    compute and weight traffic divide by ``tp`` (via ``hw.n_chips``), and
    every forward pays the per-layer all-reduce tax of
    :func:`repro.core.latency.tp_collective_s` over ``tp_link`` ("ici"
    for a group on one host's fabric, "dcn" when the group spans hosts —
    the spanning case is where the collective tax dominates and a
    link-blind router misprices the engine).  :meth:`net_blind` returns
    the collective-free twin used to model that blindness."""

    def __init__(self, cfg: ModelConfig, avg_bits: float, *,
                 hw: Hardware = V5E, attn_impl: str = "fused",
                 padded_ctx: Optional[int] = None, spec=None,
                 draft_cfg: Optional[ModelConfig] = None,
                 tp: int = 1, tp_link: Optional[str] = "ici"):
        assert attn_impl in ("fused", "gather"), attn_impl
        assert spec is None or attn_impl == "fused", \
            "speculation is priced with fused chunk-attend semantics"
        if attn_impl == "gather" and cfg.arch_type not in ("dense", "moe"):
            # the gather adjustment in step_s cancels step_latency's
            # built-in attention term; both now price per attention layer
            # group (core.latency.attn_layer_groups), so the cancellation
            # is exact for every stack the paged engine serves — dense and
            # moe, uniform-windowed (starcoder2-class) and local:global
            # (gemma3-class) included
            raise ValueError(
                "attn_impl='gather' models the paged decode path, which "
                f"supports dense/moe attention stacks only (got {cfg.name})")
        assert tp >= 1, tp
        self.cfg = cfg
        self.avg_bits = avg_bits
        # a tp-way engine splits each matmul over tp chips: the roofline
        # divides compute/bandwidth by hw.n_chips, and the collective tax
        # is added separately below (None tp_link = priced collective-free,
        # the "net-blind" router arm).
        self.hw = dataclasses.replace(hw, n_chips=hw.n_chips * tp) \
            if tp > 1 else hw
        self.attn_impl = attn_impl
        self.padded_ctx = padded_ctx
        self.spec = spec
        self.draft_cfg = draft_cfg
        self.tp = tp
        self.tp_link = tp_link
        self._prefill: Dict[Tuple[int, int], float] = {}
        self._step: Dict[Tuple[int, int], float] = {}
        self._service: Dict[Tuple[int, int], float] = {}
        self._spec_round: Dict[Tuple[int, int], float] = {}
        self._blind: Optional["LatencyProfile"] = None

    def _collective_s(self, n_tokens: int) -> float:
        """Per-forward TP all-reduce tax on ``n_tokens`` activations (0 for
        unsharded profiles and for the net-blind twin)."""
        if self.tp <= 1 or self.tp_link is None:
            return 0.0
        return lat_mod.tp_collective_s(self.cfg, n_tokens, self.tp,
                                       link=self.tp_link, hw=self.hw)

    def net_blind(self) -> "LatencyProfile":
        """The collective-free twin of this profile: same config, bits and
        tp-way compute split, but no interconnect terms — what a router
        that prices only roofline FLOPs believes this engine costs.  The
        physics stays with the true profile; this one exists so the
        net-blind baseline arm can mis-plan honestly."""
        if self.tp <= 1 or self.tp_link is None:
            return self
        if self._blind is None:
            self._blind = LatencyProfile(
                self.cfg, self.avg_bits, hw=self.hw,
                attn_impl=self.attn_impl, padded_ctx=self.padded_ctx,
                spec=self.spec, draft_cfg=self.draft_cfg,
                tp=self.tp, tp_link=None)
            # hw already carries the tp-way n_chips split; don't double it
            self._blind.hw = self.hw
        return self._blind

    def prefill_s(self, prompt_len: int, context: int = 0) -> float:
        """Cost of absorbing ``prompt_len`` prompt tokens with ``context``
        tokens already written to the request's pages (0 for a monolithic
        prefill or a first chunk).  The context term is the length-aware
        attention charge of absorbing new tokens over the lane's prior
        pages — a later chunked-prefill chunk, or a prefix-cache hit's
        remainder attending over the adopted pages
        (:func:`repro.core.latency.resume_prefill_s`)."""
        key = (prompt_len, context)
        t = self._prefill.get(key)
        if t is None:
            t = lat_mod.resume_prefill_s(self.cfg, n_new=prompt_len,
                                         context=context,
                                         w_bits=self.avg_bits, hw=self.hw)
            t += self._collective_s(prompt_len)
            self._prefill[key] = t
        return t

    def step_s(self, n_active: int, context: int) -> float:
        """One batched decode step: ``n_active`` slots each emit a token.

        The cost is memoized per context *bucket* and always evaluated at
        the bucket-representative context (``bucket * _CTX_BUCKET``), so the
        modeled cost of a bucket is independent of which exact context
        happened to be seen first — call order cannot skew the clock."""
        bucket = max(1, context // _CTX_BUCKET)
        key = (n_active, bucket)
        t = self._step.get(key)
        if t is None:
            ctx_rep = bucket * _CTX_BUCKET
            t = lat_mod.step_latency(self.cfg, n_tokens=n_active,
                                     context=ctx_rep,
                                     w_bits=self.avg_bits, hw=self.hw)
            if self.attn_impl == "gather":
                # replace the built-in (fused-equivalent) attention term
                # with the gather path's padded 3x-traffic term
                t += lat_mod.paged_attn_step_s(
                    self.cfg, n_lanes=n_active, context=ctx_rep,
                    impl="gather", padded_ctx=self.padded_ctx, hw=self.hw) \
                    - lat_mod.paged_attn_step_s(
                        self.cfg, n_lanes=n_active, context=ctx_rep,
                        impl="fused", hw=self.hw)
            t += self._collective_s(n_active)
            self._step[key] = t
        return t

    def spec_round_s(self, n_active: int, context: int) -> float:
        """One speculative round at this occupancy: ``spec.k`` draft steps
        plus the verifier's fused chunk call (memoized per context bucket,
        same discipline as :meth:`step_s`)."""
        assert self.spec is not None
        bucket = max(1, context // _CTX_BUCKET)
        key = (n_active, bucket)
        t = self._spec_round.get(key)
        if t is None:
            t = lat_mod.speculate_round_s(
                self.cfg, k=self.spec.k, n_lanes=n_active,
                context=bucket * _CTX_BUCKET, w_bits=self.avg_bits,
                draft_bits=self.spec.draft_bits, draft_cfg=self.draft_cfg,
                hw=self.hw)
            # one collective per forward: k draft steps + the verify chunk
            t += (self.spec.k + 1) * self._collective_s(n_active)
            self._spec_round[key] = t
        return t

    def tok_s(self, n_active: int, context: int) -> float:
        """Effective per-token decode time — what projections hold against
        deadlines.  Dense profiles: exactly :meth:`step_s`.  Speculative
        profiles: one round's cost amortized over its expected emission,
        ``spec_round_s / spec_expected_tokens`` — cheaper than a dense
        step above the break-even acceptance rate, honestly worse below
        it."""
        if self.spec is None:
            return self.step_s(n_active, context)
        return self.spec_round_s(n_active, context) \
            / self.spec.expected_tokens()

    def service_s(self, prompt_len: int, gen_tokens: int) -> float:
        """Uncontended end-to-end action latency (the planning estimate the
        router holds against a request's deadline slack).  Speculative
        profiles decode at the effective :meth:`tok_s` rate."""
        key = (prompt_len, gen_tokens)
        t = self._service.get(key)
        if t is None:
            if self.spec is None:
                t = lat_mod.decision_latency(self.cfg, prompt_len=prompt_len,
                                             gen_tokens=gen_tokens,
                                             w_bits=self.avg_bits, hw=self.hw)
                t += self._collective_s(prompt_len) \
                    + gen_tokens * self._collective_s(1)
            else:
                t = self.prefill_s(prompt_len) + gen_tokens * self.tok_s(
                    1, prompt_len + gen_tokens // 2)
            self._service[key] = t
        return t

    def prefill_chunked_s(self, prompt_len: int, chunk: int,
                          start_ctx: int = 0) -> float:
        """Total prefill charge when the prompt is absorbed in ``chunk``-token
        pieces: each chunk re-pays the weight-read *and* (length-aware)
        attends over every previously written chunk, so this is >= the
        monolithic ``prefill_s(prompt_len)`` — the cost side of chunked
        prefill's latency trade (the win is decode lanes not stalling).

        ``start_ctx``: tokens already written to the lane's pages before
        these chunks — pricing the *remainder* of a mid-flight prefill
        (the router's backlog estimate) must charge the attend over
        everything absorbed so far, not restart from zero context."""
        total, done = 0.0, start_ctx
        for c in prompt_chunks(prompt_len, chunk):
            total += self.prefill_s(c, context=done)
            done += c
        return total


def prompt_chunks(prompt_len: int, chunk: int) -> List[int]:
    """Chunk lengths a prompt splits into: full chunks plus a final partial
    one when ``chunk`` does not divide ``prompt_len``."""
    assert chunk >= 1, chunk
    full, rem = divmod(prompt_len, chunk)
    return [chunk] * full + ([rem] if rem else [])


@dataclasses.dataclass
class _Running:
    req: SimRequest
    remaining: int
    context: int
    #: prompt tokens not yet absorbed (chunked prefill; 0 = decoding)
    prefill_left: int = 0
    #: speculative decoding: fractional expected-emission credit carried
    #: between rounds so the deterministic mirror lands
    #: ``spec_expected_tokens`` tokens per round *on average* with
    #: integer emissions (credit += E; emit = floor(credit); credit -=
    #: emit)
    credit: float = 0.0


# ---------------------------------------------------------------------------
# Admission math, shared by the analytic batcher and the live paged engine
# (serving.paged_engine) — both project finish times on the same clock.
# ---------------------------------------------------------------------------

def ready_at(req) -> float:
    """When an engine may start serving ``req``: its arrival at the fleet
    ingress plus any network hop the router charged delivering the prompt
    to this engine's host (``t_ready``, stamped at dispatch).  Engines gate
    admission and idle-advance on this, so a cross-host dispatch cannot
    start prefilling before its bytes have landed."""
    t = getattr(req, "t_ready", None)
    return req.t_arrive if t is None else t

def _prefill_charge(profile: LatencyProfile, prompt_len: int,
                    n_active_after: int, prefill_chunk: Optional[int],
                    cached_prefix: int = 0) -> float:
    """Modeled wall time between a request's admission and the end of its
    prefill.  Monolithic: one stall.  Chunked: the per-chunk charges plus
    one interleaved decode step per chunk boundary when other lanes are
    decoding (that interleaving is the point — the *other* lanes' tokens
    keep landing; for this request it is added wait).

    ``cached_prefix``: prompt tokens adopted from the prefix cache — the
    skipped span is free, and only the remainder is absorbed (attending
    over the adopted pages, so a hit on a long system prompt is priced as
    the short remainder's resume cost, not the full prompt).  This is the
    single place the prefix cache's win enters the clock; every admission
    projection below inherits it."""
    new = prompt_len - cached_prefix
    if prefill_chunk is None:
        return profile.prefill_s(new, context=cached_prefix)
    total = profile.prefill_chunked_s(new, prefill_chunk,
                                      start_ctx=cached_prefix)
    n_chunks = len(prompt_chunks(new, prefill_chunk))
    if n_active_after > 1:
        total += (n_chunks - 1) * profile.tok_s(n_active_after, prompt_len)
    return total


def projected_finish(profile: LatencyProfile, t_now: float,
                     n_active_after: int, req, n_tokens: int, *,
                     prefill_chunk: Optional[int] = None,
                     cached_prefix: int = 0) -> float:
    """Finish-time projection if ``req`` were admitted now: prefill stalls
    the engine (monolithically, or chunk-by-chunk with interleaved decode
    steps — see :func:`_prefill_charge`), then ``n_tokens`` steps at the
    post-admission occupancy (context taken at the request's mid-decode
    point)."""
    step = profile.tok_s(n_active_after, req.prompt_len + n_tokens // 2)
    prefill = _prefill_charge(profile, req.prompt_len, n_active_after,
                              prefill_chunk, cached_prefix)
    return t_now + prefill + n_tokens * step


def projected_first_token(profile: LatencyProfile, t_now: float,
                          n_active_after: int, req, *,
                          prefill_chunk: Optional[int] = None,
                          cached_prefix: int = 0,
                          decode_first_token: bool = False) -> float:
    """First-token-time projection if ``req`` were admitted now — the
    TTFT-side admission check, shared by the analytic batcher and the
    live paged engine.  The live engine's first token *is* the prefill's
    last-position logits, so its projection is prefill-done; the analytic
    clock models no prefill-logits token (``decode_first_token=True``
    adds the first decode step, mirroring where ``t_first_token`` lands
    in :class:`ContinuousBatcher`).  Degrading trims decode budget, which
    cannot speed this up — a TTFT miss is a drop, never a degrade."""
    t = t_now + _prefill_charge(profile, req.prompt_len, n_active_after,
                                prefill_chunk, cached_prefix)
    if decode_first_token:
        t += profile.tok_s(n_active_after, req.prompt_len + 1)
    return t


def degraded_budget(profile: LatencyProfile, t_now: float,
                    n_active_after: int, req, *,
                    prefill_chunk: Optional[int] = None,
                    cached_prefix: int = 0) -> int:
    """Largest token budget that still fits ``req``'s deadline, with the
    step cost *re-projected at the trimmed budget's own context* (iterated
    to a fixed point).  A budget derived from the original ``max_new``'s
    context alone can overshoot: the first trim changes the context the
    step cost was computed at.  Starting from ``max_new`` and shrinking
    monotonically, the fixed point satisfies
    ``projected_finish(..., n) <= req.deadline_abs``.  Returns 0 when not
    even one token fits (caller drops)."""
    prefill = _prefill_charge(profile, req.prompt_len, n_active_after,
                              prefill_chunk, cached_prefix)
    slack = req.deadline_abs - t_now - prefill
    if slack <= 0:
        return 0
    n = req.max_new
    while n >= 1:
        step = profile.tok_s(n_active_after, req.prompt_len + n // 2)
        if step <= 0:
            return n
        fit = min(n, int(slack / step))
        if fit == n:
            return n
        n = fit
    return 0


def spec_round_fits(profile: LatencyProfile, t_now: float,
                    deadlines_abs, n_active: int, context: int) -> bool:
    """The deadline-aware collapse rule, shared verbatim by the analytic
    batcher and the live paged engine: run a speculative round only when
    the *whole* round (draft + verify) lands before every decoding
    lane's deadline; otherwise collapse to a dense step.  Under deadline
    pressure a round that might emit one token must not cost k-draft +
    verify time — a dense step is the safe floor.  Deterministic, so the
    two engine flavors collapse at the same clock instants."""
    return t_now + profile.spec_round_s(n_active, context) \
        <= min(deadlines_abs)


def post_prefill_fit(profile: LatencyProfile, t_now: float, n_active: int,
                     context: int, remaining: int, deadline_abs: float,
                     ) -> int:
    """Shared post-prefill re-projection: the largest decode-step budget
    ``n <= remaining`` with ``t_now + n * step <= deadline_abs``, or -1
    when ``t_now`` is already past the deadline (nothing can land on
    time).  Both engine flavors call this when a (chunked) prefill
    completes — interleaved charges from co-resident lanes landed since
    the admission projection, so the admitted budget must be re-proved.
    What a fit of 0 means is the caller's: the live engine already holds
    the prefill-logits token and finishes on time with it (a maximally
    truncated action); the analytic batcher models no such token and
    drops."""
    if t_now > deadline_abs:
        return -1
    step = profile.tok_s(max(1, n_active), context + remaining // 2)
    if step <= 0:
        return remaining
    return min(remaining, int((deadline_abs - t_now) / step))


class ContinuousBatcher:
    def __init__(self, profile: LatencyProfile, *, slots: int = 4,
                 policy: str = "degrade",
                 on_retire: Optional[Callable[[SimRequest], None]] = None,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: bool = False,
                 tracer=None):
        """``on_retire`` fires once per request leaving the system — on
        completion *and* on drop — so a learner sees the reward (or lack
        of one) for every routing decision.  ``prefill_chunk``: absorb
        admitted prompts this many tokens at a time, interleaved with
        decode steps for the other slots, instead of stalling the engine
        for the whole prompt (None = monolithic, the historical
        behavior).  ``prefix_cache``: model prefix reuse — the analytic
        mirror of the live engine's token-hash cache.  It has no token
        arrays, so it keys on the *identity* streams session traffic
        declares (``SimRequest.prefix_keys``): a (key, length) pair
        published at prefill completion marks the prompt's first
        ``length`` tokens warm under ``key``, and a later request listing
        the same key skips ``min(warm, own length)`` tokens of prefill.
        Because session prompts literally extend each other, this
        coincides with what the token-hash cache would find (modulo
        capacity eviction, which the analytic mirror does not model).
        ``tracer``: a :class:`repro.obs.Tracer` (or a scoped view)
        receiving the full request/step event stream; None = the
        zero-overhead null tracer."""
        assert policy in ("drop", "degrade", "serve"), policy
        assert prefill_chunk is None or prefill_chunk >= 1, prefill_chunk
        self.profile = profile
        self.slots = slots
        self.policy = policy
        self.on_retire = on_retire
        self.prefill_chunk = prefill_chunk
        self.prefix_cache = prefix_cache
        self._warm: Dict[str, int] = {}   # prefix-stream key -> warm tokens
        self.tr = tracer or tr_mod.NULL
        self.t = 0.0                      # engine-local simulated clock
        self.pending: List[SimRequest] = []
        self.active: List[_Running] = []
        self.completed: List[SimRequest] = []
        self.dropped: List[SimRequest] = []
        #: fault injection (serving.faults): the per-engine view, or None.
        #: Falsy when no faults are scheduled, so the clean path costs one
        #: truthiness check per boundary.
        self.faults = None
        self._slots_seized = 0            # page-pressure analog: seized slots

    # -- fault-injection protocol (serving.faults) ---------------------------

    def _charge(self, dt: float) -> None:
        """Advance the clock by ``dt`` engine-seconds, stretched by any
        active slowdown fault.  The no-fault path multiplies by exactly
        1.0 — bit-identical to the historical ``self.t += dt``."""
        if self.faults:
            dt *= self.faults.scale(self.t)
        self.t += dt

    def _slots_now(self) -> int:
        """Decode slots available right now (pressure faults seize slots
        on the analytic path; at least one always survives so the engine
        keeps making progress)."""
        return max(1, self.slots - self._slots_seized)

    def reclaim_in_flight(self) -> List[SimRequest]:
        """Crash teardown: every admitted *and* queued request leaves the
        engine (volatile state is gone; the engine's queue died with the
        process).  Returns them for the crash handler to requeue, strand,
        or re-route — they do not retire here."""
        out = [r.req for r in self.active] + list(self.pending)
        self.active = []
        self.pending = []
        return out

    def requeue(self, req: SimRequest) -> None:
        """Accept a recovered attempt without re-emitting its arrival
        (the request already arrived once; this is the same request on a
        new attempt)."""
        self.pending.append(req)

    def apply_pressure(self, fault) -> int:
        self._slots_seized += fault.slots
        return fault.slots

    def release_pressure(self, token: int) -> None:
        self._slots_seized -= token

    # -- submission ---------------------------------------------------------

    def submit(self, req: SimRequest) -> None:
        self.pending.append(req)
        if self.tr:
            emit_arrive(self.tr, req)

    # -- admission ----------------------------------------------------------

    def cached_prefix_len(self, req: SimRequest) -> int:
        """Prompt tokens a request admitted *now* would skip via prefix
        reuse — the analytic mirror of the live engine's token-hash
        lookup, and the router-facing signal ``FleetRouter`` folds into
        first-token slack.  At least one prompt token always remains
        (the first output token comes from the remainder's logits)."""
        if not self.prefix_cache:
            return 0
        best = 0
        for key, ln in getattr(req, "prefix_keys", ()) or ():
            best = max(best, min(self._warm.get(key, 0), ln))
        return min(best, req.prompt_len - 1)

    def _publish_prefixes(self, req: SimRequest) -> None:
        """At prefill completion the prompt's declared prefix streams are
        warm — later same-stream requests skip them.  (Completion, not
        admission: a concurrent same-prefix request must not hit pages
        that are still being written.)"""
        if not self.prefix_cache:
            return
        for key, ln in getattr(req, "prefix_keys", ()) or ():
            n = min(ln, req.prompt_len)
            if n > self._warm.get(key, 0):
                self._warm[key] = n

    def _projected_finish(self, req: SimRequest, n_tokens: int,
                          cached_prefix: int = 0) -> float:
        return projected_finish(self.profile, self.t, len(self.active) + 1,
                                req, n_tokens,
                                prefill_chunk=self.prefill_chunk,
                                cached_prefix=cached_prefix)

    def _admit_one(self) -> bool:
        """Admit the earliest-deadline *arrived* pending request, applying
        the drop/degrade policy.  Returns True if a slot was filled."""
        while True:
            arrived = [r for r in self.pending if ready_at(r) <= self.t]
            if not arrived or len(self.active) >= self._slots_now():
                return False
            req = min(arrived, key=lambda r: (r.deadline_abs, r.rid))
            self.pending.remove(req)
            cached = self.cached_prefix_len(req)
            if self.tr and self.prefix_cache:
                self.tr.instant(tr_mod.PREFIX_LOOKUP, self.t, track="queue",
                                rid=req.rid, hit=cached > 0, tokens=cached)
            if self.policy != "serve" and req.ttft_deadline_s is not None \
                    and projected_first_token(
                        self.profile, self.t, len(self.active) + 1, req,
                        prefill_chunk=self.prefill_chunk,
                        cached_prefix=cached, decode_first_token=True,
                    ) > req.t_arrive + req.ttft_deadline_s:
                # degrading trims decode budget, which cannot speed up the
                # first token — a TTFT miss is a drop under either policy
                retire_dropped(self, req)
                continue
            n_tok = req.max_new
            if self.policy != "serve" \
                    and self._projected_finish(req, n_tok, cached) \
                    > req.deadline_abs:
                if self.policy == "degrade":
                    n_tok = degraded_budget(self.profile, self.t,
                                            len(self.active) + 1, req,
                                            prefill_chunk=self.prefill_chunk,
                                            cached_prefix=cached)
                else:
                    n_tok = 0
                if n_tok < 1:
                    retire_dropped(self, req)
                    continue                     # slot still free; try next
                if self.tr and n_tok < req.max_new:
                    self.tr.instant(tr_mod.REQ_DEGRADE, self.t, track="steps",
                                    rid=req.rid, from_tok=req.max_new,
                                    to_tok=n_tok)
            req.t_admit = self.t
            if self.tr:
                emit_admit(self.tr, req, self.t, n_tok, track="steps")
            if self.prefill_chunk is None:
                # monolithic: the (remaining) prompt is charged as one
                # stall; an adopted prefix is free and the remainder
                # attends over it
                t0 = self.t
                self._charge(self.profile.prefill_s(req.prompt_len - cached,
                                                    context=cached))
                req.t_prefill_done = self.t
                if self.tr:
                    self.tr.span(tr_mod.REQ_PREFILL, t0, self.t,
                                 track="steps", rid=req.rid,
                                 tokens=req.prompt_len - cached,
                                 cached=cached)
                self._publish_prefixes(req)
                self.active.append(_Running(req, remaining=n_tok,
                                            context=req.prompt_len))
            else:
                # chunked: charge nothing yet — _decode_step absorbs the
                # remainder chunk-by-chunk, decode steps landing in
                # between (prefill_left starts past the adopted prefix)
                self.active.append(_Running(req, remaining=n_tok,
                                            context=req.prompt_len,
                                            prefill_left=req.prompt_len
                                            - cached))
            return True

    def _sweep_cancels(self) -> None:
        """Barge-in: retire every request whose cancel time has passed —
        queued requests leave the queue, active lanes free their slot
        mid-decode (or mid-prefill) keeping whatever tokens they
        produced.  Swept between steps, mirroring the live engine's
        page-reclaiming sweep."""
        for req in [r for r in self.pending
                    if getattr(r, "t_cancel", None) is not None
                    and r.t_cancel <= self.t]:
            self.pending.remove(req)
            retire_cancelled(self, req)
        for run in [r for r in self.active
                    if getattr(r.req, "t_cancel", None) is not None
                    and r.req.t_cancel <= self.t]:
            self.active.remove(run)
            retire_cancelled(self, run.req)

    def _admit(self) -> None:
        if self.faults:
            self.faults.tick(self)
        self._sweep_cancels()
        while self._admit_one():
            pass

    # -- the decode loop ----------------------------------------------------

    def _advance_prefills(self) -> None:
        """Absorb one chunk for every slot still prefilling (each chunk is
        its own engine stall), re-applying the drop/degrade policy when a
        prompt completes: interleaved decode charges landed since the
        admission projection, so the budget that fit then may not fit
        now."""
        for run in list(self.active):
            if run.prefill_left <= 0:
                continue
            c = min(self.prefill_chunk, run.prefill_left)
            absorbed = run.req.prompt_len - run.prefill_left
            t0 = self.t
            self._charge(self.profile.prefill_s(c, context=absorbed))
            run.prefill_left -= c
            if self.tr:
                self.tr.span(tr_mod.REQ_PREFILL_CHUNK, t0, self.t,
                             track="steps", rid=run.req.rid, chunk=c,
                             absorbed=absorbed + c)
            if run.prefill_left > 0:
                continue
            run.req.t_prefill_done = self.t
            self._publish_prefixes(run.req)
            if self.policy == "serve":
                continue
            fit = post_prefill_fit(self.profile, self.t, len(self.active),
                                   run.context, run.remaining,
                                   run.req.deadline_abs)
            if fit == run.remaining:
                continue
            if self.policy == "degrade" and fit >= 1:
                if self.tr:
                    self.tr.instant(tr_mod.REQ_DEGRADE, self.t, track="steps",
                                    rid=run.req.rid, from_tok=run.remaining,
                                    to_tok=fit)
                run.remaining = fit
            else:
                # drop policy, past deadline, or not even one token fits
                # (the analytic clock models no free prefill token)
                self.active.remove(run)
                retire_dropped(self, run.req)

    def _decode_step(self) -> None:
        self._sweep_cancels()
        if self.prefill_chunk is not None:
            self._advance_prefills()
        decoding = [r for r in self.active if r.prefill_left <= 0]
        if not decoding:
            return                        # every occupied slot still prefilling
        n = len(decoding)
        ctx = max(r.context for r in decoding)
        if self.profile.spec is not None and spec_round_fits(
                self.profile, self.t,
                [r.req.deadline_abs for r in decoding], n, ctx):
            self._spec_round(decoding, n, ctx)
            return
        t0 = self.t
        self._charge(self.profile.step_s(n, ctx))
        if self.tr:
            self.tr.span(tr_mod.ENGINE_STEP, t0, self.t, track="steps",
                         n_active=n, context=ctx,
                         lanes=[r.req.rid for r in decoding])
        still: List[_Running] = [r for r in self.active
                                 if r.prefill_left > 0]
        for run in decoding:
            run.remaining -= 1
            run.context += 1
            run.req.tokens_done += 1
            if run.req.tokens_done == 1:
                # the analytic clock models no prefill-logits token: the
                # first token lands after the first decode step
                mark_first_token(run.req, self.t)
                if self.tr:
                    self.tr.instant(tr_mod.REQ_FIRST_TOKEN, self.t,
                                    track="steps", rid=run.req.rid,
                                    ttft_s=self.t - run.req.t_arrive)
            elif self.tr:
                self.tr.instant(tr_mod.REQ_TOKEN, self.t, track="steps",
                                rid=run.req.rid)
            if run.remaining > 0:
                still.append(run)
                continue
            req = run.req
            req.t_finish = self.t
            req.latency_s = self.t - req.t_arrive
            # deadline_abs, not deadline_s: a Request with no SLO
            # (deadline_s=None) projects to +inf and always meets it
            req.met_deadline = req.t_finish <= req.deadline_abs
            self.completed.append(req)
            if self.tr:
                emit_finish(self.tr, req, track="steps")
            if self.on_retire is not None:
                self.on_retire(req)
        self.active = still
        if self.tr:
            self.tr.counter(tr_mod.CTR_LANES, self.t, len(self.active),
                            track="steps")
            self.tr.counter(tr_mod.CTR_QUEUE, self.t, len(self.pending),
                            track="queue")

    def _spec_round(self, decoding: List[_Running], n: int,
                    ctx: int) -> None:
        """The analytic mirror of one fast-draft / slow-verify round: one
        ``spec_round_s`` charge advances every decoding lane by its
        integer share of ``spec_expected_tokens`` (per-lane fractional
        credit keeps the long-run rate exact and the replay
        deterministic), capped by the lane's remaining budget and the
        round's ``k + 1`` ceiling.  Every round lands at least one token
        per lane — the verifier's own — exactly like the live engine."""
        spec = self.profile.spec
        t0 = self.t
        self._charge(self.profile.spec_round_s(n, ctx))
        if self.tr:
            rids = [r.req.rid for r in decoding]
            self.tr.instant(tr_mod.SPEC_DRAFT, t0, track="steps", k=spec.k,
                            lanes=rids, drafted=spec.k * n)
            self.tr.instant(tr_mod.SPEC_VERIFY, self.t, track="steps",
                            lanes=rids, chunk=spec.k + 1)
        e = spec.expected_tokens()
        still: List[_Running] = [r for r in self.active
                                 if r.prefill_left > 0]
        accepted = emitted = 0
        for run in decoding:
            run.credit += e
            emit = min(int(run.credit), run.remaining, spec.k + 1)
            run.credit -= emit
            accepted += emit - 1          # verifier's token is never a draft
            emitted += emit
            first = run.req.tokens_done == 0
            run.remaining -= emit
            run.context += emit
            run.req.tokens_done += emit
            if first:
                mark_first_token(run.req, self.t)
                if self.tr:
                    self.tr.instant(tr_mod.REQ_FIRST_TOKEN, self.t,
                                    track="steps", rid=run.req.rid,
                                    ttft_s=self.t - run.req.t_arrive)
            if self.tr:
                for _ in range(emit - (1 if first else 0)):
                    self.tr.instant(tr_mod.REQ_TOKEN, self.t, track="steps",
                                    rid=run.req.rid)
            if run.remaining > 0:
                still.append(run)
                continue
            req = run.req
            req.t_finish = self.t
            req.latency_s = self.t - req.t_arrive
            req.met_deadline = req.t_finish <= req.deadline_abs
            self.completed.append(req)
            if self.tr:
                emit_finish(self.tr, req, track="steps")
            if self.on_retire is not None:
                self.on_retire(req)
        self.active = still
        if self.tr:
            self.tr.instant(tr_mod.SPEC_ACCEPT, self.t, track="steps",
                            lanes=[r.req.rid for r in decoding],
                            accepted=accepted, emitted=emitted)
            self.tr.counter(tr_mod.CTR_LANES, self.t, len(self.active),
                            track="steps")
            self.tr.counter(tr_mod.CTR_QUEUE, self.t, len(self.pending),
                            track="queue")

    def _n_active(self) -> int:
        return len(self.active)

    def drain(self, until: Optional[float] = None) -> None:
        """Advance the engine clock to ``until`` (or to empty), admitting
        arrivals into free slots between decode steps."""
        drive(self, until)

    def run(self) -> List[SimRequest]:
        self.drain(until=None)
        return self.completed

    # -- router-facing estimates -------------------------------------------

    def backlog_s(self, now: float) -> float:
        """Estimated extra wait a request dispatched at ``now`` would see:
        how far this engine's clock runs ahead plus queued work divided
        over its slots.  A deliberate first-order heuristic — the router
        only needs enough signal to spread load and respect slack."""
        return estimate_backlog(self.profile, self.t, now,
                                [r.remaining for r in self.active],
                                self.pending, self.slots,
                                prefill_chunk=self.prefill_chunk,
                                active_prefill_left=[r.prefill_left
                                                     for r in self.active],
                                active_prefill_done=[
                                    r.req.prompt_len - r.prefill_left
                                    if r.prefill_left > 0 else 0
                                    for r in self.active])


# ---------------------------------------------------------------------------
# Shared trace emission, used by the analytic batcher and the live paged
# engine so the two event streams carry identical lifecycle args (and the
# invariant checker / metrics sink never special-case a path).
# ---------------------------------------------------------------------------

def _finite(x: float) -> Optional[float]:
    return x if x == x and abs(x) != float("inf") else None


def emit_arrive(tr, req) -> None:
    tr.instant(tr_mod.REQ_ARRIVE, req.t_arrive, track="queue",
               rid=req.rid, cls=getattr(req, "cls_name", "default"),
               prompt_len=req.prompt_len, max_new=req.max_new,
               deadline_abs=_finite(req.deadline_abs))


def emit_admit(tr, req, t: float, n_tok: int, track: str) -> None:
    tr.span(tr_mod.REQ_QUEUE, req.t_arrive, t, track="queue", rid=req.rid)
    tr.instant(tr_mod.REQ_ADMIT, t, track=track, rid=req.rid, n_tok=n_tok,
               max_new=req.max_new)


def emit_finish(tr, req, track: str) -> None:
    from repro.serving.metrics import request_slack
    tr.instant(tr_mod.REQ_FINISH, req.t_finish, track=track,
               rid=req.rid, cls=getattr(req, "cls_name", "default"),
               latency_s=req.latency_s, tokens=req.tokens_done,
               met_deadline=bool(req.met_deadline),
               degraded=req.tokens_done < req.max_new,
               **request_slack(req))


def mark_first_token(req, t: float) -> None:
    """Shared first-token bookkeeping: stamp ``t_first_token`` and judge
    the TTFT deadline (relative to arrival) the moment it is decidable —
    both engine flavors call this at their own notion of "first token"
    (prefill-done logits on the live paged path, first decode step on the
    analytic clock)."""
    req.t_first_token = t
    if getattr(req, "ttft_deadline_s", None) is not None:
        req.met_ttft = (t - req.t_arrive) <= req.ttft_deadline_s


def retire_cancelled(eng, req) -> None:
    """Shared barge-in bookkeeping: the request leaves at ``eng.t`` with
    whatever tokens it produced.  A cancelled turn is *not* a failure —
    the user interrupted because they had heard enough — so it retires
    into ``completed`` flagged ``cancelled``, and ``met_deadline`` is
    judged on whether streaming *started* in time (first token by the
    completion deadline); a cancel that lands while the request is still
    queued or prefilling never streamed and counts as a miss.  Retires
    through the same ``on_retire`` feedback path as finishes and drops,
    so the router's bandit sees the (partial) reward."""
    req.cancelled = True
    req.t_finish = eng.t
    req.latency_s = eng.t - req.t_arrive
    req.met_deadline = (req.t_first_token is not None
                        and req.t_first_token <= req.deadline_abs)
    eng.completed.append(req)
    tr = getattr(eng, "tr", None)
    if tr:
        tr.instant(tr_mod.REQ_CANCEL, eng.t, track="queue", rid=req.rid,
                   cls=getattr(req, "cls_name", "default"),
                   tokens=req.tokens_done,
                   admitted=req.t_admit is not None,
                   hedge_loser=bool(getattr(req, "hedge_loser", False)))
    if eng.on_retire is not None:
        eng.on_retire(req)


def retire_dropped(eng, req) -> None:
    """Shared drop bookkeeping: mark ``req`` rejected at ``eng``'s current
    clock, record it, and fire the retirement callback (drops retire
    through the same feedback path as completions)."""
    req.dropped = True
    req.t_finish = eng.t
    req.met_deadline = False
    eng.dropped.append(req)
    tr = getattr(eng, "tr", None)
    if tr:
        tr.instant(tr_mod.REQ_DROP, eng.t, track="queue", rid=req.rid,
                   cls=getattr(req, "cls_name", "default"),
                   admitted=req.t_admit is not None)
    if eng.on_retire is not None:
        eng.on_retire(req)


def drive(eng, until: Optional[float] = None) -> None:
    """The drain loop shared by the analytic batcher and the live paged
    engine: advance ``eng`` to ``until`` (or to empty), admitting arrivals
    between decode steps.  ``eng`` exposes ``t / pending / _n_active /
    _admit / _decode_step`` — the engine flavors differ only in what a
    decode step *does*, never in how time moves.

    Clock contract: an idle engine still advances its clock to ``until``
    before returning — engines drained to the same horizon must agree on
    "now", or ``backlog_s`` comparisons across a fleet are skewed by
    which engine happened to idle last."""
    while True:
        if eng._n_active() == 0 and eng.pending:
            nxt = min(ready_at(r) for r in eng.pending)
            if until is not None and nxt >= until and nxt > eng.t:
                eng.t = max(eng.t, until)        # idle through the horizon
                return
            eng.t = max(eng.t, nxt)
        if until is not None and eng.t >= until:
            return
        eng._admit()
        if eng._n_active():
            eng._decode_step()
        elif not eng.pending:
            if until is not None:
                eng.t = max(eng.t, until)        # empty: idle to the horizon
            return


def estimate_backlog(profile: LatencyProfile, t: float, now: float,
                     active_remaining: List[int], pending, slots: int, *,
                     prefill_chunk: Optional[int] = None,
                     active_prefill_left: Optional[List[int]] = None,
                     active_prefill_done: Optional[List[int]] = None,
                     ) -> float:
    """The router-facing wait estimate shared by every engine flavor.

    ``active_prefill_left``: unabsorbed prompt tokens of lanes still
    mid-prefill.  Monolithic engines charge the whole prefill to ``t`` at
    admission so it shows up in the clock-ahead term; chunked engines
    defer those charges, and a router that cannot see them would happily
    route a tight-deadline request onto an engine mid-way through a long
    chat prefill.  ``active_prefill_done`` (parallel list): tokens those
    lanes have *already* absorbed — the remaining chunks attend over them,
    so under the length-aware clock a prefill near the end of a long
    prompt is priced at its true (high) per-chunk cost, not as a fresh
    start."""
    step1 = profile.tok_s(max(1, len(active_remaining)), _CTX_BUCKET * 4)
    work = sum(active_remaining) * step1

    def prefill_cost(n_tokens: int, start_ctx: int = 0) -> float:
        if prefill_chunk is None:
            return profile.prefill_s(n_tokens)
        return profile.prefill_chunked_s(n_tokens, prefill_chunk,
                                         start_ctx=start_ctx)

    left_list = list(active_prefill_left or ())
    done_list = list(active_prefill_done or ()) or [0] * len(left_list)
    for left, done in zip(left_list, done_list):
        if left > 0:
            work += prefill_cost(left, start_ctx=done)
    for r in pending:
        work += prefill_cost(r.prompt_len) + r.max_new * step1
    return max(0.0, t - now) + work / slots
