"""Continuous batching on the analytic-latency clock.

The wave :class:`~repro.serving.scheduler.Scheduler` serves requests in
padded batches with a barrier between waves: every request inherits the
wave's makespan and a free decode slot stays idle until the whole wave
drains.  This module removes the barrier.  A :class:`ContinuousBatcher`
owns ``slots`` decode slots on one engine operating point; requests are
admitted into free slots *between decode steps* (earliest-deadline-first
among arrived requests), run for exactly their own ``max_new`` tokens, and
release the slot the step they finish — the slot is reusable immediately,
mid-flight of everyone else.

Time is simulated: the batcher advances an engine-local clock by the
roofline cost (core.latency) of each prefill and each batched decode step,
so queueing delay, batch-size effects, and per-request service time all
come out of the same analytic model the FPX controller plans with.  Real
token generation stays in engine.py; the published follow-on for marrying
the two is KV-cache paging (see ROADMAP).

Admission control: before a request enters a slot the batcher projects its
finish time.  If the projection already overshoots the deadline the
``policy`` decides — ``"drop"`` rejects it (reward 0, no slot wasted, the
paper's "a late action is worth nothing" regime) and ``"degrade"`` trims
``max_new`` to the largest token budget that still fits, modeling partial
/ truncated actions (and drops only when not even one token fits).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core import latency as lat_mod
from repro.core.latency import Hardware, V5E

from repro.serving.traffic import SimRequest

#: bucket decode contexts to this many tokens when memoizing step costs —
#: the roofline varies slowly in context, and it keeps the cache small.
_CTX_BUCKET = 64


class LatencyProfile:
    """Memoized analytic costs of one (model config, avg_bits) point."""

    def __init__(self, cfg: ModelConfig, avg_bits: float, *,
                 hw: Hardware = V5E):
        self.cfg = cfg
        self.avg_bits = avg_bits
        self.hw = hw
        self._prefill: Dict[int, float] = {}
        self._step: Dict[Tuple[int, int], float] = {}
        self._service: Dict[Tuple[int, int], float] = {}

    def prefill_s(self, prompt_len: int) -> float:
        t = self._prefill.get(prompt_len)
        if t is None:
            t = lat_mod.step_latency(self.cfg, n_tokens=prompt_len,
                                     w_bits=self.avg_bits, hw=self.hw)
            self._prefill[prompt_len] = t
        return t

    def step_s(self, n_active: int, context: int) -> float:
        """One batched decode step: ``n_active`` slots each emit a token."""
        key = (n_active, max(1, context // _CTX_BUCKET))
        t = self._step.get(key)
        if t is None:
            t = lat_mod.step_latency(self.cfg, n_tokens=n_active,
                                     context=max(1, context),
                                     w_bits=self.avg_bits, hw=self.hw)
            self._step[key] = t
        return t

    def service_s(self, prompt_len: int, gen_tokens: int) -> float:
        """Uncontended end-to-end action latency (the planning estimate the
        router holds against a request's deadline slack)."""
        key = (prompt_len, gen_tokens)
        t = self._service.get(key)
        if t is None:
            t = lat_mod.decision_latency(self.cfg, prompt_len=prompt_len,
                                         gen_tokens=gen_tokens,
                                         w_bits=self.avg_bits, hw=self.hw)
            self._service[key] = t
        return t


@dataclasses.dataclass
class _Running:
    req: SimRequest
    remaining: int
    context: int


class ContinuousBatcher:
    def __init__(self, profile: LatencyProfile, *, slots: int = 4,
                 policy: str = "degrade",
                 on_retire: Optional[Callable[[SimRequest], None]] = None):
        """``on_retire`` fires once per request leaving the system — on
        completion *and* on drop — so a learner sees the reward (or lack
        of one) for every routing decision."""
        assert policy in ("drop", "degrade", "serve"), policy
        self.profile = profile
        self.slots = slots
        self.policy = policy
        self.on_retire = on_retire
        self.t = 0.0                      # engine-local simulated clock
        self.pending: List[SimRequest] = []
        self.active: List[_Running] = []
        self.completed: List[SimRequest] = []
        self.dropped: List[SimRequest] = []

    # -- submission ---------------------------------------------------------

    def submit(self, req: SimRequest) -> None:
        self.pending.append(req)

    # -- admission ----------------------------------------------------------

    def _projected_finish(self, req: SimRequest, n_tokens: int) -> float:
        """Finish-time projection if admitted now: prefill stalls the engine,
        then ``n_tokens`` steps at the post-admission occupancy."""
        step = self.profile.step_s(len(self.active) + 1,
                                   req.prompt_len + n_tokens // 2)
        return self.t + self.profile.prefill_s(req.prompt_len) \
            + n_tokens * step

    def _admit_one(self) -> bool:
        """Admit the earliest-deadline *arrived* pending request, applying
        the drop/degrade policy.  Returns True if a slot was filled."""
        while True:
            arrived = [r for r in self.pending if r.t_arrive <= self.t]
            if not arrived or len(self.active) >= self.slots:
                return False
            req = min(arrived, key=lambda r: (r.deadline_abs, r.rid))
            self.pending.remove(req)
            n_tok = req.max_new
            if self.policy != "serve" \
                    and self._projected_finish(req, n_tok) > req.deadline_abs:
                if self.policy == "degrade":
                    step = self.profile.step_s(
                        len(self.active) + 1, req.prompt_len + n_tok // 2)
                    slack = req.deadline_abs - self.t \
                        - self.profile.prefill_s(req.prompt_len)
                    n_tok = min(n_tok, int(slack / step)) if step > 0 else 0
                else:
                    n_tok = 0
                if n_tok < 1:
                    req.dropped = True
                    req.t_finish = self.t
                    req.met_deadline = False
                    self.dropped.append(req)
                    if self.on_retire is not None:
                        self.on_retire(req)
                    continue                     # slot still free; try next
            req.t_admit = self.t
            self.t += self.profile.prefill_s(req.prompt_len)
            self.active.append(_Running(req, remaining=n_tok,
                                        context=req.prompt_len))
            return True

    def _admit(self) -> None:
        while self._admit_one():
            pass

    # -- the decode loop ----------------------------------------------------

    def _decode_step(self) -> None:
        n = len(self.active)
        ctx = max(r.context for r in self.active)
        self.t += self.profile.step_s(n, ctx)
        still: List[_Running] = []
        for run in self.active:
            run.remaining -= 1
            run.context += 1
            run.req.tokens_done += 1
            if run.remaining > 0:
                still.append(run)
                continue
            req = run.req
            req.t_finish = self.t
            req.latency_s = self.t - req.t_arrive
            req.met_deadline = req.latency_s <= req.deadline_s
            self.completed.append(req)
            if self.on_retire is not None:
                self.on_retire(req)
        self.active = still

    def drain(self, until: Optional[float] = None) -> None:
        """Advance the engine clock to ``until`` (or to empty), admitting
        arrivals into free slots between decode steps."""
        while True:
            if not self.active and self.pending:
                nxt = min(r.t_arrive for r in self.pending)
                if until is not None and nxt >= until and nxt > self.t:
                    return                       # idle until past the horizon
                self.t = max(self.t, nxt)
            if until is not None and self.t >= until:
                return
            self._admit()
            if self.active:
                self._decode_step()
            elif not self.pending:
                return

    def run(self) -> List[SimRequest]:
        self.drain(until=None)
        return self.completed

    # -- router-facing estimates -------------------------------------------

    def backlog_s(self, now: float) -> float:
        """Estimated extra wait a request dispatched at ``now`` would see:
        how far this engine's clock runs ahead plus queued work divided
        over its slots.  A deliberate first-order heuristic — the router
        only needs enough signal to spread load and respect slack."""
        step1 = self.profile.step_s(max(1, len(self.active)), _CTX_BUCKET * 4)
        work = sum(r.remaining for r in self.active) * step1
        for r in self.pending:
            work += self.profile.prefill_s(r.prompt_len) + r.max_new * step1
        return max(0.0, self.t - now) + work / self.slots
