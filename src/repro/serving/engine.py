"""Batched serving engine with FPX-aware execution.

Wraps the model zoo's prefill/decode under jit, carries the decode cache,
and exposes ``generate`` for batched requests.  The engine holds an
``ExecContext`` whose precision policy can be swapped per request wave —
this is how the FPX controller's (model, gamma) decision becomes live
weights-at-bits execution.

The latency attributed to each generation comes from the analytic TPU model
(core.latency); on-CPU wall time is meaningless for the paper's question.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import latency as lat_mod
from repro.models import transformer
from repro.models.modules import ExecContext
from repro.serving import sampler as sampler_mod
from repro.serving.sampler import SamplerPolicy


@dataclasses.dataclass
class GenerationResult:
    tokens: jax.Array            # (B, prompt + new)
    new_tokens: jax.Array        # (B, max_new)
    latency_s: float             # modeled TPU action latency (decision level)
    logits_last: Optional[jax.Array] = None


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, *,
                 ctx: Optional[ExecContext] = None,
                 max_ctx: int = 4096,
                 latency_cfg: Optional[ModelConfig] = None,
                 avg_bits: float = 16.0,
                 unroll: bool = True,
                 sampler: Optional[SamplerPolicy] = None):
        """``latency_cfg``: config used for the latency model (the full-scale
        model that this sim-scale model represents); defaults to ``cfg``.
        ``unroll=True`` executes layer loops in python — right for the small
        models served on CPU, and it makes per-name precision policies apply
        directly.  ``sampler``: token-selection policy fused into the jit'd
        steps (default greedy; swap with :meth:`set_sampler`)."""
        self.params = params
        self.cfg = cfg
        self.ctx = ctx or ExecContext()
        self.max_ctx = max_ctx
        self.latency_cfg = latency_cfg or cfg
        self.avg_bits = avg_bits
        self.unroll = unroll
        self.sampler = sampler or sampler_mod.GREEDY
        self._base_sampler = self.sampler
        self._jit_steps()

    def _jit_steps(self) -> None:
        """(Re-)jit prefill/decode with sampling fused in: each step takes
        (params, batch, [cache,] rids, positions) and returns the sampled
        (B, 1) int32 ids alongside logits + cache — token selection runs
        device-side under the current :class:`SamplerPolicy`."""
        cfg, max_ctx, unroll = self.cfg, self.max_ctx, self.unroll
        pol = self.sampler

        def pre(p, b, rids, pos):
            logits, cache = transformer.prefill(p, cfg, b, self.ctx,
                                                unroll=unroll,
                                                cache_len=max_ctx)
            return sampler_mod.sample(pol, logits, rids, pos), logits, cache

        def dec(p, b, c, rids, pos):
            logits, cache = transformer.decode_step(p, cfg, b, c, self.ctx,
                                                    unroll=unroll)
            return sampler_mod.sample(pol, logits, rids, pos), logits, cache

        self._prefill = jax.jit(pre)
        self._decode = jax.jit(dec)

    def set_policy(self, policy: Dict[str, int], default_bits: int = 8,
                   avg_bits: Optional[float] = None) -> None:
        """Swap the live FPX assignment (re-jits on next call)."""
        self.ctx = dataclasses.replace(self.ctx, policy=policy,
                                       default_bits=default_bits)
        if avg_bits is not None:
            self.avg_bits = avg_bits
        self._jit_steps()

    def set_sampler(self, sampler: SamplerPolicy) -> None:
        """Swap the standing token-selection policy (re-jits on change) —
        the sampling-layer twin of :meth:`set_policy`."""
        self._base_sampler = sampler
        self._apply_sampler(sampler)

    def _apply_sampler(self, sampler: SamplerPolicy) -> None:
        if sampler != self.sampler:
            self.sampler = sampler
            self._jit_steps()

    def modeled_latency(self, prompt_len: int, gen_tokens: int) -> float:
        """Modeled action latency for one request's own shape under the
        current precision policy — what a request would cost served alone,
        independent of the padded batch it happens to ride in."""
        return lat_mod.decision_latency(self.latency_cfg,
                                        prompt_len=prompt_len,
                                        gen_tokens=gen_tokens,
                                        w_bits=self.avg_bits)

    def generate(self, batch: Dict[str, jax.Array], *, max_new: int = 16,
                 key=None, temp: float = 0.0, top_k: int = 0,
                 rids=None) -> GenerationResult:
        """batch: {"tokens": (B, S)} (+ vision/audio for those archs).

        ``temp > 0`` samples under a per-call :class:`SamplerPolicy`
        (re-jits only when the policy actually changes); ``temp == 0``
        uses the engine's standing policy (default greedy).  Sampling is
        device-side with lane-indexed keys: row ``b`` draws under
        (seed, rids[b], output position), so a request's tokens are
        reproducible and independent of its batch slot.  ``key`` is
        accepted for backward compatibility — its trailing word seeds the
        policy (``key=None`` keeps seed 0, the historical ``PRNGKey(0)``
        fallback); ``rids`` defaults to ``arange(B)``."""
        if temp > 0.0:
            seed = 0 if key is None else int(np.asarray(key).ravel()[-1])
            self._apply_sampler(SamplerPolicy(temp=temp, top_k=top_k,
                                              seed=seed))
        else:
            self._apply_sampler(self._base_sampler)
        tokens = jnp.asarray(batch["tokens"])
        B, S = tokens.shape
        assert S + max_new <= self.max_ctx, (S, max_new, self.max_ctx)
        rids = jnp.arange(B, dtype=jnp.int32) if rids is None \
            else jnp.asarray(rids, dtype=jnp.int32)
        pos = jnp.zeros((B,), jnp.int32)
        nxt, logits, cache = self._prefill(self.params, batch, rids, pos)
        outs = [nxt]
        for i in range(1, max_new):
            nxt, logits, cache = self._decode(
                self.params, {"token": nxt}, cache, rids,
                jnp.full((B,), i, jnp.int32))
            outs.append(nxt)
        new = jnp.concatenate(outs, axis=1)
        t = self.modeled_latency(S, max_new)
        return GenerationResult(tokens=jnp.concatenate([tokens, new], axis=1),
                                new_tokens=new, latency_s=t,
                                logits_last=logits)

    def score(self, batch: Dict[str, jax.Array]) -> jax.Array:
        """Full-sequence logits (B, S, V) under the current policy."""
        return transformer.forward(self.params, self.cfg, batch, self.ctx,
                                   unroll=self.unroll)
