"""Batched serving engine with FPX-aware execution.

Wraps the model zoo's prefill/decode under jit, carries the decode cache,
and exposes ``generate`` for batched requests.  The engine holds an
``ExecContext`` whose precision policy can be swapped per request wave —
this is how the FPX controller's (model, gamma) decision becomes live
weights-at-bits execution.

The latency attributed to each generation comes from the analytic TPU model
(core.latency); on-CPU wall time is meaningless for the paper's question.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import latency as lat_mod
from repro.models import transformer
from repro.models.modules import ExecContext
from repro.serving import sampler as sampler_mod


@dataclasses.dataclass
class GenerationResult:
    tokens: jax.Array            # (B, prompt + new)
    new_tokens: jax.Array        # (B, max_new)
    latency_s: float             # modeled TPU action latency (decision level)
    logits_last: Optional[jax.Array] = None


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, *,
                 ctx: Optional[ExecContext] = None,
                 max_ctx: int = 4096,
                 latency_cfg: Optional[ModelConfig] = None,
                 avg_bits: float = 16.0,
                 unroll: bool = True):
        """``latency_cfg``: config used for the latency model (the full-scale
        model that this sim-scale model represents); defaults to ``cfg``.
        ``unroll=True`` executes layer loops in python — right for the small
        models served on CPU, and it makes per-name precision policies apply
        directly."""
        self.params = params
        self.cfg = cfg
        self.ctx = ctx or ExecContext()
        self.max_ctx = max_ctx
        self.latency_cfg = latency_cfg or cfg
        self.avg_bits = avg_bits
        self.unroll = unroll
        self._prefill = jax.jit(
            lambda p, b: transformer.prefill(p, cfg, b, self.ctx,
                                             unroll=unroll,
                                             cache_len=max_ctx))
        self._decode = jax.jit(
            lambda p, b, c: transformer.decode_step(p, cfg, b, c, self.ctx,
                                                    unroll=unroll))

    def set_policy(self, policy: Dict[str, int], default_bits: int = 8,
                   avg_bits: Optional[float] = None) -> None:
        """Swap the live FPX assignment (re-jits on next call)."""
        self.ctx = dataclasses.replace(self.ctx, policy=policy,
                                       default_bits=default_bits)
        if avg_bits is not None:
            self.avg_bits = avg_bits
        cfg, max_ctx, unroll = self.cfg, self.max_ctx, self.unroll
        self._prefill = jax.jit(
            lambda p, b: transformer.prefill(p, cfg, b, self.ctx,
                                             unroll=unroll, cache_len=max_ctx))
        self._decode = jax.jit(
            lambda p, b, c: transformer.decode_step(p, cfg, b, c, self.ctx,
                                                    unroll=unroll))

    def modeled_latency(self, prompt_len: int, gen_tokens: int) -> float:
        """Modeled action latency for one request's own shape under the
        current precision policy — what a request would cost served alone,
        independent of the padded batch it happens to ride in."""
        return lat_mod.decision_latency(self.latency_cfg,
                                        prompt_len=prompt_len,
                                        gen_tokens=gen_tokens,
                                        w_bits=self.avg_bits)

    def generate(self, batch: Dict[str, jax.Array], *, max_new: int = 16,
                 key=None, temp: float = 0.0) -> GenerationResult:
        """batch: {"tokens": (B, S)} (+ vision/audio for those archs).

        ``temp > 0`` samples; ``key=None`` then falls back to a fixed seed
        (``PRNGKey(0)``) instead of crashing inside ``jax.random.split`` —
        pass a key explicitly for independent draws across calls."""
        if temp > 0.0 and key is None:
            key = jax.random.PRNGKey(0)
        tokens = jnp.asarray(batch["tokens"])
        B, S = tokens.shape
        assert S + max_new <= self.max_ctx, (S, max_new, self.max_ctx)
        logits, cache = self._prefill(self.params, batch)
        outs = []
        for i in range(max_new):
            if temp <= 0.0:
                nxt = sampler_mod.greedy(logits)
            else:
                key, sub = jax.random.split(key)
                nxt = sampler_mod.temperature(logits, sub, temp)
            outs.append(nxt)
            if i + 1 < max_new:
                logits, cache = self._decode(self.params, {"token": nxt}, cache)
        new = jnp.concatenate(outs, axis=1)
        t = self.modeled_latency(S, max_new)
        return GenerationResult(tokens=jnp.concatenate([tokens, new], axis=1),
                                new_tokens=new, latency_s=t,
                                logits_last=logits)

    def score(self, batch: Dict[str, jax.Array]) -> jax.Array:
        """Full-sequence logits (B, S, V) under the current policy."""
        return transformer.forward(self.params, self.cfg, batch, self.ctx,
                                   unroll=self.unroll)
