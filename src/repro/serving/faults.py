"""Seeded, deterministic fault injection for the serving fleet.

The paper's premise — a late answer is a lost reward — is sharpest when
an engine *fails*: nothing is later than an answer from a crashed
engine.  This module injects failures into the serving stack on the same
``core.latency`` analytic clock every engine already advances, so a
fault schedule is as replayable as the traffic that runs under it:
identical ``(plan seed, traffic seed)`` must produce identical event
sequences, retirements, and emitted tokens (a tested property).

Fault model — four kinds, each a window ``[t, t + duration_s)`` in
analytic-clock seconds on one engine:

* ``"crash"`` — the engine loses all volatile state: every in-flight
  request is reclaimed (pages freed, shared references dropped, lanes
  cleared) and the engine's clock jumps to the end of the down window
  (restart time).  Reclaimed requests go to the crash handler — the
  default re-queues them on the same engine for a full redo; the
  ``FleetRouter`` overrides this to re-route across the fleet; the
  :func:`strand` handler models the naive baseline that simply loses
  them.
* ``"stall"`` — a straggler: the engine freezes for the window (its
  clock jumps over it, making no progress) but keeps its state.  In
  flight requests survive, just late.  Routers detect the unresponsive
  window via :meth:`FaultInjector.dead_window` and open a circuit
  breaker.
* ``"slowdown"`` — transient thermal/contention slowdown: every clock
  charge inside the window is multiplied by ``factor`` (> 1).  Engines
  route charges through ``_charge`` which consults
  :meth:`EngineFaultView.scale`; outside any window the scale is exactly
  1.0, so un-faulted runs stay bit-identical.
* ``"page_pressure"`` — an external tenant squeezes the KV pool: up to
  ``pages`` free pages are seized for the window (returned at its end).
  On the analytic (slot-based) path the same fault seizes ``slots``
  decode slots instead.

Faults *fire* at engine step boundaries — the first scheduling boundary
at or after the fault's ``t`` (charges are atomic; a decode step never
tears in half).  Window *queries* (is the engine responsive at ``t``?)
are pure functions of the plan, independent of how the engine was
driven, which is what keeps detection deterministic regardless of drive
granularity.

Engine protocol (both ``ContinuousBatcher`` and ``ContinuousEngine``
implement it): ``t`` (the clock), ``reclaim_in_flight()``,
``requeue(req)``, ``apply_pressure(fault) -> token`` /
``release_pressure(token)``, and a ``faults`` attribute holding the
:class:`EngineFaultView` this module hands out.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import trace as tr_mod

CRASH = "crash"
STALL = "stall"
SLOWDOWN = "slowdown"
PAGE_PRESSURE = "page_pressure"
KINDS = (CRASH, STALL, SLOWDOWN, PAGE_PRESSURE)


@dataclasses.dataclass(frozen=True, order=True)
class Fault:
    """One scheduled fault on one engine (see module docstring for the
    per-kind semantics of the extra fields)."""
    t: float                   # analytic-clock start
    engine_idx: int
    kind: str
    duration_s: float = 0.0    # window length (crash/stall/slowdown/pressure)
    factor: float = 1.0        # slowdown: clock-charge multiplier (> 1)
    pages: int = 0             # page_pressure: pool pages seized (paged path)
    slots: int = 0             # page_pressure: decode slots seized (analytic)

    def __post_init__(self):
        assert self.kind in KINDS, self.kind
        assert self.duration_s >= 0.0, self.duration_s

    @property
    def end(self) -> float:
        return self.t + self.duration_s


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, time-sorted fault schedule for a fleet."""
    faults: Tuple[Fault, ...]

    def __post_init__(self):
        object.__setattr__(self, "faults",
                           tuple(sorted(self.faults)))

    def __len__(self) -> int:
        return len(self.faults)

    def for_engine(self, idx: int) -> Tuple[Fault, ...]:
        return tuple(f for f in self.faults if f.engine_idx == idx)

    def by_kind(self, kind: str) -> Tuple[Fault, ...]:
        return tuple(f for f in self.faults if f.kind == kind)


def _merge(windows: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """Union of half-open intervals, sorted and coalesced."""
    out: List[Tuple[float, float]] = []
    for s, e in sorted(windows):
        if out and s <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], e))
        else:
            out.append((s, e))
    return out


def generate_plan(n_engines: int, horizon_s: float, *, seed: int = 0,
                  warmup_s: float = 0.0,
                  crash_rate: float = 0.0,
                  crash_down_s: Tuple[float, float] = (2.0, 6.0),
                  stall_rate: float = 0.0,
                  stall_s: Tuple[float, float] = (1.0, 4.0),
                  slowdown_rate: float = 0.0,
                  slowdown_s: Tuple[float, float] = (2.0, 6.0),
                  slowdown_factor: Tuple[float, float] = (1.5, 4.0),
                  pressure_rate: float = 0.0,
                  pressure_s: Tuple[float, float] = (2.0, 6.0),
                  pressure_pages: Tuple[int, int] = (8, 32),
                  pressure_slots: Tuple[int, int] = (1, 2),
                  ) -> FaultPlan:
    """Draw a Poisson fault schedule.  Rates are events per analytic
    second per engine; windows start in ``[warmup_s, horizon_s)``.  The
    draw order is fixed (engine-major, kind-minor), so one seed fully
    determines the plan."""
    rng = np.random.default_rng(seed)
    faults: List[Fault] = []

    def _arrivals(rate: float) -> List[float]:
        if rate <= 0.0:
            return []
        out, t = [], warmup_s
        while True:
            t += float(rng.exponential(1.0 / rate))
            if t >= horizon_s:
                return out
            out.append(t)

    for idx in range(n_engines):
        for t in _arrivals(crash_rate):
            faults.append(Fault(t, idx, CRASH,
                                duration_s=float(rng.uniform(*crash_down_s))))
        for t in _arrivals(stall_rate):
            faults.append(Fault(t, idx, STALL,
                                duration_s=float(rng.uniform(*stall_s))))
        for t in _arrivals(slowdown_rate):
            faults.append(Fault(
                t, idx, SLOWDOWN,
                duration_s=float(rng.uniform(*slowdown_s)),
                factor=float(rng.uniform(*slowdown_factor))))
        for t in _arrivals(pressure_rate):
            faults.append(Fault(
                t, idx, PAGE_PRESSURE,
                duration_s=float(rng.uniform(*pressure_s)),
                pages=int(rng.integers(pressure_pages[0],
                                       pressure_pages[1] + 1)),
                slots=int(rng.integers(pressure_slots[0],
                                       pressure_slots[1] + 1))))
    return FaultPlan(tuple(faults))


def reset_attempt(req):
    """A fresh attempt of a reclaimed request: identity and the original
    absolute deadline survive (``fresh`` copies ``t_arrive`` +
    ``deadline_s``), lifecycle state clears, and the attempt counter
    advances.  Because prompts are rid-seeded and the sampler keys every
    draw by ``(seed, stream, rid, position)``, the redo emits
    byte-identical tokens — recovery is a correctness property."""
    r = req.fresh()
    r.retries = req.retries + 1
    r.hedged = req.hedged
    return r


def strand(idx: int, eng, fault: Fault, reclaimed: Sequence,
           t_detect: float) -> None:
    """The naive crash handler: reclaimed requests are simply lost.
    They retire as drops (so accounting still closes — stranded work is
    a failure, not a dangling request) and are never retried."""
    from repro.serving.continuous import retire_dropped
    for r in reclaimed:
        retire_dropped(eng, r)


class EngineFaultView:
    """The per-engine handle an engine holds as ``self.faults``.  Falsy
    when the engine has no scheduled faults, so ``if self.faults:``
    guards cost one truthiness check on the clean path."""

    def __init__(self, injector: "FaultInjector", idx: int):
        self.injector = injector
        self.idx = idx
        mine = injector.plan.for_engine(idx)
        self._has_faults = len(mine) > 0
        self._slow = tuple(f for f in mine if f.kind == SLOWDOWN)

    def __bool__(self) -> bool:
        return self._has_faults

    def scale(self, t: float) -> float:
        """Clock-charge multiplier at ``t`` (1.0 outside windows).  Hot —
        consulted by every ``_charge`` — so it scans a cached per-engine
        slowdown list instead of the full plan."""
        if not self._slow:
            return 1.0
        s = 1.0
        for f in self._slow:
            if f.t <= t < f.end:
                s *= f.factor
        return s

    def tick(self, eng) -> None:
        """Fire every fault due at the engine's current boundary and
        release expired pressure seizures.  Engines call this at the top
        of every scheduling boundary (``_admit``)."""
        self.injector._tick(self.idx, eng)


class FaultInjector:
    """Replays a :class:`FaultPlan` against live engines and answers
    pure window queries for routers.

    ``on_crash(idx, eng, fault, reclaimed, t_detect)`` decides what
    happens to the requests a crash reclaimed (``t_detect`` is the firing
    boundary, before the engine clock jumps over the dead window); the
    default re-queues each (via
    :func:`reset_attempt`) on the same engine.  A router installs its
    own handler to re-route across the fleet; :func:`strand` models the
    naive fleet that loses them.
    """

    def __init__(self, plan: FaultPlan, *, tracer=None,
                 on_crash: Optional[Callable] = None):
        self.plan = plan
        self.tr = tracer or tr_mod.NULL
        self.on_crash = on_crash
        self._pending: Dict[int, List[Fault]] = {}
        self._dead: Dict[int, List[Tuple[float, float]]] = {}
        for f in plan.faults:
            self._pending.setdefault(f.engine_idx, []).append(f)
        for idx, fs in self._pending.items():
            fs.sort()
            self._dead[idx] = _merge([(f.t, f.end) for f in fs
                                      if f.kind in (CRASH, STALL)])
        #: faults in firing order — the determinism property's witness
        self.fired: List[Fault] = []
        #: live page/slot seizures: (fault, engine, token)
        self._seized: List[Tuple[Fault, object, object]] = []

    # -- wiring ---------------------------------------------------------------

    def view(self, idx: int) -> EngineFaultView:
        return EngineFaultView(self, idx)

    def attach(self, engines: Sequence) -> None:
        """Hand each engine its fault view (``eng.faults``)."""
        for idx, eng in enumerate(engines):
            eng.faults = self.view(idx)

    # -- pure window queries (independent of drive granularity) --------------

    def _covering(self, idx: int, t: float, kind: str) -> List[Fault]:
        return [f for f in self.plan.for_engine(idx)
                if f.kind == kind and f.t <= t < f.end]

    def scale(self, idx: int, t: float) -> float:
        s = 1.0
        for f in self._covering(idx, t, SLOWDOWN):
            s *= f.factor
        return s

    def dead_window(self, idx: int, t: float
                    ) -> Optional[Tuple[float, float]]:
        """The merged crash/stall window covering ``t``, if any — what a
        router's health scan sees as "unresponsive since ``start``"."""
        for s, e in self._dead.get(idx, ()):
            if s <= t < e:
                return (s, e)
            if s > t:
                break
        return None

    def responsive(self, idx: int, t: float) -> bool:
        return self.dead_window(idx, t) is None

    def down_until(self, idx: int, t: float) -> Optional[float]:
        """End of the *crash* window covering ``t`` (None if up)."""
        ends = [f.end for f in self._covering(idx, t, CRASH)]
        return max(ends) if ends else None

    # -- firing ---------------------------------------------------------------

    def _emit(self, f: Fault, t: float) -> None:
        if self.tr:
            args = {"engine_idx": f.engine_idx, "fault": f.kind,
                    "scheduled_t": f.t, "duration_s": f.duration_s}
            if f.kind == SLOWDOWN:
                args["factor"] = f.factor
            if f.kind == PAGE_PRESSURE:
                args["pages"] = f.pages
                args["slots"] = f.slots
            self.tr.instant(tr_mod.FAULT_INJECT, t, track="faults", **args)

    def _crash(self, idx: int, eng, f: Fault) -> None:
        reclaimed = eng.reclaim_in_flight()
        t_detect = eng.t           # firing boundary, *before* the dead jump
        eng.t = max(eng.t, f.end)
        handler = self.on_crash or self._requeue_same_engine
        handler(idx, eng, f, reclaimed, t_detect)

    def _requeue_same_engine(self, idx: int, eng, f: Fault,
                             reclaimed: Sequence, t_detect: float) -> None:
        for r in reclaimed:
            r2 = reset_attempt(r)
            if self.tr:
                self.tr.instant(tr_mod.REQ_REQUEUE, t_detect, track="router",
                                rid=r.rid, cls=r.cls_name, from_engine=idx,
                                attempt=r2.retries, tokens_done=r.tokens_done)
            eng.requeue(r2)

    def _tick(self, idx: int, eng) -> None:
        # release pressure seizures whose window ended
        for entry in [s for s in self._seized
                      if s[0].engine_idx == idx and s[0].end <= eng.t]:
            self._seized.remove(entry)
            entry[1].release_pressure(entry[2])
        due = self._pending.get(idx)
        while due and due[0].t <= eng.t:
            f = due.pop(0)
            self.fired.append(f)
            self._emit(f, eng.t)
            if f.kind == CRASH:
                # A window the engine *skipped over* while idle (a routed-
                # around breaker, a drain horizon past the window) held no
                # volatile state: the crash already happened and healed
                # with nothing to lose.  Firing it against work dispatched
                # after recovery would kill requests the fault never saw.
                if eng.t < f.end:
                    self._crash(idx, eng, f)
            elif f.kind == STALL:
                eng.t = max(eng.t, f.end)   # frozen: no progress, no loss
            elif f.kind == PAGE_PRESSURE:
                if f.end > eng.t:
                    token = eng.apply_pressure(f)
                    if token is not None:
                        self._seized.append((f, eng, token))
            # SLOWDOWN needs no action: _charge consults scale() purely
