"""Deadline-aware routing across a pool of engine operating points.

A fleet is a set of engines pinned at distinct FPX operating points —
(model size, gamma) candidates from the grid ``core.fpx`` builds — each
running its own :class:`~repro.serving.continuous.ContinuousBatcher`.
The router turns the paper's per-decision controller into a traffic-scale
policy: every arriving request is dispatched via
:func:`repro.core.fpx.select_for_slack`, i.e. ``select_for_budget``
evaluated against the request's *remaining deadline slack* after the
queue wait it would inherit on each engine.  Tight budgets therefore fall
through to small/high-gamma engines ("win fast") while loose budgets keep
the full-quality model ("lose slow" is only acceptable when the SLO
allows it).

Realized outcomes feed back: every retired request (completed or dropped)
carries a reward — its traffic class weight times the operating point's
quality, earned only when the deadline was met — and updates a per-class
:class:`~repro.core.fpx.OnlineSelector`.  ``mode="bandit"`` routes purely
from that learned state, automating the paper's per-task gamma sweep at
fleet scale; ``mode="fpx"`` (default) routes from the model-based slack
rule.  A *static* baseline is just a fleet whose pool is one operating
point replicated — the identical router then degrades into least-loaded
balancing, which keeps capacity comparisons fair.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core import assign as assign_mod
from repro.core import fpx
from repro.core.fpx import Candidate, OnlineSelector, SpecPoint
from repro.core.latency import Hardware, V5E
from repro.core import latency as lat_mod

from repro.obs import trace as tr_mod
from repro.serving.continuous import ContinuousBatcher, LatencyProfile
from repro.serving.traffic import SimRequest


def pool_candidates(points: Sequence[Tuple[str, ModelConfig, Dict[str, float],
                                           float]],
                    *, prompt_len: int = 256, gen_tokens: int = 16,
                    hw: Hardware = V5E) -> List[Candidate]:
    """Build the fleet's operating points.

    ``points``: (model_name, latency_cfg, eps calibration, gamma) — one
    chosen cell of the (model x gamma) grid per engine, rather than the
    full cross product ``fpx.make_grid`` enumerates."""
    out = []
    for name, cfg, eps, gamma in points:
        a = assign_mod.assign_precision(eps, gamma)
        bits = assign_mod.avg_bits(a)
        t = lat_mod.decision_latency(cfg, prompt_len=prompt_len,
                                     gen_tokens=gen_tokens, w_bits=bits,
                                     hw=hw)
        out.append(Candidate(model_name=name, cfg=cfg, gamma=gamma,
                             assignment=a, avg_bits=bits, latency_s=t))
    return out


# ---------------------------------------------------------------------------
# The reference fleet: the pool the serving benchmark, example, and
# acceptance test all share.  Operating points span ~8ms to ~230ms per
# action (see traffic.py's deadline calibration note); the quality proxy
# is the family's quality ordering with the paper's mild gamma
# degradation (Table 2: modest accuracy cost for large latency wins).
# ---------------------------------------------------------------------------

DEMO_POINTS: Tuple[Tuple[str, float], ...] = (
    ("qwen2.5-1.5b", 1.0),
    ("qwen2.5-3b", 0.6),
    ("qwen2.5-7b", 0.4),
    ("qwen2.5-14b", 0.0),
)

DEMO_BASE_QUALITY = {"qwen2.5-1.5b": 0.60, "qwen2.5-3b": 0.72,
                     "qwen2.5-7b": 0.84, "qwen2.5-14b": 0.94}
DEMO_GAMMA_PENALTY = 0.25


def demo_quality(c: Candidate) -> float:
    return DEMO_BASE_QUALITY[c.model_name] * (1.0 - DEMO_GAMMA_PENALTY
                                              * c.gamma)


def _synthetic_eps(cfg: ModelConfig, seed: int = 0) -> Dict[str, float]:
    """Stand-in Algorithm-1 sensitivities for latency-only fleet work
    (per-layer spread matters for the assignment, absolute values don't)."""
    rng = np.random.default_rng(seed)
    return {f"L{i}.lin{j}": float(rng.uniform(0.05, 0.9))
            for i in range(cfg.n_layers) for j in range(4)}


def demo_pool(*, hw: Hardware = V5E) -> List[Candidate]:
    """The canonical four-engine demo pool over the qwen2.5 family."""
    return pool_candidates(
        [(name, get_config(name), _synthetic_eps(get_config(name)), g)
         for name, g in DEMO_POINTS], hw=hw)


def spec_variants(pool: Sequence[Candidate], *,
                  models: Sequence[str] = ("qwen2.5-7b", "qwen2.5-14b"),
                  ks: Sequence[int] = (2, 4), accept: float = 0.8,
                  draft_name: Optional[str] = "qwen2.5-1.5b",
                  ) -> List[Candidate]:
    """Widen an operating-point pool along the speculation axis: for each
    candidate of the named (large) models, add a fast-draft / slow-verify
    variant per draft depth in ``ks``.  Quality is unchanged — the
    verifier's output distribution is exactly the dense candidate's — so
    the variants differ only in *priced* throughput: cheaper per token
    above the break-even acceptance rate, honestly slower below it, and
    they collapse to dense steps under deadline pressure.  The per-class
    :class:`~repro.core.fpx.OnlineSelector` then learns draft depth per
    traffic class exactly as it learns (model, gamma): draft aggressively
    where slack is rich, stay dense where deadlines are tight.

    ``draft_name``: the small FPX point doing the drafting in the
    analytic fleet (cross-model pricing); ``None`` prices self-drafting
    at ``SpecPoint.draft_bits``."""
    out = list(pool)
    for c in pool:
        if c.model_name in models and c.spec is None:
            out.extend(dataclasses.replace(
                c, spec=SpecPoint(k=k, accept=accept,
                                  draft_name=draft_name)) for k in ks)
    return out


def demo_spec_pool(*, hw: Hardware = V5E, ks: Sequence[int] = (2, 4),
                   accept: float = 0.8) -> List[Candidate]:
    """The demo pool widened along the speculation axis: the two large
    verifiers (7b, 14b) each gain draft-depth variants drafted by the
    1.5b point."""
    return spec_variants(demo_pool(hw=hw), ks=ks, accept=accept)


def _no_prefix(req) -> int:
    """Fallback ``cached_prefix_len`` for engines without a prefix cache."""
    return 0


class FleetRouter:
    """Dispatch + feedback loop over a pool of continuous batchers."""

    def __init__(self, candidates: Sequence[Candidate], *,
                 quality: Callable[[Candidate], float],
                 slots: int = 4, policy: str = "degrade",
                 mode: str = "fpx", epsilon: float = 0.1, seed: int = 0,
                 hw: Hardware = V5E, engines: Optional[Sequence] = None,
                 tracer=None):
        """``engines``: optional pre-built engine per candidate — anything
        speaking the batcher interface (``submit / drain / backlog_s /
        profile / on_retire``), e.g. live paged
        :class:`~repro.serving.paged_engine.ContinuousEngine` instances.
        Default: one analytic ``ContinuousBatcher`` per operating point.

        ``tracer``: a :class:`repro.obs.Tracer`; routing decisions and
        retirements land on the ``router`` track, and each internally
        built engine gets a :meth:`~repro.obs.Tracer.scope` named
        ``eng<i>:<model>-g<gamma>`` so one fleet trace carries every
        engine's lanes and pool as its own Perfetto process.  Pre-built
        ``engines`` keep whatever tracer they were constructed with.
        None = the zero-overhead null tracer."""
        assert mode in ("fpx", "bandit"), mode
        self.cands = list(candidates)
        self.quality = quality
        self.mode = mode
        self.epsilon = epsilon
        self.seed = seed
        self.tr = tracer or tr_mod.NULL
        if engines is None:
            self.engines = [
                ContinuousBatcher(
                    LatencyProfile(
                        c.cfg, c.avg_bits, hw=hw, spec=c.spec,
                        draft_cfg=get_config(c.spec.draft_name)
                        if c.spec is not None and c.spec.draft_name
                        else None),
                    slots=slots, policy=policy, on_retire=self._retire,
                    tracer=self.tr.scope(
                        f"eng{i}:{c.model_name}-g{c.gamma:g}"
                        + (f"-k{c.spec.k}" if c.spec else ""))
                    if self.tr else None)
                for i, c in enumerate(self.cands)]
        else:
            assert len(engines) == len(self.cands), \
                (len(engines), len(self.cands))
            self.engines = list(engines)
            for e in self.engines:
                e.on_retire = self._retire
        self.selectors: Dict[str, OnlineSelector] = {}
        self.retired: List[SimRequest] = []

    # -- feedback -----------------------------------------------------------

    def _selector(self, cls_name: str) -> OnlineSelector:
        sel = self.selectors.get(cls_name)
        if sel is None:
            sel = OnlineSelector(self.cands, epsilon=self.epsilon,
                                 seed=self.seed + len(self.selectors),
                                 prior_quality=self.quality)
            self.selectors[cls_name] = sel
        return sel

    def _retire(self, req: SimRequest) -> None:
        """Realized reward: quality earned only by on-time tokens (goodput
        semantics — a late or dropped action is worth nothing)."""
        cand = self.cands[req.engine_idx]
        if req.met_deadline and not req.dropped and req.max_new:
            frac = req.tokens_done / req.max_new
            req.reward = req.reward_weight * self.quality(cand) * frac
        else:
            req.reward = 0.0
        self._selector(req.cls_name).update(req.engine_idx, req.reward)
        self.retired.append(req)
        if self.tr:
            self.tr.instant(tr_mod.ROUTE_RETIRE, req.t_finish,
                            track="router", rid=req.rid, cls=req.cls_name,
                            engine_idx=req.engine_idx, reward=req.reward,
                            dropped=req.dropped)

    # -- dispatch -----------------------------------------------------------

    def dispatch(self, req: SimRequest) -> int:
        waits = [e.backlog_s(req.t_arrive) for e in self.engines]
        # prefix-aware service estimates: an engine holding this prompt's
        # prefix warm (cached_prefix_len > 0) skips that span's prefill,
        # so its estimate drops by the resume discount — session turns
        # gravitate to the engine already holding their pages.  Engines
        # without the hook (or without a warm prefix) keep the historical
        # estimate exactly.
        cached = [getattr(e, "cached_prefix_len", _no_prefix)(req)
                  for e in self.engines]
        lats = []
        for e, l in zip(self.engines, cached):
            t = e.profile.service_s(req.prompt_len, req.max_new)
            if l:
                t -= (e.profile.prefill_s(req.prompt_len)
                      - e.profile.prefill_s(req.prompt_len - l, context=l))
            lats.append(t)
        # first-token slack: with a streaming SLO, engines whose projected
        # TTFT (wait + discounted prefill + one uncontended step — a
        # first-order estimate, same spirit as backlog_s) misses the
        # budget are excluded, unless that excludes everyone — then the
        # completion-deadline rule decides alone rather than deadlocking.
        ok = None
        if req.ttft_deadline_s is not None:
            ok = [w + e.profile.prefill_s(req.prompt_len - l, context=l)
                  + e.profile.tok_s(1, req.prompt_len + 1)
                  <= req.ttft_deadline_s
                  for e, w, l in zip(self.engines, waits, cached)]
            if not any(ok):
                ok = None
        if self.mode == "bandit":
            fits = [w + t <= req.deadline_s for w, t in zip(waits, lats)]
            if ok is not None:
                fits = [f and o for f, o in zip(fits, ok)]
            idx = self._selector(req.cls_name).choose(waits, feasible=fits)
        else:
            cands = [dataclasses.replace(c, latency_s=t)
                     for c, t in zip(self.cands, lats)]
            if ok is not None:
                sub = [i for i, o in enumerate(ok) if o]
                pick = fpx.select_for_slack([cands[i] for i in sub],
                                            req.deadline_s,
                                            [waits[i] for i in sub],
                                            self.quality)
                idx = sub[pick]
            else:
                idx = fpx.select_for_slack(cands, req.deadline_s, waits,
                                           self.quality)
        req.engine_idx = idx
        if self.tr:
            self.tr.instant(tr_mod.ROUTE_DISPATCH, req.t_arrive,
                            track="router", rid=req.rid, cls=req.cls_name,
                            engine_idx=idx, cached=cached[idx])
        self.engines[idx].submit(req)
        return idx

    # -- simulation ---------------------------------------------------------

    def run(self, arrivals: Sequence[SimRequest]) -> List[SimRequest]:
        """Replay a time-ordered arrival stream through the fleet and drain
        it; returns every retired request (completed and dropped)."""
        for req in arrivals:
            for eng in self.engines:
                eng.drain(until=req.t_arrive)
            self.dispatch(req)
        for eng in self.engines:
            eng.drain()
        return self.retired
