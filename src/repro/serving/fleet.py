"""Deadline-aware routing across a pool of engine operating points.

A fleet is a set of engines pinned at distinct FPX operating points —
(model size, gamma) candidates from the grid ``core.fpx`` builds — each
running its own :class:`~repro.serving.continuous.ContinuousBatcher`.
The router turns the paper's per-decision controller into a traffic-scale
policy: every arriving request is dispatched via
:func:`repro.core.fpx.select_for_slack`, i.e. ``select_for_budget``
evaluated against the request's *remaining deadline slack* after the
queue wait it would inherit on each engine.  Tight budgets therefore fall
through to small/high-gamma engines ("win fast") while loose budgets keep
the full-quality model ("lose slow" is only acceptable when the SLO
allows it).

Realized outcomes feed back: every retired request (completed or dropped)
carries a reward — its traffic class weight times the operating point's
quality, earned only when the deadline was met — and updates a per-class
:class:`~repro.core.fpx.OnlineSelector`.  ``mode="bandit"`` routes purely
from that learned state, automating the paper's per-task gamma sweep at
fleet scale; ``mode="fpx"`` (default) routes from the model-based slack
rule.  A *static* baseline is just a fleet whose pool is one operating
point replicated — the identical router then degrades into least-loaded
balancing, which keeps capacity comparisons fair.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core import assign as assign_mod
from repro.core import fpx
from repro.core.fpx import Candidate, OnlineSelector, SpecPoint
from repro.core.latency import Hardware, V5E
from repro.core import latency as lat_mod

from repro.obs import trace as tr_mod
from repro.serving import faults as faults_mod
from repro.serving.continuous import ContinuousBatcher, LatencyProfile
from repro.serving.traffic import SimRequest


def pool_candidates(points: Sequence[Tuple[str, ModelConfig, Dict[str, float],
                                           float]],
                    *, prompt_len: int = 256, gen_tokens: int = 16,
                    hw: Hardware = V5E) -> List[Candidate]:
    """Build the fleet's operating points.

    ``points``: (model_name, latency_cfg, eps calibration, gamma) — one
    chosen cell of the (model x gamma) grid per engine, rather than the
    full cross product ``fpx.make_grid`` enumerates."""
    out = []
    for name, cfg, eps, gamma in points:
        a = assign_mod.assign_precision(eps, gamma)
        bits = assign_mod.avg_bits(a)
        t = lat_mod.decision_latency(cfg, prompt_len=prompt_len,
                                     gen_tokens=gen_tokens, w_bits=bits,
                                     hw=hw)
        out.append(Candidate(model_name=name, cfg=cfg, gamma=gamma,
                             assignment=a, avg_bits=bits, latency_s=t))
    return out


# ---------------------------------------------------------------------------
# The reference fleet: the pool the serving benchmark, example, and
# acceptance test all share.  Operating points span ~8ms to ~230ms per
# action (see traffic.py's deadline calibration note); the quality proxy
# is the family's quality ordering with the paper's mild gamma
# degradation (Table 2: modest accuracy cost for large latency wins).
# ---------------------------------------------------------------------------

DEMO_POINTS: Tuple[Tuple[str, float], ...] = (
    ("qwen2.5-1.5b", 1.0),
    ("qwen2.5-3b", 0.6),
    ("qwen2.5-7b", 0.4),
    ("qwen2.5-14b", 0.0),
)

DEMO_BASE_QUALITY = {"qwen2.5-1.5b": 0.60, "qwen2.5-3b": 0.72,
                     "qwen2.5-7b": 0.84, "qwen2.5-14b": 0.94}
DEMO_GAMMA_PENALTY = 0.25


def demo_quality(c: Candidate) -> float:
    return DEMO_BASE_QUALITY[c.model_name] * (1.0 - DEMO_GAMMA_PENALTY
                                              * c.gamma)


def _synthetic_eps(cfg: ModelConfig, seed: int = 0) -> Dict[str, float]:
    """Stand-in Algorithm-1 sensitivities for latency-only fleet work
    (per-layer spread matters for the assignment, absolute values don't)."""
    rng = np.random.default_rng(seed)
    return {f"L{i}.lin{j}": float(rng.uniform(0.05, 0.9))
            for i in range(cfg.n_layers) for j in range(4)}


def demo_pool(*, hw: Hardware = V5E) -> List[Candidate]:
    """The canonical four-engine demo pool over the qwen2.5 family."""
    return pool_candidates(
        [(name, get_config(name), _synthetic_eps(get_config(name)), g)
         for name, g in DEMO_POINTS], hw=hw)


def spec_variants(pool: Sequence[Candidate], *,
                  models: Sequence[str] = ("qwen2.5-7b", "qwen2.5-14b"),
                  ks: Sequence[int] = (2, 4), accept: float = 0.8,
                  draft_name: Optional[str] = "qwen2.5-1.5b",
                  ) -> List[Candidate]:
    """Widen an operating-point pool along the speculation axis: for each
    candidate of the named (large) models, add a fast-draft / slow-verify
    variant per draft depth in ``ks``.  Quality is unchanged — the
    verifier's output distribution is exactly the dense candidate's — so
    the variants differ only in *priced* throughput: cheaper per token
    above the break-even acceptance rate, honestly slower below it, and
    they collapse to dense steps under deadline pressure.  The per-class
    :class:`~repro.core.fpx.OnlineSelector` then learns draft depth per
    traffic class exactly as it learns (model, gamma): draft aggressively
    where slack is rich, stay dense where deadlines are tight.

    ``draft_name``: the small FPX point doing the drafting in the
    analytic fleet (cross-model pricing); ``None`` prices self-drafting
    at ``SpecPoint.draft_bits``."""
    out = list(pool)
    for c in pool:
        if c.model_name in models and c.spec is None:
            out.extend(dataclasses.replace(
                c, spec=SpecPoint(k=k, accept=accept,
                                  draft_name=draft_name)) for k in ks)
    return out


def demo_spec_pool(*, hw: Hardware = V5E, ks: Sequence[int] = (2, 4),
                   accept: float = 0.8) -> List[Candidate]:
    """The demo pool widened along the speculation axis: the two large
    verifiers (7b, 14b) each gain draft-depth variants drafted by the
    1.5b point."""
    return spec_variants(demo_pool(hw=hw), ks=ks, accept=accept)


def _no_prefix(req) -> int:
    """Fallback ``cached_prefix_len`` for engines without a prefix cache."""
    return 0


@dataclasses.dataclass
class EngineHealth:
    """Circuit-breaker state for one engine.  ``up`` (breaker closed) is
    the routable state; an open breaker records why it opened, since
    when, and the exponential-backoff probe schedule that will close it."""
    up: bool = True
    reason: Optional[str] = None         # "crash" | "stall" while down
    down_since: Optional[float] = None
    next_probe: Optional[float] = None
    backoff_s: float = 0.0


class FleetRouter:
    """Dispatch + feedback loop over a pool of continuous batchers.

    With a :class:`~repro.serving.faults.FaultInjector` attached the
    router is also the fleet's failure domain: crashes push a reclaim
    callback (in-flight work restarts token-identically on the healthy
    remainder), stalls are pulled by a heartbeat scan that opens a
    circuit breaker after ``stall_timeout_s`` of silence, open breakers
    probe with exponential backoff, and (optionally) requests stuck in a
    queue longer than a p99-derived delay are hedged — duplicated onto a
    second engine, first finisher wins, loser torn down mid-decode by
    the barge-in path."""

    #: cadence of health/hedge sweeps once arrivals stop (simulated s)
    _SCAN_SLICE_S = 0.025

    def __init__(self, candidates: Sequence[Candidate], *,
                 quality: Callable[[Candidate], float],
                 slots: int = 4, policy: str = "degrade",
                 mode: str = "fpx", epsilon: float = 0.1, seed: int = 0,
                 hw: Hardware = V5E, engines: Optional[Sequence] = None,
                 tracer=None, injector=None,
                 stall_timeout_s: float = 0.25,
                 probe_backoff_s: float = 0.5,
                 hedge: bool = False,
                 hedge_delay_s: Optional[float] = None,
                 recover: bool = True,
                 placements: Optional[Sequence] = None,
                 topo=None, net_aware: bool = True):
        """``engines``: optional pre-built engine per candidate — anything
        speaking the batcher interface (``submit / drain / backlog_s /
        profile / on_retire``), e.g. live paged
        :class:`~repro.serving.paged_engine.ContinuousEngine` instances.
        Default: one analytic ``ContinuousBatcher`` per operating point.

        ``tracer``: a :class:`repro.obs.Tracer`; routing decisions and
        retirements land on the ``router`` track, and each internally
        built engine gets a :meth:`~repro.obs.Tracer.scope` named
        ``eng<i>:<model>-g<gamma>`` so one fleet trace carries every
        engine's lanes and pool as its own Perfetto process.  Pre-built
        ``engines`` keep whatever tracer they were constructed with.
        None = the zero-overhead null tracer.

        ``injector``: a :class:`~repro.serving.faults.FaultInjector`; the
        router attaches it to the engines and installs itself as the
        crash handler (reclaimed work re-routes across the fleet).

        ``hedge`` / ``hedge_delay_s``: enable hedged dispatch.  An
        explicit delay is used as-is; with ``hedge=True`` alone the
        delay is learned online as the p99 of observed request latencies
        (no hedging until 16 samples exist — hedging against a tail you
        have not measured is just doubling load).

        ``recover``: with ``False`` the fleet still detects crashes and
        opens breakers, but reclaimed in-flight work is *stranded*
        (dropped) instead of re-dispatched — the naive baseline the
        fault benchmark compares recovery against.

        ``placements`` / ``topo``: pin each candidate to a
        :class:`~repro.launch.placement.Placement` on a
        :class:`~repro.launch.placement.Topology`.  Internally built
        engines then price their placement's physics — ``tp``-way
        compute split plus per-layer all-reduces over the placement's
        link — and every dispatch *applies* the topology's network hops
        to the chosen request (prompt-landing ``t_ready``, response-hop
        ``net_out_s``), whether or not the router priced them.
        ``net_aware=False`` is the blind arm: routing projections use
        each profile's :meth:`~repro.serving.continuous.LatencyProfile.
        net_blind` twin and ignore dispatch hops, so a DCN-spanning
        engine looks as fast as an ICI one — the physics still bites,
        and the mispricing shows up as goodput lost
        (``benchmarks/table_sharded.py``)."""
        assert mode in ("fpx", "bandit"), mode
        self.cands = list(candidates)
        self.quality = quality
        self.mode = mode
        self.epsilon = epsilon
        self.seed = seed
        self.tr = tracer or tr_mod.NULL
        if placements is not None:
            assert len(placements) == len(self.cands), \
                (len(placements), len(self.cands))
        self.placements = list(placements) if placements is not None \
            else None
        self.topo = topo
        self.net_aware = net_aware
        if engines is None:
            self.engines = [
                ContinuousBatcher(
                    LatencyProfile(
                        c.cfg, c.avg_bits, hw=hw, spec=c.spec,
                        draft_cfg=get_config(c.spec.draft_name)
                        if c.spec is not None and c.spec.draft_name
                        else None,
                        tp=self.placements[i].tp if self.placements
                        else 1,
                        tp_link=self.placements[i].link
                        if self.placements else "ici"),
                    slots=slots, policy=policy, on_retire=self._retire,
                    tracer=self.tr.scope(
                        f"eng{i}:{c.model_name}-g{c.gamma:g}"
                        + (f"-k{c.spec.k}" if c.spec else ""))
                    if self.tr else None)
                for i, c in enumerate(self.cands)]
        else:
            assert len(engines) == len(self.cands), \
                (len(engines), len(self.cands))
            self.engines = list(engines)
            for e in self.engines:
                e.on_retire = self._retire
        self.selectors: Dict[str, OnlineSelector] = {}
        self.retired: List[SimRequest] = []
        # -- failure handling -----------------------------------------------
        self.injector = injector
        self.health = [EngineHealth() for _ in self.engines]
        self.stall_timeout_s = stall_timeout_s
        self.probe_backoff_s = probe_backoff_s
        self.hedge_enabled = hedge or hedge_delay_s is not None
        self.hedge_delay_s = hedge_delay_s
        #: rid -> {attempts, done, t_disp} while any attempt is in flight
        self._flights: Dict[int, Dict] = {}
        self._lat_samples: List[float] = []
        if injector is not None:
            injector.attach(self.engines)
            injector.on_crash = (self._on_crash if recover
                                 else self._on_crash_strand)

    # -- feedback -----------------------------------------------------------

    def _selector(self, cls_name: str) -> OnlineSelector:
        sel = self.selectors.get(cls_name)
        if sel is None:
            sel = OnlineSelector(self.cands, epsilon=self.epsilon,
                                 seed=self.seed + len(self.selectors),
                                 prior_quality=self.quality)
            self.selectors[cls_name] = sel
        return sel

    def _retire(self, req: SimRequest) -> None:
        """Engine retirement callback: one *attempt* ended.  Unhedged rids
        account directly; hedged rids wait until every attempt lands,
        then resolve to a single winner."""
        fl = self._flights.get(req.rid)
        if fl is None:
            self._account(req)
            return
        fl["done"].append(req)
        if (len(fl["attempts"]) > 1 and len(fl["done"]) == 1
                and not req.dropped and not req.cancelled):
            # first clean finisher: barge in on the still-running sibling
            # (retires via the engines' cancel sweep, pages reclaimed)
            for sib in fl["attempts"]:
                if sib is not req and sib.t_finish is None:
                    sib.t_cancel = req.t_finish
                    sib.hedge_loser = True
        if len(fl["done"]) >= len(fl["attempts"]):
            self._resolve_flight(req.rid, fl)

    def _resolve_flight(self, rid: int, fl: Dict) -> None:
        """Every attempt of a hedged rid has retired: pick the winner —
        the earliest *clean* finish, falling back to earliest anything —
        and account the rid exactly once, by that attempt.  Losers are
        flagged so metrics exclude them from per-request tallies."""
        del self._flights[rid]
        done = fl["done"]
        if len(done) == 1:
            self._account(done[0])
            return
        clean = [a for a in done if not a.cancelled and not a.dropped]
        win = min(clean or done, key=lambda a: a.t_finish)
        for a in done:
            a.hedge_loser = a is not win
            if a is not win:
                self.retired.append(a)
        self._account(win)

    def _account(self, req: SimRequest) -> None:
        """Realized reward: quality earned only by on-time tokens (goodput
        semantics — a late or dropped action is worth nothing)."""
        if req.net_out_s and req.t_finish is not None and not req.dropped:
            # client-facing clock: the response hop lands net_out_s after
            # the engine finished.  met_deadline was already judged
            # against the hop-shrunk engine deadline, so on-time stays
            # on-time — only the reported finish/latency move.
            req.t_finish += req.net_out_s
            req.latency_s = req.t_finish - req.t_arrive
        cand = self.cands[req.engine_idx]
        if req.met_deadline and not req.dropped and req.max_new:
            frac = req.tokens_done / req.max_new
            req.reward = req.reward_weight * self.quality(cand) * frac
        else:
            req.reward = 0.0
        self._selector(req.cls_name).update(req.engine_idx, req.reward)
        self.retired.append(req)
        if not req.dropped and req.latency_s is not None:
            self._lat_samples.append(req.latency_s)
        if self.tr:
            self.tr.instant(tr_mod.ROUTE_RETIRE, req.t_finish,
                            track="router", rid=req.rid, cls=req.cls_name,
                            engine_idx=req.engine_idx, reward=req.reward,
                            dropped=req.dropped)

    # -- failure detection + recovery ---------------------------------------

    def _mark_down(self, idx: int, t: float, reason: str,
                   in_flight: int) -> None:
        h = self.health[idx]
        h.up = False
        h.reason = reason
        h.down_since = t
        h.backoff_s = self.probe_backoff_s
        h.next_probe = t + h.backoff_s
        if self.tr:
            self.tr.instant(tr_mod.ENGINE_DOWN, t, track="router",
                            engine_idx=idx, reason=reason,
                            in_flight=in_flight)

    def _on_crash(self, idx: int, eng, fault, reclaimed: Sequence,
                  t_detect: float) -> None:
        """Injector crash handler: engine ``idx`` lost its volatile state.
        Reclaimed requests — decoding lanes *and* the queue that died
        with the process — restart as fresh attempts on the rest of the
        fleet.  Because prompts are rid-seeded and the sampler keys every
        draw by (seed, stream, rid, position), each redo emits tokens
        byte-identical to the attempt that died: recovery is exact, not
        best-effort."""
        if self.health[idx].up:
            self._mark_down(idx, t_detect, "crash", len(reclaimed))
        for r in reclaimed:
            fl = self._flights.get(r.rid)
            if fl is not None:
                # identity, not ==: sibling attempts of one rid can be
                # value-equal while queued
                fl["attempts"] = [a for a in fl["attempts"] if a is not r]
                fl["t_disp"].pop(id(r), None)
                if fl["done"]:
                    # a sibling already answered this rid — the crashed
                    # duplicate is moot; resolve if it was the last one out
                    if len(fl["done"]) >= len(fl["attempts"]):
                        self._resolve_flight(r.rid, fl)
                    continue
            r2 = faults_mod.reset_attempt(r)
            if self.tr:
                self.tr.instant(tr_mod.REQ_REQUEUE, t_detect,
                                track="router", rid=r.rid, cls=r.cls_name,
                                from_engine=idx, attempt=r2.retries,
                                tokens_done=r.tokens_done)
            self.dispatch(r2, now=t_detect, exclude=(idx,))

    def _on_crash_strand(self, idx: int, eng, fault, reclaimed: Sequence,
                         t_detect: float) -> None:
        """``recover=False`` crash handler: same detection (the breaker
        still opens, routing still steers around the outage) but the
        reclaimed work is dropped on the floor — what a fleet without
        token-exact recovery loses to the same fault schedule."""
        if self.health[idx].up:
            self._mark_down(idx, t_detect, "crash", len(reclaimed))
        faults_mod.strand(idx, eng, fault, reclaimed, t_detect)

    def _health_scan(self, t: float) -> None:
        """Stall detection + breaker probing.  Crashes are *pushed* by the
        injector the moment they fire; stalls are *pulled* — an engine
        inside a dead window answers no heartbeat, and after
        ``stall_timeout_s`` of silence the breaker opens (state survives
        a stall, so nothing is reclaimed — the engine just stops taking
        new work).  Open breakers probe with exponential backoff and
        close on the first response."""
        if self.injector is None:
            return
        for i, h in enumerate(self.health):
            if h.up:
                win = self.injector.dead_window(i, t)
                if win is not None and t - win[0] >= self.stall_timeout_s:
                    self._mark_down(i, t, "stall",
                                    self.engines[i]._n_active())
            elif h.next_probe is not None and t >= h.next_probe:
                if self.injector.responsive(i, t):
                    if self.tr:
                        self.tr.instant(tr_mod.ENGINE_UP, t,
                                        track="router", engine_idx=i,
                                        down_s=t - h.down_since)
                    self.health[i] = EngineHealth()
                else:
                    h.backoff_s *= 2.0
                    h.next_probe = t + h.backoff_s

    def _hedge_delay(self) -> Optional[float]:
        if self.hedge_delay_s is not None:
            return self.hedge_delay_s
        if len(self._lat_samples) < 16:
            return None
        return float(np.quantile(np.asarray(self._lat_samples), 0.99))

    def _hedge_scan(self, t: float) -> None:
        """Tail-latency insurance: a dispatched request still *queued*
        (never admitted) ``delay`` seconds later is probably behind a
        stall the breaker has not caught yet or a backlog estimate that
        aged badly.  Launch one duplicate attempt on a different engine;
        the first finisher wins, the other is torn down by the barge-in
        path and flagged ``hedge_loser`` so the rid counts once."""
        if not self.hedge_enabled:
            return
        delay = self._hedge_delay()
        if delay is None:
            return
        for rid, fl in list(self._flights.items()):
            if len(fl["attempts"]) != 1 or fl["done"]:
                continue                    # already hedged / resolving
            a = fl["attempts"][0]
            if (a.t_admit is not None or a.t_finish is not None
                    or a.deadline_abs <= t
                    or t - fl["t_disp"][id(a)] < delay):
                continue
            clone = a.fresh()
            clone.retries = a.retries
            a.hedged = clone.hedged = True
            if self.tr:
                self.tr.instant(tr_mod.ROUTE_HEDGE, t, track="router",
                                rid=rid, cls=a.cls_name,
                                primary_engine=a.engine_idx,
                                waited_s=t - fl["t_disp"][id(a)])
            self.dispatch(clone, now=t, exclude=(a.engine_idx,))

    # -- dispatch -----------------------------------------------------------

    def dispatch(self, req: SimRequest, *, now: Optional[float] = None,
                 exclude: Sequence[int] = ()) -> int:
        """Route one request (or one recovery / hedge attempt).

        ``now`` defaults to the request's arrival; crash re-dispatch and
        hedging pass detection time instead, so feasibility is judged on
        the budget *remaining* — the deadline clock does not restart on
        retry.  ``exclude`` removes engines from consideration (the
        crashed source, the hedged primary); open circuit breakers are
        excluded automatically, falling back to the full pool when
        nothing is routable rather than deadlocking."""
        now = req.t_arrive if now is None else now
        budget_s = req.deadline_abs - now
        avail = [i for i in range(len(self.engines))
                 if self.health[i].up and i not in exclude]
        if not avail:
            avail = [i for i in range(len(self.engines))
                     if i not in exclude] or list(range(len(self.engines)))
        engines = [self.engines[i] for i in avail]
        waits = [e.backlog_s(now) for e in engines]
        # network hops per engine: (inbound, outbound, link) from the
        # topology.  Aware routing folds both hops into the wait term
        # (the prompt can't start before it lands, the response eats
        # deadline on the way back) and prices engines with their true
        # collective-taxed profiles; blind routing uses the collective-
        # free net_blind twins and ignores hops — but the chosen
        # engine's physics is APPLIED below either way.
        xfers = [(0.0, 0.0, "local")] * len(avail)
        profs = [e.profile for e in engines]
        if self.topo is not None and self.placements is not None:
            xfers = [self.topo.dispatch(self.placements[i],
                                        req.prompt_len, req.max_new)
                     for i in avail]
            if self.net_aware:
                waits = [w + x[0] + x[1] for w, x in zip(waits, xfers)]
        if not self.net_aware:
            profs = [p.net_blind() for p in profs]
        # prefix-aware service estimates: an engine holding this prompt's
        # prefix warm (cached_prefix_len > 0) skips that span's prefill,
        # so its estimate drops by the resume discount — session turns
        # gravitate to the engine already holding their pages.  Engines
        # without the hook (or without a warm prefix) keep the historical
        # estimate exactly.
        cached = [getattr(e, "cached_prefix_len", _no_prefix)(req)
                  for e in engines]
        lats = []
        for p, l in zip(profs, cached):
            t = p.service_s(req.prompt_len, req.max_new)
            if l:
                t -= (p.prefill_s(req.prompt_len)
                      - p.prefill_s(req.prompt_len - l, context=l))
            lats.append(t)
        # first-token slack: with a streaming SLO, engines whose projected
        # TTFT (wait + discounted prefill + one uncontended step — a
        # first-order estimate, same spirit as backlog_s) misses the
        # budget are excluded, unless that excludes everyone — then the
        # completion-deadline rule decides alone rather than deadlocking.
        ok = None
        if req.ttft_deadline_s is not None:
            ttft_budget = req.t_arrive + req.ttft_deadline_s - now
            ok = [w + p.prefill_s(req.prompt_len - l, context=l)
                  + p.tok_s(1, req.prompt_len + 1) <= ttft_budget
                  for p, w, l in zip(profs, waits, cached)]
            if not any(ok):
                ok = None
        if self.mode == "bandit":
            n = len(self.engines)
            full_waits = [float("inf")] * n
            feasible = [False] * n
            for j, i in enumerate(avail):
                full_waits[i] = waits[j]
                feasible[i] = (waits[j] + lats[j] <= budget_s
                               and (ok is None or ok[j]))
            idx = self._selector(req.cls_name).choose(full_waits,
                                                      feasible=feasible)
            j = avail.index(idx)
        else:
            sub = (list(range(len(avail))) if ok is None
                   else [i for i, o in enumerate(ok) if o])
            cands = [dataclasses.replace(self.cands[avail[i]],
                                         latency_s=lats[i]) for i in sub]
            pick = fpx.select_for_slack(cands, budget_s,
                                        [waits[i] for i in sub],
                                        self.quality)
            j = sub[pick]
            idx = avail[j]
        req.engine_idx = idx
        if self.topo is not None and self.placements is not None:
            # physics, not pricing: the prompt lands after its hop (the
            # engine cannot admit before t_ready) and the response hop
            # shrinks the engine-side deadline (deadline_abs property) —
            # applied to EVERY dispatch, aware and blind alike
            in_s, out_s, link = xfers[j]
            req.net_in_s = in_s
            req.net_out_s = out_s
            req.t_ready = now + in_s if in_s > 0 else None
            if self.tr:
                self.tr.instant(tr_mod.ROUTE_XFER, now, track="router",
                                rid=req.rid, cls=req.cls_name,
                                engine_idx=idx, link=link, in_s=in_s,
                                out_s=out_s, aware=self.net_aware)
        if self.tr:
            self.tr.instant(tr_mod.ROUTE_DISPATCH, now,
                            track="router", rid=req.rid, cls=req.cls_name,
                            engine_idx=idx, cached=cached[j],
                            attempt=req.retries)
        if self.hedge_enabled:
            fl = self._flights.get(req.rid)
            if fl is None:
                fl = self._flights[req.rid] = {"attempts": [], "done": [],
                                               "t_disp": {}}
            if not any(a is req for a in fl["attempts"]):
                fl["attempts"].append(req)
            fl["t_disp"][id(req)] = now
        self.engines[idx].submit(req)
        return idx

    # -- simulation ---------------------------------------------------------

    def run(self, arrivals: Sequence[SimRequest]) -> List[SimRequest]:
        """Replay a time-ordered arrival stream through the fleet and
        drain it; returns every retired request — completed, dropped, and
        hedge losers (filter ``hedge_loser`` for per-request accounting).
        Between arrivals — and on a fixed ``_SCAN_SLICE_S`` cadence once
        they stop — the router sweeps health (stall breakers, recovery
        probes) and hedges stuck work, so detection latency stays bounded
        even when no new traffic arrives to trigger a sweep."""
        for req in arrivals:
            t = req.t_arrive
            for eng in self.engines:
                eng.drain(until=t)
            self._health_scan(t)
            self._hedge_scan(t)
            self.dispatch(req)
        if self.injector is None and not self.hedge_enabled:
            for eng in self.engines:
                eng.drain()
            return self.retired
        t = max((e.t for e in self.engines), default=0.0)
        for _ in range(1_000_000):
            if not any(e.pending or e._n_active() for e in self.engines):
                break
            t += self._SCAN_SLICE_S
            for eng in self.engines:
                eng.drain(until=t)
            self._health_scan(t)
            self._hedge_scan(t)
        else:
            raise RuntimeError("fleet failed to quiesce")
        return self.retired
