"""Paged KV cache: fixed-size pages in shared per-layer-group pools.

The wave engine's decode cache is a dense (B, max_ctx, Hkv, D) slab per
layer: every batch lane owns ``max_ctx`` slots for its whole lifetime, so a
lane cannot be handed to a new request until the old one retires — the
physical root of the wave barrier.  This module breaks the slab into
``page_size``-token *pages* inside shared pools:

* A request is admitted by allocating just enough pages to cover its prompt
  plus decode budget; its **block tables** (fixed-width lists of page ids,
  one per layer group) map logical positions to pool pages.
* Attention gathers K/V through the block table
  (:func:`repro.models.attention.attn_apply` paged branch, via the fused
  paged flash-attention kernel or the jnp gather+SDPA fallback).
* On retirement the pages go back to the free list **immediately**, so a
  new request can be admitted mid-flight of everyone else — continuous
  batching on real compute, the fusion ROADMAP tracked.

**Layer groups** (:func:`repro.models.transformer.paged_layer_groups`).
Uniform stacks have one group ("layers"); gemma3-class local:global
stacks split into "local"/"global"(/"tail").  Each group owns its own
pools — shaped ``(n_group_layers, n_pages, page_size, Hkv, D)`` — its own
free list, and its own per-slot block tables, because the groups' page
*lifetimes* differ:

* **Full-attention groups** allocate every page of a request's budget at
  admission and keep them until retirement (the historical behavior).
* **Sliding-window groups** retain only the pages under the window — at
  most ``ceil(window/page_size) + 1`` live pages per slot regardless of
  decoded length, the paged equivalent of the contiguous ring buffer the
  wave path uses for windowed layers.  Pages are allocated lazily as the
  write position advances and **freed back to the pool mid-flight** the
  moment their whole extent falls out of the window (their table entries
  park on the reserved dummy page; the kernels' window-validity mask makes
  them unreachable).  This is what lets the engine size admission by the
  *window-bounded* page demand: a 4096-window starcoder2-class request
  decoding thousands of tokens costs the pool a constant handful of pages
  per local layer.

**Reservations.**  Lazy window allocation must never fail mid-flight: a
freed page is immediately reusable by *other* requests' admissions, so
each slot records its peak concurrent page demand per group at admission
and :meth:`can_admit` measures the pool's *available* (free minus
outstanding-reserved) pages.  The invariant — free >= sum over slots of
(reserved - owned)+ — makes every lazy allocation a guaranteed pop.

Page accounting (free lists, block tables, per-lane positions) is
host-side numpy — it is O(pages) bookkeeping between jit'd steps.  The
pools themselves are device arrays threaded functionally through
``transformer.paged_decode_step``.

Page 0 of every group is reserved as a *dummy page*: idle decode lanes
point their whole table at it (and window groups their retired entries) so
one compiled decode step serves any occupancy (fixed-lane batching — no
recompile as requests come and go).  Writes from idle lanes collide
harmlessly there; their outputs are discarded.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import PagedGroup, paged_layer_groups
from repro.obs import trace as tr_mod

#: id of the page idle lanes (and retired window entries) point at; never
#: allocated to a request.  One per group pool.
DUMMY_PAGE = 0


class PagedKVCache:
    """Shared per-group page pools + per-slot block tables for one engine."""

    def __init__(self, cfg: ModelConfig, *, slots: int, n_pages: int,
                 page_size: int = 16, max_ctx: int = 256,
                 dtype=jnp.float32):
        """``n_pages`` sizes each *full-attention* group's pool (the
        historical meaning — for uniform stacks it is simply the pool
        size).  Sliding-window groups never hold more than ``slots *
        win_cap + 1`` live pages, so their pools are capped there — the
        KV-memory saving windows exist to buy."""
        assert n_pages >= 2, "need at least one dummy + one real page"
        self.cfg = cfg
        self.slots = slots
        self.page_size = page_size
        self.max_ctx = max_ctx
        #: block-table width: every slot can address up to max_ctx tokens
        self.table_width = math.ceil(max_ctx / page_size)
        self.n_pages = n_pages
        self.groups: List[PagedGroup] = paged_layer_groups(cfg)
        for g in self.groups:
            assert g.window is None or g.window >= 1, (g.name, g.window)
        self._group_pages: Dict[str, int] = {}
        self.kpool: Dict[str, jax.Array] = {}
        self.vpool: Dict[str, jax.Array] = {}
        self._free: Dict[str, List[int]] = {}
        #: per (group, slot): logical page index -> owned page id
        self._owned: Dict[str, List[Dict[int, int]]] = {}
        #: per (group, slot): peak concurrent page demand of the admitted
        #: request (0 = slot idle) — see "Reservations" above
        self._reserved: Dict[str, np.ndarray] = {}
        self.block_tables: Dict[str, np.ndarray] = {}
        for g in self.groups:
            cap = self.win_cap(g)
            n_pg = n_pages if cap is None else min(n_pages, slots * cap + 1)
            self._group_pages[g.name] = n_pg
            shape = (len(g.layers), n_pg, page_size, cfg.n_kv_heads,
                     cfg.head_dim)
            self.kpool[g.name] = jnp.zeros(shape, dtype)
            self.vpool[g.name] = jnp.zeros(shape, dtype)
            self._free[g.name] = list(range(1, n_pg))    # 0 is the dummy
            self._owned[g.name] = [{} for _ in range(slots)]
            self._reserved[g.name] = np.zeros((slots,), np.int64)
            self.block_tables[g.name] = np.full(
                (slots, self.table_width), DUMMY_PAGE, np.int32)
        self.pos = np.zeros((slots,), np.int32)
        #: observability: every page transition is emitted through here
        #: once an engine binds its tracer + clock (NULL = no overhead)
        self.tr = tr_mod.NULL
        self._clock = lambda: 0.0

    # -- observability -------------------------------------------------------

    def bind_tracer(self, tracer, clock) -> None:
        """Attach a tracer and the owning engine's analytic clock
        (``clock()`` -> current engine seconds).  Emits the pool geometry
        (``pool.config``) the trace-driven invariant checker replays
        against; all subsequent page transitions (alloc / free /
        mid-flight window free / reservation set+clear) are emitted on
        the ``pool`` track."""
        self.tr = tracer or tr_mod.NULL
        self._clock = clock
        if self.tr:
            self.tr.instant(tr_mod.POOL_CONFIG, clock(), track="pool",
                            groups=dict(self._group_pages),
                            page_size=self.page_size, slots=self.slots)

    def free_by_group(self) -> Dict[str, int]:
        """Current free-list sizes per group (the pool gauges)."""
        return {g: len(f) for g, f in self._free.items()}

    # -- group geometry ------------------------------------------------------

    def win_cap(self, g: PagedGroup) -> Optional[int]:
        """Max live pages a window group ever needs per slot during plain
        decode: ``ceil(window/page_size) + 1`` (a window spanning a page
        boundary touches one extra partial page), clamped to the table."""
        if g.window is None:
            return None
        return min(self.table_width,
                   math.ceil(g.window / self.page_size) + 1)

    def _win_lo(self, g: PagedGroup, pos: int) -> int:
        """First logical page any query at position >= ``pos`` can still
        reach: queries attend slots > pos - window."""
        return max(0, pos - g.window + 1) // self.page_size

    def peak_pages(self, g: PagedGroup, n_tokens: int,
                   prefill_chunk: Optional[int] = None) -> int:
        """Peak concurrent page demand of a request writing ``n_tokens``
        positions.  Full groups: every page, for the whole lifetime.
        Window groups: the live set slides — bounded by ``win_cap`` during
        decode, transiently ``ceil((window + chunk - 1)/page_size) + 1``
        while a prefill chunk is absorbed (the chunk's own pages plus the
        in-window prior pages must coexist for the chunk attend)."""
        need = math.ceil(n_tokens / self.page_size)
        if g.window is None:
            return need
        span = g.window + max(1, prefill_chunk or 1) - 1
        cap = min(self.table_width,
                  math.ceil(span / self.page_size) + 1)
        return min(need, cap)

    # -- allocation ----------------------------------------------------------

    def pages_needed(self, n_tokens: int,
                     prefill_chunk: Optional[int] = None) -> int:
        """Total peak page demand across groups (admission feasibility)."""
        return sum(self.peak_pages(g, n_tokens, prefill_chunk)
                   for g in self.groups)

    @property
    def free_pages(self) -> int:
        """Pages currently on the free lists, across groups.  Mid-flight
        window frees show up here the step they happen."""
        return sum(len(f) for f in self._free.values())

    def available(self, g: PagedGroup) -> int:
        """Free pages of ``g`` not spoken for by live slots' reservations
        — what admission may promise to a newcomer."""
        out = len(self._free[g.name])
        owned = self._owned[g.name]
        for s in range(self.slots):
            out -= max(0, int(self._reserved[g.name][s]) - len(owned[s]))
        return out

    def fits_pool(self, n_tokens: int,
                  prefill_chunk: Optional[int] = None) -> bool:
        """Could this request *ever* be admitted (even into an empty
        pool)?  False means waiting for retirements would hang forever."""
        return (n_tokens <= self.max_ctx
                and all(self.peak_pages(g, n_tokens, prefill_chunk)
                        <= self._group_pages[g.name] - 1
                        for g in self.groups))

    def can_admit(self, n_tokens: int,
                  prefill_chunk: Optional[int] = None) -> bool:
        return (n_tokens <= self.max_ctx
                and all(self.peak_pages(g, n_tokens, prefill_chunk)
                        <= self.available(g) for g in self.groups))

    def _take(self, g: PagedGroup, slot: int, logical: int) -> int:
        """Pop a free page of ``g`` and map ``slot``'s logical page
        ``logical`` to it (reservations guarantee the pop succeeds)."""
        owned = self._owned[g.name][slot]
        assert logical not in owned, (g.name, slot, logical)
        assert len(owned) < int(self._reserved[g.name][slot]), \
            f"{g.name}/slot{slot}: allocation beyond reservation"
        assert self._free[g.name], \
            f"{g.name}: free list empty despite reservation"
        page = self._free[g.name].pop()
        owned[logical] = page
        self.block_tables[g.name][slot, logical] = page
        if self.tr:
            self.tr.instant(tr_mod.PAGE_ALLOC, self._clock(), track="pool",
                            group=g.name, page=page, slot=slot)
        return page

    def _drop_page(self, g: PagedGroup, slot: int, logical: int) -> int:
        """Return ``slot``'s logical page to the pool; the table entry
        parks on the dummy page (window-masked, never attended)."""
        page = self._owned[g.name][slot].pop(logical)
        self._free[g.name].append(page)
        self.block_tables[g.name][slot, logical] = DUMMY_PAGE
        if self.tr:
            self.tr.instant(tr_mod.PAGE_FREE, self._clock(), track="pool",
                            group=g.name, page=page, slot=slot,
                            mid_flight=True)
        return page

    def _ensure(self, g: PagedGroup, slot: int, lo: int, hi: int) -> None:
        """Window groups: make logical pages [lo, hi] live for ``slot``."""
        owned = self._owned[g.name][slot]
        for j in range(lo, hi + 1):
            if j not in owned:
                self._take(g, slot, j)

    def _trim(self, g: PagedGroup, slot: int, lo: int) -> List[int]:
        """Window groups: free every logical page below ``lo`` — the
        mid-flight window free."""
        owned = self._owned[g.name][slot]
        dropped = [j for j in owned if j < lo]
        return [self._drop_page(g, slot, j) for j in sorted(dropped)]

    def alloc(self, slot: int, n_tokens: int,
              prefill_chunk: Optional[int] = None
              ) -> List[Tuple[str, int]]:
        """Admit a request covering ``n_tokens`` logical positions into
        ``slot``: full groups get every page now; window groups only
        *reserve* their peak demand — their pages are taken lazily as the
        write position advances (and freed as it leaves them behind).
        Returns the (group, page) pairs allocated immediately."""
        assert n_tokens <= self.max_ctx, (n_tokens, self.max_ctx)
        taken: List[Tuple[str, int]] = []
        for g in self.groups:
            assert not self._owned[g.name][slot], f"slot {slot} allocated"
            need = self.peak_pages(g, n_tokens, prefill_chunk)
            assert need <= self.available(g), (g.name, need,
                                               self.available(g))
            self._reserved[g.name][slot] = need
            if self.tr:
                self.tr.instant(tr_mod.PAGE_RESERVE, self._clock(),
                                track="pool", group=g.name, slot=slot,
                                pages=need)
            self.block_tables[g.name][slot, :] = DUMMY_PAGE
            if g.window is None:
                for j in range(math.ceil(n_tokens / self.page_size)):
                    taken.append((g.name, self._take(g, slot, j)))
        self.pos[slot] = 0
        return taken

    def free(self, slot: int) -> List[Tuple[str, int]]:
        """Retire ``slot``: every group's pages return to its free list
        immediately."""
        out: List[Tuple[str, int]] = []
        for g in self.groups:
            owned = self._owned[g.name][slot]
            for j in sorted(owned):
                out.append((g.name, owned[j]))
            self._free[g.name].extend(owned.values())
            if self.tr:
                t = self._clock()
                for j in sorted(owned):
                    self.tr.instant(tr_mod.PAGE_FREE, t, track="pool",
                                    group=g.name, page=owned[j], slot=slot,
                                    mid_flight=False)
            owned.clear()
            if self.tr and int(self._reserved[g.name][slot]):
                self.tr.instant(tr_mod.PAGE_RESERVE, self._clock(),
                                track="pool", group=g.name, slot=slot,
                                pages=0)
            self._reserved[g.name][slot] = 0
            self.block_tables[g.name][slot, :] = DUMMY_PAGE
        self.pos[slot] = 0
        return out

    def live_pages(self, slot: int, group: str) -> int:
        """Pages ``slot`` currently holds in ``group`` (the quantity the
        window bound caps)."""
        return len(self._owned[group][slot])

    # -- position lifecycle --------------------------------------------------

    def prepare_tokens(self, slot: int, n_tokens: int) -> None:
        """Make the pages for writing (and attending) logical positions
        ``[pos, pos + n_tokens)`` live in every window group: pages from
        the window horizon of the first query through the last written
        position.  Full groups allocated everything at admission."""
        pos = int(self.pos[slot])
        hi = (pos + n_tokens - 1) // self.page_size
        for g in self.groups:
            if g.window is None:
                continue
            self._ensure(g, slot, self._win_lo(g, pos), hi)

    def advance(self, slot: int, n_tokens: int) -> List[Tuple[str, int]]:
        """Account ``n_tokens`` freshly written positions: advance the
        slot's position and free every window-group page whose whole
        extent fell out of the window — the pages are on the free list
        (and visible in :attr:`free_pages`) before the next engine event.
        Returns the (group, page) pairs freed."""
        self.pos[slot] += n_tokens
        pos = int(self.pos[slot])
        freed: List[Tuple[str, int]] = []
        for g in self.groups:
            if g.window is None:
                continue
            freed.extend((g.name, p)
                         for p in self._trim(g, slot, self._win_lo(g, pos)))
        return freed

    # -- data movement -------------------------------------------------------

    def write_prefill(self, slot: int, seg_kv: Dict[str, dict]) -> None:
        """Scatter a request's prefill K/V into its pages.

        ``seg_kv``: per group name, {"k","v"} of shape (n_group_layers, S,
        Hkv, D) — the raw per-position cache ``transformer.prefill(...,
        raw_kv=True)`` built for this request alone, unpadded (see
        ``transformer.raw_prefill_group_kv``).  Window groups write only
        the pages still under the window at the end of the prompt;
        positions below them are unreachable by every future query and are
        never materialized."""
        ps = self.page_size
        for g in self.groups:
            k, v = seg_kv[g.name]["k"], seg_kv[g.name]["v"]
            L, S, H, D = k.shape
            lo = 0 if g.window is None else self._win_lo(g, S)
            n_pg = math.ceil(S / ps) - lo
            if g.window is not None:
                self._ensure(g, slot, lo, lo + n_pg - 1)
            pids = np.asarray(
                [self._owned[g.name][slot][lo + j] for j in range(n_pg)],
                np.int32)
            k, v = k[:, lo * ps:], v[:, lo * ps:]
            pad = lo * ps + n_pg * ps - S
            if pad:
                widths = ((0, 0), (0, pad), (0, 0), (0, 0))
                k, v = jnp.pad(k, widths), jnp.pad(v, widths)
            kp = k.reshape(L, n_pg, ps, H, D)
            vp = v.reshape(L, n_pg, ps, H, D)
            self.kpool[g.name] = self.kpool[g.name].at[:, pids].set(
                kp.astype(self.kpool[g.name].dtype))
            self.vpool[g.name] = self.vpool[g.name].at[:, pids].set(
                vp.astype(self.vpool[g.name].dtype))
        self.pos[slot] = S

    def _live_slots(self) -> List[int]:
        return [s for s in range(self.slots)
                if any(int(self._reserved[g.name][s])
                       for g in self.groups)]

    def decode_cache(self, exclude: Tuple[int, ...] = (),
                     lookahead: int = 1) -> dict:
        """The pytree ``transformer.paged_decode_step`` consumes:
        ``{"pos": (slots,), "groups": {name: {"kpool", "vpool",
        "block_tables"}}}``.

        ``exclude``: slots whose rows are masked to the dummy page (pos 0)
        for this step — mid-prefill lanes own real pages but must not be
        written or read by a decode step, exactly like idle lanes.  For
        every *included* live lane the write pages for the next
        ``lookahead`` positions are made live first (window groups
        allocate lazily) — 1 for a dense step; a speculative round passes
        ``k + 1`` so the draft steps and the verify chunk can write the
        whole span ``[pos, pos + k]`` before the host learns how much of
        it was accepted.

        The block table / position rows are **copied** before wrapping:
        ``jnp.asarray`` of a numpy array may alias its buffer zero-copy on
        the CPU backend, and the engine mutates ``self.pos`` /
        ``self.block_tables`` between (asynchronously dispatched) steps —
        handing out the live buffers is a data race once nothing on the
        host forces a sync per step (it used to be masked by host-side
        sampling forcing a sync every step)."""
        for s in self._live_slots():
            if s not in exclude:
                self.prepare_tokens(s, lookahead)
        pos = self.pos.copy()
        groups = {}
        for g in self.groups:
            bt = self.block_tables[g.name].copy()
            for s in exclude:
                bt[s, :] = DUMMY_PAGE
            groups[g.name] = {"kpool": self.kpool[g.name],
                              "vpool": self.vpool[g.name],
                              "block_tables": jnp.asarray(bt)}
        for s in exclude:
            pos[s] = 0
        return {"pos": jnp.asarray(pos), "groups": groups}

    def chunk_cache(self, slot: int, chunk_len: int) -> dict:
        """The single-lane pytree ``transformer.prefill_chunk`` consumes:
        this slot's block tables and write position over the shared pools
        (copied, not aliased — see :meth:`decode_cache`).  Window groups
        first make every page of the chunk's span live: the chunk's own
        pages plus the in-window prior pages must coexist for the chunk
        attend."""
        self.prepare_tokens(slot, chunk_len)
        groups = {
            g.name: {"kpool": self.kpool[g.name],
                     "vpool": self.vpool[g.name],
                     "block_tables": jnp.asarray(
                         self.block_tables[g.name][slot:slot + 1].copy())}
            for g in self.groups}
        return {"pos": jnp.asarray(self.pos[slot:slot + 1].copy()),
                "groups": groups}

    def update_from(self, new_cache: dict) -> None:
        """Write back the pools a decode step returned (positions stay
        host-managed: idle lanes must not advance)."""
        for g in self.groups:
            self.kpool[g.name] = new_cache["groups"][g.name]["kpool"]
            self.vpool[g.name] = new_cache["groups"][g.name]["vpool"]

    def utilization(self) -> float:
        """Fraction of allocatable pages currently owned by live requests."""
        total = sum(n - 1 for n in self._group_pages.values())
        return 1.0 - self.free_pages / total
