"""Paged KV cache: fixed-size pages in a shared pool, per-request page lists.

The wave engine's decode cache is a dense (B, max_ctx, Hkv, D) slab per
layer: every batch lane owns ``max_ctx`` slots for its whole lifetime, so a
lane cannot be handed to a new request until the old one retires — the
physical root of the wave barrier.  This module breaks the slab into
``page_size``-token *pages* inside one shared per-layer pool:

* A request is admitted by allocating just enough pages to cover its prompt
  plus decode budget; its **block table** (a fixed-width list of page ids)
  maps logical positions to pool pages.
* Attention gathers K/V through the block table
  (:func:`repro.models.attention.attn_apply` paged branch, optionally via
  the Pallas scalar-prefetch kernel in ``kernels.paged_gather``).
* On retirement the pages go back to the free list **immediately**, so a
  new request can be admitted mid-flight of everyone else — continuous
  batching on real compute, the fusion ROADMAP tracked.

Page accounting (free list, block tables, per-lane positions) is host-side
numpy — it is O(pages) bookkeeping between jit'd steps.  The pools
themselves are device arrays threaded functionally through
``transformer.paged_decode_step``.

Page 0 is reserved as a *dummy page*: idle decode lanes point their whole
table at it so one compiled decode step serves any occupancy (fixed-lane
batching — no recompile as requests come and go).  Writes from idle lanes
collide harmlessly there; their outputs are discarded.
"""
from __future__ import annotations

import math
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

#: id of the page idle lanes point at; never allocated to a request.
DUMMY_PAGE = 0


class PagedKVCache:
    """Shared page pool + per-slot block tables for one engine."""

    def __init__(self, cfg: ModelConfig, *, slots: int, n_pages: int,
                 page_size: int = 16, max_ctx: int = 256,
                 dtype=jnp.float32):
        assert n_pages >= 2, "need at least one dummy + one real page"
        self.cfg = cfg
        self.slots = slots
        self.page_size = page_size
        self.max_ctx = max_ctx
        #: block-table width: every slot can address up to max_ctx tokens
        self.table_width = math.ceil(max_ctx / page_size)
        shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads,
                 cfg.head_dim)
        self.kpool = jnp.zeros(shape, dtype)
        self.vpool = jnp.zeros(shape, dtype)
        self.n_pages = n_pages
        self._free: List[int] = list(range(1, n_pages))   # 0 is the dummy
        self._owned: List[List[int]] = [[] for _ in range(slots)]
        self.block_tables = np.full((slots, self.table_width), DUMMY_PAGE,
                                    np.int32)
        self.pos = np.zeros((slots,), np.int32)

    # -- allocation ----------------------------------------------------------

    def pages_needed(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.page_size)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def can_admit(self, n_tokens: int) -> bool:
        return (n_tokens <= self.max_ctx
                and self.pages_needed(n_tokens) <= self.free_pages)

    def alloc(self, slot: int, n_tokens: int) -> List[int]:
        """Give ``slot`` pages covering ``n_tokens`` logical positions."""
        need = self.pages_needed(n_tokens)
        assert not self._owned[slot], f"slot {slot} already allocated"
        assert need <= len(self._free), (need, len(self._free))
        assert n_tokens <= self.max_ctx, (n_tokens, self.max_ctx)
        pages = [self._free.pop() for _ in range(need)]
        self._owned[slot] = pages
        self.block_tables[slot, :] = DUMMY_PAGE
        self.block_tables[slot, :need] = pages
        self.pos[slot] = 0
        return list(pages)

    def free(self, slot: int) -> List[int]:
        """Retire ``slot``: return its pages to the free list immediately."""
        pages = self._owned[slot]
        self._free.extend(pages)
        self._owned[slot] = []
        self.block_tables[slot, :] = DUMMY_PAGE
        self.pos[slot] = 0
        return list(pages)

    # -- data movement -------------------------------------------------------

    def write_prefill(self, slot: int, k: jax.Array, v: jax.Array) -> None:
        """Scatter a request's prefill K/V into its pages.

        k/v: (n_layers, S, Hkv, D) — the dense cache ``transformer.prefill``
        built for this request alone, unpadded."""
        L, S, H, D = k.shape
        ps = self.page_size
        n_pg = self.pages_needed(S)
        pids = np.asarray(self._owned[slot][:n_pg], np.int32)
        pad = n_pg * ps - S
        if pad:
            widths = ((0, 0), (0, pad), (0, 0), (0, 0))
            k, v = jnp.pad(k, widths), jnp.pad(v, widths)
        kp = k.reshape(L, n_pg, ps, H, D)
        vp = v.reshape(L, n_pg, ps, H, D)
        self.kpool = self.kpool.at[:, pids].set(kp.astype(self.kpool.dtype))
        self.vpool = self.vpool.at[:, pids].set(vp.astype(self.vpool.dtype))
        self.pos[slot] = S

    def decode_cache(self, exclude: Tuple[int, ...] = ()) -> dict:
        """The pytree ``transformer.paged_decode_step`` consumes.

        ``exclude``: slots whose rows are masked to the dummy page (pos 0)
        for this step — mid-prefill lanes own real pages but must not be
        written or read by a decode step, exactly like idle lanes.

        The block table / position rows are **copied** before wrapping:
        ``jnp.asarray`` of a numpy array may alias its buffer zero-copy on
        the CPU backend, and the engine mutates ``self.pos`` /
        ``self.block_tables`` between (asynchronously dispatched) steps —
        handing out the live buffers is a data race once nothing on the
        host forces a sync per step (it used to be masked by host-side
        sampling materializing the logits every step)."""
        bt, pos = self.block_tables.copy(), self.pos.copy()
        for s in exclude:
            bt[s, :] = DUMMY_PAGE
            pos[s] = 0
        return {"kpool": self.kpool, "vpool": self.vpool,
                "block_tables": jnp.asarray(bt), "pos": jnp.asarray(pos)}

    def chunk_cache(self, slot: int) -> dict:
        """The single-lane pytree ``transformer.prefill_chunk`` consumes:
        this slot's block table and write position over the shared pools
        (copied, not aliased — see :meth:`decode_cache`)."""
        return {"kpool": self.kpool, "vpool": self.vpool,
                "block_tables":
                    jnp.asarray(self.block_tables[slot:slot + 1].copy()),
                "pos": jnp.asarray(self.pos[slot:slot + 1].copy())}

    def update_from(self, new_cache: dict) -> None:
        """Write back the pools a decode step returned (positions stay
        host-managed: idle lanes must not advance)."""
        self.kpool = new_cache["kpool"]
        self.vpool = new_cache["vpool"]

    def utilization(self) -> float:
        """Fraction of allocatable pages currently owned by live requests."""
        return 1.0 - self.free_pages / (self.n_pages - 1)
