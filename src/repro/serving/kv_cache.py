"""Paged KV cache: fixed-size pages in shared per-layer-group pools.

The wave engine's decode cache is a dense (B, max_ctx, Hkv, D) slab per
layer: every batch lane owns ``max_ctx`` slots for its whole lifetime, so a
lane cannot be handed to a new request until the old one retires — the
physical root of the wave barrier.  This module breaks the slab into
``page_size``-token *pages* inside shared pools:

* A request is admitted by allocating just enough pages to cover its prompt
  plus decode budget; its **block tables** (fixed-width lists of page ids,
  one per layer group) map logical positions to pool pages.
* Attention gathers K/V through the block table
  (:func:`repro.models.attention.attn_apply` paged branch, via the fused
  paged flash-attention kernel or the jnp gather+SDPA fallback).
* On retirement the pages go back to the free list **immediately**, so a
  new request can be admitted mid-flight of everyone else — continuous
  batching on real compute, the fusion ROADMAP tracked.

**Layer groups** (:func:`repro.models.transformer.paged_layer_groups`).
Uniform stacks have one group ("layers"); gemma3-class local:global
stacks split into "local"/"global"(/"tail").  Each group owns its own
pools — shaped ``(n_group_layers, n_pages, page_size, Hkv, D)`` — its own
free list, and its own per-slot block tables, because the groups' page
*lifetimes* differ:

* **Full-attention groups** allocate every page of a request's budget at
  admission and keep them until retirement (the historical behavior).
* **Sliding-window groups** retain only the pages under the window — at
  most ``ceil(window/page_size) + 1`` live pages per slot regardless of
  decoded length, the paged equivalent of the contiguous ring buffer the
  wave path uses for windowed layers.  Pages are allocated lazily as the
  write position advances and **freed back to the pool mid-flight** the
  moment their whole extent falls out of the window (their table entries
  park on the reserved dummy page; the kernels' window-validity mask makes
  them unreachable).  This is what lets the engine size admission by the
  *window-bounded* page demand: a 4096-window starcoder2-class request
  decoding thousands of tokens costs the pool a constant handful of pages
  per local layer.

**Reservations.**  Lazy window allocation must never fail mid-flight: a
freed page is immediately reusable by *other* requests' admissions, so
each slot records its peak concurrent page demand per group at admission
and :meth:`can_admit` measures the pool's *available* (free minus
outstanding-reserved) pages.  The invariant — free >= sum over slots of
(reserved - owned)+ — makes every lazy allocation a guaranteed pop.

**Refcounted sharing + copy-on-write.**  Every page carries a reference
count.  Exclusively owned pages (the historical case) sit at refcount 1;
:meth:`share_prefix` lets additional holders — decode lanes adopting a
cached prompt prefix (``alloc(..., adopt=...)``), or the
:class:`PrefixCache` pinning a finished prompt — take references on the
*same* physical pages, so N lanes over one system prompt read one copy of
its K/V.  Shared pages are read-only by construction: before any write
lands in a shared page, :meth:`prepare_tokens` copies it into a fresh
exclusive page (copy-on-write) through the same free-list/reservation
accounting — a lane's reservation includes the one potential CoW page of
a partially-shared prefix, so the copy is a guaranteed pop too.  A
reference drop returns the page to the free list only at refcount zero
(:meth:`free` retires a lane by dropping its references, never by
returning page lists wholesale), and the trace invariant checker
(``obs.check_trace``) replays the refcounts: double-freeing a shared
page, or a page leaking when its last holder drops it, is a hard error.
Shared holdings do **not** count against a lane's reservation — only
exclusive pages do — which is exactly what makes a prefix hit cheap at
admission: the adopted pages cost the pool nothing.

Page accounting (free lists, block tables, refcounts, per-lane positions)
is host-side numpy — it is O(pages) bookkeeping between jit'd steps.  The
pools themselves are device arrays threaded functionally through
``transformer.paged_decode_step``.

Page 0 of every group is reserved as a *dummy page*: idle decode lanes
point their whole table at it (and window groups their retired entries) so
one compiled decode step serves any occupancy (fixed-lane batching — no
recompile as requests come and go).  Writes from idle lanes collide
harmlessly there; their outputs are discarded.
"""
from __future__ import annotations

import hashlib
import math
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import PagedGroup, paged_layer_groups
from repro.obs import trace as tr_mod

#: id of the page idle lanes (and retired window entries) point at; never
#: allocated to a request.  One per group pool.
DUMMY_PAGE = 0

#: pseudo-slot id the prefix cache's page references are emitted under in
#: pool trace events (it holds pages but has no lane or reservation)
CACHE_SLOT = -1

#: pseudo-slot id fault-injected page-pressure seizures are emitted under
#: (an "external tenant" squeezing the pool; see serving.faults)
PRESSURE_SLOT = -2


class PagedKVCache:
    """Shared per-group page pools + per-slot block tables for one engine."""

    def __init__(self, cfg: ModelConfig, *, slots: int, n_pages: int,
                 page_size: int = 16, max_ctx: int = 256,
                 dtype=jnp.float32):
        """``n_pages`` sizes each *full-attention* group's pool (the
        historical meaning — for uniform stacks it is simply the pool
        size).  Sliding-window groups never hold more than ``slots *
        win_cap + 1`` live pages, so their pools are capped there — the
        KV-memory saving windows exist to buy."""
        assert n_pages >= 2, "need at least one dummy + one real page"
        self.cfg = cfg
        self.slots = slots
        self.page_size = page_size
        self.max_ctx = max_ctx
        #: block-table width: every slot can address up to max_ctx tokens
        self.table_width = math.ceil(max_ctx / page_size)
        self.n_pages = n_pages
        self.groups: List[PagedGroup] = paged_layer_groups(cfg)
        self._gmap: Dict[str, PagedGroup] = {g.name: g for g in self.groups}
        for g in self.groups:
            assert g.window is None or g.window >= 1, (g.name, g.window)
        self._group_pages: Dict[str, int] = {}
        self.kpool: Dict[str, jax.Array] = {}
        self.vpool: Dict[str, jax.Array] = {}
        self._free: Dict[str, List[int]] = {}
        #: per (group, slot): logical page index -> *exclusively* owned
        #: page id (refcount contribution 1; counts against reservation)
        self._owned: Dict[str, List[Dict[int, int]]] = {}
        #: per (group, slot): logical page index -> *shared* page id — a
        #: reference on a page other holders also reference.  Read-only
        #: until copy-on-write promotes the logical into ``_owned``.
        self._shared: Dict[str, List[Dict[int, int]]] = {}
        #: per group: refcount per physical page (0 = free or dummy)
        self._refcount: Dict[str, np.ndarray] = {}
        #: per (group, slot): peak concurrent page demand of the admitted
        #: request (0 = slot idle) — see "Reservations" above
        self._reserved: Dict[str, np.ndarray] = {}
        self.block_tables: Dict[str, np.ndarray] = {}
        for g in self.groups:
            cap = self.win_cap(g)
            n_pg = n_pages if cap is None else min(n_pages, slots * cap + 1)
            self._group_pages[g.name] = n_pg
            shape = (len(g.layers), n_pg, page_size, cfg.n_kv_heads,
                     cfg.head_dim)
            self.kpool[g.name] = jnp.zeros(shape, dtype)
            self.vpool[g.name] = jnp.zeros(shape, dtype)
            self._free[g.name] = list(range(1, n_pg))    # 0 is the dummy
            self._owned[g.name] = [{} for _ in range(slots)]
            self._shared[g.name] = [{} for _ in range(slots)]
            self._refcount[g.name] = np.zeros((n_pg,), np.int32)
            self._reserved[g.name] = np.zeros((slots,), np.int64)
            self.block_tables[g.name] = np.full(
                (slots, self.table_width), DUMMY_PAGE, np.int32)
        self.pos = np.zeros((slots,), np.int32)
        #: model-axis shards the pools' kv-heads are split over (1 =
        #: unsharded; set by :meth:`shard`, reported in ``pool.config``)
        self.tp = 1
        #: per group: pages currently seized by fault-injected pressure
        #: (see :meth:`seize`) — outside the slot reservation arrays
        #: because the "holder" is no lane
        self._pressure: Dict[str, int] = {g.name: 0 for g in self.groups}
        #: observability: every page transition is emitted through here
        #: once an engine binds its tracer + clock (NULL = no overhead)
        self.tr = tr_mod.NULL
        self._clock = lambda: 0.0

    # -- observability -------------------------------------------------------

    def bind_tracer(self, tracer, clock) -> None:
        """Attach a tracer and the owning engine's analytic clock
        (``clock()`` -> current engine seconds).  Emits the pool geometry
        (``pool.config``) the trace-driven invariant checker replays
        against; all subsequent page transitions (alloc / free /
        mid-flight window free / reservation set+clear) are emitted on
        the ``pool`` track."""
        self.tr = tracer or tr_mod.NULL
        self._clock = clock
        if self.tr:
            self.tr.instant(tr_mod.POOL_CONFIG, clock(), track="pool",
                            groups=dict(self._group_pages),
                            page_size=self.page_size, slots=self.slots,
                            tp=self.tp)

    def shard(self, sharding, *, tp: int = 1) -> None:
        """Place every group's k/v pool under ``sharding`` (a
        :class:`jax.sharding.NamedSharding`, typically
        :func:`repro.launch.shardings.paged_pool_shardings` — kv-heads on
        the "model" axis).  The block tables, free lists and refcounts
        stay host-side and *shared*: every shard holds the same pages'
        head-slice, so page accounting is per-page, not per-shard.  GSPMD
        propagates the placement through the jit'd decode steps, so pools
        written by ``update_from`` stay sharded.  Call before the first
        step (re-placing hot pools would re-transfer them)."""
        assert tp >= 1, tp
        self.tp = tp
        for g in self.groups:
            self.kpool[g.name] = jax.device_put(self.kpool[g.name], sharding)
            self.vpool[g.name] = jax.device_put(self.vpool[g.name], sharding)

    def free_by_group(self) -> Dict[str, int]:
        """Current free-list sizes per group (the pool gauges)."""
        return {g: len(f) for g, f in self._free.items()}

    # -- group geometry ------------------------------------------------------

    def win_cap(self, g: PagedGroup) -> Optional[int]:
        """Max live pages a window group ever needs per slot during plain
        decode: ``ceil(window/page_size) + 1`` (a window spanning a page
        boundary touches one extra partial page), clamped to the table."""
        if g.window is None:
            return None
        return min(self.table_width,
                   math.ceil(g.window / self.page_size) + 1)

    def _win_lo(self, g: PagedGroup, pos: int) -> int:
        """First logical page any query at position >= ``pos`` can still
        reach: queries attend slots > pos - window."""
        return max(0, pos - g.window + 1) // self.page_size

    def peak_pages(self, g: PagedGroup, n_tokens: int,
                   prefill_chunk: Optional[int] = None,
                   cached_prefix: int = 0) -> int:
        """Peak concurrent page demand of a request writing ``n_tokens``
        positions.  Full groups: every page, for the whole lifetime —
        minus the pages a ``cached_prefix``-token prefix adoption shares
        instead of allocating (the partially-covered boundary page still
        counts: it is the one potential copy-on-write).  Window groups:
        the live set slides — bounded by ``win_cap`` during decode,
        transiently ``ceil((window + chunk - 1)/page_size) + 1`` while a
        prefill chunk is absorbed (the chunk's own pages plus the
        in-window prior pages must coexist for the chunk attend)."""
        need = math.ceil(n_tokens / self.page_size)
        if g.window is None:
            return need - cached_prefix // self.page_size
        span = g.window + max(1, prefill_chunk or 1) - 1
        cap = min(self.table_width,
                  math.ceil(span / self.page_size) + 1)
        return min(need, cap)

    # -- allocation ----------------------------------------------------------

    def pages_needed(self, n_tokens: int,
                     prefill_chunk: Optional[int] = None,
                     cached_prefix: int = 0) -> int:
        """Total peak page demand across groups (admission feasibility)."""
        return sum(self.peak_pages(g, n_tokens, prefill_chunk, cached_prefix)
                   for g in self.groups)

    @property
    def free_pages(self) -> int:
        """Pages currently on the free lists, across groups.  Mid-flight
        window frees show up here the step they happen."""
        return sum(len(f) for f in self._free.values())

    def available(self, g: PagedGroup) -> int:
        """Free pages of ``g`` not spoken for by live slots' reservations
        — what admission may promise to a newcomer."""
        out = len(self._free[g.name])
        owned = self._owned[g.name]
        for s in range(self.slots):
            out -= max(0, int(self._reserved[g.name][s]) - len(owned[s]))
        return out

    def fits_pool(self, n_tokens: int,
                  prefill_chunk: Optional[int] = None) -> bool:
        """Could this request *ever* be admitted (even into an empty
        pool)?  False means waiting for retirements would hang forever."""
        return (n_tokens <= self.max_ctx
                and all(self.peak_pages(g, n_tokens, prefill_chunk)
                        <= self._group_pages[g.name] - 1
                        for g in self.groups))

    def can_admit(self, n_tokens: int,
                  prefill_chunk: Optional[int] = None,
                  cached_prefix: int = 0) -> bool:
        return (n_tokens <= self.max_ctx
                and all(self.peak_pages(g, n_tokens, prefill_chunk,
                                        cached_prefix)
                        <= self.available(g) for g in self.groups))

    def _take(self, g: PagedGroup, slot: int, logical: int) -> int:
        """Pop a free page of ``g`` and map ``slot``'s logical page
        ``logical`` to it, exclusively — refcount 1 (reservations
        guarantee the pop succeeds)."""
        owned = self._owned[g.name][slot]
        assert logical not in owned, (g.name, slot, logical)
        assert logical not in self._shared[g.name][slot], \
            (g.name, slot, logical, "still shared — CoW must unref first")
        assert len(owned) < int(self._reserved[g.name][slot]), \
            f"{g.name}/slot{slot}: allocation beyond reservation"
        assert self._free[g.name], \
            f"{g.name}: free list empty despite reservation"
        page = self._free[g.name].pop()
        assert self._refcount[g.name][page] == 0, (g.name, page)
        self._refcount[g.name][page] = 1
        owned[logical] = page
        self.block_tables[g.name][slot, logical] = page
        if self.tr:
            self.tr.instant(tr_mod.PAGE_ALLOC, self._clock(), track="pool",
                            group=g.name, page=page, slot=slot)
        return page

    def _unref(self, g: PagedGroup, page: int, slot: int, *,
               mid_flight: bool = False) -> bool:
        """Drop one reference to ``page``.  Only the *last* reference
        returns the page to the free list — the refcounted free every
        release path (retire, window trim, CoW, cache eviction) goes
        through.  Returns True iff the page was actually freed."""
        rc = self._refcount[g.name]
        assert rc[page] > 0, (g.name, page, "unref of a dead page")
        rc[page] -= 1
        freed = rc[page] == 0
        if freed:
            self._free[g.name].append(page)
        if self.tr:
            self.tr.instant(tr_mod.PAGE_FREE, self._clock(), track="pool",
                            group=g.name, page=page, slot=slot,
                            refs=int(rc[page]), mid_flight=mid_flight)
        return freed

    def _drop_page(self, g: PagedGroup, slot: int, logical: int) -> int:
        """Drop ``slot``'s reference to its logical page; the table entry
        parks on the dummy page (window-masked, never attended)."""
        page = self._owned[g.name][slot].pop(logical)
        self._unref(g, page, slot, mid_flight=True)
        self.block_tables[g.name][slot, logical] = DUMMY_PAGE
        return page

    def _cow(self, g: PagedGroup, slot: int, logical: int) -> int:
        """Copy-on-write: ``slot`` is about to write into a shared page —
        copy its K/V into a fresh exclusive page (the slot's reservation
        covers it), repoint the block table, and drop the shared
        reference.  Other holders keep reading the original."""
        old = self._shared[g.name][slot].pop(logical)
        new = self._take(g, slot, logical)
        self.kpool[g.name] = self.kpool[g.name].at[:, new].set(
            self.kpool[g.name][:, old])
        self.vpool[g.name] = self.vpool[g.name].at[:, new].set(
            self.vpool[g.name][:, old])
        self._unref(g, old, slot)
        if self.tr:
            self.tr.instant(tr_mod.PAGE_COW, self._clock(), track="pool",
                            group=g.name, slot=slot, from_page=old,
                            to_page=new)
        return new

    def _ensure(self, g: PagedGroup, slot: int, lo: int, hi: int) -> None:
        """Window groups: make logical pages [lo, hi] live for ``slot``."""
        owned = self._owned[g.name][slot]
        for j in range(lo, hi + 1):
            if j not in owned:
                self._take(g, slot, j)

    def _trim(self, g: PagedGroup, slot: int, lo: int) -> List[int]:
        """Window groups: free every logical page below ``lo`` — the
        mid-flight window free."""
        owned = self._owned[g.name][slot]
        dropped = [j for j in owned if j < lo]
        return [self._drop_page(g, slot, j) for j in sorted(dropped)]

    def alloc(self, slot: int, n_tokens: int,
              prefill_chunk: Optional[int] = None, *,
              adopt: Optional[dict] = None, adopt_len: int = 0
              ) -> List[Tuple[str, int]]:
        """Admit a request covering ``n_tokens`` logical positions into
        ``slot``: full groups get every page now; window groups only
        *reserve* their peak demand — their pages are taken lazily as the
        write position advances (and freed as it leaves them behind).

        ``adopt`` (a :meth:`share_prefix` snapshot) maps the first
        ``adopt_len`` positions onto already-live *shared* pages instead
        of fresh ones: each covering page gains a reference, the block
        table points at it, and the write position starts at
        ``adopt_len`` — the prefix-cache hit path.  ``adopt_len`` may
        truncate the snapshot (tokens beyond it inside the boundary page
        are masked by ``pos`` until sequential writes — post-CoW —
        overwrite them).  The reservation covers only the exclusive pages
        the lane can ever own, *including* the boundary page a partially
        shared prefix will copy-on-write; full-page shares cost nothing.
        Adoption requires an all-full-attention stack (window groups trim
        pages below the horizon, so a snapshot taken at one position is
        not valid at another).  Returns the (group, page) pairs allocated
        immediately (exclusive takes only — not the adopted shares)."""
        assert n_tokens <= self.max_ctx, (n_tokens, self.max_ctx)
        if adopt is not None:
            assert 0 < adopt_len <= adopt["len"], (adopt_len, adopt["len"])
            assert adopt_len < n_tokens, "nothing left to write"
            assert all(g.window is None for g in self.groups), \
                "prefix adoption requires full-attention groups"
        cached = adopt_len if adopt is not None else 0
        taken: List[Tuple[str, int]] = []
        for g in self.groups:
            assert not self._owned[g.name][slot], f"slot {slot} allocated"
            assert not self._shared[g.name][slot], f"slot {slot} allocated"
            need = self.peak_pages(g, n_tokens, prefill_chunk, cached)
            assert need <= self.available(g), (g.name, need,
                                               self.available(g))
            self._reserved[g.name][slot] = need
            if self.tr:
                self.tr.instant(tr_mod.PAGE_RESERVE, self._clock(),
                                track="pool", group=g.name, slot=slot,
                                pages=need)
            self.block_tables[g.name][slot, :] = DUMMY_PAGE
            first = 0
            if cached:
                first = math.ceil(cached / self.page_size)
                shared = self._shared[g.name][slot]
                pages = adopt["pages"][g.name]
                for j in range(first):
                    page = pages[j]
                    self._refcount[g.name][page] += 1
                    shared[j] = page
                    self.block_tables[g.name][slot, j] = page
                    if self.tr:
                        self.tr.instant(
                            tr_mod.PAGE_SHARE, self._clock(), track="pool",
                            group=g.name, page=page, slot=slot,
                            refs=int(self._refcount[g.name][page]))
            if g.window is None:
                for j in range(first, math.ceil(n_tokens / self.page_size)):
                    taken.append((g.name, self._take(g, slot, j)))
        self.pos[slot] = cached
        return taken

    def free(self, slot: int) -> List[Tuple[str, int]]:
        """Retire ``slot``: drop its reference to every page it holds —
        exclusive *and* shared.  Exclusive pages whose last reference
        this was return to the free list immediately; pages the prefix
        cache (or a co-resident lane) still references stay live and
        merely lose one refcount.  Returns the (group, page) pairs
        released."""
        out: List[Tuple[str, int]] = []
        for g in self.groups:
            for holdings in (self._owned[g.name][slot],
                             self._shared[g.name][slot]):
                for j in sorted(holdings):
                    out.append((g.name, holdings[j]))
                    self._unref(g, holdings[j], slot)
                holdings.clear()
            if self.tr and int(self._reserved[g.name][slot]):
                self.tr.instant(tr_mod.PAGE_RESERVE, self._clock(),
                                track="pool", group=g.name, slot=slot,
                                pages=0)
            self._reserved[g.name][slot] = 0
            self.block_tables[g.name][slot, :] = DUMMY_PAGE
        self.pos[slot] = 0
        return out

    def live_pages(self, slot: int, group: str) -> int:
        """Pages ``slot`` currently holds in ``group`` (the quantity the
        window bound caps)."""
        return len(self._owned[group][slot])

    def refcount(self, group: str, page: int) -> int:
        """Current reference count of a physical page (0 = free)."""
        return int(self._refcount[group][page])

    # -- fault-injected page pressure ----------------------------------------

    def seize(self, n: int) -> List[Tuple[str, int]]:
        """Seize up to ``n`` free pages for an external cause (the
        fault injector's ``page_pressure`` windows) — each leaves the
        free list with refcount 1 under the :data:`PRESSURE_SLOT` pseudo
        holder, so ``available``/``can_admit`` see a genuinely smaller
        pool while conservation still closes.  Only *available* pages are
        taken (never pages promised to live slots' reservations — lazy
        window allocation and CoW must stay deadlock-free), so the actual
        seizure may fall short of ``n``.  Returns the (group, page) pairs
        taken; hand them back via :meth:`restore`."""
        taken: List[Tuple[str, int]] = []
        for g in self.groups:
            grabbed: List[int] = []
            # available() already sees the pops (the free list shrinks)
            while len(taken) + len(grabbed) < n and self.available(g) > 0:
                page = self._free[g.name].pop()
                assert self._refcount[g.name][page] == 0, (g.name, page)
                grabbed.append(page)
            if not grabbed:
                continue
            self._pressure[g.name] += len(grabbed)
            if self.tr:
                self.tr.instant(tr_mod.PAGE_RESERVE, self._clock(),
                                track="pool", group=g.name,
                                slot=PRESSURE_SLOT,
                                pages=self._pressure[g.name])
            for page in grabbed:
                self._refcount[g.name][page] = 1
                taken.append((g.name, page))
                if self.tr:
                    self.tr.instant(tr_mod.PAGE_ALLOC, self._clock(),
                                    track="pool", group=g.name, page=page,
                                    slot=PRESSURE_SLOT)
        return taken

    def restore(self, taken: List[Tuple[str, int]]) -> None:
        """Return a :meth:`seize` batch to the free lists (the pressure
        window ended)."""
        touched = set()
        for name, page in taken:
            assert self._refcount[name][page] == 1, (name, page)
            self._refcount[name][page] = 0
            self._free[name].append(page)
            self._pressure[name] -= 1
            touched.add(name)
            if self.tr:
                self.tr.instant(tr_mod.PAGE_FREE, self._clock(),
                                track="pool", group=name, page=page,
                                slot=PRESSURE_SLOT, refs=0,
                                mid_flight=False)
        if self.tr:
            for name in sorted(touched):
                self.tr.instant(tr_mod.PAGE_RESERVE, self._clock(),
                                track="pool", group=name,
                                slot=PRESSURE_SLOT,
                                pages=self._pressure[name])

    # -- prefix sharing ------------------------------------------------------

    def share_prefix(self, slot: int, n_tokens: int,
                     holder: int = CACHE_SLOT) -> dict:
        """Pin the pages covering ``slot``'s first ``n_tokens`` positions
        under an extra reference held by ``holder`` (the prefix cache) and
        return the snapshot — ``{"len", "pages": {group: [page, ...]}}``
        — that :meth:`alloc(adopt=...)` maps into future lanes.

        If the boundary page is only partially covered (``n_tokens`` not
        page-aligned) and the donor will keep writing into it (its write
        position sits inside that page), the donor's own holding of that
        page is demoted from exclusive to shared: its next write — the
        first decode token — triggers copy-on-write, so the pinned page
        stays frozen at the prompt's K/V.  The demotion releases exactly
        the reservation slot the CoW copy will consume, so the donor's
        reservation stays sufficient.  Full-attention groups only."""
        assert 0 < n_tokens <= int(self.pos[slot]), (n_tokens,
                                                     int(self.pos[slot]))
        assert all(g.window is None for g in self.groups), \
            "prefix sharing requires full-attention groups"
        n_pg = math.ceil(n_tokens / self.page_size)
        wpos = int(self.pos[slot]) // self.page_size
        pages: Dict[str, List[int]] = {}
        for g in self.groups:
            owned = self._owned[g.name][slot]
            shared = self._shared[g.name][slot]
            plist: List[int] = []
            for j in range(n_pg):
                page = owned[j] if j in owned else shared[j]
                self._refcount[g.name][page] += 1
                plist.append(page)
                if self.tr:
                    self.tr.instant(
                        tr_mod.PAGE_SHARE, self._clock(), track="pool",
                        group=g.name, page=page, slot=holder,
                        refs=int(self._refcount[g.name][page]))
                if j >= wpos and j in owned:
                    shared[j] = owned.pop(j)   # demote: next write CoWs
            pages[g.name] = plist
        return {"len": n_tokens, "pages": pages}

    def release_snapshot(self, snap: dict, holder: int = CACHE_SLOT) -> None:
        """Drop the references a :meth:`share_prefix` snapshot holds
        (prefix-cache eviction); pages with no other holder are freed."""
        for name, plist in snap["pages"].items():
            g = self._gmap[name]
            for page in plist:
                self._unref(g, page, holder)

    # -- position lifecycle --------------------------------------------------

    def prepare_tokens(self, slot: int, n_tokens: int) -> None:
        """Make the pages for logical positions ``[pos, pos + n_tokens)``
        *writable* for ``slot``: any shared page in the write span is
        copied-on-write into an exclusive page first (shared pages are
        read-only — co-holders must never see our tokens), and window
        groups make the span's pages live (pages from the window horizon
        of the first query through the last written position; full groups
        allocated everything at admission)."""
        pos = int(self.pos[slot])
        lo, hi = pos // self.page_size, (pos + n_tokens - 1) // self.page_size
        for g in self.groups:
            shared = self._shared[g.name][slot]
            if shared:
                for j in [j for j in shared if lo <= j <= hi]:
                    self._cow(g, slot, j)
            if g.window is None:
                continue
            self._ensure(g, slot, self._win_lo(g, pos), hi)

    def advance(self, slot: int, n_tokens: int) -> List[Tuple[str, int]]:
        """Account ``n_tokens`` freshly written positions: advance the
        slot's position and free every window-group page whose whole
        extent fell out of the window — the pages are on the free list
        (and visible in :attr:`free_pages`) before the next engine event.
        Returns the (group, page) pairs freed."""
        self.pos[slot] += n_tokens
        pos = int(self.pos[slot])
        freed: List[Tuple[str, int]] = []
        for g in self.groups:
            if g.window is None:
                continue
            freed.extend((g.name, p)
                         for p in self._trim(g, slot, self._win_lo(g, pos)))
        return freed

    # -- data movement -------------------------------------------------------

    def write_prefill(self, slot: int, seg_kv: Dict[str, dict]) -> None:
        """Scatter a request's prefill K/V into its pages.

        ``seg_kv``: per group name, {"k","v"} of shape (n_group_layers, S,
        Hkv, D) — the raw per-position cache ``transformer.prefill(...,
        raw_kv=True)`` built for this request alone, unpadded (see
        ``transformer.raw_prefill_group_kv``).  Window groups write only
        the pages still under the window at the end of the prompt;
        positions below them are unreachable by every future query and are
        never materialized."""
        ps = self.page_size
        for g in self.groups:
            k, v = seg_kv[g.name]["k"], seg_kv[g.name]["v"]
            L, S, H, D = k.shape
            lo = 0 if g.window is None else self._win_lo(g, S)
            n_pg = math.ceil(S / ps) - lo
            if g.window is not None:
                self._ensure(g, slot, lo, lo + n_pg - 1)
            pids = np.asarray(
                [self._owned[g.name][slot][lo + j] for j in range(n_pg)],
                np.int32)
            k, v = k[:, lo * ps:], v[:, lo * ps:]
            pad = lo * ps + n_pg * ps - S
            if pad:
                widths = ((0, 0), (0, pad), (0, 0), (0, 0))
                k, v = jnp.pad(k, widths), jnp.pad(v, widths)
            kp = k.reshape(L, n_pg, ps, H, D)
            vp = v.reshape(L, n_pg, ps, H, D)
            self.kpool[g.name] = self.kpool[g.name].at[:, pids].set(
                kp.astype(self.kpool[g.name].dtype))
            self.vpool[g.name] = self.vpool[g.name].at[:, pids].set(
                vp.astype(self.vpool[g.name].dtype))
        self.pos[slot] = S

    def _live_slots(self) -> List[int]:
        return [s for s in range(self.slots)
                if any(int(self._reserved[g.name][s])
                       for g in self.groups)]

    def decode_cache(self, exclude: Tuple[int, ...] = (),
                     lookahead: int = 1) -> dict:
        """The pytree ``transformer.paged_decode_step`` consumes:
        ``{"pos": (slots,), "groups": {name: {"kpool", "vpool",
        "block_tables"}}}``.

        ``exclude``: slots whose rows are masked to the dummy page (pos 0)
        for this step — mid-prefill lanes own real pages but must not be
        written or read by a decode step, exactly like idle lanes.  For
        every *included* live lane the write pages for the next
        ``lookahead`` positions are made live first (window groups
        allocate lazily) — 1 for a dense step; a speculative round passes
        ``k + 1`` so the draft steps and the verify chunk can write the
        whole span ``[pos, pos + k]`` before the host learns how much of
        it was accepted.

        The block table / position rows are **copied** before wrapping:
        ``jnp.asarray`` of a numpy array may alias its buffer zero-copy on
        the CPU backend, and the engine mutates ``self.pos`` /
        ``self.block_tables`` between (asynchronously dispatched) steps —
        handing out the live buffers is a data race once nothing on the
        host forces a sync per step (it used to be masked by host-side
        sampling forcing a sync every step)."""
        for s in self._live_slots():
            if s not in exclude:
                self.prepare_tokens(s, lookahead)
        pos = self.pos.copy()
        groups = {}
        for g in self.groups:
            bt = self.block_tables[g.name].copy()
            for s in exclude:
                bt[s, :] = DUMMY_PAGE
            groups[g.name] = {"kpool": self.kpool[g.name],
                              "vpool": self.vpool[g.name],
                              "block_tables": jnp.asarray(bt)}
        for s in exclude:
            pos[s] = 0
        return {"pos": jnp.asarray(pos), "groups": groups}

    def chunk_cache(self, slot: int, chunk_len: int) -> dict:
        """The single-lane pytree ``transformer.prefill_chunk`` consumes:
        this slot's block tables and write position over the shared pools
        (copied, not aliased — see :meth:`decode_cache`).  Window groups
        first make every page of the chunk's span live: the chunk's own
        pages plus the in-window prior pages must coexist for the chunk
        attend."""
        self.prepare_tokens(slot, chunk_len)
        groups = {
            g.name: {"kpool": self.kpool[g.name],
                     "vpool": self.vpool[g.name],
                     "block_tables": jnp.asarray(
                         self.block_tables[g.name][slot:slot + 1].copy())}
            for g in self.groups}
        return {"pos": jnp.asarray(self.pos[slot:slot + 1].copy()),
                "groups": groups}

    def update_from(self, new_cache: dict) -> None:
        """Write back the pools a decode step returned (positions stay
        host-managed: idle lanes must not advance)."""
        for g in self.groups:
            self.kpool[g.name] = new_cache["groups"][g.name]["kpool"]
            self.vpool[g.name] = new_cache["groups"][g.name]["vpool"]

    def utilization(self) -> float:
        """Fraction of allocatable pages currently owned by live requests."""
        total = sum(n - 1 for n in self._group_pages.values())
        return 1.0 - self.free_pages / total


class PrefixCache:
    """Token-hash-keyed cache of pinned prompt-prefix pages.

    Turns repeated prompt prefixes — a traffic class's shared system
    prompt, or a session's previous-turn prompt — into (near-)zero-cost
    prefills: when a finished prefill's prompt is inserted, the cache
    takes a reference on the pages covering it (:meth:`PagedKVCache.
    share_prefix`); when a later prompt starts with the same tokens, the
    engine adopts those pages (``alloc(adopt=...)``) and prefills only
    the remainder, so TTFT drops by the skipped prefix's prefill time.

    * **Keys are token hashes** (blake2b over the int32 prefix), but a
      hit also verifies the stored tokens byte-for-byte — a hash
      collision can never serve wrong K/V.
    * **Lookup returns the longest cached entry** that is a *strict*
      prefix of the prompt (at least one token must remain to prefill:
      the remainder chunk's last-position logits produce the first output
      token).
    * **Entries are pinned by refcount, evicted LRU**: ``max_pages``
      bounds the cache's page references; admission pressure can also
      force eviction (:meth:`evict_lru`), and an entry's pages return to
      the free list only when no lane still shares them.
    * **Full-attention stacks only** (asserted): sliding-window groups
      trim pages below the horizon, so a prompt snapshot is only valid at
      the exact position it was taken — not worth caching.

    All bookkeeping is host-side and O(entries); the pool pages are
    shared in place, never copied (lanes copy-on-write if they must
    write the boundary page).
    """

    def __init__(self, kv: PagedKVCache, *, max_pages: Optional[int] = None):
        assert all(g.window is None for g in kv.groups), \
            "PrefixCache requires an all-full-attention stack"
        self.kv = kv
        self.max_pages = max_pages
        #: insertion/recency-ordered: key -> {"len", "toks", "snap", "pages"}
        self._entries: "OrderedDict[bytes, dict]" = OrderedDict()
        self.held_pages = 0
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def _key(toks: np.ndarray, n: int) -> bytes:
        raw = np.ascontiguousarray(toks[:n]).astype(np.int32).tobytes()
        return hashlib.blake2b(raw, digest_size=16).digest()

    def lookup(self, toks: np.ndarray) -> Tuple[Optional[dict], int]:
        """Longest adoptable cached prefix of ``toks`` -> (snapshot,
        adoptable length), or (None, 0).  A hit refreshes the entry's LRU
        position.

        Adoption is *strictly* shorter than the prompt: at least one
        token must be re-absorbed, because the first output token is
        sampled from the prefill logits.  An entry covering the whole
        prompt (an identical prompt served earlier — the in-flight
        registry's wait-and-adopt case) is therefore adopted at
        ``len(toks) - 1``: :meth:`PagedKVCache.alloc` truncates the
        snapshot and the boundary page's final position is rewritten
        post-CoW by the one absorbed token."""
        lens = sorted({e["len"] for e in self._entries.values()},
                      reverse=True)
        for n in lens:
            adopt = min(n, len(toks) - 1)
            if n > len(toks) or adopt < 1:
                continue
            key = self._key(toks, n)
            e = self._entries.get(key)
            if e is not None and np.array_equal(e["toks"], toks[:n]):
                self._entries.move_to_end(key)
                self.hits += 1
                return e["snap"], adopt
        self.misses += 1
        return None, 0

    def probe(self, toks: np.ndarray) -> int:
        """The length :meth:`lookup` would return, *without* refreshing
        LRU order or counting a hit/miss — the router-facing peek
        (``ContinuousEngine.cached_prefix_len``) must not perturb
        eviction order just by estimating."""
        for n in sorted({e["len"] for e in self._entries.values()},
                       reverse=True):
            adopt = min(n, len(toks) - 1)
            if n > len(toks) or adopt < 1:
                continue
            e = self._entries.get(self._key(toks, n))
            if e is not None and np.array_equal(e["toks"], toks[:n]):
                return adopt
        return 0

    def insert(self, slot: int, toks: np.ndarray, n_tokens: int) -> bool:
        """Pin ``slot``'s first ``n_tokens`` prompt positions as a cache
        entry.  If pinning the partially-covered boundary page would
        break the reservation invariant (demoting the donor's holding
        needs one available page of CoW headroom per group), the entry is
        truncated to whole pages; returns False if nothing was cached."""
        ps = self.kv.page_size
        n = min(int(n_tokens), int(self.kv.pos[slot]))
        if n > (int(self.kv.pos[slot]) // ps) * ps:
            # pinning the donor's live write page demotes it; the CoW
            # that re-exclusives it needs one available page per group
            if any(self.kv.available(g) < 1 for g in self.kv.groups):
                n = (int(self.kv.pos[slot]) // ps) * ps
        if n <= 0:
            return False
        key = self._key(toks, n)
        if key in self._entries:
            self._entries.move_to_end(key)
            return False
        snap = self.kv.share_prefix(slot, n)
        pages = sum(len(p) for p in snap["pages"].values())
        self._entries[key] = {"len": n,
                              "toks": np.array(toks[:n], np.int32),
                              "snap": snap, "pages": pages}
        self.held_pages += pages
        if self.kv.tr:
            self.kv.tr.instant(tr_mod.PREFIX_INSERT, self.kv._clock(),
                               track="pool", tokens=n, pages=pages)
        if self.max_pages is not None:
            while self.held_pages > self.max_pages and len(self._entries) > 1:
                self.evict_lru()
        return True

    def evict_lru(self) -> bool:
        """Release the least-recently-used entry's page references (pages
        free only once no lane shares them).  False if the cache is
        empty."""
        if not self._entries:
            return False
        _, e = self._entries.popitem(last=False)
        self.kv.release_snapshot(e["snap"])
        self.held_pages -= e["pages"]
        if self.kv.tr:
            self.kv.tr.instant(tr_mod.PREFIX_EVICT, self.kv._clock(),
                               track="pool", tokens=e["len"],
                               pages=e["pages"])
        return True

    def clear(self) -> None:
        """Evict everything (e.g. before tearing an engine down)."""
        while self.evict_lru():
            pass
