"""SLO aggregation for serving runs: latency percentiles, hit-rate, goodput.

*Goodput* is the paper's reward notion lifted to traffic scale: the sum of
realized rewards, which by construction (fleet._retire) only on-time
actions earn.  Throughput counts everything served; goodput is what the
deployment was actually worth.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.traffic import SimRequest


@dataclasses.dataclass
class SLOReport:
    n: int                     # requests offered
    served: int                # completed (possibly degraded)
    dropped: int
    degraded: int              # completed with fewer tokens than asked
    hit_rate: float            # met deadline / offered
    p50_s: float               # modeled latency percentiles over completions
    p99_s: float
    goodput: float             # sum of realized on-time reward
    goodput_rate: float        # goodput / horizon (reward per simulated s)
    per_class: Optional[Dict[str, "SLOReport"]] = None

    def row(self) -> List:
        return [self.n, self.served, self.dropped,
                f"{self.hit_rate:.3f}", f"{self.p50_s * 1e3:.1f}",
                f"{self.p99_s * 1e3:.1f}", f"{self.goodput:.1f}"]


def _percentile(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else float("nan")


def summarize(reqs: Sequence[SimRequest], horizon_s: float, *,
              split_classes: bool = True) -> SLOReport:
    done = [r for r in reqs if not r.dropped and r.t_finish is not None]
    lats = [r.latency_s for r in done]
    rep = SLOReport(
        n=len(reqs),
        served=len(done),
        dropped=sum(r.dropped for r in reqs),
        degraded=sum(r.tokens_done < r.max_new for r in done),
        hit_rate=(sum(bool(r.met_deadline) for r in reqs) / len(reqs)
                  if reqs else 0.0),
        p50_s=_percentile(lats, 50), p99_s=_percentile(lats, 99),
        goodput=sum(r.reward for r in reqs),
        goodput_rate=sum(r.reward for r in reqs) / horizon_s,
    )
    if split_classes:
        names = sorted({r.cls_name for r in reqs})
        if len(names) > 1:
            rep.per_class = {
                nm: summarize([r for r in reqs if r.cls_name == nm],
                              horizon_s, split_classes=False)
                for nm in names}
    return rep
