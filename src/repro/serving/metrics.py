"""SLO aggregation for serving runs: latency percentiles, hit-rate, goodput.

*Goodput* is the paper's reward notion lifted to traffic scale: the sum of
realized rewards, which by construction (fleet._retire) only on-time
actions earn.  Throughput counts everything served; goodput is what the
deployment was actually worth.

Streaming SLOs (the million-user workload is conversational, and
streaming agents win on time-to-first-token, not completion time — see
ROADMAP): reports carry TTFT and inter-token-latency percentiles, both
derived from ``t_first_token`` (set by the analytic batcher and the paged
engine alike), plus the *slack attribution* — where a served request's
deadline slack actually went, split into queue wait (arrive -> admit),
prefill (admit -> prompt absorbed), and decode (first token -> finish).
``per_class`` recursion gives every traffic class its own attribution.

Presentation is split from data: :meth:`SLOReport.row` returns plain
numbers (consumers — ``check_regression.py``, the obs metrics sink, new
tables — never re-parse floats out of strings) and
:meth:`SLOReport.format_row` renders the historical human/CSV strings.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.traffic import SimRequest


@dataclasses.dataclass
class SLOReport:
    n: int                     # requests offered
    served: int                # completed (possibly degraded)
    dropped: int
    degraded: int              # completed with fewer tokens than asked
    hit_rate: float            # met deadline / offered
    p50_s: float               # modeled latency percentiles over completions
    p99_s: float
    goodput: float             # sum of realized on-time reward
    goodput_rate: float        # goodput / horizon (reward per simulated s)
    # -- streaming SLOs (nan when the path records no first token) --------
    ttft_p50_s: float = float("nan")   # time to first token percentiles
    ttft_p99_s: float = float("nan")
    #: barge-in cancellations (session traffic): retired early by the
    #: client, not by the engine — disjoint from ``dropped``/``degraded``
    cancelled: int = 0
    #: met_ttft / requests carrying a ttft_deadline_s (nan when none do)
    ttft_hit_rate: float = float("nan")
    # -- failure recovery: attempts vs requests ---------------------------
    #: requests that survived >= 1 engine crash (re-dispatched attempts);
    #: counted once per request, by the attempt that finally retired
    retried: int = 0
    #: requests that had a duplicate attempt launched (hedged dispatch);
    #: again once per request — losing attempts never enter the tallies
    hedged: int = 0
    itl_p50_s: float = float("nan")    # per-request mean inter-token latency
    itl_p99_s: float = float("nan")
    # -- slack attribution: mean seconds per served request ---------------
    queue_s: float = float("nan")      # arrive -> admit
    prefill_s: float = float("nan")    # admit -> prompt absorbed
    decode_s: float = float("nan")     # prompt absorbed -> finish
    per_class: Optional[Dict[str, "SLOReport"]] = None

    def row(self) -> List:
        """The table row as *numbers* (n, served, dropped, hit_rate,
        p50_ms, p99_ms, goodput) — format with :meth:`format_row`."""
        return [self.n, self.served, self.dropped, self.hit_rate,
                self.p50_s * 1e3, self.p99_s * 1e3, self.goodput]

    def format_row(self) -> List:
        """The historical presentation of :meth:`row`: counts stay ints,
        rates/latencies/goodput become fixed-precision strings."""
        n, served, dropped, hit, p50_ms, p99_ms, goodput = self.row()
        return [n, served, dropped, f"{hit:.3f}", f"{p50_ms:.1f}",
                f"{p99_ms:.1f}", f"{goodput:.1f}"]

    def streaming_row(self) -> List:
        """Numeric streaming-SLO columns: ttft p50/p99 ms, itl p50/p99 ms,
        then the queue/prefill/decode attribution in ms."""
        return [self.ttft_p50_s * 1e3, self.ttft_p99_s * 1e3,
                self.itl_p50_s * 1e3, self.itl_p99_s * 1e3,
                self.queue_s * 1e3, self.prefill_s * 1e3,
                self.decode_s * 1e3]


def _percentile(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else float("nan")


def _mean(xs: Sequence[float]) -> float:
    return float(np.mean(np.asarray(xs))) if len(xs) else float("nan")


def request_slack(r) -> Dict[str, Optional[float]]:
    """Per-request streaming timings from lifecycle fields (None where the
    path did not record the boundary): ttft_s, itl_s (mean inter-token),
    queue_s, prefill_s, decode_s.  Shared by :func:`summarize` and the
    engines' trace emission so the two feeders cannot diverge."""
    t_first = getattr(r, "t_first_token", None)
    ttft = t_first - r.t_arrive if t_first is not None else None
    itl = None
    if t_first is not None and r.t_finish is not None and r.tokens_done > 1:
        itl = (r.t_finish - t_first) / (r.tokens_done - 1)
    queue = r.t_admit - r.t_arrive if r.t_admit is not None else None
    prefill = None
    if r.t_prefill_done is not None and r.t_admit is not None:
        prefill = r.t_prefill_done - r.t_admit
    decode = None
    if r.t_finish is not None and r.t_prefill_done is not None:
        decode = r.t_finish - r.t_prefill_done
    return {"ttft_s": ttft, "itl_s": itl, "queue_s": queue,
            "prefill_s": prefill, "decode_s": decode}


def summarize(reqs: Sequence[SimRequest], horizon_s: float, *,
              split_classes: bool = True) -> SLOReport:
    # Attempt-vs-request accounting: a fleet under failure recovery may
    # retire *two attempts* of one rid (a hedged pair — the loser is torn
    # down and flagged).  Every tally below is per request, attributed to
    # the winning attempt: losers are excluded up front, so ``n`` counts
    # rids, latency is the winner's, and ``cancelled`` means client
    # barge-in — not the router cannibalizing its own duplicate.  Crash
    # retries never double count by construction (a reclaimed attempt is
    # reclaimed *instead of* retiring) and surface only in ``retried``.
    reqs = [r for r in reqs if not getattr(r, "hedge_loser", False)]
    done = [r for r in reqs if not r.dropped and r.t_finish is not None]
    lats = [r.latency_s for r in done]
    slacks = [request_slack(r) for r in done]
    pick = lambda key: [s[key] for s in slacks if s[key] is not None]
    ttfts, itls = pick("ttft_s"), pick("itl_s")
    rep = SLOReport(
        n=len(reqs),
        served=len(done),
        dropped=sum(r.dropped for r in reqs),
        degraded=sum(r.tokens_done < r.max_new for r in done
                     if not getattr(r, "cancelled", False)),
        hit_rate=(sum(bool(r.met_deadline) for r in reqs) / len(reqs)
                  if reqs else 0.0),
        p50_s=_percentile(lats, 50), p99_s=_percentile(lats, 99),
        goodput=sum(r.reward for r in reqs),
        goodput_rate=sum(r.reward for r in reqs) / horizon_s,
        ttft_p50_s=_percentile(ttfts, 50), ttft_p99_s=_percentile(ttfts, 99),
        itl_p50_s=_percentile(itls, 50), itl_p99_s=_percentile(itls, 99),
        queue_s=_mean(pick("queue_s")), prefill_s=_mean(pick("prefill_s")),
        decode_s=_mean(pick("decode_s")),
        cancelled=sum(bool(getattr(r, "cancelled", False)) for r in reqs),
        retried=sum(getattr(r, "retries", 0) > 0 for r in reqs),
        hedged=sum(bool(getattr(r, "hedged", False)) for r in reqs),
    )
    slod = [r for r in reqs if getattr(r, "ttft_deadline_s", None) is not None]
    if slod:
        rep.ttft_hit_rate = (sum(bool(getattr(r, "met_ttft", False))
                                 for r in slod) / len(slod))
    if split_classes:
        names = sorted({r.cls_name for r in reqs})
        if len(names) > 1:
            rep.per_class = {
                nm: summarize([r for r in reqs if r.cls_name == nm],
                              horizon_s, split_classes=False)
                for nm in names}
    return rep
