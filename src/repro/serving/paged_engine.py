"""Continuous batching on real compute: the paged-KV serving engine.

This is the fusion of the repo's two serving paths (ROADMAP "KV-cache
paging").  The wave :class:`~repro.serving.scheduler.Scheduler` serves real
tokens but in padded waves with a full barrier; the analytic
:class:`~repro.serving.continuous.ContinuousBatcher` admits and retires
requests mid-flight but never touches a model.  :class:`ContinuousEngine`
does both at once:

* **Real compute.**  Prompts are prefilled through the actual jit'd model
  and every decode step emits real tokens for every occupied lane —
  greedy outputs are token-identical to the wave engine's.
* **Paged KV cache** (:mod:`~repro.serving.kv_cache`).  Each admitted
  request gets just enough fixed-size pages from shared per-layer-group
  pools; attention gathers through per-lane, per-group block tables
  (:func:`repro.models.attention.attn_apply` paged branch).  Pages return
  to the free list the step a request retires, so the next request is
  admitted *mid-flight of everyone else* — no wave barrier.  Sliding-
  window layer groups (starcoder2-class uniform windows, gemma3-class
  local:global) hold at most ``ceil(window/page_size) + 1`` live pages
  per lane and free out-of-window pages back to the pool mid-flight;
  admission sizes their page demand by the window, not the context, and
  the clock prices their attention at ``min(context, window)``.
* **Fixed-lane batching.**  The decode step always runs at ``slots`` lanes;
  idle lanes point at the reserved dummy page and their outputs are
  discarded.  One compiled step serves every occupancy.
* **Chunked prefill** (``prefill_chunk=N``).  A monolithic prefill stalls
  every decode lane for the whole prompt — the head-of-line blocking the
  ROADMAP flagged after PR 2.  With chunking, an admitted prompt is
  absorbed ``N`` tokens at a time through ``transformer.prefill_chunk``
  (the chunk's K/V scatter straight into the request's block-table pages),
  one real decode step for the active lanes landing between chunks.  Each
  chunk is charged ``profile.prefill_s(N, context=absorbed)`` — length-
  aware, later chunks attend over the pages already written — so the clock
  contract holds chunk-for-chunk; greedy outputs stay token-identical to the monolithic
  path (tests/test_chunked_prefill.py).  When a prompt completes, the
  admission policy is re-applied (:meth:`ContinuousEngine.
  _post_prefill_check`) — interleaved decode charges landed since the
  admission projection, so "fits the deadline" must be re-proved before
  the decode budget is spent.
* **Jit'd sampling, optional speculation.**  Token selection is closed
  over from a :class:`~repro.serving.sampler.SamplerPolicy` inside every
  jit'd step — greedy and temperature/top-k both run device-side, with
  only ``(slots,)`` int32 ids crossing to host.  A
  :class:`~repro.core.fpx.SpecPoint` (``speculate=``) switches decode to
  fast-draft / slow-verify rounds: draft ``k`` tokens cheaply (same
  weights at ``draft_bits``), verify in one fused chunk, accept/reject
  on device — greedy output stays token-identical to dense decode, and
  rounds collapse to dense steps under deadline pressure.
* **Prefix reuse and sessions.**  With a
  :class:`~repro.serving.kv_cache.PrefixCache` attached
  (``prefix_cache=``), completed prefills publish their pages under
  token-hash keys and later requests sharing a prefix (repeated system
  prompts, a session's own earlier turns) adopt those pages as
  refcounted read-only references — admission charges and the clock
  pays only the tail ``prefill_s(P - l, context=l)``.  Writes into the
  shared region copy-on-write (the boundary page is reserved at
  admission), so co-resident lanes stay token-identical to independent
  prefills; full-attention stacks only.  Streaming SLOs ride along:
  admission drops requests whose projected first token already misses
  ``ttft_deadline_s``, and a barge-in (``t_cancel``) retires a lane at
  the next step boundary — partial output kept, private pages freed
  immediately, shared pages merely unreferenced.
* **The analytic clock.**  Between real steps the engine advances the same
  ``core.latency`` roofline clock the traffic simulator and the FPX
  controller use (CPU wall time is meaningless here), and reuses the
  *identical* EDF + drop/degrade admission math as the analytic batcher
  (:func:`~repro.serving.continuous.projected_finish` /
  :func:`~repro.serving.continuous.degraded_budget`).

The engine accepts both request flavors of the serving contract:
:class:`~repro.serving.scheduler.Request` (real prompt tokens) and
:class:`~repro.serving.traffic.SimRequest` (shape only — the engine
synthesizes deterministic tokens), so a
:class:`~repro.serving.fleet.FleetRouter` can drive a pool of live paged
engines with the same traffic streams it feeds the analytic fleet.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.fpx import SpecPoint
from repro.core.latency import Hardware, V5E
from repro.models import transformer
from repro.models.modules import ExecContext
from repro.obs import trace as tr_mod
from repro.serving import sampler as sampler_mod
from repro.serving.continuous import (LatencyProfile, degraded_budget,
                                      emit_admit, emit_arrive, emit_finish,
                                      estimate_backlog, mark_first_token,
                                      post_prefill_fit, projected_finish,
                                      projected_first_token, ready_at,
                                      retire_cancelled, retire_dropped,
                                      spec_round_fits)
from repro.serving.continuous import drive as continuous_drive
from repro.serving.kv_cache import PagedKVCache, PrefixCache
from repro.serving.traffic import session_prompt_tokens


@dataclasses.dataclass
class _Lane:
    req: object                   # Request or SimRequest
    last_token: Optional[int]     # token the next decode step consumes
    remaining: int                # decode steps left
    context: int                  # prompt + tokens written so far
    produced: List[int] = dataclasses.field(default_factory=list)
    #: chunked prefill: prompt tokens not yet absorbed into pages (None
    #: once prefill completes and the lane is decoding)
    prompt_toks: Optional[np.ndarray] = None
    absorbed: int = 0
    #: in-flight prefill registry key (full-prompt hash) this lane holds
    #: while its prompt is being prefilled — cleared on publication or
    #: teardown (see ContinuousEngine._inflight)
    inflight_key: Optional[bytes] = None

    @property
    def prefilling(self) -> bool:
        return self.prompt_toks is not None


class ContinuousEngine:
    """EDF continuous batching with a paged KV cache on a live model."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 max_ctx: int = 256, policy: str = "degrade",
                 profile: Optional[LatencyProfile] = None,
                 latency_cfg: Optional[ModelConfig] = None,
                 avg_bits: float = 16.0, hw: Hardware = V5E,
                 ctx: Optional[ExecContext] = None,
                 on_retire: Optional[Callable] = None,
                 prompt_seed: int = 0, unroll: bool = True,
                 prefill_chunk: Optional[int] = None,
                 attn_impl: str = "fused", tracer=None,
                 sampler: Optional[sampler_mod.SamplerPolicy] = None,
                 speculate: Optional[SpecPoint] = None,
                 prefix_cache=False, mesh=None,
                 sharding_policy: str = "baseline",
                 tp_link: str = "ici"):
        """``n_pages`` defaults to enough for every lane to hold ``max_ctx``
        tokens (plus the reserved dummy page); size it *below* that to study
        page-pressure admission.  ``profile`` / ``latency_cfg`` / ``avg_bits``
        parameterize the analytic clock exactly as in the analytic batcher,
        so wave vs. paged comparisons share one notion of time.

        ``prefill_chunk``: absorb admitted prompts this many tokens at a
        time through ``transformer.prefill_chunk`` — one chunk, then one
        real decode step for the lanes already decoding, alternating until
        the prompt is in its pages — instead of stalling every decode lane
        for the whole prompt (None = monolithic, the historical behavior).
        Must be a multiple of ``page_size`` so chunk writes stay
        page-aligned (the Pallas scatter path requires it; it also makes
        each full chunk exactly fill pages).  Each chunk is charged
        ``profile.prefill_s(chunk, context=absorbed)`` on the engine clock
        — length-aware, since a later chunk attends over every previously
        written page — so the clock contract with the analytic batcher
        holds chunk-for-chunk.

        ``attn_impl``: how a default-constructed profile prices the paged
        decode attention — ``"fused"`` (the paged flash-attention kernel:
        one pool-direct read of each lane's actual context; this is also
        the historical clock) or ``"gather"`` (the materialize-then-SDPA
        path the kernel replaced: ~3x the KV traffic at the padded
        block-table extent).  Ignored when ``profile`` is passed
        explicitly.

        ``tracer``: a :class:`repro.obs.Tracer` (or a scoped view)
        receiving the full lifecycle/step/page event stream — spans carry
        the host wall time of the real compute alongside the analytic
        clock (``drift_report`` compares the two).  None = the
        zero-overhead null tracer.

        ``sampler``: the :class:`~repro.serving.sampler.SamplerPolicy`
        the jit'd steps close over (None = greedy).  Stochastic policies
        run device-side too, keyed per (rid, output position) — a
        request's tokens are reproducible regardless of lane placement.

        ``speculate``: a :class:`~repro.core.fpx.SpecPoint` switches
        decode to fast-draft / slow-verify rounds: one jit'd call drafts
        ``k`` tokens per decoding lane with the *same* weights at
        ``draft_bits``, verifies them through one fused
        ``transformer.verify_chunk``, and accept/rejects on device
        (:func:`~repro.serving.sampler.spec_accept`) — greedy output is
        token-identical to dense decode for any draft quality.  Rounds
        collapse to dense steps whenever the round would blow the
        earliest lane deadline (:func:`~repro.serving.continuous.
        spec_round_fits`).  Admission reserves ``k`` extra positions of
        page headroom (a round writes up to ``pos + k`` before the host
        learns the accepted count); requires the fused attention path.

        ``prefix_cache``: enable the token-hash prefix cache
        (:class:`~repro.serving.kv_cache.PrefixCache`) — ``True`` for an
        unbounded page budget, an int to cap the cache's pinned pages,
        ``False`` (default) off.  With it on, admission looks the
        request's prompt up, adopts the longest cached prefix's pages by
        reference (copy-on-write protects them), prefills only the
        remainder — TTFT drops by the skipped span's prefill time, and
        every admission projection prices the discount
        (``cached_prefix=``) — and publishes the finished prompt's
        shareable spans back into the cache.  Requires an
        all-full-attention stack (window groups trim pages positionally,
        so prefix snapshots are not reusable).

        ``mesh``: a jax ("data", "model") mesh (e.g. :func:`repro.launch.
        mesh.sim_mesh`) makes the engine *tensor-parallel*: params are
        placed under the :mod:`repro.launch.shardings` FSDP x TP rules
        (``sharding_policy``), the paged KV pools shard their kv-heads
        over the "model" axis, and GSPMD partitions the existing jit'd
        steps — same graphs, sharded operands, token-identical outputs.
        The default-constructed profile prices the split honestly:
        per-chip compute/bandwidth divide by the model-axis size and
        every forward pays the per-layer all-reduce tax over ``tp_link``
        ("ici" intra-host, "dcn" when the TP group spans hosts).  None
        (default) = unsharded, bit-identical to the historical engine."""
        if not transformer.paged_supported(cfg):
            raise NotImplementedError(
                "ContinuousEngine needs the paged decode path, which "
                "supports dense/moe attention stacks (uniform, "
                f"sliding-window, local:global), not {cfg.name} "
                f"(arch_type={cfg.arch_type})")
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.policy = policy
        assert policy in ("drop", "degrade", "serve"), policy
        if prefill_chunk is not None and (prefill_chunk < page_size
                                          or prefill_chunk % page_size):
            raise ValueError(
                f"prefill_chunk ({prefill_chunk}) must be a positive "
                f"multiple of page_size ({page_size})")
        self.prefill_chunk = prefill_chunk
        self.speculate = speculate
        if speculate is not None and attn_impl != "fused":
            raise ValueError("speculative decoding rides the fused paged "
                             "attention path (attn_impl='fused')")
        #: extra block-table positions a speculative round may write past
        #: the committed pos before the host clamps the accepted count
        self._spec_k = 0 if speculate is None else speculate.k
        #: chunk extent that sizes transient window-group page demand:
        #: the larger of a prefill chunk and a speculative write span
        self._page_chunk = (prefill_chunk if speculate is None
                            else max(prefill_chunk or 1, speculate.k + 1))
        self.mesh = mesh
        self.tp = 1
        if mesh is not None and "model" in mesh.axis_names:
            self.tp = int(mesh.shape["model"])
        assert tp_link in ("ici", "dcn"), tp_link
        self._tp_link = tp_link
        width = -(-max_ctx // page_size)
        self.profile = profile or LatencyProfile(latency_cfg or cfg,
                                                 avg_bits, hw=hw,
                                                 attn_impl=attn_impl,
                                                 padded_ctx=width * page_size,
                                                 spec=speculate,
                                                 tp=self.tp, tp_link=tp_link)
        assert self.profile.spec == speculate, \
            "engine speculate and profile.spec must agree (one clock)"
        self.ctx = ctx or ExecContext()
        self.on_retire = on_retire
        self.prompt_seed = prompt_seed
        if n_pages is None:
            n_pages = slots * width + 1
        self.cache = PagedKVCache(cfg, slots=slots, n_pages=n_pages,
                                  page_size=page_size, max_ctx=max_ctx)
        if self.tp > 1:
            # committed shardings drive GSPMD through the jit'd steps:
            # params under the FSDP x TP rules, pools head-sharded — the
            # step graphs are unchanged and outputs stay token-identical
            # to the unsharded twin (tests/test_sharded.py pins this)
            from repro.launch import shardings as sh_mod
            self.params = jax.device_put(
                params, sh_mod.param_shardings(params, mesh,
                                               sharding_policy))
            params = self.params
            self.cache.shard(sh_mod.paged_pool_shardings(cfg, mesh),
                             tp=self.tp)
        self.prefix: Optional[PrefixCache] = None
        if prefix_cache:
            if any(g.window is not None for g in self.cache.groups):
                raise ValueError(
                    "prefix_cache requires an all-full-attention stack "
                    "(sliding-window groups trim pages positionally, so "
                    f"prefix snapshots are not reusable) — {cfg.name}")
            self.prefix = PrefixCache(
                self.cache,
                max_pages=None if prefix_cache is True else int(prefix_cache))
        self.sampler = sampler or sampler_mod.GREEDY
        self._unroll = unroll
        self._jit_steps()
        self.t = 0.0                      # engine-local analytic clock
        self.tr = tracer or tr_mod.NULL
        self.cache.bind_tracer(self.tr, lambda: self.t)
        self.lanes: List[Optional[_Lane]] = [None] * slots
        self.pending: List = []
        #: in-flight prefill registry (prefix cache on): full-prompt hash
        #: -> rid of the lane currently prefilling that exact prompt.
        #: Admission *skips* (not drops) a pending request whose prompt is
        #: in flight — publication happens only at prefill completion, so
        #: without this, N identical prompts admitted in one wave would
        #: all miss the cache and each re-prefill the full prompt; with
        #: it, the waiters admit after publication and adopt all but the
        #: last token (lookup is strict-prefix), absorbing one token each.
        self._inflight: Dict[bytes, int] = {}
        self.completed: List = []
        self.dropped: List = []
        #: (rid, page ids) per admission — observability for tests/benchmarks
        self.admissions: List[Tuple[int, List[int]]] = []
        #: fault injection (serving.faults): the per-engine view, or None
        self.faults = None

    # -- fault-injection protocol (serving.faults) ---------------------------

    def _charge(self, dt: float) -> None:
        """Advance the clock by ``dt`` engine-seconds, stretched by any
        active slowdown fault (exactly 1.0x on the clean path, so
        un-faulted runs stay bit-identical)."""
        if self.faults:
            dt *= self.faults.scale(self.t)
        self.t += dt

    def reclaim_in_flight(self) -> List:
        """Crash teardown: every lane and queued request leaves the
        engine.  Lanes drop their page references (private pages return
        to the free list, shared pages merely unref), and the prefix
        cache — volatile pool state — is cleared too, so after a crash
        every page is back on the free list.  The reclaimed requests are
        returned for the crash handler to requeue, strand, or re-route;
        they do not retire here."""
        out: List = []
        for i, l in enumerate(self.lanes):
            if l is None:
                continue
            self.lanes[i] = None
            self.cache.free(i)
            out.append(l.req)
        self._inflight.clear()
        if self.prefix is not None:
            self.prefix.clear()
        out.extend(self.pending)
        self.pending = []
        return out

    def requeue(self, req) -> None:
        """Accept a recovered attempt without re-emitting its arrival."""
        self.pending.append(req)

    def apply_pressure(self, fault):
        taken = self.cache.seize(fault.pages)
        return taken or None

    def release_pressure(self, token) -> None:
        self.cache.restore(token)

    # -- jit'd model steps ---------------------------------------------------

    def _jit_steps(self) -> None:
        """(Re)compile the model steps, closing over the current sampling
        policy: token selection runs *inside* each jit'd step (greedy and
        temperature/top-k alike), so only (slots,)-sized int32 ids cross
        the device->host boundary — never the (slots, vocab) logits.
        ``rids``/``pos`` feed the lane-keyed PRNG streams
        (:func:`~repro.serving.sampler.lane_keys`); the greedy policy
        ignores them, so the greedy steps compile to exactly the
        historical argmax-in-jit graphs.

        raw_kv on the prefill: the paged cache addresses logical
        positions, so the prefill must hand back unrotated per-position
        K/V (the wave path's windowed ring-buffer layout would scatter
        wrong slots)."""
        pol, cfg, unroll = self.sampler, self.cfg, self._unroll

        def pre(p, b, rids, pos):
            logits, cache = transformer.prefill(p, cfg, b, self.ctx,
                                                unroll=unroll, raw_kv=True)
            return sampler_mod.sample(pol, logits, rids, pos), cache

        def chk(p, b, c, rids, pos):
            logits, cache = transformer.prefill_chunk(p, cfg, b, c,
                                                      self.ctx,
                                                      unroll=unroll)
            return sampler_mod.sample(pol, logits, rids, pos), cache

        def dec(p, b, c, rids, pos):
            logits, cache = transformer.paged_decode_step(p, cfg, b, c,
                                                          self.ctx,
                                                          unroll=unroll)
            return sampler_mod.sample(pol, logits, rids, pos), cache

        # a chunk resumed on an adopted prefix starts wherever that prefix
        # ended — almost never on a page boundary — so it rides the same
        # unaligned-scatter escape the speculative verify chunk uses (the
        # scatter takes the jnp path; the attend stays fused)
        resume_ctx = dataclasses.replace(self.ctx, unaligned_scatter=True)

        def rchk(p, b, c, rids, pos):
            logits, cache = transformer.prefill_chunk(p, cfg, b, c,
                                                      resume_ctx,
                                                      unroll=unroll)
            return sampler_mod.sample(pol, logits, rids, pos), cache

        self._prefill = jax.jit(pre)
        self._chunk = jax.jit(chk)
        self._resume = jax.jit(rchk)
        self._decode = jax.jit(dec)
        if self.speculate is not None:
            k = self.speculate.k
            # same weights, cheap point: a flat low-bit policy for the
            # draft passes; the verify chunk runs at the engine's own ctx
            # (plus the unaligned-scatter escape — verify chunks start
            # wherever the lane's write position sits, rarely on a page
            # boundary)
            draft_ctx = dataclasses.replace(
                self.ctx, policy=None,
                default_bits=int(round(self.speculate.draft_bits)))
            verify_ctx = dataclasses.replace(self.ctx,
                                             unaligned_scatter=True)

            def spec(p, toks, c, rids, pos_out):
                """One fast-draft / slow-verify round, entirely on device.

                toks (slots, 1): last committed token per lane; c: decode
                cache prepared with ``lookahead=k+1``; pos_out (slots,):
                output position of the round's first emitted token.
                Returns (tokens (slots, k+1), n_emit (slots,), cache) —
                the *verify* pass's cache: its chunk scatter overwrites
                every draft-written K/V slot, so the draft cache is
                simply dropped and rejection needs no rollback beyond
                the host advancing pos by the emitted count."""
                cur, dc = toks, c
                d_toks, d_logits = [], []
                for j in range(k):
                    logits, dc = transformer.paged_decode_step(
                        p, cfg, {"token": cur}, dc, draft_ctx,
                        unroll=unroll)
                    cur = sampler_mod.sample(
                        pol, logits, rids, pos_out + j,
                        stream=sampler_mod.STREAM_DRAFT)
                    d_toks.append(cur)
                    d_logits.append(logits)
                drafts = jnp.concatenate(d_toks, axis=1)       # (slots, k)
                dlg = jnp.concatenate(d_logits, axis=1)        # (slots,k,V)
                chunk = jnp.concatenate([toks, drafts], axis=1)
                vlg, vcache = transformer.verify_chunk(
                    p, cfg, {"tokens": chunk}, c, verify_ctx,
                    unroll=unroll)
                tokens, n_emit = sampler_mod.spec_accept(
                    pol, drafts, dlg, vlg, rids, pos_out)
                return tokens, n_emit, vcache

            self._spec = jax.jit(spec)

    def set_sampler(self, sampler: sampler_mod.SamplerPolicy) -> None:
        """Swap the sampling policy (re-jits the steps on change)."""
        if sampler != self.sampler:
            self.sampler = sampler
            self._jit_steps()

    # -- submission ----------------------------------------------------------

    def submit(self, req) -> None:
        self.pending.append(req)
        if self.tr:
            emit_arrive(self.tr, req)

    def _prompt_for(self, req) -> np.ndarray:
        p = getattr(req, "prompt", None)
        if p is not None:
            return np.asarray(p, np.int32)
        if getattr(req, "session", None) is not None:
            # session SimRequest: nested deterministic streams — turn k's
            # prompt literally extends turn k-1's, so the token-hash
            # prefix cache hits exactly the spans prefix_keys declares
            return session_prompt_tokens(req, vocab=self.cfg.vocab,
                                         seed=self.prompt_seed)
        # SimRequest: deterministic synthetic tokens for its prompt_len
        rng = np.random.default_rng(self.prompt_seed * 7919 + req.rid)
        return rng.integers(0, self.cfg.vocab, req.prompt_len,
                            dtype=np.int32)

    # -- admission -----------------------------------------------------------

    def _n_active(self) -> int:
        return sum(l is not None for l in self.lanes)

    def _free_lane(self) -> Optional[int]:
        for i, l in enumerate(self.lanes):
            if l is None:
                return i
        return None

    def _drop(self, req) -> None:
        retire_dropped(self, req)

    def _admit_one(self) -> bool:
        """Admit the earliest-deadline arrived request into a free lane,
        with the shared drop/degrade projection *plus* page feasibility:
        a request that cannot get pages right now keeps its place in the
        EDF queue and waits for a retirement to free some.  With the
        prefix cache on, the prompt is looked up first and every
        projection prices the discounted (remainder-only) prefill; under
        page pressure cold prefix-cache entries are evicted before
        waiting.  A request whose exact prompt is *currently being
        prefilled* by another lane is skipped (not dropped, not admitted):
        it waits for that prefill to publish, then adopts the cached
        prefix instead of duplicating the work — the in-flight registry
        fix for the all-waiters-miss bug."""
        skipped: set = set()
        while True:
            arrived = [r for r in self.pending
                       if ready_at(r) <= self.t and r.rid not in skipped]
            lane = self._free_lane()
            if not arrived or lane is None:
                return False
            req = min(arrived, key=lambda r: (r.deadline_abs, r.rid))
            S = req.prompt_len
            # hard capability cap: the block table addresses max_ctx
            # tokens, and a speculative round needs k positions of
            # headroom past the last committed token (the verify chunk
            # writes them before the host clamps the accepted count)
            cap = self.cache.max_ctx - S + 1 - self._spec_k
            if cap < 1:
                self.pending.remove(req)
                self._drop(req)               # prompt alone can never fit
                continue
            toks = snap = None
            cached = 0
            if self.prefix is not None:
                toks = self._prompt_for(req)
                holder = self._inflight.get(
                    PrefixCache._key(toks, len(toks)))
                if holder is not None and holder != req.rid:
                    # same prompt mid-prefill on another lane: wait for
                    # it to publish, then adopt — try the next EDF
                    # candidate meanwhile (the lane stays usable)
                    skipped.add(req.rid)
                    continue
                snap, cached = self.prefix.lookup(toks)
                if self.tr:
                    self.tr.instant(tr_mod.PREFIX_LOOKUP, self.t,
                                    track="queue", rid=req.rid,
                                    hit=cached > 0, tokens=cached)
            ttft_d = getattr(req, "ttft_deadline_s", None)
            if self.policy != "serve" and ttft_d is not None \
                    and projected_first_token(
                        self.profile, self.t, self._n_active() + 1, req,
                        prefill_chunk=self.prefill_chunk,
                        cached_prefix=cached) > req.t_arrive + ttft_d:
                # the paged path's first token is the prefill logits, so
                # the projection is prefill-done; degrading trims decode
                # budget, which cannot speed that up — drop
                self.pending.remove(req)
                self._drop(req)
                continue
            n_tok = min(req.max_new, cap)
            if self.policy != "serve" and projected_finish(
                    self.profile, self.t, self._n_active() + 1, req,
                    n_tok, prefill_chunk=self.prefill_chunk,
                    cached_prefix=cached) > req.deadline_abs:
                if self.policy == "degrade":
                    n_tok = min(cap, degraded_budget(
                        self.profile, self.t, self._n_active() + 1, req,
                        prefill_chunk=self.prefill_chunk,
                        cached_prefix=cached))
                else:
                    n_tok = 0
                if n_tok < 1:
                    self.pending.remove(req)
                    self._drop(req)
                    continue                  # lane still free; try next
                if self.tr and n_tok < req.max_new:
                    self.tr.instant(tr_mod.REQ_DEGRADE, self.t,
                                    track="queue", rid=req.rid,
                                    from_tok=req.max_new, to_tok=n_tok)
            # page feasibility: prompt + (n_tok - 1) decode writes, plus
            # the speculative write headroom.  The demand is
            # *window-bounded* per layer group: a sliding-window group
            # costs at most its win_cap pages however long the request
            # runs, so windowed stacks admit far more work per pool than
            # their total token count suggests.  An adopted prefix's
            # whole pages cost nothing (shared, not allocated).
            span = S + n_tok - 1 + self._spec_k
            if not self.cache.fits_pool(span, self._page_chunk):
                self.pending.remove(req)
                self._drop(req)               # exceeds the whole pool:
                continue                      # waiting would hang forever
            if not self.cache.can_admit(span, self._page_chunk, cached):
                # shed cold prefix entries before making the EDF head
                # wait (re-looking up after each eviction: the adopted
                # entry itself may have been the LRU victim)
                while self.prefix is not None \
                        and not self.cache.can_admit(span, self._page_chunk,
                                                     cached) \
                        and self.prefix.evict_lru():
                    if cached:
                        snap, cached = self.prefix.lookup(toks)
                if not self.cache.can_admit(span, self._page_chunk, cached):
                    return False              # wait for pages (EDF head)
            self.pending.remove(req)
            self._start(lane, req, n_tok, toks=toks, snap=snap,
                        cached=cached)
            return True

    def _sweep_cancels(self) -> None:
        """Barge-in: retire every request whose cancel time has passed.
        Queued requests leave the queue; a live lane is reclaimed
        mid-flight — its pages drop one reference each, so private pages
        return to the free list immediately while pages shared with the
        prefix cache or a co-resident lane merely decrement and live
        on."""
        for req in [r for r in self.pending
                    if getattr(r, "t_cancel", None) is not None
                    and r.t_cancel <= self.t]:
            self.pending.remove(req)
            retire_cancelled(self, req)
        for i, l in enumerate(self.lanes):
            if l is None or getattr(l.req, "t_cancel", None) is None \
                    or l.req.t_cancel > self.t:
                continue
            self.lanes[i] = None
            self._release_inflight(l)
            self.cache.free(i)
            l.req.result_tokens = np.asarray(l.produced, np.int32)
            retire_cancelled(self, l.req)

    def _admit(self) -> None:
        if self.faults:
            self.faults.tick(self)
        self._sweep_cancels()
        while self._admit_one():
            pass

    def _start(self, lane: int, req, n_tok: int, *, toks=None, snap=None,
               cached: int = 0) -> None:
        """Admit ``req`` into ``lane`` over freshly allocated pages —
        minus the ``cached`` leading tokens adopted by reference from the
        prefix-cache snapshot ``snap`` (copy-on-write keeps the shared
        pages frozen).

        Monolithic (``prefill_chunk=None``): run the real prefill of the
        *remainder* now — the full prompt through ``transformer.prefill``
        on a miss, or the uncached tail as one resumed chunk attending
        over the adopted pages on a hit — charge ``prefill_s(S - cached,
        context=cached)``, and seed the lane with the first output token
        from the prefill logits (same contract as engine.generate).
        Chunked: just stage the prompt — the drive loop absorbs it
        chunk-by-chunk via :meth:`_advance_prefills`, decode steps
        landing in between (absorption starts past the adopted span)."""
        S = req.prompt_len
        pages = self.cache.alloc(lane, S + n_tok - 1 + self._spec_k,
                                 self._page_chunk,
                                 adopt=snap if cached else None,
                                 adopt_len=cached)
        self.admissions.append((req.rid, pages))
        req.t_admit = self.t
        if self.tr:
            emit_admit(self.tr, req, self.t, n_tok, track=f"lane{lane}")
        if toks is None:
            toks = self._prompt_for(req)
        ikey = None
        if self.prefix is not None:
            # claim the prompt in the in-flight registry until the prefill
            # publishes — concurrent identical prompts wait-and-adopt
            ikey = PrefixCache._key(toks, len(toks))
            self._inflight[ikey] = req.rid
        if self.prefill_chunk is not None:
            self.lanes[lane] = _Lane(req, last_token=None, remaining=n_tok,
                                     context=cached, prompt_toks=toks,
                                     absorbed=cached, inflight_key=ikey)
            return
        w0 = time.perf_counter()
        if cached:
            # remainder prefill: one resumed chunk over the adopted pages
            # (chunk_cache CoWs the shared boundary page before the
            # scatter; advance moves pos to S)
            first_tok, new_cache = self._resume(
                self.params, {"tokens": jnp.asarray(toks[None, cached:])},
                self.cache.chunk_cache(lane, S - cached),
                jnp.asarray([req.rid], jnp.int32),
                jnp.zeros((1,), jnp.int32))
            self.cache.update_from(new_cache)
            self.cache.advance(lane, S - cached)
        else:
            first_tok, raw_cache = self._prefill(
                self.params, {"tokens": jnp.asarray(toks[None, :])},
                jnp.asarray([req.rid], jnp.int32),
                jnp.zeros((1,), jnp.int32))
            self.cache.write_prefill(
                lane, transformer.raw_prefill_group_kv(self.cfg, raw_cache))
        t0 = self.t
        self._charge(self.profile.prefill_s(S - cached, context=cached))
        if self.tr:
            self.tr.span(tr_mod.REQ_PREFILL, t0, self.t,
                         track=f"lane{lane}", rid=req.rid, tokens=S - cached,
                         cached=cached, wall_s=time.perf_counter() - w0)
        lane_state = _Lane(req, last_token=None, remaining=n_tok,
                           context=S, inflight_key=ikey)
        self.lanes[lane] = lane_state
        self._finish_prefill(lane, lane_state, first_tok, toks)

    # -- chunked prefill -----------------------------------------------------

    def _advance_prefills(self) -> None:
        """Absorb one chunk for every lane still prefilling: real compute
        through ``transformer.prefill_chunk`` (the chunk's K/V scatter into
        the lane's pages), one length-aware ``prefill_s(chunk,
        context=absorbed)`` charge per chunk — later chunks attend over
        the lane's previously written pages and are priced accordingly."""
        for i, l in enumerate(self.lanes):
            if l is None or not l.prefilling:
                continue
            S = len(l.prompt_toks)
            c = min(self.prefill_chunk, S - l.absorbed)
            toks = jnp.asarray(l.prompt_toks[None, l.absorbed:l.absorbed + c])
            w0 = time.perf_counter()
            # an adopted prefix leaves absorbed at an arbitrary (page-
            # unaligned) offset — those chunks ride the unaligned-scatter
            # resume closure; the normal path keeps the aligned graph
            step = (self._chunk if l.absorbed % self.cache.page_size == 0
                    else self._resume)
            # pos 0: only the final chunk's sample is consumed, and it
            # selects the request's output position 0
            first_tok, new_cache = step(
                self.params, {"tokens": toks}, self.cache.chunk_cache(i, c),
                jnp.asarray([l.req.rid], jnp.int32),
                jnp.zeros((1,), jnp.int32))
            self.cache.update_from(new_cache)
            # window groups free the pages this chunk pushed out of the
            # window — back to the pool mid-flight, before the next event
            self.cache.advance(i, c)
            t0 = self.t
            self._charge(self.profile.prefill_s(c, context=l.absorbed))
            if self.tr:
                self.tr.span(tr_mod.REQ_PREFILL_CHUNK, t0, self.t,
                             track=f"lane{i}", rid=l.req.rid, chunk=c,
                             absorbed=l.absorbed + c,
                             wall_s=time.perf_counter() - w0)
            l.absorbed += c
            l.context += c
            if l.absorbed == S:
                prompt = l.prompt_toks
                l.prompt_toks = None
                self._finish_prefill(i, l, first_tok, prompt)

    def _release_inflight(self, l: _Lane) -> None:
        """Drop the lane's in-flight registry claim (prefill published, or
        the lane tore down without publishing — waiters then prefill
        themselves)."""
        if l.inflight_key is not None:
            self._inflight.pop(l.inflight_key, None)
            l.inflight_key = None

    def _maybe_insert(self, lane: int, req, toks) -> None:
        """Publish the finished prompt's shareable spans into the prefix
        cache: the lengths the request declared in ``prefix_keys``
        (session traffic: the class system prompt and the accumulated
        session prompt), or the whole prompt when it declared none.
        Host-side pinning only — no pool data moves, no clock charge."""
        if self.prefix is None or toks is None:
            return
        keys = getattr(req, "prefix_keys", ()) or ()
        lens = sorted({min(int(n), len(toks)) for _, n in keys}
                      or {len(toks)})
        for n in lens:
            if n > 0:
                self.prefix.insert(lane, toks, n)

    def _finish_prefill(self, lane: int, l: _Lane, first_tok,
                        prompt_toks=None) -> None:
        """Shared prefill completion: seed the lane with the first output
        token (sampled on-device inside the jit'd prefill/chunk step),
        publish the prompt's shareable spans into the prefix cache, then
        re-apply the admission policy — interleaved decode charges (and
        co-resident lanes' real step costs) landed since the admission-time
        projection, so a request can reach this point already unable to
        meet its deadline (the past-deadline-after-prefill bug: previously
        such a request was served late)."""
        req = l.req
        req.t_prefill_done = self.t
        # the first output token is sampled from the prefill logits, so it
        # exists the instant the prompt is absorbed: TTFT == prefill done
        mark_first_token(req, self.t)
        self._maybe_insert(lane, req, prompt_toks)
        self._release_inflight(l)         # published: waiters may adopt
        t0 = int(np.asarray(first_tok)[0, 0])
        l.last_token = t0
        l.produced = [t0]
        req.tokens_done = 1
        l.remaining -= 1
        if self.tr:
            self.tr.instant(tr_mod.REQ_FIRST_TOKEN, self.t,
                            track=f"lane{lane}", rid=req.rid,
                            ttft_s=self.t - req.t_arrive)
        if self.policy != "serve" and not self._post_prefill_check(lane, l):
            return
        if l.remaining == 0:
            self.lanes[lane] = None
            self._finish(req, l, lane_allocated=lane)

    def _post_prefill_check(self, lane: int, l: _Lane) -> bool:
        """Drop/degrade a request whose remaining budget no longer fits its
        deadline now that prefill has actually been charged (shared
        re-projection: :func:`~repro.serving.continuous.post_prefill_fit`).
        Returns False when the lane was released (dropped, or finished
        early with just the prefill token)."""
        req = l.req
        fit = post_prefill_fit(self.profile, self.t, self._n_active(),
                               l.context, l.remaining, req.deadline_abs)
        if fit == l.remaining:
            return True
        if self.policy == "degrade" and fit >= 0:
            if self.tr:
                self.tr.instant(tr_mod.REQ_DEGRADE, self.t,
                                track=f"lane{lane}", rid=req.rid,
                                from_tok=l.remaining, to_tok=fit)
            l.remaining = fit
            if l.remaining > 0:
                return True
            # only the prefill token fits — a maximally truncated action,
            # still on time
            self.lanes[lane] = None
            self._finish(req, l, lane_allocated=lane)
            return False
        # past deadline (or drop policy): the late action is worth nothing
        self.lanes[lane] = None
        self.cache.free(lane)
        req.tokens_done = 0
        self._drop(req)
        return False

    # -- the decode loop -----------------------------------------------------

    def _decode_step(self) -> None:
        """One engine iteration: sweep barge-in cancels, advance every
        mid-prefill lane by one chunk, then one real batched decode step
        for the lanes already decoding."""
        self._sweep_cancels()
        if self.prefill_chunk is not None:
            self._advance_prefills()
        active = [(i, l) for i, l in enumerate(self.lanes)
                  if l is not None and not l.prefilling]
        if not active:
            return                        # every occupied lane mid-prefill
        prefilling = tuple(i for i, l in enumerate(self.lanes)
                           if l is not None and l.prefilling)
        if self.speculate is not None and spec_round_fits(
                self.profile, self.t,
                [l.req.deadline_abs for _, l in active],
                len(active), max(l.context for _, l in active)):
            self._spec_step(active, prefilling)
            return
        toks = np.zeros((self.slots, 1), np.int32)
        rids = np.zeros((self.slots,), np.int32)
        pos = np.zeros((self.slots,), np.int32)
        for i, l in active:
            toks[i, 0] = l.last_token
            rids[i] = l.req.rid
            pos[i] = l.req.tokens_done     # output position being decoded
        w0 = time.perf_counter()
        next_toks, new_cache = self._decode(self.params,
                                            {"token": jnp.asarray(toks)},
                                            self.cache.decode_cache(
                                                exclude=prefilling),
                                            jnp.asarray(rids),
                                            jnp.asarray(pos))
        self.cache.update_from(new_cache)
        nxt = np.asarray(next_toks)                  # (slots, 1) int32 only
        t0 = self.t
        ctx = max(l.context for _, l in active)
        self._charge(self.profile.step_s(len(active), ctx))
        if self.tr:
            self.tr.span(tr_mod.ENGINE_STEP, t0, self.t, track="steps",
                         n_active=len(active), context=ctx,
                         lanes=[l.req.rid for _, l in active],
                         wall_s=time.perf_counter() - w0)
            if self.tp > 1:
                self.tr.span(tr_mod.ENGINE_SHARD_STEP, t0, self.t,
                             track="steps", n_active=len(active),
                             tp=self.tp, link=self._tp_link,
                             collective_s=self.profile._collective_s(
                                 len(active)))
        for i, l in active:
            # the step wrote position pos; window-group pages that fell
            # out of the window go back to the pool immediately
            self.cache.advance(i, 1)
            l.context += 1
            tok = int(nxt[i, 0])
            l.produced.append(tok)
            l.last_token = tok
            l.remaining -= 1
            l.req.tokens_done += 1
            if self.tr:
                self.tr.instant(tr_mod.REQ_TOKEN, self.t, track=f"lane{i}",
                                rid=l.req.rid)
            if l.remaining == 0:
                self.lanes[i] = None
                self._finish(l.req, l, lane_allocated=i)
        if self.tr:
            self.tr.counter(tr_mod.CTR_LANES, self.t, self._n_active(),
                            track="steps")
            self.tr.counter(tr_mod.CTR_QUEUE, self.t, len(self.pending),
                            track="queue")
            self.tr.counter(tr_mod.CTR_UTIL, self.t,
                            self.cache.utilization(), track="pool")
            for g, free in self.cache.free_by_group().items():
                self.tr.counter(f"{tr_mod.CTR_FREE_PAGES}.{g}", self.t,
                                free, track="pool")

    def _spec_step(self, active, prefilling) -> None:
        """One fast-draft / slow-verify round for every decoding lane:
        one jit'd call drafts ``k`` tokens per lane, verifies them in a
        single fused chunk, and accept/rejects on device — the host sees
        only the (slots, k+1) committed-token matrix and the per-lane
        emit counts.  Page rollback is implicit: the cache pools already
        hold the verifier's K/V for every chunk position, so a lane that
        emits ``n`` tokens just advances its pos by ``n`` and the stale
        positions beyond are overwritten by the next round's
        scatter-before-attend.  The round is charged
        ``profile.spec_round_s`` — the same price the analytic mirror
        and the admission projections use."""
        k = self.speculate.k
        toks = np.zeros((self.slots, 1), np.int32)
        rids = np.zeros((self.slots,), np.int32)
        pos = np.zeros((self.slots,), np.int32)
        for i, l in active:
            toks[i, 0] = l.last_token
            rids[i] = l.req.rid
            pos[i] = l.req.tokens_done     # round's first output position
        w0 = time.perf_counter()
        tokens, n_emit, new_cache = self._spec(
            self.params, jnp.asarray(toks),
            self.cache.decode_cache(exclude=prefilling, lookahead=k + 1),
            jnp.asarray(rids), jnp.asarray(pos))
        self.cache.update_from(new_cache)
        tokens = np.asarray(tokens)                  # (slots, k+1) int32
        n_emit = np.asarray(n_emit)                  # (slots,) int32
        t0 = self.t
        ctx = max(l.context for _, l in active)
        self._charge(self.profile.spec_round_s(len(active), ctx))
        lane_rids = [l.req.rid for _, l in active]
        if self.tr:
            self.tr.instant(tr_mod.SPEC_DRAFT, t0, track="steps", k=k,
                            lanes=lane_rids, drafted=k * len(active))
            self.tr.instant(tr_mod.SPEC_VERIFY, self.t, track="steps",
                            lanes=lane_rids, chunk=k + 1,
                            wall_s=time.perf_counter() - w0)
        accepted = emitted = 0
        for i, l in active:
            # clamp to the lane's decode budget: a deep round near the
            # tail may propose more tokens than the request has left
            n = min(int(n_emit[i]), l.remaining)
            # of the n emitted, the last is the verifier's correction /
            # bonus token iff the round wasn't budget-clamped
            accepted += n - 1 if n == int(n_emit[i]) else n
            emitted += n
            self.cache.advance(i, n)
            l.context += n
            for tok in tokens[i, :n]:
                l.produced.append(int(tok))
                l.req.tokens_done += 1
                if self.tr:
                    self.tr.instant(tr_mod.REQ_TOKEN, self.t,
                                    track=f"lane{i}", rid=l.req.rid)
            l.last_token = int(tokens[i, n - 1])
            l.remaining -= n
            if l.remaining == 0:
                self.lanes[i] = None
                self._finish(l.req, l, lane_allocated=i)
        if self.tr:
            self.tr.instant(tr_mod.SPEC_ACCEPT, self.t, track="steps",
                            lanes=lane_rids, accepted=accepted,
                            emitted=emitted)
            self.tr.counter(tr_mod.CTR_LANES, self.t, self._n_active(),
                            track="steps")
            self.tr.counter(tr_mod.CTR_QUEUE, self.t, len(self.pending),
                            track="queue")
            self.tr.counter(tr_mod.CTR_UTIL, self.t,
                            self.cache.utilization(), track="pool")
            for g, free in self.cache.free_by_group().items():
                self.tr.counter(f"{tr_mod.CTR_FREE_PAGES}.{g}", self.t,
                                free, track="pool")

    def _finish(self, req, lane_state: _Lane, *, lane_allocated: int) -> None:
        self.cache.free(lane_allocated)       # pages reusable immediately
        req.t_finish = self.t
        req.latency_s = self.t - req.t_arrive
        req.met_deadline = req.t_finish <= req.deadline_abs
        req.result_tokens = np.asarray(lane_state.produced, np.int32)
        self.completed.append(req)
        if self.tr:
            emit_finish(self.tr, req, track=f"lane{lane_allocated}")
        if self.on_retire is not None:
            self.on_retire(req)

    # -- driving -------------------------------------------------------------

    def drain(self, until: Optional[float] = None) -> None:
        """Advance the engine to ``until`` (or to empty), running real
        decode steps and admitting arrivals between them — the shared
        drive loop, so clock semantics cannot diverge from the analytic
        batcher's."""
        continuous_drive(self, until)

    def run(self) -> List:
        self.drain(until=None)
        return self.completed

    # -- router-facing estimates ---------------------------------------------

    def cached_prefix_len(self, req) -> int:
        """Prompt tokens this engine would skip for ``req`` via its prefix
        cache right now — the routing signal :class:`~repro.serving.fleet.
        FleetRouter` folds into first-token slack (an engine that has the
        session's pages warm wins the dispatch).  A non-perturbing peek:
        LRU order and hit/miss counters are untouched."""
        if self.prefix is None:
            return 0
        return self.prefix.probe(self._prompt_for(req))

    def backlog_s(self, now: float) -> float:
        lanes = [l for l in self.lanes if l is not None]
        return estimate_backlog(self.profile, self.t, now,
                                [l.remaining for l in lanes],
                                self.pending, self.slots,
                                prefill_chunk=self.prefill_chunk,
                                active_prefill_left=[
                                    len(l.prompt_toks) - l.absorbed
                                    if l.prefilling else 0 for l in lanes],
                                active_prefill_done=[
                                    l.absorbed if l.prefilling else 0
                                    for l in lanes])
