"""Token samplers + the jit'd sampling policy shared by every engine.

Historically each engine fused greedy argmax ad hoc into its jit'd step
and pushed stochastic sampling to the host (``ServingEngine.generate``
split a single PRNG key per *wave*, silently defaulting to
``PRNGKey(0)`` for every request).  This module lifts token selection
into a first-class policy layer:

* :class:`SamplerPolicy` — a frozen, hashable (temp, top_k, seed)
  triple.  Engines close over it in their jit'd step functions (the
  ``set_sampler`` re-jit pattern — the sampling-layer twin of the
  precision policy's ``set_policy``), so greedy *and* temperature/top-k run
  device-side on every path with only ``(slots,)`` int32 ids crossing to
  host, exactly as greedy does today.  ``temp == 0`` reduces *exactly*
  to ``argmax`` — the policy layer is bit-identical to the historical
  greedy path.
* Lane-indexed keys — every draw is keyed by
  ``fold_in(fold_in(fold_in(PRNGKey(seed), stream), rid), position)``,
  derived inside jit.  A request's tokens depend only on (seed, rid,
  its own output positions): reproducible across runs and independent
  of which lane or wave slot the request lands in.
* :func:`spec_accept` — the jit'd accept/reject sampler for fast-draft /
  slow-verify speculative decoding.  Greedy: cumulative argmax match
  (token-identical to dense decode by construction).  Temperature:
  standard speculative sampling — accept draft ``d`` w.p.
  ``min(1, p_v(d)/p_d(d))``, resample rejections from the normalized
  residual ``(p_v - p_d)+`` — which preserves the verifier's
  distribution for any draft proposal.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Independent PRNG streams per draw kind, folded into every lane key so
# e.g. a draft draw at position p can never correlate with the accept
# coin or residual draw at the same position.
STREAM_POLICY = 0     # dense sampling + the bonus token on full accept
STREAM_DRAFT = 1      # draft-model proposals inside a speculative round
STREAM_ACCEPT = 2     # accept/reject uniforms
STREAM_RESIDUAL = 3   # residual resampling on rejection


@dataclasses.dataclass(frozen=True)
class SamplerPolicy:
    """Token-selection policy carried through jit'd engine steps.

    Frozen + hashable so jit'd lambdas can close over it; changing the
    policy re-jits (cheap, and explicit — the same contract as the FPX
    precision-policy swap).  ``temp == 0`` is exact greedy regardless of
    ``top_k``/``seed``.
    """
    temp: float = 0.0
    top_k: int = 0
    seed: int = 0

    @property
    def stochastic(self) -> bool:
        return self.temp > 0.0


GREEDY = SamplerPolicy()


def greedy(logits: jax.Array, key=None) -> jax.Array:
    """logits: (B, 1, V) -> (B, 1) int32."""
    return logits.argmax(axis=-1).astype(jnp.int32)


def _mask_top_k(lg: jax.Array, top_k: int) -> jax.Array:
    """Mask all but the top-k logits to -inf (O(V) via lax.top_k, not a
    full O(V log V) sort)."""
    if top_k:
        kth = jax.lax.top_k(lg, top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, -1e30, lg)
    return lg


def temperature(logits: jax.Array, key, temp: float = 1.0,
                top_k: int = 0) -> jax.Array:
    """Host-keyed sampling (one key for the whole batch).  Kept as the
    simple entry point; engines use :func:`sample` with lane keys."""
    lg = _mask_top_k(logits.astype(jnp.float32) / max(temp, 1e-4), top_k)
    B = lg.shape[0]
    flat = lg.reshape(B, -1)
    toks = jax.random.categorical(key, flat, axis=-1)
    return toks.reshape(B, 1).astype(jnp.int32)


def lane_keys(seed: int, stream: int, rids: jax.Array,
              positions: jax.Array) -> jax.Array:
    """(B,) rids x (B,) positions -> (B,) per-lane PRNG keys, derived
    entirely inside jit.  The draw at (rid, position) is invariant to
    lane order, wave packing, and draft depth."""
    base = jax.random.fold_in(jax.random.PRNGKey(seed), stream)

    def one(r, p):
        return jax.random.fold_in(jax.random.fold_in(base, r), p)

    return jax.vmap(one)(rids.astype(jnp.uint32),
                         positions.astype(jnp.uint32))


def _policy_logits(policy: SamplerPolicy, logits: jax.Array) -> jax.Array:
    return _mask_top_k(logits.astype(jnp.float32)
                       / max(policy.temp, 1e-4), policy.top_k)


def policy_probs(policy: SamplerPolicy, logits: jax.Array) -> jax.Array:
    """The policy's sampling distribution (tempered, top-k-masked
    softmax) — the target measure :func:`spec_accept` preserves."""
    return jax.nn.softmax(_policy_logits(policy, logits), axis=-1)


def sample(policy: SamplerPolicy, logits: jax.Array, rids: jax.Array,
           positions: jax.Array, stream: int = STREAM_POLICY) -> jax.Array:
    """Device-side token selection: (B, 1, V) logits -> (B, 1) int32.

    ``policy.temp == 0`` is exactly :func:`greedy`; otherwise each row
    draws from its tempered top-k softmax under its own lane key."""
    if not policy.stochastic:
        return greedy(logits)
    lg = _policy_logits(policy, logits)
    B = lg.shape[0]
    keys = lane_keys(policy.seed, stream, rids, positions)
    flat = lg.reshape(B, -1)
    toks = jax.vmap(jax.random.categorical)(keys, flat)
    return toks.reshape(B, 1).astype(jnp.int32)


def spec_accept(policy: SamplerPolicy, draft_toks: jax.Array,
                draft_logits: jax.Array, verify_logits: jax.Array,
                rids: jax.Array, pos0: jax.Array):
    """Jit'd accept/reject for a k-token speculative round.

    Inputs (``k`` = draft depth, ``B`` = lanes):
      draft_toks    (B, k)      draft proposals d_1..d_k
      draft_logits  (B, k, V)   draft logits that proposed them
      verify_logits (B, k+1, V) verifier logits l_0..l_k from the
                                verify chunk [t_0, d_1..d_k]
      rids, pos0    (B,)        lane request ids + output position of
                                the round's first emitted token

    Returns ``(tokens (B, k+1) int32, n_emit (B,) int32)``: lane ``b``
    emits ``tokens[b, :n_emit[b]]``.  Always ``1 <= n_emit <= k+1`` —
    the verifier's own token at the first divergence (or the bonus token
    on full accept) is emitted unconditionally, so a round never
    produces less than a dense step.

    Greedy: accept while draft matches the verifier argmax; the emitted
    tokens are the verifier argmaxes themselves, which is what dense
    greedy decode would have produced — token identity by construction,
    for any draft quality.  Temperature: standard speculative sampling
    (accept w.p. ``min(1, p_v/p_d)``; rejection resamples the normalized
    residual ``(p_v - p_d)+``; full accept samples the bonus from
    ``p_v``), every draw under its own (stream, rid, position) lane key.
    """
    B, k = draft_toks.shape
    if not policy.stochastic:
        v = verify_logits.argmax(axis=-1).astype(jnp.int32)       # (B, k+1)
        match = (draft_toks == v[:, :k]).astype(jnp.int32)
        n_acc = jnp.cumprod(match, axis=1).sum(axis=1)            # (B,)
        return v, n_acc + 1

    pv = policy_probs(policy, verify_logits)                      # (B,k+1,V)
    pd = policy_probs(policy, draft_logits)                       # (B, k, V)
    pv_d = jnp.take_along_axis(pv[:, :k], draft_toks[..., None],
                               axis=-1)[..., 0]                   # (B, k)
    pd_d = jnp.take_along_axis(pd, draft_toks[..., None],
                               axis=-1)[..., 0]

    pos = pos0[:, None] + jnp.arange(k)[None, :]                  # (B, k)
    flat = lambda x: x.reshape(-1)
    u_keys = lane_keys(policy.seed, STREAM_ACCEPT,
                       jnp.repeat(rids, k), flat(pos))
    u = jax.vmap(jax.random.uniform)(u_keys).reshape(B, k)
    accept = (u < jnp.minimum(1.0, pv_d / jnp.maximum(pd_d, 1e-30)))
    n_acc = jnp.cumprod(accept.astype(jnp.int32), axis=1).sum(axis=1)

    # Correction draw per draft position from the normalized residual;
    # where the residual vanishes (p_v == p_d) fall back to p_v.
    res = jnp.maximum(pv[:, :k] - pd, 0.0)
    mass = res.sum(axis=-1, keepdims=True)
    res = jnp.where(mass > 1e-30, res / jnp.maximum(mass, 1e-30),
                    pv[:, :k])
    r_keys = lane_keys(policy.seed, STREAM_RESIDUAL,
                       jnp.repeat(rids, k), flat(pos))
    corr = jax.vmap(jax.random.categorical)(
        r_keys, jnp.log(jnp.maximum(res.reshape(B * k, -1), 1e-30)))
    corr = corr.reshape(B, k).astype(jnp.int32)

    # Bonus token on full accept: a plain policy draw from l_k.
    bonus_keys = lane_keys(policy.seed, STREAM_POLICY, rids, pos0 + k)
    bonus = jax.vmap(jax.random.categorical)(
        bonus_keys, jnp.log(jnp.maximum(pv[:, k], 1e-30)))
    bonus = bonus.astype(jnp.int32)

    fix = jnp.concatenate([corr, bonus[:, None]], axis=1)         # (B,k+1)
    pad = jnp.concatenate([draft_toks, jnp.zeros((B, 1), jnp.int32)],
                          axis=1)
    j = jnp.arange(k + 1)[None, :]
    tokens = jnp.where(j < n_acc[:, None], pad,
                       jnp.where(j == n_acc[:, None], fix, 0))
    return tokens.astype(jnp.int32), n_acc + 1
