"""Token samplers for the serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array, key=None) -> jax.Array:
    """logits: (B, 1, V) -> (B, 1) int32."""
    return logits.argmax(axis=-1).astype(jnp.int32)


def temperature(logits: jax.Array, key, temp: float = 1.0,
                top_k: int = 0) -> jax.Array:
    lg = logits.astype(jnp.float32) / max(temp, 1e-4)
    if top_k:
        kth = jnp.sort(lg, axis=-1)[..., -top_k][..., None]
        lg = jnp.where(lg < kth, -1e30, lg)
    B = lg.shape[0]
    flat = lg.reshape(B, -1)
    toks = jax.random.categorical(key, flat, axis=-1)
    return toks.reshape(B, 1).astype(jnp.int32)
