"""Request scheduler: slot-based continuous batching over the engine.

Requests arrive with deadlines (latency-sensitive serving); the scheduler
packs them into fixed batch slots, pads prompts to a common length, and
tracks modeled completion latency per request.  Simple by design — the
paper's contribution is the precision controller, not the batcher — but it
exercises the real multi-request path the benchmarks and the serve example
drive.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.obs import trace as tr_mod
from repro.serving.engine import ServingEngine


@dataclasses.dataclass
class Request:
    """A real-compute request.

    Shares the serving contract with :class:`~repro.serving.traffic.
    SimRequest`: both expose ``rid / prompt_len / max_new / t_arrive /
    deadline_abs`` plus the lifecycle fields below, so the same object can
    be driven through the wave :class:`Scheduler`, the analytic
    :class:`~repro.serving.continuous.ContinuousBatcher`, or the live paged
    :class:`~repro.serving.paged_engine.ContinuousEngine`.  ``Request``
    additionally carries the actual prompt tokens (``SimRequest`` only has
    a length; live engines synthesize tokens for it)."""
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    deadline_s: Optional[float] = None   # relative to t_arrive; None = no SLO
    extra: Optional[Dict] = None  # vision/audio inputs
    t_arrive: float = 0.0
    cls_name: str = "default"
    reward_weight: float = 1.0

    result_tokens: Optional[np.ndarray] = None
    latency_s: Optional[float] = None
    met_deadline: Optional[bool] = None
    # lifecycle, filled by the continuous engines (SimRequest contract)
    engine_idx: Optional[int] = None
    t_admit: Optional[float] = None
    #: prompt fully absorbed (chunked prefill sets this later than t_admit
    #: plus the bare prefill cost — decode steps interleave with chunks)
    t_prefill_done: Optional[float] = None
    #: first output token existed (TTFT anchor; see SimRequest)
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None
    tokens_done: int = 0
    dropped: bool = False
    reward: float = 0.0
    #: barge-in (SimRequest contract): client abandons at this absolute
    #: time; a wave never launches a request already cancelled
    t_cancel: Optional[float] = None
    cancelled: bool = False

    # network placement (SimRequest contract): prompt-landing time and
    # modeled hop costs, stamped by a topology-aware router
    t_ready: Optional[float] = None
    net_in_s: float = 0.0
    net_out_s: float = 0.0

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def deadline_abs(self) -> float:
        if self.deadline_s is None:
            return float("inf")
        return self.t_arrive + self.deadline_s - self.net_out_s


class Scheduler:
    def __init__(self, engine: ServingEngine, *, batch_slots: int = 8,
                 pad_id: int = 0, tracer=None, sampler=None):
        """``tracer``: a :class:`repro.obs.Tracer` receiving wave spans and
        per-request lifecycle events on the modeled clock (waves execute
        back-to-back: each wave starts where the previous one's makespan
        ended).  None = the zero-overhead null tracer.  ``sampler``: a
        :class:`~repro.serving.sampler.SamplerPolicy` applied to every
        wave (default: the engine's standing policy, greedy unless
        overridden).  Waves pass each request's ``rid`` as its lane key
        index, so a stochastic request's tokens do not depend on which
        wave or slot it lands in."""
        self.engine = engine
        if sampler is not None:
            engine.set_sampler(sampler)
        self.slots = batch_slots
        self.pad_id = pad_id
        self.tr = tracer or tr_mod.NULL
        self.t = 0.0                      # modeled clock, advances per wave
        self.queue: Deque[Request] = deque()
        self.done: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)
        if self.tr:
            from repro.serving.continuous import emit_arrive
            emit_arrive(self.tr, req)

    @staticmethod
    def _extra_sig(req: Request) -> frozenset:
        return frozenset(req.extra.keys() if req.extra else ())

    def _make_batch(self, reqs: List[Request]) -> Dict[str, jnp.ndarray]:
        sigs = {self._extra_sig(r) for r in reqs}
        if len(sigs) > 1:
            raise ValueError(
                "cannot batch requests with heterogeneous extra inputs: "
                f"saw key sets {[sorted(s) for s in sigs]}; "
                "submit homogeneous waves (Scheduler.step splits by "
                "extra-signature automatically)")
        S = max(len(r.prompt) for r in reqs)
        toks = np.full((len(reqs), S), self.pad_id, np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt   # left-pad: ragged prompts
        batch = {"tokens": jnp.asarray(toks)}
        if reqs[0].extra:
            for k in reqs[0].extra:
                batch[k] = jnp.stack([jnp.asarray(r.extra[k]) for r in reqs])
        return batch

    def step(self) -> List[Request]:
        """Serve one wave of up to ``batch_slots`` queued requests.

        A wave only batches requests whose ``extra`` inputs have the same
        key set (vision/audio tensors must stack); mismatched requests keep
        their queue position and go out in a later wave."""
        # barge-in sweep: a request cancelled before its wave starts never
        # reaches the engine (waves are atomic — once launched, members run
        # to completion; mid-wave cancellation is the continuous engines'
        # territory)
        for r in [r for r in self.queue
                  if r.t_cancel is not None and r.t_cancel <= self.t]:
            self.queue.remove(r)
            r.cancelled = True
            r.t_finish = self.t
            r.latency_s = self.t - r.t_arrive
            r.met_deadline = False      # never produced a first token
            if self.tr:
                self.tr.instant(tr_mod.REQ_CANCEL, self.t, track="waves",
                                rid=r.rid, cls=r.cls_name, tokens=0,
                                admitted=False)
            self.done.append(r)
        if not self.queue:
            return []
        sig = self._extra_sig(self.queue[0])
        wave, rest = [], deque()
        while self.queue and len(wave) < self.slots:
            r = self.queue.popleft()
            (wave if self._extra_sig(r) == sig else rest).append(r)
        rest.extend(self.queue)
        self.queue = rest
        max_new = max(r.max_new for r in wave)
        res = self.engine.generate(self._make_batch(wave), max_new=max_new,
                                   rids=np.array([r.rid for r in wave],
                                                 np.int32))
        new = np.asarray(res.new_tokens)
        t0 = self.t
        for i, r in enumerate(wave):
            r.result_tokens = new[i, :r.max_new]
            # each request is charged its own shape, not the padded wave's
            r.latency_s = self.engine.modeled_latency(len(r.prompt), r.max_new)
            if r.deadline_s is not None:
                r.met_deadline = r.latency_s <= r.deadline_s
            r.t_admit = t0
            r.t_finish = t0 + r.latency_s
        # the wave's makespan is its slowest member; waves run back-to-back
        self.t = t0 + max(r.latency_s for r in wave)
        if self.tr:
            self.tr.span(tr_mod.WAVE_STEP, t0, self.t, track="waves",
                         n=len(wave), lanes=[r.rid for r in wave])
            for r in wave:
                self.tr.instant(tr_mod.REQ_ADMIT, t0, track="waves",
                                rid=r.rid, n_tok=r.max_new,
                                max_new=r.max_new)
                self.tr.instant(tr_mod.REQ_FINISH, r.t_finish, track="waves",
                                rid=r.rid, cls=r.cls_name,
                                latency_s=r.latency_s, tokens=r.max_new,
                                met_deadline=r.met_deadline is not False,
                                degraded=False)
        self.done.extend(wave)
        return wave

    def run(self) -> List[Request]:
        while self.queue:
            self.step()
        return self.done
