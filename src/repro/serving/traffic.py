"""Workload generation for the serving fleet: arrivals, deadlines, classes.

A *traffic class* bundles an arrival process (Poisson or bursty
Markov-modulated Poisson), a deadline distribution, and prompt/decode
shapes — e.g. HFT-like tick reactions (short prompts, tens-of-ms budgets)
vs. chat turns (longer prompts, second-scale budgets).  ``generate`` draws
a time-ordered stream of :class:`SimRequest` over a horizon of *simulated*
seconds; the clock is the same analytic-latency clock the engines run on
(core.latency), so one unit of traffic time is one unit of modeled TPU
time and the two sides of the simulation stay in sync by construction.

Beyond independent requests, :func:`generate_sessions` draws *session*
traffic — multi-turn conversations over a shared per-class system prompt,
the workload shape that makes prefix reuse matter.  Turn ``k``'s prompt is
literally a token-prefix extension of turn ``k-1``'s (system prompt ++
accumulated user turns; :func:`session_prompt_tokens` materializes the
actual nested token arrays for the live engines), each request declares
its shareable spans as ``SimRequest.prefix_keys``, and turns may carry a
streaming SLO (``ttft_deadline_s``) and a barge-in cancel time
(``t_cancel`` — the user interrupts mid-stream and the engine reclaims
the lane's pages).

Everything is seeded and deterministic: the same (classes, horizon, seed)
triple always yields the same workload, so competing routers can be
measured on identical request streams.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class SimRequest:
    """One request in the simulated stream, plus its lifecycle results.

    Timing fields are *absolute* simulated seconds except ``deadline_s``,
    which is relative to ``t_arrive`` (the SLO the client asked for)."""
    rid: int
    cls_name: str
    t_arrive: float
    prompt_len: int
    max_new: int
    deadline_s: float
    reward_weight: float = 1.0
    #: streaming SLO, relative to ``t_arrive`` (None = completion deadline
    #: only).  Admission drops — never degrades — on a projected miss:
    #: trimming decode budget cannot speed up the first token.
    ttft_deadline_s: Optional[float] = None
    #: barge-in: *absolute* time the client cancels mid-stream (None =
    #: never).  Engines sweep between steps; the request retires with the
    #: tokens it produced and its lane/pages are reclaimed.
    t_cancel: Optional[float] = None

    # session structure (empty for independent-request traffic)
    #: session identity, e.g. "support/s3" (None = not session traffic)
    session: Optional[str] = None
    #: 0-based turn index within the session
    turn: int = 0
    #: leading tokens shared class-wide (the system prompt)
    sys_len: int = 0
    #: shareable-prefix declarations: (key, length) pairs meaning "this
    #: prompt's first ``length`` tokens are the content stream ``key``".
    #: The analytic batcher's prefix mirror warms/looks up these keys; the
    #: live engine inserts the corresponding token spans into its
    #: token-hash cache at the same lengths.  Session turns declare the
    #: class system prompt and the session's own accumulated prompt.
    prefix_keys: Tuple[Tuple[str, int], ...] = ()

    # filled in by the continuous batcher / fleet router
    engine_idx: Optional[int] = None
    t_admit: Optional[float] = None
    #: when the prompt was fully absorbed (== t_admit + prefill for the
    #: monolithic path; later under chunked prefill, which interleaves
    #: decode steps for other lanes between chunks)
    t_prefill_done: Optional[float] = None
    #: when the first output token existed — TTFT = t_first_token -
    #: t_arrive, the streaming SLO.  The paged engine samples it from the
    #: prefill logits (== t_prefill_done); the analytic batcher models no
    #: prefill token, so it lands after the first decode step
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None
    latency_s: Optional[float] = None
    met_deadline: Optional[bool] = None
    #: first token by ``ttft_deadline_s``?  None when no streaming SLO,
    #: or when the request never produced a token
    met_ttft: Optional[bool] = None
    tokens_done: int = 0
    dropped: bool = False
    #: retired by barge-in (kept its partial output; see
    #: ``continuous.retire_cancelled`` for how met_deadline is judged)
    cancelled: bool = False
    reward: float = 0.0

    # failure-recovery lifecycle (serving.faults / fleet failover)
    #: attempt number: how many times this request was reclaimed from a
    #: crashed engine and re-dispatched (0 = first attempt)
    retries: int = 0
    #: a duplicate attempt was launched for this rid (set on *both*
    #: attempts of a hedged pair)
    hedged: bool = False
    #: this attempt lost its hedge race and was torn down mid-decode;
    #: metrics count the rid once, by the winning attempt
    hedge_loser: bool = False

    # network placement (stamped by the fleet router at dispatch when a
    # topology is configured; zero/None for co-located engines)
    #: absolute time the prompt bytes land on the serving host — the
    #: engine may not start prefill before this (None = t_arrive)
    t_ready: Optional[float] = None
    #: modeled inbound / outbound hop costs (ingress→engine prompt
    #: transfer, engine→ingress response transfer), for accounting
    net_in_s: float = 0.0
    net_out_s: float = 0.0

    @property
    def deadline_abs(self) -> float:
        """When the *engine* must finish: the client's absolute deadline
        pulled in by the response hop — tokens generated at the client's
        deadline minus the return transfer still arrive on time."""
        return self.t_arrive + self.deadline_s - self.net_out_s

    def fresh(self) -> "SimRequest":
        """Copy with lifecycle state cleared — lets the same workload be
        replayed against several routers."""
        return SimRequest(rid=self.rid, cls_name=self.cls_name,
                          t_arrive=self.t_arrive, prompt_len=self.prompt_len,
                          max_new=self.max_new, deadline_s=self.deadline_s,
                          reward_weight=self.reward_weight,
                          ttft_deadline_s=self.ttft_deadline_s,
                          t_cancel=self.t_cancel, session=self.session,
                          turn=self.turn, sys_len=self.sys_len,
                          prefix_keys=self.prefix_keys)


@dataclasses.dataclass(frozen=True)
class TrafficClass:
    """Arrival + shape + SLO distribution for one kind of traffic.

    ``burst_factor`` > 1 turns the Poisson process into a two-state MMPP:
    the rate alternates between ``rate_hz * burst_factor`` (bursts) and a
    compensating quiet rate so the long-run mean stays ``rate_hz``.
    ``burst_frac`` is the fraction of time spent inside bursts."""
    name: str
    rate_hz: float                       # mean arrival rate
    deadline_range_s: Tuple[float, float]  # uniform SLO draw
    prompt_range: Tuple[int, int] = (64, 256)
    max_new_range: Tuple[int, int] = (8, 16)
    reward_weight: float = 1.0
    burst_factor: float = 1.0
    burst_frac: float = 0.2
    burst_len_s: float = 0.5             # mean burst duration


def _poisson_times(rate_hz: float, horizon_s: float,
                   rng: np.random.Generator) -> List[float]:
    t, out = 0.0, []
    while True:
        t += rng.exponential(1.0 / rate_hz)
        if t >= horizon_s:
            return out
        out.append(t)


def _bursty_times(cls: TrafficClass, horizon_s: float,
                  rng: np.random.Generator) -> List[float]:
    """Two-state MMPP: mean-preserving on/off modulation of the base rate."""
    hi = cls.rate_hz * cls.burst_factor
    lo_frac = 1.0 - cls.burst_frac
    lo = max(1e-9, (cls.rate_hz - cls.burst_frac * hi) / lo_frac)
    quiet_len = cls.burst_len_s * lo_frac / cls.burst_frac
    t, out, in_burst = 0.0, [], False
    while t < horizon_s:
        dur = rng.exponential(cls.burst_len_s if in_burst else quiet_len)
        rate = hi if in_burst else lo
        seg_end = min(t + dur, horizon_s)
        tt = t
        while True:
            tt += rng.exponential(1.0 / rate)
            if tt >= seg_end:
                break
            out.append(tt)
        t, in_burst = seg_end, not in_burst
    return out


def generate(classes: Sequence[TrafficClass], horizon_s: float, *,
             seed: int = 0) -> List[SimRequest]:
    """Draw the merged, time-sorted request stream for one simulation run."""
    reqs: List[SimRequest] = []
    for ci, cls in enumerate(classes):
        rng = np.random.default_rng(seed * 1009 + ci)
        if cls.burst_factor > 1.0:
            times = _bursty_times(cls, horizon_s, rng)
        else:
            times = _poisson_times(cls.rate_hz, horizon_s, rng)
        for t in times:
            d = rng.uniform(*cls.deadline_range_s)
            p = int(rng.integers(cls.prompt_range[0], cls.prompt_range[1] + 1))
            m = int(rng.integers(cls.max_new_range[0],
                                 cls.max_new_range[1] + 1))
            reqs.append(SimRequest(rid=-1, cls_name=cls.name, t_arrive=t,
                                   prompt_len=p, max_new=m, deadline_s=d,
                                   reward_weight=cls.reward_weight))
    reqs.sort(key=lambda r: r.t_arrive)
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


# ---------------------------------------------------------------------------
# Session traffic: multi-turn conversations over shared system prompts —
# the workload where prefix reuse and TTFT decide the reward.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SessionClass:
    """Arrival + shape distribution for one kind of *session* traffic.

    ``rate_hz`` is the session *start* rate; each session then runs
    ``turns`` requests.  Turn ``k``'s prompt is the class system prompt
    plus every user turn so far (``sys_len + sum(user_len_1..k)``), so
    prompts within a session nest as literal token prefixes — turn ``k``
    can adopt turn ``k-1``'s pages wholesale, and the first turn of a new
    session can adopt the class-wide system prompt.  (Assistant replies
    are abstracted out of the prompt stream: content is synthetic, and
    what the memory substrate cares about is that prompts nest.)

    The stream is open-loop, so the next turn's arrival is modeled as the
    previous turn's *deadline* plus a think-time gap — the client read
    the answer, typed, and sent.  ``barge_in_frac`` of turns carry a
    cancel time drawn in ``(ttft_deadline, deadline)``: the user heard
    enough and interrupted mid-stream."""
    name: str
    rate_hz: float                           # session starts per second
    turns_range: Tuple[int, int] = (2, 5)
    sys_len_range: Tuple[int, int] = (192, 320)
    user_len_range: Tuple[int, int] = (16, 48)
    max_new_range: Tuple[int, int] = (8, 16)
    deadline_range_s: Tuple[float, float] = (0.6, 1.4)
    #: streaming SLO draw; None = no TTFT deadline on this class
    ttft_range_s: Optional[Tuple[float, float]] = (0.25, 0.45)
    think_range_s: Tuple[float, float] = (0.5, 2.0)
    barge_in_frac: float = 0.0
    reward_weight: float = 1.0


def generate_sessions(classes: Sequence[SessionClass], horizon_s: float, *,
                      seed: int = 0) -> List[SimRequest]:
    """Draw the merged, time-sorted session-request stream.

    Every turn declares two shareable spans in ``prefix_keys``: the class
    system prompt (``"<cls>/sys"``, warm after *any* session of the class
    prefilled once) and the session's own accumulated prompt
    (``"<cls>/<session>"``, warm after the previous turn) — which is
    exactly what the live engine's token-hash prefix cache discovers from
    the nested token arrays (:func:`session_prompt_tokens`)."""
    reqs: List[SimRequest] = []
    for ci, cls in enumerate(classes):
        rng = np.random.default_rng(seed * 1013 + ci)
        starts = _poisson_times(cls.rate_hz, horizon_s, rng)
        sys_key = f"{cls.name}/sys"
        for sid, t0 in enumerate(starts):
            n_turns = int(rng.integers(cls.turns_range[0],
                                       cls.turns_range[1] + 1))
            sys_len = int(rng.integers(cls.sys_len_range[0],
                                       cls.sys_len_range[1] + 1))
            session = f"{cls.name}/s{sid}"
            t, prompt_len = t0, sys_len
            for k in range(n_turns):
                if t >= horizon_s:
                    break
                prompt_len += int(rng.integers(cls.user_len_range[0],
                                               cls.user_len_range[1] + 1))
                m = int(rng.integers(cls.max_new_range[0],
                                     cls.max_new_range[1] + 1))
                d = float(rng.uniform(*cls.deadline_range_s))
                ttft = None
                if cls.ttft_range_s is not None:
                    ttft = float(rng.uniform(*cls.ttft_range_s))
                t_cancel = None
                if cls.barge_in_frac > 0.0 \
                        and rng.random() < cls.barge_in_frac:
                    t_cancel = t + float(rng.uniform(ttft or 0.0, d))
                reqs.append(SimRequest(
                    rid=-1, cls_name=cls.name, t_arrive=t,
                    prompt_len=prompt_len, max_new=m, deadline_s=d,
                    reward_weight=cls.reward_weight, ttft_deadline_s=ttft,
                    t_cancel=t_cancel, session=session, turn=k,
                    sys_len=sys_len,
                    prefix_keys=((sys_key, sys_len),
                                 (session, prompt_len))))
                t += d + float(rng.uniform(*cls.think_range_s))
    reqs.sort(key=lambda r: r.t_arrive)
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


def _stream_tokens(tag: str, n: int, vocab: int, seed: int) -> np.ndarray:
    """``n`` tokens of the deterministic content stream named ``tag`` —
    seeded by a stable digest of the tag (not Python's salted ``hash``),
    so streams are reproducible across processes and draws of different
    lengths share their common prefix."""
    rng = np.random.default_rng([seed, zlib.crc32(tag.encode())])
    return rng.integers(0, vocab, size=n, dtype=np.int32)


def session_prompt_tokens(req: SimRequest, *, vocab: int,
                          seed: int = 0) -> np.ndarray:
    """Materialize a session request's actual prompt tokens for the live
    engines: the class system stream followed by the session stream,
    truncated to ``prompt_len``.  Because both pieces are deterministic
    streams, turn ``k``'s array is byte-identical to turn ``k-1``'s for
    their common length — the token-hash prefix cache hits exactly the
    spans ``prefix_keys`` declares."""
    assert req.session is not None, "not a session request"
    sys_toks = _stream_tokens(f"{req.cls_name}/sys", req.sys_len, vocab,
                              seed)
    rest = _stream_tokens(req.session, req.prompt_len - req.sys_len, vocab,
                          seed)
    return np.concatenate([sys_toks, rest])


# ---------------------------------------------------------------------------
# Scenario presets.  Deadlines are calibrated against the analytic ladder
# (core.latency, qwen2.5 family): ~20ms (1.5B @ FP4) ... ~300ms (14B @ FP8)
# per action — so "trading" budgets are only meetable by small/high-gamma
# operating points while "chat" budgets admit the full-quality 14B.
# ---------------------------------------------------------------------------

def trading_class(rate_hz: float = 30.0) -> TrafficClass:
    """HFT-like tick reactions: tiny prompts, tens-of-ms hard budgets,
    bursty arrivals (order-book activity clusters).  The 15-45ms budget
    straddles the small/high-gamma operating points (~8-20ms per action)
    and excludes the big models (>=50ms)."""
    return TrafficClass(name="trading", rate_hz=rate_hz,
                        deadline_range_s=(0.015, 0.045),
                        prompt_range=(48, 96), max_new_range=(4, 8),
                        reward_weight=1.0, burst_factor=3.0,
                        burst_frac=0.25, burst_len_s=0.4)


def chat_class(rate_hz: float = 8.0) -> TrafficClass:
    """Chat-like turns: longer prompts, sub-second soft budgets that the
    full-quality 14B point (~230ms per action) meets with queueing room."""
    return TrafficClass(name="chat", rate_hz=rate_hz,
                        deadline_range_s=(0.4, 1.2),
                        prompt_range=(128, 384), max_new_range=(8, 16),
                        reward_weight=1.0)


def support_sessions(rate_hz: float = 0.8) -> SessionClass:
    """Customer-support-style sessions: a long shared system prompt
    (policies, tools), short user turns, streaming TTFT budgets well
    under the completion deadline, and occasional barge-in."""
    return SessionClass(name="support", rate_hz=rate_hz,
                        turns_range=(2, 5), sys_len_range=(192, 320),
                        user_len_range=(16, 48), max_new_range=(8, 16),
                        deadline_range_s=(0.6, 1.4),
                        ttft_range_s=(0.25, 0.45),
                        think_range_s=(0.5, 2.0), barge_in_frac=0.15)


def session_scenario(name: str) -> List[SessionClass]:
    """Named session mixes used by benchmarks/table_sessions.py."""
    if name == "support":
        return [support_sessions()]
    raise KeyError(f"unknown session scenario {name!r}; known: support")


def scenario(name: str) -> List[TrafficClass]:
    """Named traffic mixes used by benchmarks/table_serving.py."""
    if name == "trading":
        return [trading_class()]
    if name == "chat":
        return [chat_class()]
    if name == "mixed":
        return [trading_class(), chat_class()]
    raise KeyError(f"unknown scenario {name!r}; "
                   "known: trading, chat, mixed")


SCENARIOS = ("trading", "chat", "mixed")
