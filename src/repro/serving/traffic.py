"""Workload generation for the serving fleet: arrivals, deadlines, classes.

A *traffic class* bundles an arrival process (Poisson or bursty
Markov-modulated Poisson), a deadline distribution, and prompt/decode
shapes — e.g. HFT-like tick reactions (short prompts, tens-of-ms budgets)
vs. chat turns (longer prompts, second-scale budgets).  ``generate`` draws
a time-ordered stream of :class:`SimRequest` over a horizon of *simulated*
seconds; the clock is the same analytic-latency clock the engines run on
(core.latency), so one unit of traffic time is one unit of modeled TPU
time and the two sides of the simulation stay in sync by construction.

Everything is seeded and deterministic: the same (classes, horizon, seed)
triple always yields the same workload, so competing routers can be
measured on identical request streams.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class SimRequest:
    """One request in the simulated stream, plus its lifecycle results.

    Timing fields are *absolute* simulated seconds except ``deadline_s``,
    which is relative to ``t_arrive`` (the SLO the client asked for)."""
    rid: int
    cls_name: str
    t_arrive: float
    prompt_len: int
    max_new: int
    deadline_s: float
    reward_weight: float = 1.0

    # filled in by the continuous batcher / fleet router
    engine_idx: Optional[int] = None
    t_admit: Optional[float] = None
    #: when the prompt was fully absorbed (== t_admit + prefill for the
    #: monolithic path; later under chunked prefill, which interleaves
    #: decode steps for other lanes between chunks)
    t_prefill_done: Optional[float] = None
    #: when the first output token existed — TTFT = t_first_token -
    #: t_arrive, the streaming SLO.  The paged engine samples it from the
    #: prefill logits (== t_prefill_done); the analytic batcher models no
    #: prefill token, so it lands after the first decode step
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None
    latency_s: Optional[float] = None
    met_deadline: Optional[bool] = None
    tokens_done: int = 0
    dropped: bool = False
    reward: float = 0.0

    @property
    def deadline_abs(self) -> float:
        return self.t_arrive + self.deadline_s

    def fresh(self) -> "SimRequest":
        """Copy with lifecycle state cleared — lets the same workload be
        replayed against several routers."""
        return SimRequest(rid=self.rid, cls_name=self.cls_name,
                          t_arrive=self.t_arrive, prompt_len=self.prompt_len,
                          max_new=self.max_new, deadline_s=self.deadline_s,
                          reward_weight=self.reward_weight)


@dataclasses.dataclass(frozen=True)
class TrafficClass:
    """Arrival + shape + SLO distribution for one kind of traffic.

    ``burst_factor`` > 1 turns the Poisson process into a two-state MMPP:
    the rate alternates between ``rate_hz * burst_factor`` (bursts) and a
    compensating quiet rate so the long-run mean stays ``rate_hz``.
    ``burst_frac`` is the fraction of time spent inside bursts."""
    name: str
    rate_hz: float                       # mean arrival rate
    deadline_range_s: Tuple[float, float]  # uniform SLO draw
    prompt_range: Tuple[int, int] = (64, 256)
    max_new_range: Tuple[int, int] = (8, 16)
    reward_weight: float = 1.0
    burst_factor: float = 1.0
    burst_frac: float = 0.2
    burst_len_s: float = 0.5             # mean burst duration


def _poisson_times(rate_hz: float, horizon_s: float,
                   rng: np.random.Generator) -> List[float]:
    t, out = 0.0, []
    while True:
        t += rng.exponential(1.0 / rate_hz)
        if t >= horizon_s:
            return out
        out.append(t)


def _bursty_times(cls: TrafficClass, horizon_s: float,
                  rng: np.random.Generator) -> List[float]:
    """Two-state MMPP: mean-preserving on/off modulation of the base rate."""
    hi = cls.rate_hz * cls.burst_factor
    lo_frac = 1.0 - cls.burst_frac
    lo = max(1e-9, (cls.rate_hz - cls.burst_frac * hi) / lo_frac)
    quiet_len = cls.burst_len_s * lo_frac / cls.burst_frac
    t, out, in_burst = 0.0, [], False
    while t < horizon_s:
        dur = rng.exponential(cls.burst_len_s if in_burst else quiet_len)
        rate = hi if in_burst else lo
        seg_end = min(t + dur, horizon_s)
        tt = t
        while True:
            tt += rng.exponential(1.0 / rate)
            if tt >= seg_end:
                break
            out.append(tt)
        t, in_burst = seg_end, not in_burst
    return out


def generate(classes: Sequence[TrafficClass], horizon_s: float, *,
             seed: int = 0) -> List[SimRequest]:
    """Draw the merged, time-sorted request stream for one simulation run."""
    reqs: List[SimRequest] = []
    for ci, cls in enumerate(classes):
        rng = np.random.default_rng(seed * 1009 + ci)
        if cls.burst_factor > 1.0:
            times = _bursty_times(cls, horizon_s, rng)
        else:
            times = _poisson_times(cls.rate_hz, horizon_s, rng)
        for t in times:
            d = rng.uniform(*cls.deadline_range_s)
            p = int(rng.integers(cls.prompt_range[0], cls.prompt_range[1] + 1))
            m = int(rng.integers(cls.max_new_range[0],
                                 cls.max_new_range[1] + 1))
            reqs.append(SimRequest(rid=-1, cls_name=cls.name, t_arrive=t,
                                   prompt_len=p, max_new=m, deadline_s=d,
                                   reward_weight=cls.reward_weight))
    reqs.sort(key=lambda r: r.t_arrive)
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


# ---------------------------------------------------------------------------
# Scenario presets.  Deadlines are calibrated against the analytic ladder
# (core.latency, qwen2.5 family): ~20ms (1.5B @ FP4) ... ~300ms (14B @ FP8)
# per action — so "trading" budgets are only meetable by small/high-gamma
# operating points while "chat" budgets admit the full-quality 14B.
# ---------------------------------------------------------------------------

def trading_class(rate_hz: float = 30.0) -> TrafficClass:
    """HFT-like tick reactions: tiny prompts, tens-of-ms hard budgets,
    bursty arrivals (order-book activity clusters).  The 15-45ms budget
    straddles the small/high-gamma operating points (~8-20ms per action)
    and excludes the big models (>=50ms)."""
    return TrafficClass(name="trading", rate_hz=rate_hz,
                        deadline_range_s=(0.015, 0.045),
                        prompt_range=(48, 96), max_new_range=(4, 8),
                        reward_weight=1.0, burst_factor=3.0,
                        burst_frac=0.25, burst_len_s=0.4)


def chat_class(rate_hz: float = 8.0) -> TrafficClass:
    """Chat-like turns: longer prompts, sub-second soft budgets that the
    full-quality 14B point (~230ms per action) meets with queueing room."""
    return TrafficClass(name="chat", rate_hz=rate_hz,
                        deadline_range_s=(0.4, 1.2),
                        prompt_range=(128, 384), max_new_range=(8, 16),
                        reward_weight=1.0)


def scenario(name: str) -> List[TrafficClass]:
    """Named traffic mixes used by benchmarks/table_serving.py."""
    if name == "trading":
        return [trading_class()]
    if name == "chat":
        return [chat_class()]
    if name == "mixed":
        return [trading_class(), chat_class()]
    raise KeyError(f"unknown scenario {name!r}; "
                   "known: trading, chat, mixed")


SCENARIOS = ("trading", "chat", "mixed")
