"""Losses: causal-LM cross entropy (+ z-loss) with padding masks."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def causal_lm_loss(logits: jax.Array, tokens: jax.Array,
                   mask: Optional[jax.Array] = None,
                   z_loss: float = 1e-4) -> Tuple[jax.Array, jax.Array]:
    """logits: (B, S, V); tokens: (B, S).  Predict token[t+1] from logits[t].

    Returns (mean loss, mean accuracy)."""
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    if mask is None:
        mask = jnp.ones_like(targets, jnp.float32)
    else:
        mask = mask[:, 1:].astype(jnp.float32)

    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    true_logit = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - true_logit
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    # z-loss keeps the softmax normalizer bounded (stability at bf16)
    loss = loss + z_loss * ((lse ** 2) * mask).sum() / jnp.maximum(mask.sum(), 1.0)

    acc = ((logits.argmax(-1) == targets) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, acc
