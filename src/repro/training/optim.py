"""AdamW + cosine schedule (no optax in this container — hand-rolled)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def cosine_lr(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def adamw_init(params: Any) -> Dict[str, Any]:
    # moments in fp32 regardless of param dtype (bf16 weights, fp32 Adam
    # state — the standard mixed-precision training memory layout)
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads: Any, state: Dict[str, Any], params: Any,
                 cfg: AdamWConfig) -> Tuple[Any, Dict[str, Any]]:
    step = state["step"] + 1
    lr = cosine_lr(step, cfg)

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * clip, grads)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state["mu"], grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * jnp.square(g),
                      state["nu"], grads)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return (p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                          + cfg.weight_decay * p)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "step": step}
