"""Training step: forward, loss, backward, AdamW update.

``make_train_step`` returns a pure function suitable for ``jax.jit`` with
in/out shardings (launch/train.py) or direct CPU execution (examples).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.models.modules import ExecContext
from repro.training import losses
from repro.training.optim import AdamWConfig, adamw_init, adamw_update


def make_loss_fn(cfg: ModelConfig, ctx: Optional[ExecContext] = None):
    ctx = ctx or ExecContext()

    def loss_fn(params, batch):
        logits = transformer.forward(params, cfg, batch, ctx)
        loss, acc = losses.causal_lm_loss(logits, batch["tokens"],
                                          batch.get("mask"))
        return loss, acc

    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    ctx: Optional[ExecContext] = None,
                    remat: bool = False) -> Callable:
    loss_fn = make_loss_fn(cfg, ctx)
    if remat:
        loss_fn = jax.checkpoint(loss_fn)

    def train_step(params, opt_state, batch) -> Tuple[Any, Any, Dict[str, jax.Array]]:
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        new_params, new_state = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = {"loss": loss, "accuracy": acc,
                   "grad_norm": jnp.sqrt(sum(
                       jnp.sum(jnp.square(g.astype(jnp.float32)))
                       for g in jax.tree.leaves(grads)))}
        return new_params, new_state, metrics

    return train_step


def init_train_state(key, cfg: ModelConfig, dtype=jnp.float32):
    params = transformer.init_params(key, cfg, dtype)
    return params, adamw_init(params)
