"""Test-environment compatibility shims + shared serving-test helpers.

The property tests use `hypothesis`, which not every execution image ships
(this container bakes in jax but not hypothesis).  Rather than lose those
tests to a collection ImportError, install a minimal deterministic
stand-in when the real package is absent: strategies become seeded
samplers and ``@given`` replays ``max_examples`` random draws.  The real
hypothesis, when present, is always preferred — the shim only fills the
gap, it does not shadow.

The serving helpers back the cross-path differential harness
(tests/test_hybrid_paged.py): enumerate every *servable* config in
``src/repro/configs`` (smallified), run the same greedy requests through
the contiguous-cache wave path and the paged continuous path, and hand
both back for token-identity comparison.  ``REPRO_PAGED_MODES`` (env:
"jnp", "pallas", or "both"/unset) selects which paged-attention
implementations the harness sweeps — CI runs the suite once per mode so a
fused-kernel regression cannot hide behind the fallback (or vice versa).
"""
import functools
import os
import random
import sys
import types


def _install_hypothesis_shim():
    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[rng.randrange(len(opts))])

    def composite(fn):
        def strategy_factory(*args, **kwargs):
            return _Strategy(
                lambda rng: fn(lambda s: s.sample(rng), *args, **kwargs))
        return strategy_factory

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", 20))
                for _ in range(n):
                    fn(*args, *[s.sample(rng) for s in strategies], **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(max_examples=20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    st = types.ModuleType("hypothesis.strategies")
    st.floats, st.integers = floats, integers
    st.sampled_from, st.composite = sampled_from, composite
    hyp = types.ModuleType("hypothesis")
    hyp.given, hyp.settings, hyp.strategies = given, settings, st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()


# ---------------------------------------------------------------------------
# Shared serving-test helpers (the cross-path differential harness)
# ---------------------------------------------------------------------------

def pallas_modes():
    """The paged-attention implementations the differential suite sweeps:
    [False] (jnp gather+SDPA fallback), [True] (fused Pallas kernel in
    interpret mode), or both.  Controlled by REPRO_PAGED_MODES so ci.yml
    can run the suite once per isolated mode."""
    mode = os.environ.get("REPRO_PAGED_MODES", "both").lower()
    if mode in ("jnp", "fallback", "gather"):
        return [False]
    if mode in ("pallas", "fused"):
        return [True]
    return [False, True]


def servable_smoke_configs():
    """Every config in ``src/repro/configs`` the paged continuous path can
    serve, smallified for CPU smoke runs: each assigned architecture is
    ``reduced()`` and the sim-scale qwen family passes through as-is
    (the full-scale qwen entries are the same stacks at widths that only
    matter to the latency model), filtered by
    ``transformer.paged_supported`` — dense and moe stacks: uniform,
    uniform-windowed (starcoder2-class) and local:global (gemma3-class).
    Returns (name, cfg) pairs, deterministic order."""
    from repro.configs import ASSIGNED, QWEN_SIM
    from repro.models.transformer import paged_supported

    out = []
    for name in sorted(ASSIGNED):
        cfg = ASSIGNED[name].reduced()
        if paged_supported(cfg):
            out.append((name, cfg))
    for name in sorted(QWEN_SIM):
        cfg = QWEN_SIM[name]
        if paged_supported(cfg):
            out.append((name, cfg))
    return out


@functools.lru_cache(maxsize=None)
def smoke_params(name):
    """Init params once per servable smoke config (shared across the
    differential sweep's parametrizations)."""
    import jax
    from repro.models import transformer

    cfg = dict(servable_smoke_configs())[name]
    return transformer.init_params(jax.random.PRNGKey(0), cfg)


def make_requests(cfg, lens, *, max_new=4, deadline=100.0, seed=1):
    """Deterministic greedy requests shared by both serving paths."""
    import numpy as np
    from repro.serving.scheduler import Request

    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, n)
                    .astype(np.int32), max_new=max_new, deadline_s=deadline)
            for i, n in enumerate(lens)]


def run_wave_reference(params, cfg, reqs, *, max_ctx=64):
    """The contiguous-cache oracle: each request served alone through the
    wave path (batch_slots=1 — left-padding would change what ragged
    prompts attend to), returning its greedy tokens."""
    from repro.serving.engine import ServingEngine
    from repro.serving.scheduler import Scheduler

    sched = Scheduler(ServingEngine(params, cfg, max_ctx=max_ctx),
                      batch_slots=1)
    for r in reqs:
        sched.submit(r)
    sched.run()
    return reqs


def run_paged(params, cfg, reqs, *, page_size=8, max_ctx=64, chunk=None,
              use_pallas=False, slots=None, policy="serve", **engine_kw):
    """The same requests through the paged ``ContinuousEngine``."""
    from repro.models.modules import ExecContext
    from repro.serving.paged_engine import ContinuousEngine

    eng = ContinuousEngine(params, cfg, slots=slots or len(reqs),
                           page_size=page_size, max_ctx=max_ctx,
                           policy=policy, prefill_chunk=chunk,
                           ctx=ExecContext(use_pallas=use_pallas),
                           **engine_kw)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return reqs, eng
