"""Test-environment compatibility shims.

The property tests use `hypothesis`, which not every execution image ships
(this container bakes in jax but not hypothesis).  Rather than lose those
tests to a collection ImportError, install a minimal deterministic
stand-in when the real package is absent: strategies become seeded
samplers and ``@given`` replays ``max_examples`` random draws.  The real
hypothesis, when present, is always preferred — the shim only fills the
gap, it does not shadow.
"""
import random
import sys
import types


def _install_hypothesis_shim():
    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[rng.randrange(len(opts))])

    def composite(fn):
        def strategy_factory(*args, **kwargs):
            return _Strategy(
                lambda rng: fn(lambda s: s.sample(rng), *args, **kwargs))
        return strategy_factory

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", 20))
                for _ in range(n):
                    fn(*args, *[s.sample(rng) for s in strategies], **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(max_examples=20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    st = types.ModuleType("hypothesis.strategies")
    st.floats, st.integers = floats, integers
    st.sampled_from, st.composite = sampled_from, composite
    hyp = types.ModuleType("hypothesis")
    hyp.given, hyp.settings, hyp.strategies = given, settings, st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()
