"""Regenerate tests/data/golden_trace.json from the pinned event stream.

    PYTHONPATH=src:tests python tests/data/make_golden_trace.py

The golden file pins the Chrome ``trace_event`` export format
(test_obs.test_chrome_export_matches_golden_file).  Re-run this after an
*intentional* format change so the diff is reviewable.
"""
import json
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(_HERE, "..", "..", "src"))
sys.path.insert(0, os.path.join(_HERE, ".."))

from repro.obs import to_chrome  # noqa: E402
from test_obs import _tiny_stream  # noqa: E402

out = os.path.join(_HERE, "golden_trace.json")
with open(out, "w") as f:
    json.dump(to_chrome(_tiny_stream()), f, indent=1, sort_keys=False)
    f.write("\n")
print(f"wrote {out}")
