"""Benchmark environment tests: the latency-reward mechanics themselves.

These verify the paper's qualitative structure *independent of any model*:
oracles with lower latency earn more; wrong decisions lose; the SF frame
cap creates a latency floor."""
import numpy as np
import pytest

from repro.bench import elo
from repro.bench.env import Teacher
from repro.bench.hft import HFTBench, HFTConfig, run_session, HOLD
from repro.bench.streetfighter import SFGame, play_match


class Oracle:
    """Perfect decisions at a fixed latency."""

    def __init__(self, teacher, latency_s, flip=0.0, seed=0, n_actions=3):
        self.t = teacher
        self.latency_s = latency_s
        self.flip = flip
        self.rng = np.random.default_rng(seed)
        self.n_actions = n_actions

    def decide(self, obs):
        feats = self._decode(obs["tokens"])
        a = int(self.t.label(feats))
        if self.flip and self.rng.random() < self.flip:
            a = int(self.rng.integers(0, self.n_actions))
        return a, self.latency_s

    def _decode(self, toks):
        k = self.t.n_features
        f = np.asarray(toks[1:1 + k])
        return (f - 16) - np.arange(k) * self.t.n_values


def _teacher(env):
    return env.teacher


def test_hft_fast_oracle_profits():
    env = HFTBench()
    res = run_session(env, Oracle(_teacher(env), 0.05), seed=0)
    assert res["daily_yield"] > 5.0


def test_hft_latency_monotone():
    env = HFTBench()
    ys = [run_session(env, Oracle(_teacher(env), lat), seed=0)["daily_yield"]
          for lat in (0.05, 0.7, 1.5, 5.0)]
    assert all(a >= b for a, b in zip(ys, ys[1:]))
    assert ys[-1] <= 0.5     # slower than every window's decay: nothing left


def test_hft_bad_decisions_lose_even_if_fast():
    env = HFTBench()
    res = run_session(env, Oracle(_teacher(env), 0.05, flip=0.9, seed=1),
                      seed=0)
    good = run_session(env, Oracle(_teacher(env), 0.05), seed=0)
    assert res["daily_yield"] < good["daily_yield"]
    assert res["daily_yield"] < 0


def test_hft_cooling_window_limits_trades():
    cfg = HFTConfig(cooling_s=600.0)
    env = HFTBench(cfg)
    res = run_session(env, Oracle(_teacher(env), 0.05), seed=0)
    env2 = HFTBench(HFTConfig(cooling_s=10.0))
    res2 = run_session(env2, Oracle(_teacher(env2), 0.05), seed=0)
    assert res["trades"] < res2["trades"]


def test_sf_fast_oracle_beats_slow_oracle():
    game = SFGame()
    fast = Oracle(game.teacher, 0.15, n_actions=5)
    slow = Oracle(game.teacher, 1.2, n_actions=5)
    wins = sum(play_match(fast, slow, rounds=1, seed=s) == 0
               for s in range(9))
    assert wins >= 7


def test_sf_quality_matters_at_equal_speed():
    game = SFGame()
    good = Oracle(game.teacher, 0.2, n_actions=5)
    bad = Oracle(game.teacher, 0.2, flip=0.9, seed=3, n_actions=5)
    wins = sum(play_match(good, bad, rounds=1, seed=s) == 0
               for s in range(9))
    assert wins >= 7


def test_sf_latency_floor():
    """Below the ~200ms action slot, extra speed gives no edge (paper 5.3)."""
    game = SFGame()
    a = Oracle(game.teacher, 0.02, n_actions=5)
    b = Oracle(game.teacher, 0.15, n_actions=5)
    wins = sum(play_match(a, b, rounds=1, seed=s) == 0 for s in range(20))
    assert 6 <= wins <= 14          # statistically indistinguishable


def test_elo_updates_and_ordering():
    names = ["strong", "weak"]
    ratings = elo.tournament(
        names, lambda i, j, s: 1.0 if i == 0 else 0.0, rounds_per_pair=10)
    assert ratings["strong"] > 0 > ratings["weak"]


def test_env_reward_depends_on_evolved_state():
    """Same action, later landing -> different reward (paper Eq. 5)."""
    env = HFTBench()
    env.reset(0)
    obs = env.next_window()
    cls = int(env._cur["cls"])
    if cls == HOLD:
        while cls == HOLD:
            env.ev_i += 1
            obs = env.next_window()
            cls = int(env._cur["cls"])
    ev = env._cur
    r_fast, _, _ = env.step(cls, 0.05)
    env._cur = ev
    env.ev_i -= 1
    env.cash = env.cfg.initial_cash
    env.ev_i += 1
    r_slow, _, _ = env.step(cls, ev["decay"] * 0.9)
    assert r_fast > r_slow
