"""Unit tests for the bench regression gate itself.

``benchmarks/check_regression.py`` is the only thing standing between a
PR and a silent serving regression, and until now it was untested: a
refactor could break its drift math, its ordering re-checks, or — the
historical failure mode — crash on a renamed column and surface in CI as
a traceback instead of a finding.  These tests drive the real
``main(argv)`` on synthetic fresh/baseline table directories.
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))
import check_regression as cr  # noqa: E402


# -- synthetic tables ---------------------------------------------------------

PAGED = (["path", "tokens", "p99_ms", "goodput"],
         [["wave", "640", "90.0", "10.0"],
          ["paged", "640", "60.0", "14.0"]])
CHUNKED = (["path", "class", "tokens", "p99_ms", "goodput"],
           [["stall", "trading", "100", "50.0", "8.0"],
            ["stall", "all", "400", "80.0", "20.0"],
            ["chunked", "trading", "100", "35.0", "9.0"],
            ["chunked", "all", "400", "85.0", "33.0"]])
ATTN = (["impl", "context", "lanes", "attn_us", "step_us", "hbm_kb"],
        [["gather", "1024", "4", "300.0", "900.0", "4000"],
         ["fused", "1024", "4", "100.0", "700.0", "1000"],
         ["gather", "4096", "4", "1200.0", "2000.0", "16000"],
         ["fused", "4096", "4", "400.0", "1100.0", "4000"]])
HYBRID = (["kind", "name", "context", "window", "attn_us", "step_us",
           "kv_kib", "goodput", "p99_ms", "tokens"],
          [["attn", "windowed", "256", "1024", "50.0", "500.0", "100",
            "", "", ""],
           ["attn", "dense", "256", "", "50.0", "500.0", "100",
            "", "", ""],
           ["attn", "windowed", "4096", "1024", "100.0", "600.0", "200",
            "", "", ""],
           ["attn", "dense", "4096", "", "400.0", "900.0", "800",
            "", "", ""],
           ["fleet", "hybrid-pool", "", "", "", "", "", "12.0", "800.0",
            "1900"],
           ["fleet", "dense-pool", "", "", "", "", "", "9.0", "850.0",
            "1500"]])

SPEC = (["mix", "arm", "offered", "served", "dropped", "hit_rate",
         "p50_ms", "p99_ms", "goodput", "itl_ms"],
        [["trading", "spec-learned", "100", "97", "3", "0.970", "20.0",
          "36.0", "82.0", "2.6"],
         ["trading", "dense", "100", "98", "2", "0.980", "21.0", "43.0",
          "85.0", "3.0"],
         ["chat", "spec-learned", "200", "199", "1", "0.995", "300.0",
          "750.0", "225.0", "10.8"],
         ["chat", "dense", "200", "195", "5", "0.975", "350.0", "1100.0",
          "209.0", "18.0"],
         ["mixed", "spec-learned", "300", "297", "3", "0.990", "120.0",
          "550.0", "302.0", "7.5"],
         ["mixed", "dense", "300", "294", "6", "0.980", "150.0", "1050.0",
          "290.0", "12.4"],
         ["mixed", "fixed-k2", "300", "272", "28", "0.910", "140.0",
          "1040.0", "288.0", "9.2"],
         ["mixed", "fixed-k4", "300", "276", "24", "0.920", "130.0",
          "460.0", "296.0", "7.2"]])

SESSIONS = (["path", "offered", "served", "dropped", "cancelled",
             "hit_rate", "ttft_hit_rate", "ttft_p50_ms", "ttft_p99_ms",
             "p99_ms", "goodput", "tokens"],
            [["sharing", "280", "200", "80", "17", "0.620", "0.690",
              "130.0", "400.0", "950.0", "170.0", "2250"],
             ["no-sharing", "280", "185", "95", "14", "0.540", "0.620",
              "155.0", "395.0", "1000.0", "148.0", "2080"]])

FAULTS = (["path", "offered", "served", "dropped", "retried", "hedged",
           "hit_rate", "p99_ms", "goodput", "tokens", "faults_fired"],
          [["ceiling", "243", "243", "0", "0", "0", "1.000", "4100.0",
            "250.0", "5660", "0"],
           ["naive", "243", "226", "17", "0", "0", "0.901", "5350.0",
            "198.0", "5086", "21"],
           ["recovering", "243", "235", "8", "13", "0", "0.938", "5790.0",
            "210.0", "5562", "21"],
           ["recovering+hedge", "243", "240", "3", "13", "20", "0.963",
            "3690.0", "209.0", "5628", "21"]])

SHARDED = (["arm", "engines", "max_tp", "max_link", "net_aware",
            "offered", "served", "dropped", "hit_rate", "p99_ms",
            "goodput", "engine_shares"],
           [["sharded-tp8", "1", "8", "ici", "1", "49", "49", "0",
             "1.000", "63.0", "49.0", "49"],
            ["fallback-tp1", "8", "1", "ici", "1", "49", "49", "0",
             "1.000", "249.8", "42.3", "28/14/6/1/0/0/0/0"],
            ["net-aware", "2", "16", "dcn", "1", "49", "49", "0",
             "1.000", "63.0", "49.0", "49/0"],
            ["net-blind", "2", "16", "dcn", "0", "49", "47", "2",
             "0.959", "253.5", "22.6", "18/29"]])

ALL = {"table_paged.csv": PAGED, "table_chunked.csv": CHUNKED,
       "table_paged_attn.csv": ATTN, "table_hybrid.csv": HYBRID,
       "table_spec.csv": SPEC, "table_sessions.csv": SESSIONS,
       "table_faults.csv": FAULTS, "table_sharded.csv": SHARDED}


def mutate_spec(mix, arm, column, value):
    """Rewrite one cell of the spec table, keyed (mix, arm)."""
    def over(header, rows):
        ci = header.index(column)
        for r in rows:
            if r[0] == mix and r[1] == arm:
                r[ci] = value
        return header, rows
    return {"table_spec.csv": over}


def write_tables(d, overrides=None):
    os.makedirs(d, exist_ok=True)
    for name, (header, rows) in ALL.items():
        header, rows = list(header), [list(r) for r in rows]
        if overrides and name in overrides:
            header, rows = overrides[name](header, rows)
        with open(os.path.join(d, name), "w") as f:
            f.write(",".join(header) + "\n")
            for r in rows:
                f.write(",".join(str(x) for x in r) + "\n")
    return d


def run_gate(tmp_path, fresh_override=None, base_override=None, tol=5.0):
    fresh = write_tables(str(tmp_path / "fresh"), fresh_override)
    base = write_tables(str(tmp_path / "base"), base_override)
    return cr.main(["--results", fresh, "--baseline-dir", base,
                    "--tol-pct", str(tol)])


def mutate(name, path_key, column, value, key_col="path"):
    """Build an override that rewrites one cell of one table."""
    def over(header, rows):
        ci = header.index(column)
        ki = header.index(key_col)
        for r in rows:
            if r[ki] == path_key:
                r[ci] = value
        return header, rows
    return {name: over}


# -- the clean case -----------------------------------------------------------

def test_identical_tables_pass(tmp_path, capsys):
    assert run_gate(tmp_path) == 0
    assert "8 tables OK" in capsys.readouterr().out


def test_within_tolerance_passes(tmp_path):
    over = mutate("table_paged.csv", "paged", "goodput", "13.6")  # -2.9%
    assert run_gate(tmp_path, fresh_override=over) == 0


# -- drift detection ----------------------------------------------------------

def test_goodput_drop_fails(tmp_path, capsys):
    over = mutate("table_paged.csv", "paged", "goodput", "10.5")  # -25%
    assert run_gate(tmp_path, fresh_override=over) == 1
    assert "goodput dropped" in capsys.readouterr().err


def test_p99_rise_fails(tmp_path, capsys):
    over = mutate("table_chunked.csv", "chunked", "p99_ms", "45.0")
    assert run_gate(tmp_path, fresh_override=over) == 1
    assert "p99 rose" in capsys.readouterr().err


def test_row_set_change_fails(tmp_path, capsys):
    def drop_row(header, rows):
        return header, rows[:-1]
    assert run_gate(tmp_path,
                    fresh_override={"table_paged.csv": drop_row}) == 1
    assert "row set changed" in capsys.readouterr().err


def test_attn_time_rise_fails(tmp_path, capsys):
    over = mutate("table_paged_attn.csv", "fused", "step_us", "900.0",
                  key_col="impl")
    assert run_gate(tmp_path, fresh_override=over) == 1
    assert "step_us rose" in capsys.readouterr().err


def test_hybrid_kv_rise_fails(tmp_path, capsys):
    over = mutate("table_hybrid.csv", "windowed", "kv_kib", "1000",
                  key_col="name")
    assert run_gate(tmp_path, fresh_override=over) == 1
    assert "kv_kib rose" in capsys.readouterr().err


# -- ordering re-checks -------------------------------------------------------

def test_paged_not_beating_wave_fails(tmp_path, capsys):
    # better-than-baseline p99 (so drift passes) but above wave's: the
    # structural claim is violated even though nothing "regressed"
    over = {"table_paged.csv": lambda h, r: (h, [
        ["wave", "640", "90.0", "10.0"],
        ["paged", "640", "95.0", "14.0"]])}
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "paged p99 not below wave" in capsys.readouterr().err


def test_token_divergence_fails(tmp_path, capsys):
    over = mutate("table_paged.csv", "paged", "tokens", "641")
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "token counts diverged" in capsys.readouterr().err


def test_fused_not_dominating_fails(tmp_path, capsys):
    over = mutate("table_paged_attn.csv", "fused", "attn_us", "1300.0",
                  key_col="impl")
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "not below gather" in capsys.readouterr().err


def test_windowed_not_undercutting_dense_fails(tmp_path, capsys):
    def bloat(header, rows):
        for r in rows:
            if r[1] == "windowed" and r[2] == "4096":
                r[5] = "950.0"               # step_us above dense's 900
        return header, rows
    assert run_gate(tmp_path,
                    fresh_override={"table_hybrid.csv": bloat},
                    base_override={"table_hybrid.csv": bloat}) == 1
    err = capsys.readouterr().err
    assert "windowed step_us" in err and "dense" in err


def test_spec_goodput_drift_fails(tmp_path, capsys):
    over = mutate_spec("chat", "spec-learned", "goodput", "150.0")
    assert run_gate(tmp_path, fresh_override=over) == 1
    assert "goodput dropped" in capsys.readouterr().err


def test_spec_chat_below_dense_fails(tmp_path, capsys):
    # fresh == base (no drift) but the slack-rich margin is inverted
    over = mutate_spec("chat", "spec-learned", "goodput", "190.0")
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "spec-learned goodput 190.0 below dense" in \
        capsys.readouterr().err


def test_spec_trading_p99_above_dense_fails(tmp_path, capsys):
    over = mutate_spec("trading", "spec-learned", "p99_ms", "44.0")
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "spec-learned p99 44.0ms above dense" in capsys.readouterr().err


def test_spec_mixed_not_beating_fixed_k_fails(tmp_path, capsys):
    over = mutate_spec("mixed", "fixed-k4", "goodput", "310.0")
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "below fixed-k4" in capsys.readouterr().err


def test_sessions_ttft_rise_fails(tmp_path, capsys):
    over = mutate("table_sessions.csv", "sharing", "ttft_p50_ms", "150.0")
    assert run_gate(tmp_path, fresh_override=over) == 1
    assert "ttft_p50_ms rose" in capsys.readouterr().err


def test_sessions_hit_rate_drop_fails(tmp_path, capsys):
    over = mutate("table_sessions.csv", "sharing", "ttft_hit_rate", "0.500")
    assert run_gate(tmp_path, fresh_override=over) == 1
    assert "ttft_hit_rate dropped" in capsys.readouterr().err


def test_sessions_row_set_change_fails(tmp_path, capsys):
    def drop_row(header, rows):
        return header, rows[:-1]
    assert run_gate(tmp_path,
                    fresh_override={"table_sessions.csv": drop_row}) == 1
    assert "row set changed" in capsys.readouterr().err


def test_sessions_sharing_not_cutting_ttft_fails(tmp_path, capsys):
    # drift-clean (fresh == base) but sharing's TTFT p50 no longer sits
    # below no-sharing's: the structural claim itself is violated
    over = mutate("table_sessions.csv", "sharing", "ttft_p50_ms", "155.0")
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "not strictly below no-sharing" in capsys.readouterr().err


def test_sessions_sharing_goodput_below_cold_fails(tmp_path, capsys):
    over = mutate("table_sessions.csv", "sharing", "goodput", "140.0")
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "sharing goodput 140.0 below no-sharing" in \
        capsys.readouterr().err


def test_faults_goodput_drift_fails(tmp_path, capsys):
    over = mutate("table_faults.csv", "recovering", "goodput", "180.0")
    assert run_gate(tmp_path, fresh_override=over) == 1
    assert "goodput dropped" in capsys.readouterr().err


def test_faults_recovery_not_beating_naive_fails(tmp_path, capsys):
    # drift-clean (fresh == base) but recovery no longer strictly beats
    # stranding: the claim the table exists to prove is gone
    over = mutate("table_faults.csv", "recovering", "goodput", "198.0")
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "not strictly above naive" in capsys.readouterr().err


def test_faults_row_above_ceiling_fails(tmp_path, capsys):
    over = mutate("table_faults.csv", "recovering", "goodput", "260.0")
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "above the fault-free ceiling" in capsys.readouterr().err


def test_faults_recovery_dropping_more_fails(tmp_path, capsys):
    over = mutate("table_faults.csv", "recovering", "dropped", "20")
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "more than naive" in capsys.readouterr().err


def test_faults_no_retries_fails(tmp_path, capsys):
    over = mutate("table_faults.csv", "recovering", "retried", "0")
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "exercises no recovery" in capsys.readouterr().err


def test_faults_missing_row_fails(tmp_path, capsys):
    def drop_naive(header, rows):
        return header, [r for r in rows if r[0] != "naive"]
    assert run_gate(tmp_path,
                    fresh_override={"table_faults.csv": drop_naive},
                    base_override={"table_faults.csv": drop_naive}) == 1
    assert "missing rows" in capsys.readouterr().err


def test_sharded_goodput_drift_fails(tmp_path, capsys):
    over = mutate("table_sharded.csv", "sharded-tp8", "goodput", "30.0",
                  key_col="arm")
    assert run_gate(tmp_path, fresh_override=over) == 1
    assert "goodput dropped" in capsys.readouterr().err


def test_sharded_not_beating_fallback_fails(tmp_path, capsys):
    # drift-clean, but tensor parallelism no longer wins at equal
    # capacity: the claim the table exists to prove is gone
    over = mutate("table_sharded.csv", "sharded-tp8", "goodput", "42.3",
                  key_col="arm")
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "not strictly above fallback-tp1" in capsys.readouterr().err


def test_sharded_aware_not_beating_blind_fails(tmp_path, capsys):
    over = mutate("table_sharded.csv", "net-blind", "goodput", "49.0",
                  key_col="arm")
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "not strictly above net-blind" in capsys.readouterr().err


def test_sharded_vacuous_blind_comparison_fails(tmp_path, capsys):
    # the blind router never used the DCN-spanning engine: the
    # aware/blind goodput gap proves nothing about link pricing
    over = mutate("table_sharded.csv", "net-blind", "engine_shares",
                  "47/0", key_col="arm")
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "never chose the DCN-spanning engine" in \
        capsys.readouterr().err


def test_sharded_missing_row_fails(tmp_path, capsys):
    def drop_blind(header, rows):
        return header, [r for r in rows if r[0] != "net-blind"]
    assert run_gate(tmp_path,
                    fresh_override={"table_sharded.csv": drop_blind},
                    base_override={"table_sharded.csv": drop_blind}) == 1
    assert "missing rows" in capsys.readouterr().err


def test_hybrid_pool_goodput_ordering_fails(tmp_path, capsys):
    over = mutate("table_hybrid.csv", "hybrid-pool", "goodput", "8.0",
                  key_col="name")
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "hybrid-pool goodput" in capsys.readouterr().err


# -- malformed tables ---------------------------------------------------------

def test_missing_column_is_a_finding_not_a_crash(tmp_path, capsys):
    def drop_goodput(header, rows):
        i = header.index("goodput")
        return ([c for c in header if c != "goodput"],
                [[x for j, x in enumerate(r) if j != i] for r in rows])
    rc = run_gate(tmp_path,
                  fresh_override={"table_paged.csv": drop_goodput})
    assert rc == 1
    assert "missing column 'goodput'" in capsys.readouterr().err


def test_missing_key_column_is_a_finding_not_a_crash(tmp_path, capsys):
    def drop_path(header, rows):
        i = header.index("path")
        return ([c for c in header if c != "path"],
                [[x for j, x in enumerate(r) if j != i] for r in rows])
    rc = run_gate(tmp_path,
                  fresh_override={"table_paged.csv": drop_path})
    assert rc == 1                       # not a KeyError traceback
    err = capsys.readouterr().err
    assert "row set changed" in err or "missing" in err


def test_non_numeric_cell_is_a_finding(tmp_path, capsys):
    over = mutate("table_paged.csv", "paged", "p99_ms", "fast!")
    assert run_gate(tmp_path, fresh_override=over) == 1
    assert "non-numeric" in capsys.readouterr().err


def test_empty_table_aborts_with_named_error(tmp_path):
    write_tables(str(tmp_path / "base"))
    fresh = write_tables(str(tmp_path / "fresh"))
    open(os.path.join(fresh, "table_paged.csv"), "w").close()
    with pytest.raises(SystemExit):
        cr.main(["--results", fresh, "--baseline-dir",
                 str(tmp_path / "base")])


def test_missing_window_column_in_hybrid_fails(tmp_path, capsys):
    over = mutate("table_hybrid.csv", "windowed", "window", "",
                  key_col="name")
    assert run_gate(tmp_path, fresh_override=over,
                    base_override=over) == 1
    assert "no windowed rows with a window" in capsys.readouterr().err
