"""Chunked prefill interleaved with paged decode: the scatter kernel, the
``transformer.prefill_chunk`` entry point, token identity with monolithic
prefill across chunk sizes, decode-lane progress during a long prompt's
prefill, the chunk-aware admission projections, and the
past-deadline-after-prefill drop/degrade re-check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref
from repro.models import transformer as T
from repro.serving.continuous import (ContinuousBatcher, LatencyProfile,
                                      projected_finish, prompt_chunks)
from repro.serving.kv_cache import PagedKVCache
from repro.serving.paged_engine import ContinuousEngine
from repro.serving.scheduler import Request
from repro.serving.traffic import SimRequest


CFG = get_config("qwen-sim-1.5b")
FULL = get_config("qwen2.5-1.5b")         # real-scale clock


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), CFG)


def _prompts(lens, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab, n).astype(np.int32) for n in lens]


# -- scatter kernel ----------------------------------------------------------

def test_scatter_chunk_kernel_matches_ref():
    rng = np.random.default_rng(0)
    for n_pages, ps, H, D, B, P, C in ((12, 4, 2, 8, 2, 4, 4),
                                       (12, 4, 2, 8, 2, 4, 8),
                                       (12, 8, 1, 16, 3, 3, 5),
                                       (10, 8, 1, 16, 2, 3, 11)):
        pool = jnp.asarray(rng.normal(size=(n_pages, ps, H, D))
                           .astype(np.float32))
        ids = rng.permutation(np.arange(1, n_pages))[:B * P].reshape(B, P)
        bt = jnp.asarray(ids.astype(np.int32))
        pos = jnp.asarray((rng.integers(0, 2, B) * ps).astype(np.int32))
        chunk = jnp.asarray(rng.normal(size=(B, C, H, D)).astype(np.float32))
        want = np.asarray(kernel_ref.scatter_chunk_ref(pool, bt, pos, chunk))
        got_p = kernel_ops.scatter_chunk(pool, bt, pos, chunk,
                                         use_pallas=True)
        got_j = kernel_ops.scatter_chunk(pool, bt, pos, chunk,
                                         use_pallas=False)
        assert np.array_equal(want, np.asarray(got_p)), (n_pages, ps, C)
        assert np.array_equal(want, np.asarray(got_j)), (n_pages, ps, C)


def test_scatter_chunk_unaligned_offset_jnp_path():
    """The jnp path takes any start offset (the Pallas path requires
    page-aligned chunk starts, which the engine guarantees)."""
    rng = np.random.default_rng(2)
    pool = jnp.asarray(rng.normal(size=(8, 4, 2, 8)).astype(np.float32))
    bt = jnp.asarray(rng.permutation(np.arange(1, 7))[:6]
                     .reshape(2, 3).astype(np.int32))
    pos = jnp.asarray(np.array([3, 5], np.int32))
    chunk = jnp.asarray(rng.normal(size=(2, 5, 2, 8)).astype(np.float32))
    want = kernel_ref.scatter_chunk_ref(pool, bt, pos, chunk)
    got = kernel_ops.scatter_chunk(pool, bt, pos, chunk)
    assert np.array_equal(np.asarray(want), np.asarray(got))


# -- transformer.prefill_chunk vs monolithic prefill ------------------------

def test_prefill_chunk_matches_monolithic_pools(params):
    """Absorbing a prompt chunk-by-chunk leaves the pools and last-position
    logits equivalent to a monolithic prefill + page write, for chunk sizes
    that do and do not divide the prompt length (and one > the prompt)."""
    S = 20
    prompt = _prompts([S])[0]
    mono = PagedKVCache(CFG, slots=1, n_pages=10, page_size=8, max_ctx=64)
    mono.alloc(0, S + 4)
    logits_m, raw = T.prefill(params, CFG,
                              {"tokens": jnp.asarray(prompt[None])},
                              raw_kv=True)
    mono.write_prefill(0, T.raw_prefill_group_kv(CFG, raw))
    lm = np.asarray(logits_m)[0, 0]

    for chunk in (8, 5, 16, 32):
        ch = PagedKVCache(CFG, slots=1, n_pages=10, page_size=8, max_ctx=64)
        pages = [p for _, p in ch.alloc(0, S + 4)]
        cache = ch.chunk_cache(0, min(chunk, S))
        logits_c, off = None, 0
        while off < S:
            c = min(chunk, S - off)
            logits_c, cache = T.prefill_chunk(
                params, CFG, {"tokens": jnp.asarray(prompt[None, off:off + c])},
                cache)
            off += c
        assert int(np.asarray(cache["pos"])[0]) == S
        n_pg = ch.pages_needed(S)
        sel = np.asarray(pages[:n_pg])
        km = np.asarray(mono.kpool["layers"])[:, sel] \
            .reshape(CFG.n_layers, -1, CFG.n_kv_heads, CFG.head_dim)[:, :S]
        kc = np.asarray(cache["groups"]["layers"]["kpool"])[:, sel] \
            .reshape(CFG.n_layers, -1, CFG.n_kv_heads, CFG.head_dim)[:, :S]
        np.testing.assert_allclose(kc, km, atol=1e-4)
        lc = np.asarray(logits_c)[0, 0]
        np.testing.assert_allclose(lc, lm, atol=1e-4)
        assert lc.argmax() == lm.argmax(), chunk


def test_prefill_chunk_rejects_unsupported_arch():
    hcfg = get_config("hymba-1.5b")
    with pytest.raises(NotImplementedError, match="dense/moe"):
        T.prefill_chunk({}, hcfg, {"tokens": jnp.zeros((1, 4), jnp.int32)},
                        {})


def test_engine_rejects_misaligned_chunk(params):
    with pytest.raises(ValueError, match="multiple of page_size"):
        ContinuousEngine(params, CFG, page_size=8, prefill_chunk=12)


# -- engine-level token identity (acceptance) -------------------------------

def test_chunked_engine_token_identical_to_monolithic(params):
    """Same greedy requests through the paged engine with and without
    chunked prefill: identical tokens, for chunk sizes that do (8 | 24)
    and do not (16 ∤ 24, 8 ∤ 13) divide the prompt lengths."""
    lens = [24, 13, 20]
    base = _prompts(lens)

    def run(chunk):
        reqs = [Request(rid=i, prompt=p.copy(), max_new=5, deadline_s=10.0)
                for i, p in enumerate(base)]
        pe = ContinuousEngine(params, CFG, slots=3, page_size=8, max_ctx=64,
                              policy="serve", prefill_chunk=chunk)
        for r in reqs:
            pe.submit(r)
        pe.run()
        return reqs

    mono = run(None)
    for chunk in (8, 16):
        chunked = run(chunk)
        for m, c in zip(mono, chunked):
            assert np.array_equal(m.result_tokens, c.result_tokens), \
                (chunk, m.rid)
            assert c.tokens_done == c.max_new and c.met_deadline
            assert c.t_prefill_done is not None


def test_decode_lanes_advance_during_long_prefill(params):
    """The head-of-line fix (acceptance): a short request decoding when a
    long prompt arrives keeps landing tokens between the newcomer's prefill
    chunks — and retires *during* that prefill.  Monolithically the same
    short request cannot finish before the long prefill completes."""
    def run(chunk):
        rng = np.random.default_rng(3)
        A = Request(rid=0,
                    prompt=rng.integers(0, CFG.vocab, 8).astype(np.int32),
                    max_new=6, deadline_s=100.0, t_arrive=0.0)
        B = Request(rid=1,
                    prompt=rng.integers(0, CFG.vocab, 48).astype(np.int32),
                    max_new=2, deadline_s=100.0, t_arrive=1e-6)
        pe = ContinuousEngine(params, CFG, slots=2, page_size=8, max_ctx=64,
                              policy="serve", latency_cfg=FULL, avg_bits=8.0,
                              prefill_chunk=chunk)
        pe.submit(A)
        pe.submit(B)
        pe.run()
        return A, B

    A, B = run(chunk=8)
    assert B.t_admit < A.t_finish < B.t_prefill_done   # A retired mid-prefill
    assert A.tokens_done == 6 and B.tokens_done == 2
    Am, Bm = run(chunk=None)
    assert Am.t_finish > Bm.t_prefill_done             # the stall, for contrast
    # same greedy tokens either way
    assert np.array_equal(A.result_tokens, Am.result_tokens)
    assert np.array_equal(B.result_tokens, Bm.result_tokens)


# -- chunk-aware projections -------------------------------------------------

def test_prompt_chunks_and_chunked_cost():
    assert prompt_chunks(32, 16) == [16, 16]
    assert prompt_chunks(20, 8) == [8, 8, 4]
    assert prompt_chunks(5, 8) == [5]
    prof = LatencyProfile(FULL, 8.0)
    total = prof.prefill_chunked_s(48, 16)
    # length-aware: chunk j attends over the j*16 already-written tokens,
    # so the total is the per-chunk sum at growing context — strictly above
    # three context-free chunks, which in turn exceed the monolithic cost
    # (each chunk re-pays the weight read)
    assert total == pytest.approx(prof.prefill_s(16)
                                  + prof.prefill_s(16, context=16)
                                  + prof.prefill_s(16, context=32))
    assert total > 3 * prof.prefill_s(16) > prof.prefill_s(48)
    # first chunk has nothing to attend over: context 0 adds nothing
    assert prof.prefill_s(16, context=0) == prof.prefill_s(16)


def test_projected_finish_prices_interleave():
    """With other lanes decoding, the chunked projection must exceed the
    monolithic one (chunk overhead + interleaved decode steps); with the
    engine otherwise empty no decode steps interleave."""
    prof = LatencyProfile(FULL, 8.0)
    req = SimRequest(rid=0, cls_name="t", t_arrive=0.0, prompt_len=64,
                     max_new=4, deadline_s=1.0)
    mono = projected_finish(prof, 0.0, 2, req, 4)
    chunked = projected_finish(prof, 0.0, 2, req, 4, prefill_chunk=16)
    assert chunked > mono
    alone = projected_finish(prof, 0.0, 1, req, 4, prefill_chunk=16)
    interleave = chunked - alone
    assert interleave == pytest.approx(
        (len(prompt_chunks(64, 16)) - 1) * prof.step_s(2, 64)
        + 4 * (prof.step_s(2, 66) - prof.step_s(1, 66)), abs=1e-9)


def test_backlog_prices_absorbed_prefill_context():
    """The router backlog estimate must charge a mid-prefill lane's
    remaining chunks at the context it has already absorbed: near the end
    of a long prompt each chunk attends over ~the whole prompt, so the
    same 128 tokens left must cost more than a fresh 128-token start."""
    from repro.serving.continuous import estimate_backlog

    prof = LatencyProfile(FULL, 8.0)
    common = dict(prefill_chunk=64, active_prefill_left=[128])
    near_end = estimate_backlog(prof, 0.0, 0.0, [0], [], 4,
                                active_prefill_done=[3968], **common)
    fresh = estimate_backlog(prof, 0.0, 0.0, [0], [], 4,
                             active_prefill_done=[0], **common)
    assert near_end > fresh
    # omitted absorbed contexts default to zero (monolithic callers)
    legacy = estimate_backlog(prof, 0.0, 0.0, [0], [], 4, **common)
    assert legacy == pytest.approx(fresh)


# -- analytic mirror ---------------------------------------------------------

def test_analytic_batcher_chunked_mirror():
    """The analytic ContinuousBatcher admits chunk-granularly exactly like
    the live engine: a short decode finishes during a long prompt's chunked
    prefill, and the total prefill charge is the per-chunk sum."""
    prof = LatencyProfile(FULL, 8.0)

    def run(chunk):
        A = SimRequest(rid=0, cls_name="t", t_arrive=0.0, prompt_len=16,
                       max_new=6, deadline_s=100.0)
        B = SimRequest(rid=1, cls_name="t", t_arrive=1e-6, prompt_len=96,
                       max_new=2, deadline_s=100.0)
        cb = ContinuousBatcher(prof, slots=2, policy="serve",
                               prefill_chunk=chunk)
        cb.submit(A)
        cb.submit(B)
        cb.run()
        return A, B

    A, B = run(16)
    assert B.t_admit < A.t_finish < B.t_prefill_done
    assert A.tokens_done == 6 and B.tokens_done == 2
    # B's prefill window carries its own chunk charges plus A's steps
    assert B.t_prefill_done - B.t_admit >= prof.prefill_chunked_s(96, 16)
    Am, Bm = run(None)
    assert Am.t_finish > Bm.t_prefill_done
    assert Bm.t_prefill_done == pytest.approx(Bm.t_admit
                                              + prof.prefill_s(96))


# -- the past-deadline-after-prefill bugfix ----------------------------------

def _co_prefill_scenario(params, *, policy, b_deadline_s, c_prompt=64):
    """A decoding lane plus two prompts admitted back-to-back: each
    newcomer's admission projection cannot see the *other's* chunk charges,
    so the earlier one (B) completes its prefill well past its projection.
    Returns (A, B, C) after the run."""
    rng = np.random.default_rng(7)
    A = Request(rid=0, prompt=rng.integers(0, CFG.vocab, 16).astype(np.int32),
                max_new=30, deadline_s=1000.0, t_arrive=0.0)
    B = Request(rid=1, prompt=rng.integers(0, CFG.vocab, 64).astype(np.int32),
                max_new=4, deadline_s=b_deadline_s, t_arrive=1e-6)
    C = Request(rid=2,
                prompt=rng.integers(0, CFG.vocab, c_prompt).astype(np.int32),
                max_new=4, deadline_s=500.0, t_arrive=2e-6)
    pe = ContinuousEngine(params, CFG, slots=3, page_size=8, max_ctx=128,
                          policy=policy, latency_cfg=FULL, avg_bits=8.0,
                          prefill_chunk=16)
    for r in (A, B, C):
        pe.submit(r)
    pe.run()
    return A, B, C, pe


def test_post_prefill_deadline_drop(params):
    """Regression (the ISSUE bugfix): a request whose deadline can no longer
    be met once its prefill has actually been charged must be dropped at
    that point — previously it was served to completion and landed late."""
    # reality first: how late does B actually finish under no policy?
    _, B0, _, _ = _co_prefill_scenario(params, policy="serve",
                                       b_deadline_s=100.0)
    prof = LatencyProfile(FULL, 8.0)
    projection = projected_finish(prof, B0.t_admit, 2, B0, 4,
                                  prefill_chunk=16)
    # the co-prefilling prompt C opens a real gap between projection and truth
    assert projection < B0.t_finish, "precondition: projection optimistic"
    deadline_abs = 0.5 * (projection + B0.t_finish)

    _, B, C, pe = _co_prefill_scenario(
        params, policy="drop", b_deadline_s=deadline_abs - 1e-6)
    assert B.dropped and B.tokens_done == 0          # caught at prefill end
    assert B.t_prefill_done is not None
    assert not C.dropped and C.tokens_done == 4      # loose deadline unharmed
    assert pe.cache.free_pages == pe.cache.n_pages - 1   # pages returned


def test_post_prefill_deadline_degrade_trims(params):
    """Same trigger under ``degrade``: the decode budget is re-trimmed when
    the prompt completes, so the request still lands on time (with fewer
    tokens) instead of running its full admitted budget late."""
    _, B0, _, _ = _co_prefill_scenario(params, policy="serve",
                                       b_deadline_s=100.0, c_prompt=32)
    prof = LatencyProfile(FULL, 8.0)
    projection = projected_finish(prof, B0.t_admit, 2, B0, 4,
                                  prefill_chunk=16)
    # after B's prefill ends, nothing but decode steps remain (C's shorter
    # prompt finished prefilling earlier), so the re-trim is near-exact
    deadline_abs = B0.t_prefill_done + 2.5 * prof.step_s(3, 66)
    assert deadline_abs > projection, "precondition: admission must not trim"

    _, B, C, _ = _co_prefill_scenario(
        params, policy="degrade", b_deadline_s=deadline_abs - 1e-6,
        c_prompt=32)
    assert not B.dropped
    assert 0 < B.tokens_done < 4                     # trimmed post-prefill
    assert B.met_deadline                            # ...and on time
    assert not C.dropped
