"""Prefill + incremental decode must match the full causal forward —
the core serving invariant, verified for every architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import transformer as T

ARCHS = sorted(ASSIGNED)


def _inputs(cfg, B=2, S=12, extra=3, seed=1):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, S + extra),
                              0, cfg.vocab)
    b = {"tokens": toks}
    if cfg.arch_type == "vlm":
        b["vision"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (B, cfg.vision_tokens, cfg.vision_dim or cfg.d_model)) * 0.1
    if cfg.arch_type == "audio":
        b["audio"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.audio_frames, cfg.d_model)) * 0.1
    return b, toks


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S, extra = 2, 12, 3
    batch, toks = _inputs(cfg, B, S, extra)
    full = T.forward(params, cfg, batch)

    pf = dict(batch)
    pf["tokens"] = toks[:, :S]
    logits0, cache = T.prefill(params, cfg, pf, cache_len=S + extra)
    np.testing.assert_allclose(np.asarray(logits0[:, 0]),
                               np.asarray(full[:, S - 1]),
                               rtol=2e-3, atol=2e-3)
    for i in range(extra):
        lg, cache = T.decode_step(params, cfg,
                                  {"token": toks[:, S + i:S + i + 1]}, cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, S + i]),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["starcoder2-15b", "gemma3-4b", "hymba-1.5b"])
def test_sliding_window_ring_buffer(arch):
    """Decode past the window: ring buffer keeps only the last W tokens and
    still matches the windowed full forward."""
    cfg = get_config(arch).reduced()
    assert cfg.sliding_window is not None
    W = cfg.sliding_window
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, W + 6        # go past the window
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S + 2), 0, cfg.vocab)
    full = T.forward(params, cfg, {"tokens": toks})
    _, cache = T.prefill(params, cfg, {"tokens": toks[:, :S]},
                         cache_len=S + 2)
    for i in range(2):
        lg, cache = T.decode_step(params, cfg,
                                  {"token": toks[:, S + i:S + i + 1]}, cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, S + i]),
                                   rtol=2e-3, atol=2e-3)


def test_ssm_state_is_constant_size():
    """xlstm decode state does not grow with context (sub-quadratic claim)."""
    cfg = get_config("xlstm-1.3b").reduced()
    c1 = T.init_decode_cache(cfg, 2, 128)
    c2 = T.init_decode_cache(cfg, 2, 4096)
    s1 = sum(np.prod(x.shape) for x in jax.tree.leaves(c1))
    s2 = sum(np.prod(x.shape) for x in jax.tree.leaves(c2))
    assert s1 == s2


def test_windowed_cache_is_bounded():
    cfg = get_config("starcoder2-15b").reduced()
    W = cfg.sliding_window
    cache = T.init_decode_cache(cfg, 2, 10 * W)
    assert cache["layers"]["k"].shape[-3] == W
