"""Fault injection, failover, and token-exact recovery — the PR 9 suite.

The contract under test: a seeded :class:`~repro.serving.faults.FaultPlan`
replayed against the fleet is deterministic end to end (same (plan seed,
traffic seed) ⇒ same fired faults, same retirements, same tokens); a
request killed mid-decode by an injected crash is re-routed and its full
output is **byte-identical** to an uninterrupted run (rid-seeded prompts
plus the (seed, stream, rid, position)-keyed sampler make recovery a
correctness property, not best effort); the router's circuit breaker
opens on stalls and closes via backoff probes; hedged dispatch retires
each rid exactly once; and every fault trace passes ``check_trace`` —
no page leaks through crash reclamation, no unlicensed double
admissions or double retirements.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import pallas_modes, servable_smoke_configs, smoke_params
from repro.configs import get_config
from repro.models.modules import ExecContext
from repro.obs import trace as tr_mod
from repro.obs.check_trace import check
from repro.serving import faults as faults_mod
from repro.serving import metrics as metrics_mod
from repro.serving import traffic
from repro.serving.continuous import ContinuousBatcher, LatencyProfile
from repro.serving.faults import (CRASH, PAGE_PRESSURE, SLOWDOWN, STALL,
                                  Fault, FaultInjector, FaultPlan,
                                  generate_plan)
from repro.serving.fleet import FleetRouter, pool_candidates
from repro.serving.paged_engine import ContinuousEngine

SERVABLE = servable_smoke_configs()
DENSE = [(n, c) for n, c in SERVABLE if not c.sliding_window]
NAME, CFG = DENSE[0]


def _eps(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return {f"L{i}.lin{j}": float(rng.uniform(0.05, 0.9))
            for i in range(cfg.n_layers) for j in range(4)}


def _pool(n=2, name="qwen2.5-1.5b", gamma=1.0):
    cfg = get_config(name)
    return pool_candidates([(name, cfg, _eps(cfg), gamma)] * n)


def _reqs(n, *, deadline=50.0, max_new=8, prompt=24, gap=0.01):
    return [traffic.SimRequest(rid=i, cls_name="t", t_arrive=i * gap,
                               prompt_len=prompt, max_new=max_new,
                               deadline_s=deadline) for i in range(n)]


# -- plan generation: seeded determinism (the property the module promises)

@settings(max_examples=15)
@given(st.integers(min_value=0, max_value=10_000))
def test_plan_seeded_determinism_and_structure(seed):
    kw = dict(crash_rate=0.2, stall_rate=0.2, slowdown_rate=0.2,
              pressure_rate=0.2, warmup_s=1.0)
    a = generate_plan(3, 20.0, seed=seed, **kw)
    b = generate_plan(3, 20.0, seed=seed, **kw)
    assert a == b                            # frozen dataclass equality
    for f in a.faults:
        assert 1.0 <= f.t < 20.0
        assert 0 <= f.engine_idx < 3
        assert f.kind in faults_mod.KINDS
        assert f.duration_s > 0.0
        if f.kind == SLOWDOWN:
            assert f.factor > 1.0
        if f.kind == PAGE_PRESSURE:
            assert f.pages > 0 and f.slots > 0
    assert list(a.faults) == sorted(a.faults)


def test_plan_different_seeds_differ():
    a = generate_plan(2, 50.0, seed=0, crash_rate=0.3)
    b = generate_plan(2, 50.0, seed=1, crash_rate=0.3)
    assert a != b


# -- clean-path bit-identity + slowdown scaling ------------------------------

def _analytic_run(plan, reqs, *, slots=2):
    prof = LatencyProfile(get_config("qwen2.5-1.5b"), 16.0)
    eng = ContinuousBatcher(prof, slots=slots, policy="serve")
    if plan is not None:
        FaultInjector(plan).attach([eng])
    for r in reqs:
        eng.submit(r)
    out = eng.run()
    return {r.rid: r for r in out}


def test_attached_injector_with_no_overlapping_fault_is_bit_identical():
    """A fault window that never covers the run must not move a single
    timestamp — the clean path through ``_charge`` is exactly the
    historical arithmetic (scale 1.0 short-circuits)."""
    base = _analytic_run(None, _reqs(6))
    late = FaultPlan((Fault(1e6, 0, SLOWDOWN, duration_s=1.0, factor=3.0),))
    slow = _analytic_run(late, _reqs(6))
    for rid, r in base.items():
        assert slow[rid].t_finish == r.t_finish
        assert slow[rid].t_first_token == r.t_first_token


def test_slowdown_window_stretches_covered_charges_only():
    base = _analytic_run(None, _reqs(6))
    horizon = max(r.t_finish for r in base.values())
    cover = FaultPlan((Fault(0.0, 0, SLOWDOWN, duration_s=10 * horizon,
                             factor=4.0),))
    slow = _analytic_run(cover, _reqs(6))
    assert all(slow[rid].t_finish > r.t_finish for rid, r in base.items())


def test_analytic_pressure_seizes_and_releases_slots():
    """During the window the batcher decodes with fewer concurrent slots;
    after it, full concurrency returns (seizure is released)."""
    prof = LatencyProfile(get_config("qwen2.5-1.5b"), 16.0)
    eng = ContinuousBatcher(prof, slots=2, policy="serve")
    plan = FaultPlan((Fault(0.0, 0, PAGE_PRESSURE, duration_s=1e-3,
                            slots=1, pages=4),))
    FaultInjector(plan).attach([eng])
    for r in _reqs(2, gap=0.0):
        eng.submit(r)
    eng.drain(until=1e-4)
    assert eng._slots_now() == 1 and len(eng.active) == 1
    out = eng.run()
    assert eng._slots_now() == 2             # window over: released
    assert all(r.t_finish is not None for r in out)


# -- crash recovery: default same-engine redo is deterministic ---------------

def test_crash_requeue_same_engine_deterministic_tokens():
    """Satellite: identical (plan seed, traffic seed) ⇒ identical fired
    sequence, retirements, and *emitted tokens* across runs.  Live paged
    engine, default crash handler (full redo on the same engine)."""
    params = smoke_params(NAME)

    def run(plan):
        eng = ContinuousEngine(params, CFG, slots=2, page_size=8,
                               max_ctx=64, policy="serve",
                               ctx=ExecContext(use_pallas=False))
        inj = None
        if plan is not None:
            inj = FaultInjector(plan)
            inj.attach([eng])
        for r in _reqs(3, prompt=16, max_new=6, gap=0.0):
            eng.submit(r)
        eng.run()
        return inj, {r.rid: r for r in eng.completed}

    _, base = run(None)             # dry run fixes the crash time mid-decode
    v = base[0]
    plan = FaultPlan((Fault(v.t_first_token + 0.5 * (v.t_finish
                                                     - v.t_first_token),
                            0, CRASH, duration_s=0.05),))
    ia, a = run(plan)
    ib, b = run(plan)
    assert ia.fired == ib.fired and len(ia.fired) == 1
    assert set(a) == set(b) == set(base)
    retried = [r for r in a.values() if r.retries > 0]
    assert retried, "mid-decode crash should have reclaimed in-flight work"
    for rid, r in a.items():
        assert b[rid].retries == r.retries
        assert b[rid].t_finish == r.t_finish
        assert np.array_equal(b[rid].result_tokens, r.result_tokens)
        # the redo is byte-identical to the uninterrupted run, too
        assert np.array_equal(base[rid].result_tokens, r.result_tokens)


# -- the tentpole acceptance: token-exact recovery across a crash ------------

@pytest.mark.parametrize("use_pallas", pallas_modes())
def test_token_exact_recovery_across_crash(use_pallas):
    """A two-engine live fleet; engine 0 crashes mid-decode.  The victim
    is reclaimed, re-routed to engine 1, fully redone — and every rid's
    final output is byte-identical to the fault-free run.  The whole
    trace passes check_trace: exactly-once final retirement per rid
    (crash re-admission licensed by req.requeue) and zero page leaks
    through crash reclamation."""
    params = smoke_params(NAME)
    cands = _pool(2)

    def fleet(tracer, injector):
        engines = [
            ContinuousEngine(params, CFG, slots=2, page_size=8, max_ctx=64,
                             policy="serve",
                             ctx=ExecContext(use_pallas=use_pallas),
                             tracer=tracer.scope(f"eng{i}")
                             if tracer else None)
            for i in range(2)]
        return FleetRouter(cands, quality=lambda c: 1.0, engines=engines,
                           tracer=tracer, injector=injector)

    base = {r.rid: r for r in fleet(None, None).run(_reqs(4, prompt=16,
                                                          max_new=6))}
    victim = base[0]
    assert victim.engine_idx == 0            # empty fleet: tie -> first
    t_crash = victim.t_first_token + 0.5 * (victim.t_finish
                                            - victim.t_first_token)

    tr = tr_mod.Tracer()
    inj = FaultInjector(FaultPlan((Fault(t_crash, 0, CRASH,
                                         duration_s=0.2),)), tracer=tr)
    router = fleet(tr, inj)
    done = {r.rid: r for r in router.run(_reqs(4, prompt=16, max_new=6))}

    requeues = [e for e in tr.events if e.name == tr_mod.REQ_REQUEUE]
    assert requeues and any(e.args["tokens_done"] > 0 for e in requeues)
    assert done[0].retries >= 1 and done[0].engine_idx == 1  # re-routed
    for rid, want in base.items():
        got = done[rid]
        assert not got.dropped and got.result_tokens is not None
        assert np.array_equal(want.result_tokens, got.result_tokens), rid
    assert any(e.name == tr_mod.ENGINE_DOWN for e in tr.events)
    assert check(tr.events) == []
    for eng in router.engines:               # reclamation freed every page
        assert eng.cache.free_pages == sum(
            n - 1 for n in eng.cache._group_pages.values())


# -- fleet-scale failover, breaker, hedging ----------------------------------

def _mixed_fleet(plan, *, hedge_delay_s=None, recover=True, seed=1):
    tr = tr_mod.Tracer()
    inj = FaultInjector(plan, tracer=tr) if plan is not None else None
    from repro.serving.fleet import demo_pool, demo_quality
    router = FleetRouter(demo_pool(), quality=demo_quality, seed=seed,
                         tracer=tr, injector=inj, recover=recover,
                         hedge_delay_s=hedge_delay_s)
    reqs = traffic.generate(traffic.scenario("mixed"), 8.0, seed=7)
    done = router.run([r.fresh() for r in reqs])
    return tr, router, reqs, done


def test_fleet_failover_accounts_every_rid_exactly_once():
    # seed 2's schedule crashes the *busy* engines (in-flight work exists
    # to reclaim) — a crash on an idle engine is a correct no-op
    plan = generate_plan(4, 8.0, seed=2, crash_rate=0.2, stall_rate=0.1,
                         slowdown_rate=0.1)
    tr, router, reqs, done = _mixed_fleet(plan, hedge_delay_s=0.5)
    winners = [r for r in done if not r.hedge_loser]
    assert sorted(r.rid for r in winners) == sorted(r.rid for r in reqs)
    assert any(r.retries > 0 for r in winners)
    assert any(e.name == tr_mod.ENGINE_DOWN for e in tr.events)
    assert any(e.name == tr_mod.ENGINE_UP for e in tr.events)
    assert check(tr.events) == []
    rep = metrics_mod.summarize(done, 8.0)
    assert rep.n == len(reqs)                # losers never enter tallies
    assert rep.retried >= 1
    # recovery must beat stranding on the same schedule and traffic
    _, _, _, naive = _mixed_fleet(plan, recover=False)
    assert (sum(r.reward for r in done) >
            sum(r.reward for r in naive))


def test_stall_opens_breaker_and_probe_closes_it():
    """A stall is detected by silence (no reclamation — state survives),
    the breaker excludes the engine while open, and a backoff probe
    closes it after the window."""
    cands = _pool(2)
    tr = tr_mod.Tracer()
    plan = FaultPlan((Fault(0.2, 0, STALL, duration_s=1.0),))
    router = FleetRouter(cands, quality=lambda c: 1.0, tracer=tr,
                         injector=FaultInjector(plan, tracer=tr),
                         stall_timeout_s=0.1, probe_backoff_s=0.05)
    router.run(_reqs(40, gap=0.05, deadline=20.0, max_new=4))
    downs = [e for e in tr.events if e.name == tr_mod.ENGINE_DOWN]
    ups = [e for e in tr.events if e.name == tr_mod.ENGINE_UP]
    assert len(downs) == 1 and downs[0].args["reason"] == "stall"
    assert 0.3 <= downs[0].t0 <= 0.6         # start + timeout + scan slack
    assert len(ups) == 1 and ups[0].t0 >= 1.2
    assert not any(e.name == tr_mod.REQ_REQUEUE for e in tr.events)
    # while the breaker is open, nothing routes to engine 0
    for e in tr.events:
        if (e.name == tr_mod.ROUTE_DISPATCH
                and downs[0].t0 <= e.t0 < ups[0].t0):
            assert e.args["engine_idx"] == 1
    assert check(tr.events) == []


def test_hedge_first_finisher_wins_and_loser_is_flagged():
    """A request stuck behind a busy engine is hedged onto the other one;
    the idle engine's attempt wins, the stuck primary is torn down and
    flagged, and metrics count the rid exactly once (``cancelled``
    excludes the router's own duplicate)."""
    fast = get_config("qwen2.5-1.5b")
    slow = get_config("qwen2.5-14b")
    cands = pool_candidates([("qwen2.5-1.5b", fast, _eps(fast), 1.0),
                             ("qwen2.5-14b", slow, _eps(slow), 0.0)])
    quality = lambda c: {"qwen2.5-1.5b": 0.9, "qwen2.5-14b": 0.5}[
        c.model_name]
    tr = tr_mod.Tracer()
    router = FleetRouter(cands, quality=quality, slots=1, tracer=tr,
                         hedge_delay_s=0.05)
    blocker = traffic.SimRequest(rid=0, cls_name="t", t_arrive=0.0,
                                 prompt_len=64, max_new=4096,
                                 deadline_s=100.0)
    victim = traffic.SimRequest(rid=1, cls_name="t", t_arrive=0.01,
                                prompt_len=64, max_new=8, deadline_s=100.0)
    done = router.run([blocker, victim])
    assert any(e.name == tr_mod.ROUTE_HEDGE for e in tr.events)
    attempts = [r for r in done if r.rid == 1]
    assert len(attempts) == 2                # winner + torn-down loser
    win = next(r for r in attempts if not r.hedge_loser)
    lose = next(r for r in attempts if r.hedge_loser)
    assert win.engine_idx == 1 and win.hedged and not win.cancelled
    assert win.tokens_done == 8
    assert lose.cancelled                    # barge-in teardown, not a drop
    rep = metrics_mod.summarize(done, 2.0)
    assert rep.n == 2 and rep.hedged == 1 and rep.cancelled == 0
    assert check(tr.events) == []


def test_router_infeasible_deadline_degrades_to_fastest():
    """Satellite regression: an empty feasible set in mode="fpx" (nothing
    meets the deadline) degrades to the fastest effective engine — the
    win-fast rule — instead of failing or routing by quality."""
    fast = get_config("qwen2.5-1.5b")
    slow = get_config("qwen2.5-14b")
    cands = pool_candidates([("qwen2.5-14b", slow, _eps(slow), 0.0),
                             ("qwen2.5-1.5b", fast, _eps(fast), 1.0)])
    quality = lambda c: {"qwen2.5-1.5b": 0.1, "qwen2.5-14b": 0.9}[
        c.model_name]
    router = FleetRouter(cands, quality=quality)
    req = traffic.SimRequest(rid=0, cls_name="t", t_arrive=0.0,
                             prompt_len=256, max_new=8, deadline_s=1e-9)
    assert router.dispatch(req) == 1         # fastest, despite quality 0.1


# -- check_trace: the new lifecycle licenses ---------------------------------

def _ev(name, t, track, **args):
    return tr_mod.Event("instant", name, t, None, track, args, 0.0)


def test_check_trace_rejects_unlicensed_readmission():
    events = [_ev(tr_mod.REQ_ADMIT, 0.0, "queue", rid=7),
              _ev(tr_mod.REQ_ADMIT, 1.0, "queue", rid=7),
              _ev(tr_mod.REQ_FINISH, 2.0, "queue", rid=7)]
    assert any("admitted twice" in e for e in check(events))


def test_check_trace_accepts_requeue_licensed_readmission():
    events = [_ev(tr_mod.REQ_ADMIT, 0.0, "queue", rid=7),
              _ev(tr_mod.REQ_REQUEUE, 0.5, "router", rid=7, attempt=1),
              _ev(tr_mod.REQ_ADMIT, 1.0, "queue", rid=7),
              _ev(tr_mod.REQ_FINISH, 2.0, "queue", rid=7)]
    assert check(events) == []


def test_check_trace_requeue_licenses_exactly_one_extra_admit():
    events = [_ev(tr_mod.REQ_ADMIT, 0.0, "queue", rid=7),
              _ev(tr_mod.REQ_REQUEUE, 0.5, "router", rid=7, attempt=1),
              _ev(tr_mod.REQ_ADMIT, 1.0, "queue", rid=7),
              _ev(tr_mod.REQ_ADMIT, 1.5, "queue", rid=7),
              _ev(tr_mod.REQ_FINISH, 2.0, "queue", rid=7)]
    assert any("admitted 3 times" in e for e in check(events))


def test_check_trace_rejects_unlicensed_double_retirement():
    events = [_ev(tr_mod.REQ_ADMIT, 0.0, "queue", rid=7),
              _ev(tr_mod.REQ_FINISH, 1.0, "queue", rid=7),
              _ev(tr_mod.REQ_CANCEL, 1.5, "queue", rid=7)]
    assert any("retired twice" in e for e in check(events))


def test_check_trace_hedge_licenses_twin_terminals():
    events = [_ev(tr_mod.REQ_ADMIT, 0.0, "queue", rid=7),
              _ev(tr_mod.ROUTE_HEDGE, 0.5, "router", rid=7),
              _ev(tr_mod.REQ_ADMIT, 0.6, "queue", rid=7),
              _ev(tr_mod.REQ_FINISH, 1.0, "queue", rid=7),
              _ev(tr_mod.REQ_CANCEL, 1.5, "queue", rid=7,
                  hedge_loser=True)]
    assert check(events) == []
