"""FPX pipeline tests: Algorithm-1 calibration, Eq.-7 assignment, policy
plumbing (unrolled names <-> scanned arrays), and the controller."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import assign as A, calibrate as C, fpx, latency as L
from repro.data import pipeline as dp
from repro.models import transformer as T
from repro.models.modules import ExecContext


@pytest.fixture(scope="module")
def sim():
    cfg = get_config("qwen-sim-1.5b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batches = dp.calibration_batches(cfg, n=1, batch=2, seq=32)
    batches = [{k: jnp.asarray(v) for k, v in b.items()} for b in batches]
    eps = C.calibrate(params, cfg, batches)
    return cfg, params, batches, eps


def test_calibration_covers_all_linears(sim):
    cfg, params, _, eps = sim
    # 4 layers x 7 linears (qkvo + gate/up/down)
    assert len(eps) == cfg.n_layers * 7
    assert all(0.0 <= v < 1.5 for v in eps.values())


@settings(max_examples=10, deadline=None)
@given(st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_assignment_monotone_in_gamma(g1, g2):
    eps = {f"L{i}.l": float(v) for i, v in
           enumerate(np.random.default_rng(0).random(20))}
    if g1 > g2:
        g1, g2 = g2, g1
    a1 = A.assign_precision(eps, g1)
    a2 = A.assign_precision(eps, g2)
    s1 = {k for k, b in a1.items() if b == 4}
    s2 = {k for k, b in a2.items() if b == 4}
    assert s1 <= s2           # S_gamma grows monotonically


def test_assignment_picks_lowest_eps():
    eps = {"a": 0.1, "b": 0.5, "c": 0.2, "d": 0.9}
    a = A.assign_precision(eps, 0.5)
    assert a == {"a": 4, "c": 4, "b": 8, "d": 8}


def test_pinned_layers_never_fp4():
    eps = {"block.moe.router": 0.01, "lm_head": 0.01, "block.ffn.up": 0.5}
    a = A.assign_precision(eps, 1.0)
    assert a["block.moe.router"] == 8
    assert a["lm_head"] == 8


def test_avg_bits():
    assert A.avg_bits({"a": 4, "b": 8}) == 6.0
    assert abs(L.gamma_to_avg_bits(0.3) - 6.8) < 1e-9   # paper's 3B setting


def test_policy_roundtrip_scanned_vs_unrolled(sim):
    """The scanned per-segment policy arrays produce the same logits as the
    unrolled per-name assignment — the core plumbing invariant."""
    cfg, params, batches, eps = sim
    assignment = A.assign_precision(eps, 0.4)
    ctx_unrolled = ExecContext(policy=assignment, default_bits=8)
    pol = A.build_policy(cfg, assignment)
    ctx_scanned = ExecContext(policy=pol, default_bits=8)
    b = batches[0]
    lu = np.asarray(T.forward(params, cfg, b, ctx_unrolled, unroll=True))
    ls = np.asarray(T.forward(params, cfg, b, ctx_scanned, unroll=False))
    # scan-vs-unroll changes XLA fusion -> fp32 reassociation -> inputs that
    # sit exactly on quantization midpoints can flip a grid step.  Require
    # agreement in aggregate and allow a small fraction of threshold flips.
    frac_off = np.mean(~np.isclose(lu, ls, rtol=5e-3, atol=5e-3))
    assert frac_off < 0.02, frac_off
    assert np.mean(np.abs(lu - ls)) < 5e-3


@pytest.mark.parametrize("arch", ["gemma3-4b", "xlstm-1.3b", "hymba-1.5b",
                                  "dbrx-132b", "seamless-m4t-medium",
                                  "llama-3.2-vision-11b"])
def test_name_to_slot_all_archs(arch):
    """Every calibration name maps to a well-formed policy slot."""
    cfg = get_config(arch).reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b = {"tokens": jnp.ones((1, 8), jnp.int32)}
    if cfg.arch_type == "vlm":
        b["vision"] = jnp.zeros((1, cfg.vision_tokens,
                                 cfg.vision_dim or cfg.d_model))
    if cfg.arch_type == "audio":
        b["audio"] = jnp.zeros((1, cfg.audio_frames, cfg.d_model))
    eps = C.calibrate(params, cfg, [b])
    for name in eps:
        key, idx = A.name_to_slot(cfg, name)
        assert "/" in key or idx == ()
    pol = A.build_policy(cfg, A.assign_precision(eps, 0.5))
    assert pol


def test_controller_budget_selection():
    models = []
    for name in ("qwen2.5-1.5b", "qwen2.5-14b"):
        cfg = get_config(name)
        eps = {f"L{i}.l": 0.1 * (i % 5) for i in range(cfg.n_layers)}
        models.append((name, cfg, eps))
    grid = fpx.make_grid(models, gammas=(0.0, 0.5, 1.0))
    q = lambda c: {"qwen2.5-1.5b": 1.0, "qwen2.5-14b": 3.0}[c.model_name] - c.gamma
    tight = fpx.select_for_budget(grid, 0.05, q)
    loose = fpx.select_for_budget(grid, 10.0, q)
    assert tight.latency_s <= loose.latency_s
    assert loose.model_name == "qwen2.5-14b" and loose.gamma == 0.0
    front = fpx.pareto_frontier(grid, q)
    lats = [c.latency_s for c in front]
    assert lats == sorted(lats)


def test_online_selector_learns():
    cfg = get_config("qwen2.5-3b")
    eps = {f"L{i}.l": 0.1 for i in range(10)}
    grid = fpx.make_grid([("m", cfg, eps)], gammas=(0.0, 0.5, 1.0))
    sel = fpx.OnlineSelector(grid, epsilon=0.2, seed=0)
    for _ in range(300):
        i = sel.choose()
        reward = 1.0 if grid[i].gamma == 0.5 else 0.0   # true optimum
        sel.update(i, reward)
    assert sel.best().gamma == 0.5


def _slack_grid():
    cfg = get_config("qwen2.5-1.5b")
    eps = {f"L{i}.l": 0.1 for i in range(cfg.n_layers)}
    grid = fpx.make_grid([("m", cfg, eps)], gammas=(0.0, 1.0))
    return sorted(grid, key=lambda c: c.latency_s)     # [fast, slow]


def test_select_for_slack_empty_feasible_degrades_to_fastest():
    """Regression (fleet dispatch, mode="fpx"): when *nothing* meets the
    deadline the pick must degrade to the fastest effective candidate —
    the win-fast rule — never raise or route by quality."""
    fast, slow = _slack_grid()
    q = lambda c: 1.0 - c.gamma                        # quality prefers slow
    # deadline below every wait+service: feasible set is empty
    i = fpx.select_for_slack([slow, fast], 1e-12, [0.5, 0.5], q)
    assert i == 1                                      # fastest, not best-q
    # waits dominate: the *effective* fastest wins, not the raw-latency one
    i = fpx.select_for_slack([slow, fast], 1e-12, [0.0, 10.0], q)
    assert i == 0


def test_select_for_slack_duplicate_replicas_route_by_index():
    """Regression: a pool of *identical* operating points (a replicated
    static fleet) must resolve picks by index, not equality search — the
    old ``adj.index(pick)`` collapsed every pick onto replica 0, breaking
    least-loaded degradation."""
    fast, _ = _slack_grid()
    pool = [fast, fast, fast]
    q = lambda c: 1.0
    # replica 1 is least loaded and feasible: the pick must be index 1
    assert fpx.select_for_slack(pool, 10.0, [0.4, 0.1, 0.4], q) == 1
    # empty feasible set with duplicates: still the least-loaded index
    assert fpx.select_for_slack(pool, 1e-12, [0.4, 0.1, 0.4], q) == 1
    # all-equal waits tie-break deterministically to the first replica
    assert fpx.select_for_slack(pool, 10.0, [0.2, 0.2, 0.2], q) == 0
