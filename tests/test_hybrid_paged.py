"""Sliding-window & local:global hybrid stacks on the paged continuous
path, locked in by a cross-path differential harness.

The contract under test: for *every* servable config in
``src/repro/configs`` (dense uniform, uniform-windowed starcoder2-class,
local:global gemma3-class, moe), any page size, any chunk size, and both
paged-attention implementations (fused Pallas kernel in interpret mode /
jnp gather+SDPA fallback), the paged ``ContinuousEngine``'s greedy
outputs are token-identical to the contiguous-cache wave engine's — while
sliding-window layer groups hold at most ``ceil(window/page_size) + 1``
live pages regardless of decoded length, freeing out-of-window pages back
to the pool mid-flight.

Window masking itself is pinned against the direct-softmax oracle
``kernels.ref.paged_attend_ref`` (no online softmax, no shared code with
the kernel), and page accounting is property-tested under random
admit/chunk/decode/retire sequences.

Set ``REPRO_PAGED_MODES=jnp|pallas`` to restrict the sweep to one
implementation (ci.yml runs the suite once per mode).
"""
import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import (make_requests, pallas_modes, run_paged,
                      run_wave_reference, servable_smoke_configs,
                      smoke_params)
from repro.configs import REGISTRY, get_config
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref
from repro.serving.kv_cache import DUMMY_PAGE, PagedKVCache

SERVABLE = servable_smoke_configs()
WINDOWED = [(n, c) for n, c in SERVABLE if c.sliding_window]
#: one representative per windowed class for the expensive page/chunk
#: sweep: gemma3-4b (local:global) and starcoder2 (uniform window) —
#: gemma3-12b smallifies to the same 2-layer 1:1 shape as gemma3-4b and
#: still rides the cheap every-config identity test below
SWEEP = [(n, c) for n, c in WINDOWED if n != "gemma3-12b"]

#: wave-path result tokens per (config, prompts, budget), computed once
#: per session — the reference does not depend on page size / chunk size
#: / kernel impl, which is the point of the differential design
_WAVE = {}

RAGGED_LENS = (9, 14, 5)
MAX_NEW = 4


def _wave_tokens(name, cfg, lens, max_new):
    key = (name, lens, max_new)
    if key not in _WAVE:
        reqs = make_requests(cfg, lens, max_new=max_new)
        run_wave_reference(smoke_params(name), cfg, reqs)
        _WAVE[key] = [r.result_tokens for r in reqs]
    return _WAVE[key]


def _assert_identical(name, cfg, *, page_size, chunk, use_pallas,
                      lens=RAGGED_LENS, max_new=MAX_NEW):
    want = _wave_tokens(name, cfg, lens, max_new)
    reqs, eng = run_paged(smoke_params(name), cfg,
                          make_requests(cfg, lens, max_new=max_new),
                          page_size=page_size, chunk=chunk,
                          use_pallas=use_pallas)
    for w, r in zip(want, reqs):
        assert r.result_tokens is not None, (name, r.rid)
        assert np.array_equal(w, r.result_tokens), \
            (name, page_size, chunk, use_pallas, r.rid, w, r.result_tokens)
    # nothing leaked: every allocatable page is back on the free lists
    assert eng.cache.free_pages == sum(
        n - 1 for n in eng.cache._group_pages.values())


# -- the differential sweep (acceptance) -------------------------------------

@pytest.mark.parametrize("use_pallas", pallas_modes())
@pytest.mark.parametrize("name,cfg", SERVABLE, ids=[n for n, _ in SERVABLE])
def test_token_identity_every_servable_config(name, cfg, use_pallas):
    """Every servable config in src/repro/configs, paged vs contiguous."""
    _assert_identical(name, cfg, page_size=8, chunk=None,
                      use_pallas=use_pallas)


@pytest.mark.parametrize("use_pallas", pallas_modes())
@pytest.mark.parametrize("page_size,chunk",
                         [(4, None), (4, 8), (8, 8), (8, 16)])
@pytest.mark.parametrize("name,cfg", SWEEP, ids=[n for n, _ in SWEEP])
def test_windowed_page_and_chunk_size_sweep(name, cfg, page_size, chunk,
                                            use_pallas):
    """gemma3-class and starcoder2-class stacks across page sizes and
    chunk sizes — including windows that do not divide the page size,
    chunks larger than the window, and ragged prompts longer than the
    window."""
    _assert_identical(name, cfg, page_size=page_size, chunk=chunk,
                      use_pallas=use_pallas, lens=(13, 22, 5), max_new=6)


@pytest.mark.parametrize("use_pallas", pallas_modes())
def test_local_global_tail_segment(use_pallas):
    """A local:global depth that does not divide into whole superblocks
    leaves a windowed *tail* segment (full-scale gemma3 has one; the
    smallified configs happen not to) — the tail must route through its
    own window-group tables like any other segment."""
    import dataclasses

    import jax

    from repro.models import transformer

    cfg = dataclasses.replace(dict(SERVABLE)["gemma3-4b"], n_layers=3,
                              name="gemma3-tail-smoke")
    groups = {g.name: g for g in transformer.paged_layer_groups(cfg)}
    assert set(groups) == {"local", "global", "tail"}
    assert groups["tail"].window == cfg.sliding_window
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    lens, max_new = (13, 22, 5), 6
    wave = make_requests(cfg, lens, max_new=max_new)
    run_wave_reference(params, cfg, wave)
    for chunk in (None, 8):
        reqs, _ = run_paged(params, cfg,
                            make_requests(cfg, lens, max_new=max_new),
                            page_size=4, chunk=chunk,
                            use_pallas=use_pallas)
        for w, r in zip(wave, reqs):
            assert np.array_equal(w.result_tokens, r.result_tokens), \
                (chunk, r.rid)


def test_window_live_page_bound_and_midflight_frees():
    """Acceptance: decoding far past the window keeps every window
    group's live page count at <= ceil(window/page_size) + 1, and the
    freed pages are visible on the pool's free list *mid-flight* (not
    only at retirement)."""
    name, cfg = WINDOWED[0]
    ps = 4
    reqs = make_requests(cfg, (9,), max_new=40)
    params = smoke_params(name)

    from repro.models.modules import ExecContext
    from repro.serving.paged_engine import ContinuousEngine

    eng = ContinuousEngine(params, cfg, slots=1, page_size=ps, max_ctx=64,
                           policy="serve", ctx=ExecContext())
    for r in reqs:
        eng.submit(r)
    seen, free_during = [], []
    orig = eng._decode_step

    def instrumented():
        orig()
        for g in eng.cache.groups:
            if g.window is not None:
                seen.append(eng.cache.live_pages(0, g.name))
        free_during.append(eng.cache.free_pages)
    eng._decode_step = instrumented
    eng.run()

    cap = math.ceil(cfg.sliding_window / ps) + 1
    assert seen and max(seen) <= cap, (max(seen), cap)
    # pages came back to the pool while the request was still decoding
    assert max(free_during[:-1]) > min(free_during[:-1])


def test_windowed_admission_sized_by_window_not_context():
    """A pool far too small for the request's total token count still
    admits it when every layer group is windowed: peak demand is the
    window cap, not the context."""
    name, cfg = next((n, c) for n, c in WINDOWED if not c.local_global_ratio)
    ps = 8
    cap = math.ceil(cfg.sliding_window / ps) + 1
    reqs = make_requests(cfg, (9,), max_new=50)          # ~58 positions
    assert math.ceil(58 / ps) > cap + 1                  # dense could not fit
    reqs, eng = run_paged(smoke_params(name), cfg, reqs, page_size=ps,
                          n_pages=cap + 1)               # window demand only
    assert not reqs[0].dropped and reqs[0].tokens_done == 50
    # identity vs an ample-pool run of the same engine flavor
    ample, _ = run_paged(smoke_params(name), cfg,
                         make_requests(cfg, (9,), max_new=50), page_size=ps)
    assert np.array_equal(ample[0].result_tokens, reqs[0].result_tokens)


# -- window masking vs the direct-softmax oracle -----------------------------

def _oracle_case(rng, *, n_pages, ps, Hkv, G, D, B, P, Sq, pos):
    H = Hkv * G
    kpool = jnp.asarray(rng.normal(size=(n_pages, ps, Hkv, D))
                        .astype(np.float32))
    vpool = jnp.asarray(rng.normal(size=(n_pages, ps, Hkv, D))
                        .astype(np.float32))
    ids = rng.permutation(np.arange(1, n_pages))[:B * P]
    if len(ids) < B * P:
        ids = rng.integers(1, n_pages, B * P)
    bt = jnp.asarray(np.asarray(ids).reshape(B, P).astype(np.int32))
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)).astype(np.float32))
    return q, kpool, vpool, bt, jnp.asarray(np.asarray(pos, np.int32))


@pytest.mark.parametrize("use_pallas", pallas_modes())
def test_window_masking_matches_oracle(use_pallas):
    """Decode and chunk shapes across window sizes — including windows
    smaller than a page, spanning several pages, and larger than the
    whole context — against the direct-softmax oracle."""
    rng = np.random.default_rng(0)
    for Sq, pos in ((1, [5, 13]), (1, [0, 15]), (4, [0, 8]), (6, [2, 9])):
        q, kp, vp, bt, posj = _oracle_case(rng, n_pages=12, ps=4, Hkv=2,
                                           G=2, D=8, B=2, P=4, Sq=Sq,
                                           pos=pos)
        scale = q.shape[-1] ** -0.5
        for window in (1, 3, 4, 7, 100):
            want = np.asarray(kernel_ref.paged_attend_ref(
                q, kp, vp, bt, posj, scale, window=window))
            got = np.asarray(kernel_ops.paged_attend(
                q, kp, vp, bt, posj, scale=scale, use_pallas=use_pallas,
                window=window))
            np.testing.assert_allclose(got, want, atol=1e-5,
                                       err_msg=f"Sq={Sq} W={window}")
            assert np.isfinite(got).all()


@pytest.mark.parametrize("use_pallas", pallas_modes())
def test_window_masking_excludes_stale_pages(use_pallas):
    """Clobbering a page that lies entirely under the window horizon (the
    pages kv_cache frees mid-flight) must not change the output — the
    in-kernel window mask is what makes the mid-flight free safe."""
    rng = np.random.default_rng(1)
    ps, P, W = 4, 4, 5
    q, kp, vp, bt, pos = _oracle_case(rng, n_pages=12, ps=ps, Hkv=2, G=2,
                                      D=8, B=1, P=P, Sq=1, pos=[14])
    scale = q.shape[-1] ** -0.5
    base = np.asarray(kernel_ops.paged_attend(
        q, kp, vp, bt, pos, scale=scale, use_pallas=use_pallas, window=W))
    # slots <= 14 - 5 are out of window; page 1 covers slots 4..7 < 10
    stale_page = int(np.asarray(bt)[0, 1])
    kp2 = kp.at[stale_page].set(99.0)
    vp2 = vp.at[stale_page].set(-99.0)
    pert = np.asarray(kernel_ops.paged_attend(
        q, kp2, vp2, bt, pos, scale=scale, use_pallas=use_pallas, window=W))
    np.testing.assert_array_equal(pert, base)
    # ...and pointing the stale entry at the dummy page (what the cache
    # actually does when it frees mid-flight) is equally invisible
    bt2 = np.asarray(bt).copy()
    bt2[0, 1] = DUMMY_PAGE
    dummy = np.asarray(kernel_ops.paged_attend(
        q, kp, vp, jnp.asarray(bt2), pos, scale=scale,
        use_pallas=use_pallas, window=W))
    np.testing.assert_allclose(dummy, base, atol=1e-6)


@pytest.mark.parametrize("use_pallas", pallas_modes())
def test_scatter_skip_page_suppresses_retired_destinations(use_pallas):
    """The write-side window mask: chunk pages whose table entries were
    parked on the dummy page are not written (several lanes' retired
    entries alias the same physical page — unsuppressed in-place writes
    would be order-dependent), while real destinations match the
    oracle."""
    rng = np.random.default_rng(2)
    n_pages, ps, H, D, B, C = 10, 4, 2, 8, 2, 12
    pool = jnp.asarray(rng.normal(size=(n_pages, ps, H, D))
                       .astype(np.float32))
    bt = jnp.asarray(np.array([[1, DUMMY_PAGE, 2],
                               [DUMMY_PAGE, 3, 4]], np.int32))
    pos = jnp.asarray(np.zeros(2, np.int32))
    chunk = jnp.asarray(rng.normal(size=(B, C, H, D)).astype(np.float32))
    got = kernel_ops.scatter_chunk(pool, bt, pos, chunk,
                                   use_pallas=use_pallas, skip_page=0)
    want = np.asarray(kernel_ref.scatter_chunk_ref(pool, bt, pos, chunk))
    got = np.asarray(got)
    np.testing.assert_array_equal(got[0], np.asarray(pool)[0])  # suppressed
    for page in (1, 2, 3, 4):                                   # written
        np.testing.assert_allclose(got[page], want[page])


# -- page-accounting property test -------------------------------------------

def _check_invariants(cache):
    """The pool-soundness invariants after any operation sequence."""
    for g in cache.groups:
        n_pg = cache._group_pages[g.name]
        free = cache._free[g.name]
        owned_all = [p for s in range(cache.slots)
                     for p in cache._owned[g.name][s].values()]
        # no page leaked, none double-freed / double-owned
        assert len(free) == len(set(free)), g.name
        assert len(owned_all) == len(set(owned_all)), g.name
        assert not set(free) & set(owned_all), g.name
        assert set(free) | set(owned_all) == set(range(1, n_pg)), g.name
        assert DUMMY_PAGE not in owned_all
        # live block tables reference only owned pages (or the dummy)
        for s in range(cache.slots):
            owned = cache._owned[g.name][s]
            row = cache.block_tables[g.name][s]
            live = {j: p for j, p in enumerate(row) if p != DUMMY_PAGE}
            assert live == owned, (g.name, s, live, owned)
        # reservations never over-commit the pool
        assert cache.available(g) >= 0, g.name


def _zero_prefill_kv(cfg, cache, S):
    """A synthetic raw-prefill K/V pytree of the right per-group shapes
    (the property test exercises page accounting, not numerics)."""
    return {g.name: {"k": jnp.zeros((len(g.layers), S, cfg.n_kv_heads,
                                     cfg.head_dim)),
                     "v": jnp.zeros((len(g.layers), S, cfg.n_kv_heads,
                                     cfg.head_dim))}
            for g in cache.groups}


@settings(max_examples=25)
@given(st.integers(min_value=0, max_value=10_000))
def test_page_accounting_property(seed):
    """Random admit / prefill (monolithic and chunked) / decode / retire
    sequences — with mid-flight window frees — never leak, double-free,
    or dangle a page, and window groups respect their live-page cap
    during decode.  Ops follow the engine's contract: monolithic prompts
    land via ``write_prefill``, chunk advances never exceed the admitted
    chunk size, decode advances one position with its write page prepared
    first (what ``decode_cache`` does for live lanes)."""
    rng = np.random.default_rng(seed)
    cfg = get_config(("gemma3-4b", "starcoder2-15b", "gemma3-12b")
                     [seed % 3]).reduced()
    ps = int(rng.choice([3, 4, 8]))          # odd page size on purpose
    max_ctx = 48
    cache = PagedKVCache(cfg, slots=3, n_pages=int(rng.integers(4, 20)),
                         page_size=ps, max_ctx=max_ctx)
    # slot -> [total positions, prompt len, chunk or None, absorbed]
    live = {}
    for _ in range(60):
        op = rng.integers(0, 4)
        if op == 0 and len(live) < cache.slots:          # admit
            slot = next(s for s in range(cache.slots) if s not in live)
            total = int(rng.integers(2, max_ctx + 1))
            prompt = int(rng.integers(1, total + 1))
            chunk = None if rng.integers(0, 2) else ps * int(
                rng.integers(1, 3))
            if cache.can_admit(total, chunk):
                cache.alloc(slot, total, chunk)
                if chunk is None:                        # monolithic
                    cache.write_prefill(
                        slot, _zero_prefill_kv(cfg, cache, prompt))
                    live[slot] = [total, prompt, chunk, prompt]
                else:
                    live[slot] = [total, prompt, chunk, 0]
        elif op == 1 and live:                           # prefill chunk
            slot = int(rng.choice(list(live)))
            total, prompt, chunk, done = live[slot]
            if chunk is not None and done < prompt:
                c = min(chunk, prompt - done)
                cache.prepare_tokens(slot, c)
                cache.advance(slot, c)
                live[slot][3] += c
        elif op == 2 and live:                           # decode one token
            slot = int(rng.choice(list(live)))
            total, prompt, chunk, done = live[slot]
            if done >= prompt and done < total:
                cache.prepare_tokens(slot, 1)
                cache.advance(slot, 1)
                live[slot][3] += 1
                # the decode-steady window bound (acceptance)
                for g in cache.groups:
                    cap = cache.win_cap(g)
                    if cap is not None:
                        assert cache.live_pages(slot, g.name) <= cap
        elif op == 3 and live:                           # retire
            slot = int(rng.choice(list(live)))
            cache.free(slot)
            del live[slot]
        _check_invariants(cache)
    for slot in list(live):
        cache.free(slot)
    _check_invariants(cache)
    assert cache.free_pages == sum(n - 1
                                   for n in cache._group_pages.values())
    assert cache.utilization() == pytest.approx(0.0)


# -- reduced() paged invariants (the smallify fix) ---------------------------

def test_reduced_configs_keep_paged_window_invariants():
    """``ModelConfig.reduced()`` must hand the paged path a sane window:
    never larger than the original, never below 1 — for every config in
    the registry — and the window-group page math must hold for page
    sizes that do not divide the window (there is no divisibility
    requirement)."""
    from repro.models.transformer import paged_layer_groups, paged_supported

    for name, cfg in sorted(REGISTRY.items()):
        red = cfg.reduced()
        if cfg.sliding_window:
            assert red.sliding_window is not None
            assert 1 <= red.sliding_window <= cfg.sliding_window, name
        if not paged_supported(red):
            continue
        for ps in (3, 5, 8, 16):
            cache = PagedKVCache(red, slots=2, n_pages=8, page_size=ps,
                                 max_ctx=32)
            for g in cache.groups:
                cap = cache.win_cap(g)
                if g.window is not None:
                    assert 1 <= cap <= cache.table_width, (name, ps, cap)
                    # the cap always covers the whole window (clamped to
                    # the table): no page size strands in-window slots
                    assert cap >= min(math.ceil(g.window / ps),
                                      cache.table_width), (name, ps)


def test_reduced_never_grows_a_tiny_window():
    """A config whose real window is already below the smoke default must
    keep it (growing the window would change what the smoke model
    attends to vs. its full-scale counterpart)."""
    import dataclasses

    tiny = dataclasses.replace(get_config("starcoder2-15b"),
                               sliding_window=3)
    assert tiny.reduced().sliding_window == 3
    assert get_config("starcoder2-15b").reduced().sliding_window == 8
