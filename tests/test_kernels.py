"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels import ops, ref
from repro.kernels.fp8_matmul import fp8_matmul
from repro.kernels.fpx_matmul import fpx_matmul


def _rand(shape, seed, scale=0.3):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (128, 256, 128),
                                   (256, 128, 256)])
def test_fp8_kernel_matches_ref(M, K, N):
    x, w = _rand((M, K), 0), _rand((K, N), 1, 0.05)
    xq, wq = quant.quantize(x, 8), quant.quantize(w, 8)
    got = fp8_matmul(xq.data, wq.data, xq.scale, wq.scale)
    want = ref.fp8_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (128, 256, 256)])
def test_fpx_kernel_matches_ref(M, K, N):
    x, w = _rand((M, K), 2), _rand((K, N), 3, 0.05)
    xq = quant.quantize(x, 8)
    wq = quant.quantize(w, 4)
    got = fpx_matmul(xq.data, wq.data, xq.scale, wq.scale)
    want = ref.fp4_matmul_ref(x, w, x_bits=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape,xb,wb", list(itertools.product(
    [(8, 96, 200), (130, 260, 120), (1, 48, 48)], [4, 8, 16], [4, 8])))
def test_ops_quant_matmul_sweep(shape, xb, wb):
    """The jit wrapper (pad/unpad + dispatch) matches Eq. 2 exactly."""
    M, K, N = shape
    x, w = _rand((M, K), M + K), _rand((K, N), N, 0.05)
    got = ops.quant_matmul(x, w, x_bits=xb, w_bits=wb)
    want = quant.quant_matmul_ref(x, w, xb, wb)
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4 * scale)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ops_dtype_preserved(dtype):
    x = _rand((16, 64), 5).astype(dtype)
    w = _rand((64, 32), 6, 0.05)
    out = ops.quant_matmul(x, w, x_bits=8, w_bits=4)
    assert out.dtype == dtype
    assert out.shape == (16, 32)


def test_ops_batched_leading_dims():
    x = _rand((2, 3, 64), 7)
    w = _rand((64, 32), 8, 0.05)
    out = ops.quant_matmul(x, w, x_bits=8, w_bits=8)
    assert out.shape == (2, 3, 32)
    flat = ops.quant_matmul(x.reshape(6, 64), w, x_bits=8, w_bits=8)
    np.testing.assert_allclose(np.asarray(out).reshape(6, 32),
                               np.asarray(flat), rtol=1e-5)


def test_quant_linear_pallas_path_matches_jnp_path():
    """modules.quant_linear(use_pallas=True) == the jnp fallback."""
    from repro.models import modules
    key = jax.random.PRNGKey(0)
    p = modules.linear_init(key, 64, 48)
    x = _rand((4, 10, 64), 9)
    ctx_j = modules.ExecContext(default_bits=4)
    ctx_p = modules.ExecContext(default_bits=4, use_pallas=True)
    yj = modules.quant_linear(p, x, name="l", ctx=ctx_j)
    yp = modules.quant_linear(p, x, name="l", ctx=ctx_p)
    np.testing.assert_allclose(np.asarray(yj), np.asarray(yp),
                               rtol=1e-4, atol=1e-4)
