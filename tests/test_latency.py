"""Analytic TPU latency model invariants (paper Table 4 structure)."""
import pytest

from repro.configs import QWEN_FULL, get_config
from repro.core import latency as L


@pytest.mark.parametrize("name", sorted(QWEN_FULL))
def test_ladder_ordering(name):
    cfg = QWEN_FULL[name]
    lad = L.quant_ladder(cfg)
    assert lad["FP4"] < lad["FP8"] < lad["FP16"]
    assert lad["W4A16(int)"] > lad["FP8"]       # dequant overhead (Table 4)


def test_bigger_model_slower():
    t = [L.decision_latency(QWEN_FULL[n], w_bits=8)
         for n in ("qwen2.5-1.5b", "qwen2.5-3b", "qwen2.5-7b", "qwen2.5-14b")]
    assert t == sorted(t)


def test_fractional_bits_interpolate():
    cfg = QWEN_FULL["qwen2.5-7b"]
    t4 = L.decision_latency(cfg, w_bits=4)
    t8 = L.decision_latency(cfg, w_bits=8)
    t6 = L.decision_latency(cfg, w_bits=6)
    assert t4 < t6 < t8
    assert abs(t6 - 0.5 * (t4 + t8)) < 1e-3 * t8


def test_gamma_monotone_latency():
    cfg = QWEN_FULL["qwen2.5-14b"]
    ts = [L.decision_latency(cfg, w_bits=L.gamma_to_avg_bits(g))
          for g in (0.0, 0.25, 0.5, 0.75, 1.0)]
    assert all(a > b for a, b in zip(ts, ts[1:]))


def test_ratios_match_paper_regime():
    """FP8 ~ 0.45-0.65x FP16; FP4 ~ 0.2-0.45x FP16 (paper Table 4 ratios)."""
    for cfg in QWEN_FULL.values():
        lad = L.quant_ladder(cfg)
        assert 0.40 < lad["FP8"] / lad["FP16"] < 0.65
        assert 0.15 < lad["FP4"] / lad["FP16"] < 0.45


def test_sliding_window_bounds_decode_context():
    sc = get_config("starcoder2-15b")
    t_short = L.step_latency(sc, n_tokens=1, context=4096, w_bits=16)
    t_long = L.step_latency(sc, n_tokens=1, context=500_000, w_bits=16)
    # all layers windowed at 4096: long context costs the same
    assert abs(t_long - t_short) / t_short < 0.01


def test_multichip_scales():
    cfg = QWEN_FULL["qwen2.5-14b"]
    t1 = L.decision_latency(cfg, w_bits=8, hw=L.Hardware(n_chips=1))
    t8 = L.decision_latency(cfg, w_bits=8, hw=L.Hardware(n_chips=8))
    assert t8 < t1
