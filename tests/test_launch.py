"""Launch layer: the serve.py entry point over all three serving paths,
dryrun hardening (cost_analysis drift, mesh override), and the simulated
mesh helpers.

These are the import-and-smoke tests the launch scripts never had — both
had drifted against the serving stack without CI noticing (serve.py's
always-true gamma gate, dryrun's `cost.get` on a list).
"""
import jax
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.launch import serve as serve_mod
from repro.launch.mesh import make_host_mesh, sim_device_count, sim_mesh


needs_mesh = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs a simulated multi-device mesh (set XLA_FLAGS="
           "--xla_force_host_platform_device_count=8 before jax imports)")


# -- mesh helpers -----------------------------------------------------------

def test_sim_mesh_degrades_to_none():
    assert sim_device_count() == jax.device_count()
    assert sim_mesh(1) is None                    # tp=1 is not a mesh
    assert sim_mesh(jax.device_count() + 1) is None


def test_host_mesh_axes():
    mesh = make_host_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["model"] == 1


@needs_mesh
def test_sim_mesh_shape():
    mesh = sim_mesh(2)
    assert mesh is not None
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["model"] == 2


# -- serve.py ---------------------------------------------------------------

def test_serve_argparser_defaults():
    args = serve_mod.build_argparser().parse_args([])
    # --gamma omitted means FP16 baseline; the drifted launcher's default
    # of 0.0 passed an always-true `>= 0.0` gate and quantized everything
    assert args.gamma is None
    assert args.path == "wave"
    assert args.deadline_ms is None


SMOKE = ["--arch", "qwen-sim-1.5b", "--requests", "2",
         "--prompt-len", "8", "--max-new", "2", "--batch-slots", "2"]


def test_serve_wave_smoke(capsys):
    assert serve_mod.main(SMOKE) == 0
    assert "served 2/2 requests" in capsys.readouterr().out


def test_serve_paged_smoke_with_deadline(capsys):
    assert serve_mod.main(SMOKE + ["--path", "paged",
                                   "--deadline-ms", "2000"]) == 0
    out = capsys.readouterr().out
    assert "served" in out and "met deadline" in out


def test_serve_paged_gamma_runs_assignment(capsys):
    assert serve_mod.main(SMOKE + ["--path", "paged",
                                   "--gamma", "0.5"]) == 0
    out = capsys.readouterr().out
    # the FPX pipeline actually ran (calibrate -> assign -> avg bits)
    assert "FPX gamma=0.5" in out and "avg bits" in out


def test_serve_sharded_graceful_without_devices(capsys):
    # tp larger than any simulated mesh: exit 2 with a hint, not a crash
    assert serve_mod.main(SMOKE + ["--path", "sharded", "--tp", "64"]) == 2
    assert "xla_force_host_platform_device_count" in capsys.readouterr().out


@needs_mesh
def test_serve_sharded_smoke(capsys):
    assert serve_mod.main(SMOKE + ["--path", "sharded", "--tp", "2"]) == 0
    out = capsys.readouterr().out
    assert "sharded: tp=2" in out and "served 2/2 requests" in out


# -- dryrun.py --------------------------------------------------------------

def test_dryrun_main_skip_path(capsys, tmp_path):
    """main() end-to-end over a pair skip_reason rejects: argparse works,
    the result records the skip, exit is clean."""
    from repro.launch import dryrun as D
    out_file = tmp_path / "dryrun.jsonl"
    D.main(["--arch", "gemma-7b", "--shape", "long_500k",
            "--out", str(out_file)])
    assert "0 errors" in capsys.readouterr().err
    assert "skipped" in out_file.read_text()


def test_dryrun_run_one_normalizes_cost(monkeypatch):
    """run_one on a reduced config over the 1-device host mesh: the
    cost_analysis result is a plain dict whatever form jax returned
    (the list form drifted the launcher), memory analysis lands, and the
    explicit mesh override is respected (no 512-device force)."""
    from repro.launch import dryrun as D
    monkeypatch.setattr(D, "get_config",
                        lambda name: get_config(name).reduced())
    monkeypatch.setitem(D.INPUT_SHAPES, "tiny_train",
                        InputShape("tiny_train", 32, 4, "train"))
    res = D.run_one("gemma-7b", "tiny_train", mesh=make_host_mesh(),
                    verbose=False)
    assert "skipped" not in res and "error" not in res
    assert res["n_devices"] == 1
    assert "error" not in res["cost"]
    assert res["cost"]["flops"] is not None
    assert "error" not in res["memory"]
