"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned arch runs one forward + one train step on CPU with correct shapes
and finite outputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import transformer as T
from repro.training.optim import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step

ARCHS = sorted(ASSIGNED)


def _batch(cfg, B=2, S=16, seed=0):
    k = jax.random.PRNGKey(seed)
    b = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab)}
    if cfg.arch_type == "vlm":
        b["vision"] = jax.random.normal(
            k, (B, cfg.vision_tokens, cfg.vision_dim or cfg.d_model)) * 0.1
    if cfg.arch_type == "audio":
        b["audio"] = jax.random.normal(k, (B, cfg.audio_frames, cfg.d_model)) * 0.1
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    logits = T.forward(params, cfg, _batch(cfg, B, S))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig(total_steps=10)))
    p2, o2, m = step(params, opt, _batch(cfg))
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(jnp.isfinite(m["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full (non-reduced) config carries the assigned hyperparameters."""
    cfg = get_config(arch)
    spec = {
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == spec


def test_moe_expert_counts():
    assert get_config("dbrx-132b").n_experts == 16
    assert get_config("dbrx-132b").top_k == 4
    assert get_config("granite-moe-1b-a400m").n_experts == 32
    assert get_config("granite-moe-1b-a400m").top_k == 8


def test_hymba_ssm_state():
    assert get_config("hymba-1.5b").ssm_state == 16
