"""Expert-parallel (shard_map) MoE must match the gather formulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_host_mesh
from repro.models import moe
from repro.models.modules import ExecContext


@pytest.mark.parametrize("top_k", [1, 2])
def test_expert_parallel_matches_gather(top_k):
    key = jax.random.PRNGKey(0)
    E, d, ff = 4, 32, 64
    params = moe.moe_init(key, d, ff, E, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d)) * 0.5
    ctx = ExecContext()
    # ample capacity so the two formulations' capacity semantics
    # (global vs per-shard) never bind
    ref = moe.moe_apply(params, x, n_experts=E, top_k=top_k, kind="swiglu",
                        ctx=ctx, name="moe", capacity_factor=8.0)
    mesh = make_host_mesh()
    with mesh:
        got = moe.moe_apply_expert_parallel(
            params, x, n_experts=E, top_k=top_k, kind="swiglu", ctx=ctx,
            name="moe", capacity_factor=8.0, mesh=mesh, data_axes=("data",))
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=2e-5, atol=2e-5)


def test_expert_parallel_under_jit():
    key = jax.random.PRNGKey(2)
    E, d, ff = 4, 16, 32
    params = moe.moe_init(key, d, ff, E, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 4, d))
    mesh = make_host_mesh()
    ctx = ExecContext()
    with mesh:
        fn = jax.jit(lambda p, t: moe.moe_apply_expert_parallel(
            p, t, n_experts=E, top_k=2, kind="swiglu", ctx=ctx, name="moe",
            capacity_factor=4.0, mesh=mesh, data_axes=("data",)))
        out = fn(params, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
