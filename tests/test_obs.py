"""Observability subsystem: tracer overhead contract, exporters, the
trace-driven invariant checker, streaming metrics, and TTFT/slack
semantics across the serving paths.

The two load-bearing guarantees:

* **Zero overhead when disabled.**  A run with the default ``NullTracer``
  must be token- and clock-identical to a traced run (tracing observes,
  never perturbs) — checked on the analytic batcher and on the live paged
  engine.
* **The trace is audit-grade.**  ``check_trace`` must accept every real
  traced run and reject corrupted streams (double alloc/free, negative
  reservations, backwards clocks, double retirement) — the golden-file
  round-trip pins the Chrome export format so an exported file carries
  the same information as the in-memory stream.
"""
import itertools
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.obs import (MetricsSink, NullTracer, Reservoir, Tracer, check,
                       check_file, drift_report, from_chrome, to_chrome,
                       write_chrome)
from repro.obs import trace as tr_mod
from repro.obs.check_trace import main as check_main
from repro.serving.continuous import ContinuousBatcher, LatencyProfile
from repro.serving.fleet import FleetRouter, demo_pool, demo_quality
from repro.serving.metrics import SLOReport, request_slack, summarize
from repro.serving.paged_engine import ContinuousEngine
from repro.serving.scheduler import Request, Scheduler
from repro.serving.engine import ServingEngine
from repro.serving import traffic

CFG = get_config("qwen-sim-1.5b")
GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "golden_trace.json")


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), CFG)


def _profile():
    c = demo_pool()[0]
    return LatencyProfile(c.cfg, c.avg_bits)


def _sim_reqs(horizon=1.0, seed=0):
    return traffic.generate(traffic.scenario("mixed"), horizon, seed=seed)


def _live_reqs(n=4, seed=1, max_new=4, deadline=10.0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab, 12 + 5 * i)
                    .astype(np.int32),
                    max_new=max_new, deadline_s=deadline, t_arrive=0.001 * i)
            for i in range(n)]


# -- tracer core ------------------------------------------------------------

def test_null_tracer_is_falsy_and_inert():
    nt = NullTracer()
    assert not nt and not tr_mod.NULL
    nt.instant("x", 0.0)
    nt.span("x", 0.0, 1.0)
    nt.counter("x", 0.0, 1.0)
    assert nt.scope("eng0") is nt          # no allocation for scopes either


def test_scoped_tracer_prefixes_tracks_into_shared_stream():
    tr = Tracer()
    s = tr.scope("eng0")
    s2 = s.scope("pool")
    tr.instant("a", 0.0, track="queue")
    s.instant("b", 1.0, track="lane0")
    s2.counter("c", 2.0, 1.0)
    assert [e.track for e in tr.events] == ["queue", "eng0/lane0",
                                            "eng0/pool"]
    assert s.events is tr.events


def test_reservoir_small_stream_is_exact_and_bounded():
    r = Reservoir(k=8, seed=0)
    for x in [5.0, 1.0, 3.0]:
        r.add(x)
    assert r.percentile(50) == 3.0
    big = Reservoir(k=16, seed=0)
    for x in range(1000):
        big.add(float(x))
    assert len(big.sample) == 16 and big.n == 1000
    assert np.isnan(Reservoir().percentile(50))


# -- zero-overhead contract -------------------------------------------------

def _run_batcher(tracer, prefill_chunk):
    b = ContinuousBatcher(_profile(), slots=4, policy="degrade",
                          prefill_chunk=prefill_chunk, tracer=tracer)
    reqs = _sim_reqs()
    for r in reqs:
        b.submit(r)
    b.drain()
    return b, reqs


@pytest.mark.parametrize("prefill_chunk", [None, 64])
def test_tracing_does_not_perturb_analytic_run(prefill_chunk):
    _, untraced = _run_batcher(None, prefill_chunk)
    tr = Tracer()
    _, traced = _run_batcher(tr, prefill_chunk)
    assert len(tr.events) > 0
    by = {r.rid: r for r in untraced}
    for r in traced:
        u = by[r.rid]
        assert (r.tokens_done, r.dropped) == (u.tokens_done, u.dropped)
        assert r.t_finish == u.t_finish and r.latency_s == u.latency_s
        assert r.t_first_token == u.t_first_token


def test_tracing_does_not_perturb_paged_run(params):
    outs = []
    for tracer in (None, Tracer()):
        pe = ContinuousEngine(params, CFG, slots=2, page_size=8, max_ctx=64,
                              tracer=tracer)
        reqs = _live_reqs()
        for r in reqs:
            pe.submit(r)
        pe.run()
        outs.append(reqs)
    for u, t in zip(*outs):
        assert np.array_equal(u.result_tokens, t.result_tokens)
        assert u.t_finish == t.t_finish
        assert u.t_first_token == t.t_first_token


def test_every_real_trace_passes_the_checker(params):
    for chunk in (None, 8):
        tr = Tracer()
        pe = ContinuousEngine(params, CFG, slots=2, page_size=8, max_ctx=64,
                              prefill_chunk=chunk, tracer=tr)
        for r in _live_reqs():
            pe.submit(r)
        pe.run()
        assert check(tr.events) == [], f"chunk={chunk}"
        assert any(e.name == tr_mod.PAGE_ALLOC for e in tr.events)
        assert any(e.name == tr_mod.ENGINE_STEP for e in tr.events)


def test_fleet_trace_scopes_engines_and_passes_checker():
    tr = Tracer()
    router = FleetRouter(demo_pool(), quality=demo_quality, slots=4,
                         tracer=tr)
    out = router.run([a.fresh() for a in _sim_reqs(horizon=2.0, seed=3)])
    assert out and check(tr.events) == []
    heads = {e.track.split("/")[0] for e in tr.events}
    assert "router" in heads
    assert sum(h.startswith("eng") for h in heads) == len(demo_pool())
    retire = [e for e in tr.events if e.name == tr_mod.ROUTE_RETIRE]
    assert len(retire) == len(out)


def test_wave_scheduler_trace(params):
    tr = Tracer()
    sched = Scheduler(ServingEngine(params, CFG, max_ctx=64), batch_slots=2,
                      tracer=tr)
    rng = np.random.default_rng(0)
    for i in range(3):
        sched.submit(Request(rid=i,
                             prompt=rng.integers(0, CFG.vocab, 8)
                             .astype(np.int32),
                             max_new=2, deadline_s=5.0))
    done = sched.run()
    assert check(tr.events) == []
    waves = [e for e in tr.events if e.name == tr_mod.WAVE_STEP]
    assert len(waves) == 2                 # 2 slots -> ceil(3/2) waves
    assert waves[1].t0 == waves[0].t1      # back-to-back on the wave clock
    assert all(r.t_finish is not None for r in done)


# -- exporters --------------------------------------------------------------

def _tiny_stream():
    """A deterministic stream covering every event kind and track shape."""
    wall = itertools.count()
    tr = Tracer(wall_clock=lambda: next(wall) * 0.5)
    tr.instant(tr_mod.POOL_CONFIG, 0.0, track="pool",
               groups={"layers": 4}, page_size=8, slots=2)
    tr.instant(tr_mod.REQ_ARRIVE, 0.0, track="queue", rid=0, cls="trading",
               prompt_len=16, max_new=4, deadline_abs=None)
    tr.span(tr_mod.REQ_QUEUE, 0.0, 0.25, track="queue", rid=0)
    tr.instant(tr_mod.REQ_ADMIT, 0.25, track="lane0", rid=0, n_tok=4,
               max_new=4)
    tr.instant(tr_mod.PAGE_RESERVE, 0.25, track="pool", group="layers",
               slot=0, pages=2)
    tr.instant(tr_mod.PAGE_ALLOC, 0.25, track="pool", group="layers",
               page=1, slot=0)
    tr.span(tr_mod.ENGINE_STEP, 0.25, 0.5, track="steps", n_active=1,
            context=16, lanes=[0], wall_s=0.125)
    tr.counter(tr_mod.CTR_LANES, 0.5, 1, track="steps")
    tr.instant(tr_mod.PAGE_FREE, 0.75, track="pool", group="layers",
               page=1, slot=0, mid_flight=False)
    tr.instant(tr_mod.PAGE_RESERVE, 0.75, track="pool", group="layers",
               slot=0, pages=0)
    tr.instant(tr_mod.REQ_FINISH, 0.75, track="lane0", rid=0,
               cls="trading", latency_s=0.75, tokens=4, met_deadline=True)
    tr.instant("free.form", 1.0)           # empty track -> main/main
    return tr.events


def test_chrome_round_trip_preserves_events():
    events = _tiny_stream()
    back = from_chrome(to_chrome(events))
    assert back == events


def test_chrome_export_matches_golden_file():
    """The exported JSON is a pinned format: Perfetto-loadable, stable
    pids/tids, args intact.  Regenerate with
    ``python tests/data/make_golden_trace.py`` when the format changes —
    the diff is then a reviewable format change, not an accident."""
    got = to_chrome(_tiny_stream())
    with open(GOLDEN) as f:
        want = json.load(f)
    assert got == want


def test_chrome_file_round_trip_and_cli(tmp_path):
    events = _tiny_stream()
    path = str(tmp_path / "t.json")
    write_chrome(events, path)
    assert from_chrome(path) == events
    assert check_file(path) == []
    assert check_main([path]) == 0
    # corrupt it: drop the admission, keep the finish
    doc = json.load(open(path))
    doc["traceEvents"] = [r for r in doc["traceEvents"]
                          if r["name"] != tr_mod.REQ_ADMIT]
    bad = str(tmp_path / "bad.json")
    json.dump(doc, open(bad, "w"))
    assert check_file(bad) != []
    assert check_main([bad]) == 1


def test_chrome_process_thread_split():
    doc = to_chrome(_tiny_stream())
    names = {(r["args"]["name"]) for r in doc["traceEvents"]
             if r["ph"] == "M" and r["name"] == "process_name"}
    assert {"pool", "queue", "lane0", "steps", "main"} <= names


def test_drift_report_ratio():
    events = _tiny_stream()
    rep = drift_report(events)
    step = rep[tr_mod.ENGINE_STEP]
    assert step["n"] == 1
    assert step["modeled_s"] == pytest.approx(0.25)
    assert step["ratio"] == pytest.approx(0.125 / 0.25)
    assert rep[tr_mod.REQ_QUEUE]["ratio"] is None    # no wall_s arg


def test_drift_report_zero_modeled_span_has_no_ratio():
    """A measured span whose modeled time is zero (zero-token chunk, clock
    stub) has no finite correction factor: ratio must be None, not inf —
    inf would poison any mean over ratios and is not JSON-serializable."""
    tr = Tracer(wall_clock=lambda: 0.0)
    tr.span(tr_mod.ENGINE_STEP, 1.0, 1.0, track="steps", n_active=1,
            wall_s=0.005)
    rep = drift_report(tr.events)
    step = rep[tr_mod.ENGINE_STEP]
    assert step["modeled_s"] == 0.0
    assert step["wall_s"] == pytest.approx(0.005)
    assert step["ratio"] is None
    json.dumps(rep)                        # exportable as-is


def test_reservoir_empty_percentile_is_nan_not_inf():
    """Percentile of an empty reservoir is nan at every q (not inf, not a
    crash) — the empty-window case every percentile gauge hits at t=0."""
    r = Reservoir(k=4, seed=0)
    for q in (0, 50, 99, 100):
        assert np.isnan(r.percentile(q))
    r.add(2.0)
    assert r.percentile(99) == 2.0


# -- the invariant checker rejects corrupted streams ------------------------

def _pool_stream(*extra_args_events):
    tr = Tracer(wall_clock=lambda: 0.0)
    tr.instant(tr_mod.POOL_CONFIG, 0.0, track="pool",
               groups={"layers": 4}, page_size=8, slots=2)
    for (name, t, args) in extra_args_events:
        tr.instant(name, t, track="pool", **args)
    return tr.events


def test_checker_catches_double_alloc():
    ev = _pool_stream(
        (tr_mod.PAGE_RESERVE, 0.0, dict(group="layers", slot=0, pages=3)),
        (tr_mod.PAGE_ALLOC, 0.1, dict(group="layers", page=1, slot=0)),
        (tr_mod.PAGE_ALLOC, 0.2, dict(group="layers", page=1, slot=0)))
    assert any("double alloc" in f for f in check(ev))


def test_checker_catches_free_of_unowned_page():
    ev = _pool_stream(
        (tr_mod.PAGE_FREE, 0.1, dict(group="layers", page=2, slot=0)))
    assert any("double free" in f for f in check(ev))


def test_checker_catches_dummy_and_out_of_range_alloc():
    ev = _pool_stream(
        (tr_mod.PAGE_RESERVE, 0.0, dict(group="layers", slot=0, pages=3)),
        (tr_mod.PAGE_ALLOC, 0.1, dict(group="layers", page=0, slot=0)),
        (tr_mod.PAGE_ALLOC, 0.2, dict(group="layers", page=9, slot=0)))
    f = check(ev)
    assert any("dummy page" in x for x in f)
    assert any("out of range" in x for x in f)


def test_checker_catches_alloc_beyond_reservation():
    ev = _pool_stream(
        (tr_mod.PAGE_RESERVE, 0.0, dict(group="layers", slot=0, pages=1)),
        (tr_mod.PAGE_ALLOC, 0.1, dict(group="layers", page=1, slot=0)),
        (tr_mod.PAGE_ALLOC, 0.2, dict(group="layers", page=2, slot=0)))
    assert any("beyond its reservation" in f for f in check(ev))


def test_checker_catches_negative_reservation_accounting():
    # two slots each reserve 2 of the 3 allocatable pages: 3 - 4 < 0
    ev = _pool_stream(
        (tr_mod.PAGE_RESERVE, 0.0, dict(group="layers", slot=0, pages=2)),
        (tr_mod.PAGE_RESERVE, 0.1, dict(group="layers", slot=1, pages=2)))
    assert any("accounting negative" in f for f in check(ev))


def test_checker_catches_reservation_cleared_while_pages_live():
    ev = _pool_stream(
        (tr_mod.PAGE_RESERVE, 0.0, dict(group="layers", slot=0, pages=2)),
        (tr_mod.PAGE_ALLOC, 0.1, dict(group="layers", page=1, slot=0)),
        (tr_mod.PAGE_RESERVE, 0.2, dict(group="layers", slot=0, pages=0)))
    assert any("still live" in f for f in check(ev))


def test_checker_catches_page_leak_at_quiescence():
    ev = _pool_stream(
        (tr_mod.PAGE_RESERVE, 0.0, dict(group="layers", slot=0, pages=2)),
        (tr_mod.PAGE_ALLOC, 0.1, dict(group="layers", page=1, slot=0)))
    assert any("leak" in f for f in check(ev))


def test_checker_catches_backwards_clock_and_negative_span():
    tr = Tracer(wall_clock=lambda: 0.0)
    tr.span(tr_mod.ENGINE_STEP, 1.0, 1.5, track="steps", n_active=1)
    tr.span(tr_mod.ENGINE_STEP, 0.5, 0.9, track="steps", n_active=1)
    tr.span(tr_mod.REQ_PREFILL, 2.0, 1.0, track="steps", rid=0)
    f = check(tr.events)
    assert any("clock moved backwards" in x for x in f)
    assert any("negative-duration" in x for x in f)


def test_checker_catches_lifecycle_violations():
    tr = Tracer(wall_clock=lambda: 0.0)
    tr.instant(tr_mod.REQ_ADMIT, 0.0, track="steps", rid=1, n_tok=4)
    tr.instant(tr_mod.REQ_ADMIT, 0.1, track="steps", rid=1, n_tok=4)
    tr.instant(tr_mod.REQ_FINISH, 0.2, track="steps", rid=1)
    tr.instant(tr_mod.REQ_DROP, 0.3, track="steps", rid=1)
    tr.instant(tr_mod.REQ_FINISH, 0.4, track="steps", rid=2)
    tr.instant(tr_mod.REQ_ADMIT, 0.5, track="steps", rid=3, n_tok=4)
    f = check(tr.events)
    assert any("admitted twice" in x for x in f)
    assert any("retired twice" in x for x in f)
    assert any("finished without admission" in x for x in f)
    assert any("admitted but never retired" in x for x in f)


# -- TTFT / slack semantics -------------------------------------------------

def test_paged_ttft_is_prefill_done_analytic_is_first_step(params):
    pe = ContinuousEngine(params, CFG, slots=1, page_size=8, max_ctx=64)
    r = _live_reqs(n=1)[0]
    pe.submit(r)
    pe.run()
    # live engine: first token sampled from the prefill logits
    assert r.t_first_token == r.t_prefill_done
    s = request_slack(r)
    assert s["ttft_s"] == pytest.approx(r.t_first_token - r.t_arrive)
    assert s["decode_s"] == pytest.approx(r.t_finish - r.t_prefill_done)
    assert s["itl_s"] == pytest.approx(
        (r.t_finish - r.t_first_token) / (r.tokens_done - 1))

    b = ContinuousBatcher(_profile(), slots=1, policy="serve")
    sr = traffic.SimRequest(rid=0, cls_name="chat", t_arrive=0.0,
                            prompt_len=32, max_new=4, deadline_s=10.0)
    b.submit(sr)
    b.drain()
    # analytic clock models no prefill token: TTFT lands one step later
    assert sr.t_first_token > sr.t_prefill_done
    assert sr.t_first_token == pytest.approx(
        sr.t_prefill_done + b.profile.step_s(1, sr.prompt_len))


def test_summarize_reports_streaming_slos():
    b = ContinuousBatcher(_profile(), slots=4, policy="degrade")
    reqs = _sim_reqs()
    for r in reqs:
        b.submit(r)
    b.drain()
    rep = summarize(reqs, 1.0)
    assert np.isfinite(rep.ttft_p50_s) and np.isfinite(rep.ttft_p99_s)
    assert np.isfinite(rep.itl_p50_s)
    assert rep.ttft_p50_s <= rep.ttft_p99_s
    assert rep.queue_s >= 0 and rep.prefill_s > 0 and rep.decode_s > 0
    assert rep.per_class and set(rep.per_class) == {"chat", "trading"}


# -- SLOReport presentation split ------------------------------------------

def test_row_is_numeric_format_row_is_historical_strings():
    rep = SLOReport(n=10, served=8, dropped=2, degraded=1, hit_rate=0.8,
                    p50_s=0.0123, p99_s=0.0456, goodput=7.25,
                    goodput_rate=0.3625)
    assert rep.row() == [10, 8, 2, 0.8, 12.3, pytest.approx(45.6), 7.25]
    assert all(isinstance(x, (int, float)) for x in rep.row())
    assert rep.format_row() == [10, 8, 2, "0.800", "12.3", "45.6", "7.2"]
    srow = rep.streaming_row()
    assert len(srow) == 7 and all(np.isnan(x) for x in srow[:4])


# -- streaming sink vs. batch summarize ------------------------------------

def test_metrics_sink_agrees_with_summarize():
    tr = Tracer()
    sink = MetricsSink()
    tr.add_sink(sink)
    router = FleetRouter(demo_pool(), quality=demo_quality, slots=4,
                         tracer=tr)
    out = router.run([a.fresh() for a in _sim_reqs(horizon=2.0, seed=1)])
    batch = summarize(out, 2.0)
    live = sink.report(2.0)
    assert (live.n, live.served, live.dropped) == \
        (batch.n, batch.served, batch.dropped)
    assert live.degraded == batch.degraded
    assert live.hit_rate == pytest.approx(batch.hit_rate)
    assert live.goodput == pytest.approx(batch.goodput)
    # reservoirs unsaturated at this size -> percentiles are exact
    assert live.p50_s == pytest.approx(batch.p50_s)
    assert live.ttft_p50_s == pytest.approx(batch.ttft_p50_s)
    assert live.itl_p99_s == pytest.approx(batch.itl_p99_s)
    assert live.queue_s == pytest.approx(batch.queue_s)
    assert set(live.per_class) == set(batch.per_class)
    for nm, sub in live.per_class.items():
        assert sub.goodput == pytest.approx(batch.per_class[nm].goodput)


def test_drop_events_reach_sink():
    tr = Tracer()
    sink = MetricsSink()
    tr.add_sink(sink)
    # one slot + impossible deadlines under load -> drops guaranteed
    b = ContinuousBatcher(_profile(), slots=1, policy="drop", tracer=tr)
    for r in _sim_reqs(horizon=2.0, seed=2):
        r.deadline_s = min(r.deadline_s, 0.002)
        b.submit(r)
    b.drain()
    assert b.dropped
    rep = sink.report(2.0)
    assert rep.dropped == len(b.dropped)
    assert check(tr.events) == []
