"""Fused paged flash-attention: the kernel vs the pure-jnp oracle across
page-table shapes (page counts, non-full last pages, mixed per-lane
lengths, dummy-page idle lanes, chunk sizes), agreement between the jnp
gather+SDPA fallback and the oracle, and an engine-level token-identity
regression of the fused kernel against the gather+SDPA path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref
from repro.models import transformer as T
from repro.models.modules import ExecContext
from repro.serving.paged_engine import ContinuousEngine
from repro.serving.scheduler import Request


CFG = get_config("qwen-sim-1.5b")


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), CFG)


def _case(rng, *, n_pages, ps, Hkv, G, D, B, P, Sq, pos):
    """Build one (q, pools, table, pos) problem with distinct real pages."""
    H = Hkv * G
    kpool = jnp.asarray(rng.normal(size=(n_pages, ps, Hkv, D))
                        .astype(np.float32))
    vpool = jnp.asarray(rng.normal(size=(n_pages, ps, Hkv, D))
                        .astype(np.float32))
    ids = rng.permutation(np.arange(1, n_pages))[:B * P]
    if len(ids) < B * P:                       # small pools: allow reuse
        ids = rng.integers(1, n_pages, B * P)
    bt = jnp.asarray(np.asarray(ids).reshape(B, P).astype(np.int32))
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)).astype(np.float32))
    return q, kpool, vpool, bt, jnp.asarray(np.asarray(pos, np.int32))


def _check(q, kpool, vpool, bt, pos, *, atol=1e-5):
    scale = q.shape[-1] ** -0.5
    want = np.asarray(kernel_ref.paged_attend_ref(q, kpool, vpool, bt, pos,
                                                  scale))
    got_pallas = np.asarray(kernel_ops.paged_attend(
        q, kpool, vpool, bt, pos, scale=scale, use_pallas=True))
    got_jnp = np.asarray(kernel_ops.paged_attend(
        q, kpool, vpool, bt, pos, scale=scale, use_pallas=False))
    np.testing.assert_allclose(got_pallas, want, atol=atol)
    np.testing.assert_allclose(got_jnp, want, atol=atol)
    assert np.isfinite(got_pallas).all() and np.isfinite(got_jnp).all()


# -- kernel vs oracle sweeps -------------------------------------------------

def test_decode_sweep_page_counts_and_gqa():
    """Decode (Sq=1) across pool sizes, table widths, GQA group sizes."""
    rng = np.random.default_rng(0)
    for n_pages, ps, Hkv, G, D, B, P in ((6, 4, 2, 2, 8, 2, 3),
                                         (9, 8, 1, 4, 16, 3, 2),
                                         (17, 4, 2, 1, 8, 4, 4),
                                         (5, 16, 2, 3, 8, 1, 1)):
        pos = rng.integers(0, P * ps, B)
        q, kp, vp, bt, pos = _case(rng, n_pages=n_pages, ps=ps, Hkv=Hkv,
                                   G=G, D=D, B=B, P=P, Sq=1, pos=pos)
        _check(q, kp, vp, bt, pos)


def test_decode_non_full_last_page_and_mixed_lengths():
    """Per-lane positions deliberately mid-page and wildly mixed: lane 0 at
    slot 0 of page 0, others partway into later pages."""
    rng = np.random.default_rng(1)
    ps, P = 8, 4
    pos = [0, 3, ps * P - 1, ps * 2 + 5]       # mixed, none page-aligned
    q, kp, vp, bt, pos = _case(rng, n_pages=20, ps=ps, Hkv=2, G=2, D=8,
                               B=4, P=P, Sq=1, pos=pos)
    _check(q, kp, vp, bt, pos)


def test_decode_dummy_page_idle_lanes():
    """Idle lanes: whole table at the reserved dummy page, pos 0 — output
    must be finite (it is discarded), live lanes must match the oracle."""
    rng = np.random.default_rng(2)
    ps, P, B = 4, 3, 3
    q, kp, vp, bt, pos = _case(rng, n_pages=10, ps=ps, Hkv=2, G=2, D=8,
                               B=B, P=P, Sq=1, pos=[5, 0, 0])
    bt = np.array(bt)
    bt[1:, :] = 0                              # lanes 1, 2 idle
    bt = jnp.asarray(bt)
    _check(q, kp, vp, bt, pos)                 # oracle covers idle rows too


def test_chunk_sweep_sizes_and_offsets():
    """Prefill chunks: several chunk sizes, including chunks spanning
    multiple pages, starting page-aligned and mid-table."""
    rng = np.random.default_rng(3)
    for ps, P, Sq, pos in ((4, 4, 4, [0, 8]),       # exactly one page
                           (4, 4, 8, [0, 4]),       # two pages
                           (8, 3, 5, [8, 3]),       # partial, odd start
                           (4, 6, 12, [4, 8])):     # three pages
        q, kp, vp, bt, pos = _case(rng, n_pages=26, ps=ps, Hkv=2, G=2, D=8,
                                   B=2, P=P, Sq=Sq, pos=pos)
        _check(q, kp, vp, bt, pos)


def test_chunk_causality_within_chunk():
    """Row i of a chunk must see exactly slots <= pos + i: perturbing a
    *future* slot's K/V must not change row i's output."""
    rng = np.random.default_rng(4)
    ps, P, Sq = 4, 3, 6
    q, kp, vp, bt, pos = _case(rng, n_pages=12, ps=ps, Hkv=2, G=2, D=8,
                               B=1, P=P, Sq=Sq, pos=[2])
    scale = q.shape[-1] ** -0.5
    base = np.asarray(kernel_ops.paged_attend(q, kp, vp, bt, pos,
                                              scale=scale, use_pallas=True))
    # clobber the slot just past the *middle* query row's horizon
    row = 2
    future = int(np.asarray(pos)[0]) + row + 1
    page, within = np.asarray(bt)[0, future // ps], future % ps
    kp2 = kp.at[page, within].set(99.0)
    vp2 = vp.at[page, within].set(99.0)
    pert = np.asarray(kernel_ops.paged_attend(q, kp2, vp2, bt, pos,
                                              scale=scale, use_pallas=True))
    np.testing.assert_allclose(pert[0, :row + 1], base[0, :row + 1],
                               atol=1e-6)      # past rows untouched
    assert not np.allclose(pert[0, row + 1:], base[0, row + 1:])


def test_fallback_matches_historical_gather_sdpa():
    """The jnp fallback must reproduce the exact gather+SDPA composition it
    replaced (single fused take aside): gather via ops.gather_pages, then
    attention._sdpa with the slot <= pos + row mask."""
    from repro.models.attention import _sdpa

    rng = np.random.default_rng(5)
    ps, P, B, Sq = 4, 3, 2, 4
    q, kp, vp, bt, pos = _case(rng, n_pages=12, ps=ps, Hkv=2, G=2, D=8,
                               B=B, P=P, Sq=Sq, pos=[0, 4])
    scale = q.shape[-1] ** -0.5
    ck = kernel_ops.gather_pages(kp, bt)
    cv = kernel_ops.gather_pages(vp, bt)
    slot = jnp.arange(P * ps)
    qpos = pos[:, None] + jnp.arange(Sq)[None, :]
    mask = (slot[None, None, :] <= qpos[:, :, None])[:, None]
    want = _sdpa(q, ck, cv, jnp.broadcast_to(mask, (B, 1, Sq, P * ps)),
                 scale)
    got = kernel_ops.paged_attend(q, kp, vp, bt, pos, scale=scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


# -- engine-level token identity (acceptance) -------------------------------

def test_engine_tokens_identical_fused_vs_gather_sdpa(params):
    """The same greedy requests through the live engine with the fused
    Pallas kernel (``use_pallas``, interpret mode) and with the jnp
    gather+SDPA path: identical tokens for plain decode *and* chunked
    prefill — the kernel changes where bytes move, never what is
    computed."""
    rng = np.random.default_rng(6)
    lens = [12, 9, 5]
    base = [rng.integers(0, CFG.vocab, n).astype(np.int32) for n in lens]

    def run(use_pallas, chunk):
        reqs = [Request(rid=i, prompt=p.copy(), max_new=3, deadline_s=10.0)
                for i, p in enumerate(base)]
        pe = ContinuousEngine(params, CFG, slots=3, page_size=4, max_ctx=32,
                              policy="serve", prefill_chunk=chunk,
                              ctx=ExecContext(use_pallas=use_pallas))
        for r in reqs:
            pe.submit(r)
        pe.run()
        return reqs

    for chunk in (None, 4):
        ref_run = run(False, chunk)
        fused = run(True, chunk)
        for a, b in zip(ref_run, fused):
            assert np.array_equal(a.result_tokens, b.result_tokens), \
                (chunk, a.rid, a.result_tokens, b.result_tokens)
            assert b.tokens_done == b.max_new and b.met_deadline
