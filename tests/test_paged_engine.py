"""Paged KV-cache serving: block-table cache, Pallas gather, and the live
``ContinuousEngine`` — token-equivalence with the wave scheduler, mid-flight
admission with page reuse (no wave barrier), admission policies on real
compute, and fleet routing over live paged engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref
from repro.models import transformer as T
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import DUMMY_PAGE, PagedKVCache
from repro.serving.paged_engine import ContinuousEngine
from repro.serving.scheduler import Request, Scheduler
from repro.serving.traffic import SimRequest


CFG = get_config("qwen-sim-1.5b")


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), CFG)


def _prompts(lens, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab, n).astype(np.int32) for n in lens]


def _reqs(prompts, *, max_new=4, deadline=10.0, arrive=0.0):
    return [Request(rid=i, prompt=p.copy(), max_new=max_new,
                    deadline_s=deadline, t_arrive=arrive)
            for i, p in enumerate(prompts)]


# -- gather kernel ----------------------------------------------------------

def test_paged_gather_kernel_matches_ref():
    rng = np.random.default_rng(0)
    for n_pages, ps, H, D, B, P in ((6, 4, 2, 8, 2, 3), (9, 8, 1, 16, 3, 2)):
        pool = jnp.asarray(rng.normal(size=(n_pages, ps, H, D))
                           .astype(np.float32))
        bt = jnp.asarray(rng.integers(0, n_pages, (B, P)).astype(np.int32))
        got = kernel_ops.gather_pages(pool, bt, use_pallas=True)
        ref = kernel_ref.gather_pages_ref(pool, bt).reshape(B, P * ps, H, D)
        assert np.array_equal(np.asarray(got), np.asarray(ref))
        jnp_path = kernel_ops.gather_pages(pool, bt, use_pallas=False)
        assert np.array_equal(np.asarray(got), np.asarray(jnp_path))


# -- page accounting --------------------------------------------------------

def test_kv_cache_alloc_free_accounting():
    cache = PagedKVCache(CFG, slots=2, n_pages=7, page_size=8, max_ctx=32)
    assert cache.free_pages == 6 and cache.table_width == 4
    a = cache.alloc(0, 17)                     # 3 pages, all in "layers"
    a_ids = [p for _, p in a]
    assert len(a) == 3 and DUMMY_PAGE not in a_ids
    assert all(gname == "layers" for gname, _ in a)
    assert cache.free_pages == 3
    assert list(cache.block_tables["layers"][0, :3]) == a_ids
    assert all(cache.block_tables["layers"][0, 3:] == DUMMY_PAGE)
    assert cache.utilization() == pytest.approx(0.5)
    b = cache.alloc(1, 24)                     # 3 pages
    assert not (set(a) & set(b))               # disjoint ownership
    assert not cache.can_admit(8)              # pool exhausted
    freed = cache.free(0)
    assert sorted(freed) == sorted(a)
    assert cache.free_pages == 3 and cache.can_admit(24)
    assert all(cache.block_tables["layers"][0] == DUMMY_PAGE)
    assert cache.pos[0] == 0


def test_paged_decode_rejects_unsupported_arch(params):
    hcfg = get_config("hymba-1.5b")            # hybrid: ssm state per block
    with pytest.raises(NotImplementedError, match="dense/moe"):
        T.paged_decode_step({}, hcfg, {"token": jnp.zeros((1, 1), jnp.int32)},
                            {})
    with pytest.raises(NotImplementedError):
        ContinuousEngine(params, hcfg)


# -- equivalence with the wave scheduler (acceptance) -----------------------

def test_paged_engine_token_identical_to_wave_batch(params):
    """Same greedy requests, equal-length prompts: the paged engine's
    continuous decode produces token-identical outputs to one padded wave."""
    prompts = _prompts([12, 12, 12])
    sched = Scheduler(ServingEngine(params, CFG, max_ctx=64), batch_slots=4)
    wave = _reqs(prompts)
    for r in wave:
        sched.submit(r)
    sched.run()

    pe = ContinuousEngine(params, CFG, slots=4, page_size=8, max_ctx=64,
                          policy="serve")
    paged = _reqs(prompts)
    for r in paged:
        pe.submit(r)
    pe.run()
    for w, p in zip(wave, paged):
        assert np.array_equal(w.result_tokens, p.result_tokens), w.rid
        assert p.tokens_done == p.max_new and p.met_deadline


def test_paged_engine_token_identical_ragged(params):
    """Ragged prompts: compared per-request against the unpadded wave path
    (batch_slots=1), since left-padding changes what a prompt attends to."""
    prompts = _prompts([8, 20, 13])
    sched = Scheduler(ServingEngine(params, CFG, max_ctx=64), batch_slots=1)
    wave = _reqs(prompts, max_new=5)
    for r in wave:
        sched.submit(r)
    sched.run()

    pe = ContinuousEngine(params, CFG, slots=3, page_size=8, max_ctx=64,
                          policy="serve")
    paged = _reqs(prompts, max_new=5)
    for r in paged:
        pe.submit(r)
    pe.run()
    for w, p in zip(wave, paged):
        assert np.array_equal(w.result_tokens, p.result_tokens), w.rid


def test_mid_flight_retire_and_page_reuse(params):
    """The no-barrier property (acceptance): with mixed arrivals, a short
    request retires and its pages are re-allocated to a later arrival while
    the long request is still decoding."""
    prompts = _prompts([8, 20, 13])
    # pool of 8 allocatable pages: A needs 2, B needs 4, C needs 2 — C can
    # only be admitted once A's pages are back in the free list.
    pe = ContinuousEngine(params, CFG, slots=2, page_size=8, max_ctx=32,
                          n_pages=9, policy="serve")
    A = Request(rid=0, prompt=prompts[0], max_new=2, deadline_s=100.0)
    B = Request(rid=1, prompt=prompts[1], max_new=12, deadline_s=100.0)
    C = Request(rid=2, prompt=prompts[2], max_new=2, deadline_s=100.0,
                t_arrive=1e-6)
    for r in (A, B, C):
        pe.submit(r)
    pe.run()
    # C was admitted after A retired but strictly before B finished...
    assert A.t_finish <= C.t_admit < B.t_finish
    assert C.t_finish < B.t_finish            # ...and retired mid-flight too
    pages = {rid: set(p) for rid, p in pe.admissions}
    assert pages[2] & pages[0]                # C physically reused A's pages
    assert pe.cache.free_pages == 8           # everything returned at drain


# -- admission policies on real compute -------------------------------------

def test_paged_engine_degrade_trims_on_real_compute(params):
    full = get_config("qwen2.5-1.5b")         # real-scale latency model
    pe = ContinuousEngine(params, CFG, slots=1, page_size=8, max_ctx=128,
                          latency_cfg=full, policy="degrade")
    prefill = pe.profile.prefill_s(16)
    step = pe.profile.step_s(1, 16)
    prompts = _prompts([16])
    r = Request(rid=0, prompt=prompts[0], max_new=64,
                deadline_s=prefill + 6.5 * step)
    pe.submit(r)
    pe.run()
    assert not r.dropped and r.met_deadline
    assert 0 < r.tokens_done < 64             # trimmed, still on time
    assert len(r.result_tokens) == r.tokens_done


def test_paged_engine_drop_policy(params):
    full = get_config("qwen2.5-1.5b")
    retired = []
    pe = ContinuousEngine(params, CFG, slots=1, page_size=8, max_ctx=128,
                          latency_cfg=full, policy="drop",
                          on_retire=retired.append)
    prompts = _prompts([16, 16])
    bad = Request(rid=0, prompt=prompts[0], max_new=32, deadline_s=1e-9)
    ok = Request(rid=1, prompt=prompts[1], max_new=2, deadline_s=10.0)
    pe.submit(bad)
    pe.submit(ok)
    pe.run()
    assert bad.dropped and bad.tokens_done == 0 and bad.result_tokens is None
    assert not ok.dropped and ok.met_deadline and len(ok.result_tokens) == 2
    assert retired == [bad, ok]
    assert pe.cache.free_pages == pe.cache.n_pages - 1   # nothing leaked


def test_request_exceeding_pool_drops_instead_of_hanging(params):
    """A request whose pages can never fit the pool (even empty) must be
    dropped, not waited on forever — waiting deadlocks an idle engine."""
    pe = ContinuousEngine(params, CFG, slots=2, page_size=8, n_pages=4,
                          max_ctx=64, policy="serve")
    prompts = _prompts([30, 8])
    big = Request(rid=0, prompt=prompts[0], max_new=4, deadline_s=10.0)
    ok = Request(rid=1, prompt=prompts[1], max_new=2, deadline_s=10.0)
    pe.submit(big)
    pe.submit(ok)
    pe.run()                                  # must terminate
    assert big.dropped and big.tokens_done == 0
    assert not ok.dropped and len(ok.result_tokens) == 2


# -- fleet routing over live engines ----------------------------------------

def test_fleet_router_drives_live_paged_engines(params):
    """The SimRequest/Request contract end-to-end: the same FleetRouter that
    runs analytic batchers drives a pool of live paged engines, which
    synthesize prompts for SimRequests and emit real tokens."""
    from repro.serving import fleet as fleet_mod
    from repro.serving.fleet import FleetRouter, pool_candidates

    fast, slow = get_config("qwen2.5-1.5b"), get_config("qwen2.5-14b")
    cands = pool_candidates(
        [("qwen2.5-1.5b", fast, fleet_mod._synthetic_eps(fast), 1.0),
         ("qwen2.5-14b", slow, fleet_mod._synthetic_eps(slow), 0.0)])
    sim_params = {"qwen2.5-1.5b": params,
                  "qwen2.5-14b": T.init_params(jax.random.PRNGKey(1),
                                               get_config("qwen-sim-14b"))}
    sim_cfgs = {"qwen2.5-1.5b": CFG,
                "qwen2.5-14b": get_config("qwen-sim-14b")}
    engines = [ContinuousEngine(sim_params[c.model_name],
                                sim_cfgs[c.model_name], slots=2,
                                page_size=8, max_ctx=64,
                                latency_cfg=c.cfg, avg_bits=c.avg_bits)
               for c in cands]
    quality = {"qwen2.5-1.5b": 0.6, "qwen2.5-14b": 0.95}
    router = FleetRouter(cands, quality=lambda c: quality[c.model_name],
                         slots=2, engines=engines)
    arrivals = [SimRequest(rid=i, cls_name="t", t_arrive=0.01 * i,
                           prompt_len=16, max_new=4,
                           deadline_s=0.04 if i % 2 else 2.0)
                for i in range(6)]
    out = router.run(arrivals)
    assert len(out) == 6
    served = [r for r in out if not r.dropped]
    assert served and all(len(r.result_tokens) == r.tokens_done
                          for r in served)
    # tight deadlines landed on the fast engine, loose ones on the 14b
    assert {r.engine_idx for r in arrivals if r.deadline_s < 0.1} == {0}
    assert 1 in {r.engine_idx for r in arrivals if r.deadline_s > 1.0}


# -- in-flight prefill registry ----------------------------------------------

def test_identical_prompts_share_one_prefill(params):
    """Regression: N identical prompts admitted in one wave used to ALL
    miss the prefix cache — publication happens only at prefill
    completion, so every concurrent admission prefilled the full prompt
    from scratch.  The in-flight registry holds the waiters in the queue
    until the leader publishes; each then adopts all but the last token
    and absorbs exactly one (the first output token is sampled from the
    prefill logits, so one token must be re-absorbed)."""
    from repro.obs import trace as tr_mod

    N, P = 3, 20
    prompt = _prompts([P])[0]

    def wave():
        return _reqs([prompt] * N, max_new=4, deadline=100.0)

    base = wave()
    beng = ContinuousEngine(params, CFG, slots=N, page_size=8, max_ctx=40,
                            policy="serve", prefill_chunk=8)
    for r in base:
        beng.submit(r)
    beng.run()

    reqs = wave()
    tr = tr_mod.Tracer()
    eng = ContinuousEngine(params, CFG, slots=N, page_size=8, max_ctx=40,
                           policy="serve", prefill_chunk=8,
                           prefix_cache=True, tracer=tr)
    for r in reqs:
        eng.submit(r)
    eng.run()

    # token identity with the registry-free engine
    for b, r in zip(base, reqs):
        assert r.result_tokens is not None
        assert np.array_equal(b.result_tokens, r.result_tokens)
    # exactly one prefill's worth of chunk charges plus one absorbed
    # token per waiter — not N full prefills
    chunks = [e for e in tr.events
              if e.name == tr_mod.REQ_PREFILL_CHUNK]
    assert sum(e.args["chunk"] for e in chunks) == P + (N - 1)
    assert eng.prefix.hits == N - 1 and eng.prefix.misses == 1
    # the registry is empty at quiescence (every key released)
    assert eng._inflight == {}


def test_inflight_registry_released_on_cancel(params):
    """A leader cancelled mid-prefill must release its registry key, or
    the identical waiter would be skipped forever (admission livelock)."""
    P = 20
    prompt = _prompts([P])[0]
    leader, waiter = _reqs([prompt] * 2, max_new=4, deadline=100.0)
    leader.t_cancel = 1e-9                # barge-in before prefill finishes
    eng = ContinuousEngine(params, CFG, slots=1, page_size=8, max_ctx=40,
                           policy="serve", prefill_chunk=8,
                           prefix_cache=True)
    eng.submit(leader)
    eng.submit(waiter)
    eng.run()
    assert waiter.result_tokens is not None and len(waiter.result_tokens)
    assert eng._inflight == {}
