"""Property tests for the FP quantization core (paper Eq. 1-2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quant

SHAPES = st.sampled_from([(4, 8), (16, 16), (3, 130), (128, 128), (1, 7)])


@st.composite
def arrays(draw, max_scale=1e3):
    shape = draw(SHAPES)
    seed = draw(st.integers(0, 2**16))
    scale = draw(st.floats(1e-4, max_scale))
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


@settings(max_examples=30, deadline=None)
@given(arrays(), st.sampled_from([4, 8]))
def test_fake_quant_idempotent(x, bits):
    q1 = quant.fake_quant(jnp.asarray(x), bits)
    q2 = quant.fake_quant(q1, bits)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(arrays(), st.sampled_from([4, 8]))
def test_fake_quant_bounded_error(x, bits):
    """Relative (to absmax) error bounded by half the coarsest grid step."""
    xj = jnp.asarray(x)
    q = np.asarray(quant.fake_quant(xj, bits))
    amax = np.abs(x).max()
    if amax == 0:
        return
    # E2M1 worst step = 2 (between 4 and 6) over range 6 -> half-step 1/6.
    # e4m3 clipped at 240: top binade [128, 240] has step 16 -> half-step
    # 8/240 = 1/30 of absmax.  The fp8 path casts through the hardware
    # float8 conversion, which XLA routes via an f16 intermediate on CPU;
    # that double rounding can push a near-midpoint value one extra f16
    # ulp (2^-11 of the value) past the half-step bound.
    worst = (1.0 / 6.0) if bits == 4 else (1.0 / 30.0 + 2.0 ** -11)
    assert np.abs(q - x).max() <= amax * worst + 1e-6


@settings(max_examples=20, deadline=None)
@given(arrays())
def test_fp4_grid_membership(x):
    """Quantized values / scale all land exactly on the E2M1 grid."""
    xj = jnp.asarray(x)
    amax = np.abs(x).max()
    if amax == 0:
        return
    scale = amax / quant.FP4_RANGE
    q = np.asarray(quant.fake_quant(xj, 4)) / scale
    grid = np.asarray(quant.FP4_GRID)
    dist = np.min(np.abs(q[..., None] - grid[None, None]), axis=-1)
    assert dist.max() < 1e-4 * max(1.0, np.abs(q).max())


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**16), st.sampled_from([(2, 8), (5, 16), (1, 64)]))
def test_pack_unpack_roundtrip(seed, shape):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 16, size=shape).astype(np.uint8)
    packed = quant.fp4_pack(jnp.asarray(codes))
    un = np.asarray(quant.fp4_unpack(packed))
    np.testing.assert_array_equal(un, codes)


@settings(max_examples=20, deadline=None)
@given(arrays(), st.sampled_from([4, 8]))
def test_qtensor_matches_fake_quant(x, bits):
    """Materialized quantize->dequantize == fake_quant (same numerics)."""
    if x.shape[-1] % 2 != 0 and bits == 4:
        x = x[..., : x.shape[-1] // 2 * 2]
        if x.shape[-1] == 0:
            return
    xj = jnp.asarray(x)
    qt = quant.quantize(xj, bits)
    deq = np.asarray(quant.dequantize(qt))
    fq = np.asarray(quant.fake_quant(xj, bits))
    np.testing.assert_allclose(deq, fq, rtol=1e-5, atol=1e-6)


def test_fp4_payload_bytes():
    x = jnp.ones((8, 64))
    qt = quant.quantize(x, 4)
    assert qt.data.dtype == jnp.uint8
    assert qt.data.shape == (8, 32)          # two codes per byte
    assert qt.nbytes_payload == 8 * 64 // 2


def test_fp8_range_clip():
    x = jnp.asarray([[1e6, -1e6, 1.0, 0.0]])
    qt = quant.quantize(x, 8)
    deq = np.asarray(quant.dequantize(qt))
    np.testing.assert_allclose(deq[0, 0], 1e6, rtol=0.05)


def test_relative_error_zero_on_identity():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((16, 16)))
    assert float(quant.relative_error(x, x)) == 0.0


@settings(max_examples=10, deadline=None)
@given(arrays())
def test_eq2_matmul_error_small_vs_fp16(x):
    """Quantized matmul approximates the fp32 product (Eq. 2)."""
    rng = np.random.default_rng(1)
    w = rng.standard_normal((x.shape[-1], 24)).astype(np.float32) * 0.1
    ref = x @ w
    got8 = np.asarray(quant.quant_matmul_ref(jnp.asarray(x), jnp.asarray(w), 8, 8))
    scale = max(np.abs(ref).max(), 1e-6)
    assert np.abs(got8 - ref).max() / scale < 0.15
